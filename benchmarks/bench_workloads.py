"""Workload-compiler benchmarks: the model stack's traffic on the mesh.

All four workload families (ring all-reduce, MoE all-to-all, pipeline
p2p, PGAS scatter) run on an 8x8 mesh with ``backend="both"`` — every
record is simultaneously a numpy-vs-jax bit-identical parity check (the
runner raises on any divergence) and a cycles-per-step measurement.  The
full :class:`~repro.workloads.WorkloadReport` rides along under
``"report"`` so ``benchmarks/run.py`` can persist the set as
``experiments/workload_reports.json``; the trajectory file tracks the
wall/ok fields PR-over-PR as usual.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.workloads import (CongestionModel, moe_all_to_all, pgas_scatter,
                             pipeline_p2p, ring_all_reduce, run_workload)

__all__ = ["bench_ring_allreduce", "bench_moe_a2a", "bench_pipeline",
           "bench_pgas", "bench_congestion_fit", "run"]

NX = NY = 8


def _rec(name: str, report, **extra) -> Dict:
    d = {"name": name, "mesh": report.mesh, "cycles": report.cycles,
         "cycles_per_step": report.cycles_per_step,
         "accepted_throughput": report.accepted_throughput,
         "mean_latency": report.mean_latency,
         "peak_link_util": report.peak_link_util,
         "parity": report.backend == "both",
         "report": report.to_json()}
    d.update(extra)
    return d


def bench_ring_allreduce(words: int = 64) -> Dict:
    """8x8 snake-ring all-reduce: 2(k-1) phases, chunk packets per rank
    per phase.  ok gates on full delivery at a sane per-step cost: each
    phase must cost at least ``chunk`` cycles (link serialization) and
    the run must not be orders beyond that bound (uncongested ring)."""
    w = ring_all_reduce(NX, NY, words)
    r = run_workload(w, backend="both")
    chunk = w.meta["chunk"]
    ok = (r.delivered == r.injected
          and r.cycles_per_step >= chunk
          and r.cycles_per_step <= 16 * chunk + 16)
    return _rec("workload_ring_allreduce_8x8", r, words=words,
                chunk=chunk, steps=w.n_steps, ok=ok)


def bench_moe_a2a(tokens_per_tile: int = 8) -> Dict:
    """8x8 MoE token all-to-all, uniform vs hot-expert (imbalance=0.5).
    The skewed run concentrates traffic on the hot expert's tile, so it
    must cost at least as many cycles and show a hotter peak link."""
    uni = run_workload(moe_all_to_all(NX, NY, tokens_per_tile,
                                      imbalance=0.0, seed=0),
                       backend="both")
    hot = run_workload(moe_all_to_all(NX, NY, tokens_per_tile,
                                      imbalance=0.5, seed=0),
                       backend="both")
    ok = (hot.cycles >= uni.cycles
          and hot.peak_link_util >= uni.peak_link_util
          and hot.delivered == hot.injected)
    return _rec("workload_moe_a2a_8x8", hot,
                tokens_per_tile=tokens_per_tile,
                uniform_cycles=uni.cycles, hot_cycles=hot.cycles,
                hot_expert_share=hot.meta.get("hot_expert_share"),
                uniform_report=uni.to_json(), ok=ok)


def bench_pipeline(n_micro: int = 8, act_words: int = 8) -> Dict:
    """64-stage forward+backward microbatch pipeline over the snake."""
    w = pipeline_p2p(NX, NY, n_micro=n_micro, act_words=act_words,
                     backward=True)
    r = run_workload(w, backend="both")
    ok = r.delivered == r.injected and r.cycles >= w.n_steps
    return _rec("workload_pipeline_8x8", r, n_micro=n_micro,
                act_words=act_words,
                bubble_fraction=w.meta["bubble_fraction"], ok=ok)


def bench_pgas(slots: int = 8) -> Dict:
    """PGAS scatter: every tile stores ``slots`` words to rotated peers."""
    w = pgas_scatter(NX, NY, slots)
    r = run_workload(w, backend="both")
    ok = r.delivered == r.injected
    return _rec("workload_pgas_scatter_8x8", r, slots=slots, ok=ok)


def bench_congestion_fit() -> Dict:
    """Fit a CongestionModel from 8x8 reports and check the closed loop:
    the netsim roofline collective term must come from simulated cycles
    (differ from the analytic wire-bytes/bandwidth estimate)."""
    from repro.launch import roofline as rl
    from repro.workloads import calibrate

    cm = calibrate(NX, NY, backend="numpy")
    colls = {"all-reduce": {"bytes": 1e9, "count": 2, "wire_bytes": 1.5e9},
             "all-to-all": {"bytes": 2e8, "count": 4, "wire_bytes": 1.9e8}}
    analytic_s = sum(d["bytes"] for d in colls.values()) / rl.HW.ICI_BW
    sim = cm.collective_times(colls)
    netsim_s = sum(d["sim_s"] for d in sim.values())
    ok = (all(a > 0 for a, _ in cm.coeffs.values())
          and netsim_s > 0 and netsim_s != analytic_s)
    return {"name": "workload_congestion_fit_8x8", "mesh": f"{NX}x{NY}",
            "coeffs": {k: [round(a, 3), round(b, 3)]
                       for k, (a, b) in cm.coeffs.items()},
            "n_points": dict(cm.n_points),
            "analytic_collective_s": round(analytic_s, 6),
            "netsim_collective_s": round(netsim_s, 6),
            "congestion_model": cm.to_json(), "ok": ok}


def run() -> List[Dict]:
    out = []
    for fn in (bench_ring_allreduce, bench_moe_a2a, bench_pipeline,
               bench_pgas, bench_congestion_fit):
        t0 = time.perf_counter()
        rec = fn()
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        brief = {k: v for k, v in rec.items()
                 if k not in ("report", "uniform_report",
                              "congestion_model")}
        print(f"[{status}] {rec['name']:32s} {brief}", flush=True)
    return out


if __name__ == "__main__":
    run()
