"""CI perf-smoke: fail fast on router-datapath regressions.

A fraction of the full benchmark battery, sized for a CI job:

* the fused-step throughput microbenchmark on a 4x4 mesh (compile +
  steady-state rate, speedup vs the numpy oracle) — catches gross
  compile-time or throughput regressions in minutes, not tens of them;
* a quick 2-shape x 3-pattern differential parity grid, run to the
  global drain fence on both backends and compared with the full
  ``assert_state_equal`` contract (memory, stats, traces, telemetry, and
  decoded in-flight packets field-for-field) — catches datapath
  *correctness* regressions without waiting for the full test suite;
* a 4x4 ``impl="pallas"`` parity microbench — the Pallas router-step
  kernel in interpret mode (CPU CI has no compiled Pallas backend, so
  this is a correctness gate: same drain cycle, bit-identical telemetry
  and memory vs the fused step);
* a 4x4 **torus** three-way parity microbench — the wrapped datapath
  (ring routing, bubble flow control, wrap connectivity) on oracle,
  fused and Pallas backends at once, so a topology regression cannot
  hide behind a mesh-only smoke;
* a workloads smoke: a 4x4 ring all-reduce and one MoE all-to-all from
  the workload traffic compiler, each run on BOTH backends with the
  bit-identical telemetry assert — catches regressions in the
  compile -> attach -> drain -> report loop the cost model's netsim mode
  depends on;
* a 4x4 design-space-exploration smoke: a tiny mesh-vs-torus sweep run
  twice through ``repro.dse`` — the second submission must be a pure
  result-cache replay and the extracted Pareto frontiers non-empty and
  monotone;
* a 4x4 simulation-service smoke: two same-shape requests submitted
  concurrently must ride ONE vmapped batch, compile exactly ONE block
  executable, and return stats bit-identical to direct
  ``phased_stats`` runs — the batched-serving contract in seconds.

  PYTHONPATH=src python -m benchmarks.perf_smoke

Exit status: nonzero on any parity mismatch or a jax-vs-oracle speedup
below the (deliberately loose, CI-hardware-safe) floor.
"""
from __future__ import annotations

import sys
import time
from typing import Dict, List

import numpy as np

from repro.mesh import MeshConfig, Simulator, make_traffic
from repro.netsim_jax.testing import assert_state_equal

from benchmarks.bench_netsim_jax import bench_step_throughput

# small but non-degenerate: one square, one non-square shape; patterns
# that exercise random, adversarial-shift and line-rate routing
SMOKE_SHAPES = ((4, 4), (3, 2))
SMOKE_PATTERNS = ("uniform", "tornado", "neighbor")
SPEEDUP_FLOOR = 2.0          # vs oracle on 4x4 — loose for slow CI boxes


def parity_grid() -> List[Dict]:
    out = []
    for nx, ny in SMOKE_SHAPES:
        cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=4, router_fifo=2)
        for pattern in SMOKE_PATTERNS:
            entries = make_traffic(pattern, nx, ny, 6, rate=0.7, seed=11)
            a = Simulator(cfg, backend="numpy")
            a.attach({k: v.copy() for k, v in entries.items()})
            b = Simulator(cfg, backend="jax")
            b.attach(entries)
            t0 = time.perf_counter()
            ca = a.run_until_drained(4000)
            cb = b.run_until_drained(4000)
            ok = True
            err = ""
            try:
                assert ca == cb, f"drain cycle diverged: {ca} != {cb}"
                assert_state_equal(a, b)
            except AssertionError as e:
                # numpy assertion messages start with a newline — strip
                # before taking the headline, or the diagnostic is empty
                head = str(e).strip().splitlines()
                ok, err = False, head[0] if head else "?"
            out.append({"name": f"parity_{pattern}_{nx}x{ny}", "ok": ok,
                        "drain_cycle": ca,
                        "wall_s": round(time.perf_counter() - t0, 2),
                        **({"error": err} if err else {})})
    return out


def pallas_parity_smoke() -> List[Dict]:
    """4x4 ``impl="pallas"`` parity microbench: the Pallas router-step
    kernel (interpret mode on CPU CI — a correctness gate, not a perf
    claim) run to the drain fence with a multi-cycle inner loop that does
    not divide the fence cadence, against the fused step: same drain
    cycle, bit-identical telemetry and memory."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=4)
    entries = make_traffic("uniform", 4, 4, 8, rate=0.7, seed=11)
    t0 = time.perf_counter()
    # same fence cadence on both sims (a run stops on a fence-block
    # boundary, so check_every is part of the final-state contract); the
    # kernel's inner loop deliberately does NOT divide it
    a = Simulator(cfg, backend="jax", check_every=4)
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax", impl="pallas", cycles_per_call=3,
                  check_every=4)
    b.attach(entries)
    ok, err, ca = True, "", -1
    try:
        ca = a.run_until_drained(4000)
        cb = b.run_until_drained(4000)
        assert ca == cb, f"drain cycle diverged: fused {ca} != pallas {cb}"
        a.telemetry().assert_bit_identical(b.telemetry())
        np.testing.assert_array_equal(np.asarray(a.mem), np.asarray(b.mem))
    except AssertionError as e:
        head = str(e).strip().splitlines()
        ok, err = False, head[0] if head else "?"
    return [{"name": "pallas_kernel_parity_4x4", "ok": ok,
             "drain_cycle": ca,
             "wall_s": round(time.perf_counter() - t0, 2),
             **({"error": err} if err else {})}]


def torus_parity_smoke() -> List[Dict]:
    """4x4 torus parity microbench: the wrapped datapath (ring routing,
    bubble flow control, wrap connectivity) on all three backends —
    oracle vs fused vs Pallas kernel — run to the drain fence with the
    full state-equality contract."""
    from repro.mesh import Topology
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=4, router_fifo=2,
                     topology=Topology.torus())
    entries = make_traffic("uniform", 4, 4, 8, rate=0.7, seed=11,
                           topology=cfg.topology)
    t0 = time.perf_counter()
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax")
    b.attach({k: v.copy() for k, v in entries.items()})
    c = Simulator(cfg, backend="jax", impl="pallas", cycles_per_call=3)
    c.attach(entries)
    ok, err, ca = True, "", -1
    try:
        ca = a.run_until_drained(4000)
        cb = b.run_until_drained(4000)
        assert ca == cb, f"drain cycle diverged: oracle {ca} != fused {cb}"
        assert_state_equal(a, b)
        # the pallas leg runs the same cycle count in 3-cycle kernel
        # launches plus a remainder launch, landing on the exact fence
        # cycle — full telemetry (including the per-cycle completion
        # trace) and memory must match the fused step bit for bit
        c.run(cb)
        b.telemetry().assert_bit_identical(c.telemetry())
        np.testing.assert_array_equal(np.asarray(b.mem), np.asarray(c.mem))
    except AssertionError as e:
        head = str(e).strip().splitlines()
        ok, err = False, head[0] if head else "?"
    return [{"name": "torus_parity_3way_4x4", "ok": ok,
             "drain_cycle": ca,
             "wall_s": round(time.perf_counter() - t0, 2),
             **({"error": err} if err else {})}]


def workloads_smoke() -> List[Dict]:
    """4x4 ring all-reduce + MoE all-to-all, parity-checked on both
    backends (run_workload raises on any telemetry divergence)."""
    from repro.workloads import moe_all_to_all, ring_all_reduce, run_workload
    out = []
    for w in (ring_all_reduce(4, 4, 16),
              moe_all_to_all(4, 4, 4, imbalance=0.5, seed=0)):
        t0 = time.perf_counter()
        ok = True
        err = ""
        cycles = -1
        try:
            r = run_workload(w, backend="both")
            cycles = r.cycles
            ok = r.delivered == r.injected
        except AssertionError as e:
            head = str(e).strip().splitlines()
            ok, err = False, head[0] if head else "?"
        out.append({"name": f"workload_{w.family}_4x4", "ok": ok,
                    "drain_cycle": cycles,
                    "wall_s": round(time.perf_counter() - t0, 2),
                    **({"error": err} if err else {})})
    return out


def dse_smoke() -> List[Dict]:
    """Tiny 4x4 design-space sweep through the full DSE service path —
    bucketed/batched execution, on-disk result-cache resume (second
    submission must simulate and compile nothing), and a monotone
    non-empty mesh-vs-torus frontier.  Minutes-scale CI stand-in for the
    576-point ``benchmarks.bench_dse`` acceptance sweep."""
    import tempfile

    from repro.dse import SweepSpec, frontier_artifact, run_sweep
    spec = SweepSpec(nx=4, ny=4, fifo_depths=(2, 4), credits=(4, 16),
                     patterns=("uniform",), loads=(0.05, 0.2, 0.4),
                     topologies=("mesh", "torus"), warmup=50, measure=100,
                     drain=100, name="ci_smoke")
    t0 = time.perf_counter()
    ok, err = True, ""
    n = resumed_sim = -1
    try:
        with tempfile.TemporaryDirectory() as td:
            first = run_sweep(spec, cache_dir=td, chunk=8)
            resumed = run_sweep(spec, cache_dir=td, chunk=8)
        n, resumed_sim = first.n_points, resumed.simulated
        art = frontier_artifact(first)
        assert resumed.simulated == 0 and resumed.compiles == 0, \
            "cache resume re-simulated"
        assert resumed.records == first.records, "resume records diverged"
        for topo, f in art["frontiers"].items():
            assert f["frontier"] and f["monotone"], \
                f"{topo} frontier empty or non-monotone"
    except AssertionError as e:
        head = str(e).strip().splitlines()
        ok, err = False, head[0] if head else "?"
    return [{"name": "dse_sweep_smoke_4x4", "ok": ok, "points": n,
             "resumed_simulated": resumed_sim,
             "wall_s": round(time.perf_counter() - t0, 2),
             **({"error": err} if err else {})}]


def sim_service_smoke() -> List[Dict]:
    """4x4 simulation-service smoke: two concurrent same-shape requests
    (different seeds) share ONE vmapped batch and ONE block compile, and
    each response is bit-identical to its direct ``phased_stats`` run."""
    from repro.netsim_jax.measure import phased_stats, _as_simconfig
    from repro.netsim_jax.sim import init_state, load_program
    from repro.sim_service import SimRequest, SimService, clear_service_cache

    phases = dict(warmup=50, measure=100, drain=100, check_every=50)
    reqs = [SimRequest(cfg=MeshConfig(nx=4, ny=4), pattern="uniform",
                       load=0.3, seed=s, **phases) for s in (0, 1)]
    t0 = time.perf_counter()
    ok, err = True, ""
    try:
        clear_service_cache()
        svc = SimService(max_batch=4)
        resps = svc.run(reqs)
        assert svc.metrics.batches == 1, \
            f"expected 1 batch, got {svc.metrics.batches}"
        assert svc.metrics.sim_compiles == 1, \
            f"expected 1 block compile, got {svc.metrics.sim_compiles}"
        for req, resp in zip(reqs, resps):
            cfg = _as_simconfig(req.cfg)
            length = int(np.ceil(req.load * req.horizon)) + 1
            prog = load_program(make_traffic(
                req.pattern, 4, 4, length, rate=req.load, seed=req.seed,
                topology=req.cfg.topology))
            direct = phased_stats(cfg, prog, init_state(cfg), req.warmup,
                                  req.measure, req.drain)
            for f in direct._fields:
                a = np.asarray(getattr(direct, f))
                b = np.asarray(getattr(resp.stats, f))
                assert (a == b).all(), \
                    f"seed={req.seed}: PhaseStats.{f} differs"
    except AssertionError as e:
        head = str(e).strip().splitlines()
        ok, err = False, head[0] if head else "?"
    return [{"name": "sim_service_smoke_4x4", "ok": ok,
             "wall_s": round(time.perf_counter() - t0, 2),
             **({"error": err} if err else {})}]


def main() -> int:
    records = parity_grid()
    records.extend(pallas_parity_smoke())
    records.extend(torus_parity_smoke())
    records.extend(workloads_smoke())
    records.extend(dse_smoke())
    records.extend(sim_service_smoke())
    micro = bench_step_throughput(shapes=((4, 4),), cycles=800,
                                  oracle_cycles=100)
    m = micro["meshes"]["4x4"]
    micro["ok"] = m["speedup_vs_oracle"] >= SPEEDUP_FLOOR
    records.append(micro)
    failed = [r["name"] for r in records if not r.get("ok")]
    for r in records:
        status = "OK " if r.get("ok") else "FAIL"
        print(f"[{status}] {r['name']:32s} "
              f"{ {k: v for k, v in r.items() if k not in ('name', 'ok')} }",
              flush=True)
    print(f"\n{len(records) - len(failed)}/{len(records)} perf-smoke checks OK")
    if failed:
        print(f"FAILED: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
