"""Benchmark aggregator: one suite per paper table/claim + system harnesses.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --suite netsim

Writes a JSON summary to experiments/bench_results.json; the netsim_jax
load–latency saturation curves are additionally written to
experiments/load_latency.json (uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

SUITES = ("netsim", "netsim_jax", "collectives", "kernels", "train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=SUITES, default=None)
    args = ap.parse_args()
    picked = [args.suite] if args.suite else list(SUITES)

    # the collectives/train suites exercise a 2x4 device mesh; must be set
    # before the first jax backend use
    from repro.compat import set_host_device_count
    set_host_device_count(8)

    results = {}
    t0 = time.perf_counter()
    for name in picked:
        print(f"\n=== suite: {name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            results[name] = mod.run()
        except Exception as e:  # still write the JSON for the other suites
            print(f"[FAIL] suite {name} crashed: {e!r}", flush=True)
            results[name] = [{"name": f"{name} (crashed)", "ok": False,
                              "error": repr(e)}]
    wall = time.perf_counter() - t0

    flat = [r for rs in results.values() for r in rs]
    n_ok = sum(1 for r in flat if r.get("ok"))
    print(f"\n{n_ok}/{len(flat)} benchmarks OK in {wall:.1f}s")
    out = Path(__file__).resolve().parents[1] / "experiments"
    out.mkdir(exist_ok=True)
    with open(out / "bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"wrote {out / 'bench_results.json'}")
    # standalone artifact: the load–latency saturation curves
    sweeps = [r for r in results.get("netsim_jax", [])
              if r.get("name") == "load_latency_curves_8x8"]
    if sweeps:
        with open(out / "load_latency.json", "w") as f:
            json.dump(sweeps[0], f, indent=1, default=str)
        print(f"wrote {out / 'load_latency.json'}")
    if n_ok != len(flat):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
