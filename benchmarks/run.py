"""Benchmark aggregator: one suite per paper table/claim + system harnesses.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --suite netsim

Writes a JSON summary to experiments/bench_results.json; the netsim_jax
load–latency saturation curves are additionally written to
experiments/load_latency.json, the cross-topology saturation records
to experiments/topology_saturation.json, the design-space Pareto
frontiers (buffer area vs. saturation throughput) to
experiments/dse_frontier.json, and the simulation-service amortization
record to experiments/service_latency.json (uploaded as CI artifacts).

Every run arms JAX's persistent on-disk compilation cache under
experiments/xla_cache/<config-hash>/ (shared with the sim service and
repro.dse), and the summary reports its hit/miss/entry counts.

Every run also APPENDS a trajectory entry to experiments/BENCH_netsim.json
— per-benchmark wall seconds with compile time and run time recorded
separately (the jax suites AOT-compile via ``jitted.lower(...).compile()``
and time the two phases independently) — so speedups and compile-time
regressions are tracked PR-over-PR.

Exit status: nonzero if any benchmark reports ``ok: false`` OR any suite
crashes outright — a crashed suite still gets a failure record and the
JSON artifacts are still written, but the process must not report
success.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List

SUITES = ("netsim", "netsim_jax", "topology", "workloads", "collectives",
          "kernels", "train", "dse", "service")

# trajectory entries keep only the timing/health fields, not full payloads
_TRAJECTORY_KEYS = ("wall_s", "compile_s", "run_s", "wall_s_incl_compile",
                    "speedup_vs_baseline", "ok")

# step-throughput regression gate: post-compile cycles/s per mesh must
# stay above this fraction of the frozen bench_baseline.json snapshot
# (generous enough for shared-runner noise, tight enough to catch a
# datapath regression, which shows up as an integer-factor slowdown)
STEP_THROUGHPUT_FLOOR = 0.5


def gate_step_throughput(results: Dict[str, List[Dict]],
                         floor: float = STEP_THROUGHPUT_FLOOR) -> bool:
    """Compare this run's step-throughput microbench against the frozen
    ``experiments/bench_baseline.json`` snapshot, mesh by mesh; False (and
    a [FAIL] line) when any mesh's post-compile cycles/s fell below
    ``floor`` x baseline.  Vacuously True when either side lacks the
    record (fresh checkout, suite not selected, or a crashed suite)."""
    from benchmarks.bench_netsim_jax import load_baseline
    base = load_baseline().get("step_throughput_microbench", {})
    recs = [r for r in results.get("netsim_jax", [])
            if r.get("name") == "step_throughput_microbench"]
    if not base.get("meshes") or not recs:
        return True
    ok = True
    for mesh, brec in base["meshes"].items():
        want = brec.get("jax_cycles_per_s")
        got = recs[0].get("meshes", {}).get(mesh, {}).get("jax_cycles_per_s")
        if not want or got is None:
            continue
        if float(got) < floor * float(want):
            print(f"[FAIL] step-throughput regression on {mesh}: "
                  f"{float(got):.1f} cycles/s < {floor} x baseline "
                  f"{float(want):.1f}", flush=True)
            ok = False
    if ok:
        print(f"[OK ] step-throughput gate: every mesh >= {floor} x "
              f"baseline cycles/s", flush=True)
    return ok


def gate_topology_saturation(results: Dict[str, List[Dict]],
                             floor: float = STEP_THROUGHPUT_FLOOR) -> bool:
    """Gate the cross-topology saturation sweep's MESH row against the
    frozen baseline: the plain-mesh saturation rate must not fall below
    ``floor`` x the snapshot's (the other topologies have no pre-topology
    baseline to compare to, and their cross-checks live in the suite's
    own ``checks``).  Vacuously True when either side lacks the record —
    in particular when ``bench_baseline.json`` predates topology
    support."""
    from benchmarks.bench_netsim_jax import load_baseline
    base = load_baseline().get("topology_saturation_16x16", {})
    recs = [r for r in results.get("topology", [])
            if r.get("name") == "topology_saturation_16x16"]
    want = base.get("topologies", {}).get("mesh", {}).get("saturation_rate")
    got = (recs[0].get("topologies", {}).get("mesh", {})
           .get("saturation_rate")) if recs else None
    if want is None or got is None:
        return True
    if float(got) < floor * float(want):
        print(f"[FAIL] mesh saturation regression: {float(got):.3f} < "
              f"{floor} x baseline {float(want):.3f}", flush=True)
        return False
    print(f"[OK ] topology gate: mesh saturation {float(got):.3f} >= "
          f"{floor} x baseline {float(want):.3f}", flush=True)
    return True


def gate_dse_frontier(results: Dict[str, List[Dict]]) -> bool:
    """Gate the design-space sweep's emitted Pareto frontier: the MESH
    frontier must be non-empty and monotone (strictly more saturation
    throughput for every extra mm² of buffer area) — an empty or
    non-monotone frontier means the sweep, the cost model, or the
    extractor regressed.  Vacuously True when the dse suite did not run
    or crashed (the crash is already a failure on its own)."""
    recs = [r for r in results.get("dse", [])
            if r.get("name") == "dse_frontier_16x16" and "artifact" in r]
    if not recs:
        return True
    mesh = recs[0]["artifact"]["frontiers"].get("mesh", {})
    front = mesh.get("frontier") or []
    if front and mesh.get("monotone"):
        print(f"[OK ] dse gate: mesh frontier has {len(front)} "
              f"configuration(s), monotone", flush=True)
        return True
    print(f"[FAIL] dse frontier gate: mesh frontier "
          f"{'empty' if not front else 'not monotone'}", flush=True)
    return False


def trajectory_entry(results: Dict[str, List[Dict]], wall: float) -> Dict:
    """One PR-over-PR record: per-benchmark timing split + suite walls."""
    from repro.compat import compilation_cache_stats
    return {
        "when": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "total_wall_s": round(wall, 1),
        "compile_cache": compilation_cache_stats(),
        "suites": {
            name: {
                "wall_s": round(sum(float(r.get("wall_s", 0) or 0)
                                    for r in recs), 2),
                "compile_s": round(sum(float(r.get("compile_s", 0) or 0)
                                       for r in recs), 2),
                "ok": all(bool(r.get("ok")) for r in recs),
            } for name, recs in results.items()},
        "benchmarks": {
            r["name"]: {k: r[k] for k in _TRAJECTORY_KEYS if k in r}
            for recs in results.values() for r in recs if "name" in r},
    }


def append_trajectory(out_dir: Path, entry: Dict) -> Path:
    path = out_dir / "BENCH_netsim.json"
    try:
        history = json.loads(path.read_text())
        if not isinstance(history, list):
            history = []
    except (OSError, json.JSONDecodeError):
        history = []
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=1, default=str)
    return path


def run_suite(name: str) -> List[Dict]:
    """Import and execute one benchmark suite (separated out so tests can
    stub it when exercising the aggregator's crash handling)."""
    mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
    return mod.run()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=SUITES, default=None)
    ap.add_argument("--out", type=Path,
                    default=Path(__file__).resolve().parents[1] / "experiments",
                    help="directory for the JSON artifacts")
    args = ap.parse_args(argv)
    picked = [args.suite] if args.suite else list(SUITES)

    # the collectives/train suites exercise a 2x4 device mesh; must be set
    # before the first jax backend use
    from repro.compat import set_host_device_count
    set_host_device_count(8)

    # persistent on-disk XLA compilation cache, keyed by the simulator
    # source hash: repeat bench runs (and the CI bench job, which caches
    # this directory) deserialize executables instead of re-compiling
    from repro.compat import enable_persistent_compilation_cache
    from repro.dse.cache import config_hash
    enable_persistent_compilation_cache(args.out / "xla_cache",
                                        subkey=config_hash())

    results: Dict[str, List[Dict]] = {}
    crashed: List[str] = []
    t0 = time.perf_counter()
    for name in picked:
        print(f"\n=== suite: {name} ===", flush=True)
        try:
            results[name] = run_suite(name)
        except Exception as e:  # still write the JSON for the other suites
            print(f"[FAIL] suite {name} crashed: {e!r}", flush=True)
            results[name] = [{"name": f"{name} (crashed)", "ok": False,
                              "error": repr(e)}]
            crashed.append(name)
    wall = time.perf_counter() - t0

    flat = [r for rs in results.values() for r in rs]
    n_ok = sum(1 for r in flat if r.get("ok"))
    print(f"\n{n_ok}/{len(flat)} benchmarks OK in {wall:.1f}s")
    out = args.out
    out.mkdir(parents=True, exist_ok=True)
    with open(out / "bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print(f"wrote {out / 'bench_results.json'}")
    # standalone artifact: the load–latency saturation curves
    sweeps = [r for r in results.get("netsim_jax", [])
              if r.get("name") == "load_latency_curves_8x8"]
    if sweeps:
        with open(out / "load_latency.json", "w") as f:
            json.dump(sweeps[0], f, indent=1, default=str)
        print(f"wrote {out / 'load_latency.json'}")
    # standalone artifact: the cross-topology saturation records (the
    # acceptance artifact for the torus-vs-mesh wraparound claim)
    topo = [r for r in results.get("topology", [])
            if r.get("name") == "topology_saturation_16x16"]
    if topo:
        with open(out / "topology_saturation.json", "w") as f:
            json.dump(topo[0], f, indent=1, default=str)
        print(f"wrote {out / 'topology_saturation.json'}")
    # standalone artifact: the parity-checked workload reports + fitted
    # congestion model from the workloads suite
    wl = [r for r in results.get("workloads", [])
          if "report" in r or "congestion_model" in r]
    if wl:
        with open(out / "workload_reports.json", "w") as f:
            json.dump(wl, f, indent=1, default=str)
        print(f"wrote {out / 'workload_reports.json'}")
    # standalone artifact: the design-space Pareto frontiers (buffer
    # area vs. saturation throughput per topology) from the dse suite
    dse = [r for r in results.get("dse", []) if "artifact" in r]
    if dse:
        with open(out / "dse_frontier.json", "w") as f:
            json.dump(dse[0]["artifact"], f, indent=1, default=str)
        print(f"wrote {out / 'dse_frontier.json'}")
    # standalone artifact: the simulation-service amortization record
    # (sequential vs batched vs warm vs disk-cache-restart latencies)
    svc = [r for r in results.get("service", [])
           if r.get("name") == "service_latency_4x4"]
    if svc:
        with open(out / "service_latency.json", "w") as f:
            json.dump(svc[0], f, indent=1, default=str)
        print(f"wrote {out / 'service_latency.json'}")
    # persistent compile-cache accounting for the whole run (also stored
    # per-entry in the trajectory): a warm CI cache shows hits > 0 here
    from repro.compat import compilation_cache_stats
    cc = compilation_cache_stats()
    print(f"compile cache: {cc['hits']} hits, {cc['misses']} misses, "
          f"{cc['entries']} entries ({cc['dir']})")
    # PR-over-PR timing trajectory (appended, never overwritten)
    print(f"appended {append_trajectory(out, trajectory_entry(results, wall))}")
    gate_ok = gate_step_throughput(results)
    gate_ok &= gate_topology_saturation(results)
    gate_ok &= gate_dse_frontier(results)
    if crashed:
        print(f"FAILED: suite(s) crashed: {', '.join(crashed)}")
        return 1
    if n_ok != len(flat) or not gate_ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
