"""Simulation-service amortization benchmark + acceptance gates.

The service's reason to exist is fixed-cost amortization: ONE XLA
compile and ONE vmapped dispatch should serve every concurrent request
of the same compiled shape.  This suite measures exactly that story and
writes ``experiments/service_latency.json``:

* **sequential baseline** — N same-shape requests served as N
  *independent cold client sessions*: every jit/service cache (and the
  persistent on-disk cache) cleared between requests, so each pays the
  full XLA compile a fresh process would.  This leg runs FIRST — its
  per-request ``jax.clear_caches()`` would wipe the service's compiled
  programs.
* **cold service** — the same N requests submitted concurrently to one
  freshly-cleared :class:`~repro.sim_service.SimService`: they ride one
  batch, compile exactly ONCE (asserted via the service's executed-shape
  accounting), and must beat the sequential leg by >= 3x wall-clock
  throughput.
* **warm service** — a second service instance re-serves the shape with
  ZERO fresh executables (asserted), giving the steady-state per-request
  latency.
* **persistent compile cache** — the same shape re-compiled after
  ``jax.clear_caches()`` with the on-disk cache armed: XLA deserializes
  the executable (disk hits > 0) instead of re-running the compiler,
  the cross-*process* warm-start story.

Every service response is checked bit-identical to its sequential
direct-run twin before any timing is reported.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax

from repro.compat import (compilation_cache_stats,
                          disable_persistent_compilation_cache,
                          enable_persistent_compilation_cache,
                          reset_compilation_cache_stats)
from repro.mesh import MeshConfig
from repro.netsim_jax.measure import (clear_sweep_cache, phased_stats,
                                      _as_simconfig)
from repro.netsim_jax.sim import init_state, load_program
from repro.netsim_jax.traffic import make_traffic
from repro.sim_service import SimRequest, SimService, clear_service_cache

__all__ = ["bench_service_amortization", "run"]

N_REQUESTS = 8
THROUGHPUT_FLOOR = 3.0       # cold service must beat sequential by this
PHASES = dict(warmup=100, measure=200, drain=200, check_every=100)


def _requests(cfg: MeshConfig) -> List[SimRequest]:
    return [SimRequest(cfg=cfg, pattern="uniform", load=0.3, seed=s,
                       **PHASES) for s in range(N_REQUESTS)]


def _clear_all_caches() -> None:
    """What a fresh client process looks like, in-process: every jitted
    program and executed-shape registry dropped."""
    jax.clear_caches()
    clear_sweep_cache()
    clear_service_cache()


def _direct(req: SimRequest):
    """One cold direct run (the per-request work a non-batching client
    performs); returns host PhaseStats."""
    cfg = _as_simconfig(req.cfg)
    length = int(np.ceil(req.load * req.horizon)) + 1
    prog = load_program(make_traffic(req.pattern, req.cfg.nx, req.cfg.ny,
                                     length, rate=req.load, seed=req.seed,
                                     topology=req.cfg.topology))
    stats = phased_stats(cfg, prog, init_state(cfg), req.warmup,
                         req.measure, req.drain)
    return type(stats)(*(np.asarray(f) for f in stats))


def bench_service_amortization(n: int = N_REQUESTS) -> Dict:
    cfg = MeshConfig(nx=4, ny=4)
    reqs = _requests(cfg)
    checks: Dict[str, bool] = {}
    prior_cache_dir = compilation_cache_stats()["dir"]
    disable_persistent_compilation_cache()

    # -- leg 1: sequential cold sessions (must run first: it clears the
    # caches the service legs then warm up) ------------------------------
    seq_lat: List[float] = []
    direct_stats = []
    for r in reqs:
        _clear_all_caches()
        t0 = time.perf_counter()
        direct_stats.append(_direct(r))
        seq_lat.append(time.perf_counter() - t0)
    seq_wall = sum(seq_lat)

    # -- leg 2: cold service, one concurrent batch -----------------------
    _clear_all_caches()
    svc = SimService(max_batch=n)
    t0 = time.perf_counter()
    cold_resp = svc.run(reqs)
    cold_wall = time.perf_counter() - t0
    checks["one_batch"] = svc.metrics.batches == 1
    checks["compiles_once"] = svc.metrics.sim_compiles == 1
    checks["bit_identical"] = all(
        all((np.asarray(getattr(d, f)) == np.asarray(getattr(r.stats, f)))
            .all() for f in d._fields)
        for d, r in zip(direct_stats, cold_resp))
    ratio = seq_wall / max(cold_wall, 1e-9)
    checks["throughput_3x"] = ratio >= THROUGHPUT_FLOOR

    # -- leg 3: warm service (same process, new instance) ----------------
    warm = SimService(max_batch=n)
    t0 = time.perf_counter()
    warm_resp = warm.run(reqs)
    warm_wall = time.perf_counter() - t0
    checks["warm_zero_recompiles"] = (
        warm.metrics.sim_compiles == 0 and warm.metrics.aux_compiles == 0
        and all(r.metrics["new_sim_compiles"] == 0 for r in warm_resp))

    # -- leg 4: persistent on-disk compile cache (cross-process story) ---
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        enable_persistent_compilation_cache(td, subkey="bench_service")
        reset_compilation_cache_stats()
        _clear_all_caches()
        SimService(max_batch=n).run(reqs)       # populates the disk cache
        misses = compilation_cache_stats()["misses"]
        _clear_all_caches()                     # drop in-process programs
        t0 = time.perf_counter()
        SimService(max_batch=n).run(reqs)       # reloads from disk
        disk_wall = time.perf_counter() - t0
        disk = compilation_cache_stats()
        checks["disk_cache_hits"] = disk["hits"] > 0 and misses > 0
    disable_persistent_compilation_cache()
    if prior_cache_dir:                          # restore run.py's wiring
        enable_persistent_compilation_cache(prior_cache_dir)

    print(f"  sequential cold x{n}: {seq_wall:.1f}s "
          f"(mean {np.mean(seq_lat):.2f}s/req)", flush=True)
    print(f"  cold service batch:  {cold_wall:.1f}s -> {ratio:.1f}x "
          f"({svc.metrics.sim_compiles} compile)", flush=True)
    print(f"  warm service batch:  {warm_wall:.2f}s "
          f"({warm.metrics.sim_compiles} compiles)", flush=True)
    print(f"  disk-cache restart:  {disk_wall:.1f}s "
          f"(hits {disk['hits']}, misses {disk['misses']})", flush=True)

    return {
        "name": "service_latency_4x4",
        "ok": all(checks.values()),
        "wall_s": round(seq_wall + cold_wall + warm_wall, 2),
        "n_requests": n,
        "sequential": {"total_s": round(seq_wall, 3),
                       "per_request_s": [round(x, 3) for x in seq_lat]},
        "cold_service": {"total_s": round(cold_wall, 3),
                         "batches": svc.metrics.batches,
                         "sim_compiles": svc.metrics.sim_compiles,
                         "aux_compiles": svc.metrics.aux_compiles,
                         "throughput_vs_sequential": round(ratio, 2)},
        "warm_service": {"total_s": round(warm_wall, 3),
                         "sim_compiles": warm.metrics.sim_compiles,
                         "aux_compiles": warm.metrics.aux_compiles},
        "disk_cache_restart": {"total_s": round(disk_wall, 3),
                               "hits": disk["hits"],
                               "misses": disk["misses"],
                               "entries": disk["entries"]},
        "checks": checks,
    }


def run() -> List[Dict]:
    return [bench_service_amortization()]


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=str))
