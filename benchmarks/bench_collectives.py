"""Dimension-ordered (XY) vs flat collectives: HLO schedule + cost model.

The paper's argument for XY routing is that traffic crosses each link once
per dimension phase.  We verify the JAX re-expression produces exactly the
two smaller-group phases (vs one big-group op) by counting collectives in
the compiled HLO, and compare modeled wall times on v5e link bandwidth.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.compat import make_auto_mesh
from repro.core.routing import a2a_phase_cost, allreduce_cost
from repro.launch.mesh import HW

__all__ = ["bench_xy_vs_flat_a2a", "bench_hierarchical_allreduce", "run"]


def _collective_count(fn, args, mesh, in_specs, out_specs, names):
    import jax
    from repro.compat import shard_map
    from repro.launch.roofline import parse_collectives
    sm = shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   axis_names=names)
    compiled = jax.jit(sm).lower(*args).compile()
    return parse_collectives(compiled.as_text())


def bench_xy_vs_flat_a2a(bytes_per_dev: float = 64e6) -> Dict:
    """MoE dispatch pattern: all-to-all over the full 256-chip group,
    flat vs X-phase-then-Y-phase."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.routing import xy_all_to_all

    mesh = make_auto_mesh((2, 4), ("data", "model"))
    n = mesh.devices.size
    x = jnp.zeros((8 * n, 64))          # divisible by the 8-device group

    flat = _collective_count(
        lambda a: lax.all_to_all(a, ("data", "model"), 0, 0, tiled=True),
        (x,), mesh, P(("data", "model"), None), P(("data", "model"), None),
        {"data", "model"})
    xy = _collective_count(
        lambda a: xy_all_to_all(a, "model", "data"),
        (x,), mesh, P(("data", "model"), None), P(("data", "model"), None),
        {"data", "model"})

    # modeled time on the production 16x16 pod
    t_flat = a2a_phase_cost(bytes_per_dev, 256, HW.ICI_BW)     # one big phase
    t_xy = a2a_phase_cost(bytes_per_dev, 16, HW.ICI_BW) * 2    # two row/col
    return {"name": "xy_vs_flat_all_to_all",
            "flat_hlo_a2a_count": flat.get("all-to-all", {}).get("count", 0),
            "xy_hlo_a2a_count": xy.get("all-to-all", {}).get("count", 0),
            "modeled_flat_s_256chips": round(t_flat, 6),
            "modeled_xy_s_256chips": round(t_xy, 6),
            "modeled_speedup": round(t_flat / t_xy, 2),
            "ok": xy.get("all-to-all", {}).get("count", 0) == 2}


def bench_hierarchical_allreduce(bytes_per_dev: float = 512e6) -> Dict:
    """Gradient reduction: psum over (data, model) vs X-then-Y phases."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core.routing import xy_all_reduce

    mesh = make_auto_mesh((2, 4), ("data", "model"))
    x = jnp.zeros((64, 64))
    flat = _collective_count(
        lambda a: lax.psum(a, ("data", "model")),
        (x,), mesh, P(None, None), P(None, None), {"data", "model"})
    xy = _collective_count(
        lambda a: xy_all_reduce(a, "model", "data"),
        (x,), mesh, P(None, None), P(None, None), {"data", "model"})
    t_flat = allreduce_cost(bytes_per_dev, 256, HW.ICI_BW)
    t_xy = (allreduce_cost(bytes_per_dev, 16, HW.ICI_BW)
            + allreduce_cost(bytes_per_dev, 16, HW.ICI_BW))
    return {"name": "hierarchical_allreduce",
            "flat_hlo_ar_count": flat.get("all-reduce", {}).get("count", 0),
            "xy_hlo_ar_count": xy.get("all-reduce", {}).get("count", 0),
            "modeled_flat_s": round(t_flat, 6), "modeled_xy_s": round(t_xy, 6),
            "ok": xy.get("all-reduce", {}).get("count", 0) == 2}


def run() -> List[Dict]:
    out = []
    for fn in (bench_xy_vs_flat_a2a, bench_hierarchical_allreduce):
        t0 = time.perf_counter()
        rec = fn()
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
