"""Fleet-scale design-space-exploration benchmark + acceptance gates.

Runs the canonical 16x16 buffer-sizing sweep through the DSE service —
fifo depth x credit allowance x traffic pattern x offered load x
topology, 576 points — and extracts the mesh-vs-torus Pareto frontiers
of buffer area vs. saturation throughput.  The full cross product rides
TWO compiled programs (one per topology bucket: every depth/credits/
pattern/load combination batches under the bucket's capacity config),
which is the service's whole reason to exist.

Checks (the acceptance bar for ``experiments/dse_frontier.json``):

* the sweep spans >= 500 points and completes through the bucketed/
  batched path with one compile per bucket;
* an immediate re-submission is served ENTIRELY from the on-disk result
  cache — zero points simulated, zero recompiles;
* the swept baseline configuration (router_fifo=16, credits=128 — the
  ``sweep_config`` every earlier benchmark measured) reproduces the
  cross-topology benchmark's saturation knees: 0.25 on the mesh, 0.40
  on the torus, from byte-identical programs and configs;
* every topology's frontier is non-empty and monotone (area buys
  throughput, dominated configurations dropped).

The result cache lives in ``experiments/dse_cache/<code-hash>/``; a
second local run (or a re-costed frontier) simulates nothing.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, List

from repro.dse import (SweepSpec, frontier_artifact, frontier_ascii,
                       run_sweep)
from repro.netsim_jax import DEFAULT_SWEEP_RATES

__all__ = ["dse_spec", "bench_dse_frontier", "run"]

# PR-8 cross-topology knees at the sweep_config baseline point
# (router_fifo=16, max_out_credits=128, uniform, seed 0, 300/500/500)
EXPECTED_KNEES = {"mesh": 0.25, "torus": 0.40}
BASELINE = {"fifo_depth": 16, "credits": 128}

CACHE_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dse_cache"


def dse_spec() -> SweepSpec:
    """The canonical 16x16 fleet sweep: 2 topologies x 4 depths x
    3 credits x (2 patterns x 12 loads) = 576 points, phased exactly
    like the cross-topology benchmark so the baseline point is
    bit-identical to its curves."""
    return SweepSpec(
        nx=16, ny=16,
        fifo_depths=(2, 4, 8, 16),
        credits=(8, 32, 128),
        patterns=("uniform", "tornado"),
        loads=DEFAULT_SWEEP_RATES,
        topologies=("mesh", "torus"),
        warmup=300, measure=500, drain=500, seed=0,
        name="16x16_fleet")


def _baseline_knee(artifact: Dict, topology: str):
    for p in artifact["frontiers"][topology]["points"]:
        if (p["fifo_depth"] == BASELINE["fifo_depth"]
                and p["credits"] == BASELINE["credits"]):
            return p["saturation_rate"]
    return None


def bench_dse_frontier(cache_dir=CACHE_DIR) -> Dict:
    spec = dse_spec()
    t0 = time.perf_counter()
    first = run_sweep(spec, cache_dir=cache_dir, progress=print)
    # resubmission must be pure cache replay: nothing simulated, nothing
    # (re)compiled — the resumability half of the acceptance bar
    resumed = run_sweep(spec, cache_dir=cache_dir)
    wall = time.perf_counter() - t0

    artifact = frontier_artifact(first)
    checks = {
        "spans_500_points": first.n_points >= 500,
        "bucketed": 0 < first.buckets <= len(spec.topologies) * 2
        and first.compiles <= first.buckets,
        "resume_simulates_nothing": resumed.simulated == 0
        and resumed.compiles == 0 and resumed.records == first.records,
    }
    for topo, want in EXPECTED_KNEES.items():
        knee = _baseline_knee(artifact, topo)
        checks[f"{topo}_baseline_knee"] = (
            knee is not None and abs(knee - want) < 1e-9)
        f = artifact["frontiers"][topo]
        checks[f"{topo}_frontier_monotone"] = bool(
            f["frontier"]) and f["monotone"]
        print(f"  {topo}: baseline knee {knee} (want {want}), "
              f"frontier {len(f['frontier'])}/{len(f['points'])} configs, "
              f"monotone {f['monotone']}", flush=True)
    print(frontier_ascii(artifact), flush=True)

    return {
        "name": "dse_frontier_16x16",
        "ok": all(checks.values()),
        "wall_s": round(wall, 2),
        "n_points": first.n_points,
        "simulated": first.simulated,
        "cache_hits": first.cache_hits,
        "buckets": first.buckets,
        "compiles": first.compiles,
        "infeasible": first.infeasible,
        "resume": {"simulated": resumed.simulated,
                   "compiles": resumed.compiles,
                   "cache_hits": resumed.cache_hits,
                   "wall_s": resumed.wall_s},
        "checks": checks,
        "artifact": artifact,
    }


def run() -> List[Dict]:
    return [bench_dse_frontier()]


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=str))
