"""Paper-claims benchmarks (C9): one function per published number/figure.

Each benchmark returns a dict with the measured value and the paper's
claimed value; ``run()`` prints the comparison table.  These are the same
phenomena asserted in tests/test_netsim.py, measured at benchmark scale.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.netsim import OP_LOAD, OP_STORE, unloaded_rtt
from repro.mesh import MeshConfig, Simulator, empty_program, make_traffic

__all__ = ["bench_fig3_rtt", "bench_bisection", "bench_credit_bdp",
           "bench_ordering", "bench_fence", "bench_jax_speedup", "run"]


def _empty_prog(ny, nx, L):
    return empty_program(nx, ny, L)


def bench_fig3_rtt() -> Dict:
    """Fig. 3 + mesh_master_example.v: first load returns at cycle 7,
    then one per cycle; RTT grows +2 per Manhattan hop."""
    sim = Simulator(MeshConfig(nx=8, ny=8, record_log=True))
    prog = _empty_prog(8, 8, 8)
    sim.mem[0, 1, :8] = np.arange(8)
    for i in range(8):
        prog["op"][0, 0, i] = OP_LOAD
        prog["dst_x"][0, 0, i] = 1
        prog["addr"][0, 0, i] = i
    sim.load_program(prog)
    sim.run(40)
    first = sim.log[0][0]
    gaps = np.diff([c for (c, *_r) in sim.log])
    rtts = {h: unloaded_rtt(h) for h in (1, 2, 4, 7)}
    return {"name": "fig3_first_response_cycle", "paper": 7,
            "measured": first,
            "pipelined_gap_cycles": float(np.mean(gaps)),
            "rtt_by_hops(2h+5)": rtts,
            "ok": first == 7 and np.all(gaps == 1)}


def bench_bisection(nx: int = 16, ny: int = 16) -> Dict:
    """Paper: 'if every core sent a message across the median of the array,
    with 16 links crossing the bisection, only 32 remote operations can be
    sustained per cycle, corresponding to one operation per 16 cycles on a
    core' (stores cross E<->W so fwd+rev each cross once -> 2/link/cycle)."""
    L = 48
    prog = _empty_prog(ny, nx, L)
    # every west-half core hammers its mirror in the east half
    for y in range(ny):
        for x in range(nx):
            prog["op"][y, x, :] = OP_STORE
            prog["dst_x"][y, x, :] = (x + nx // 2) % nx
            prog["dst_y"][y, x, :] = y
            prog["addr"][y, x, :] = np.arange(L)
    sim = Simulator(MeshConfig(nx=nx, ny=ny, max_out_credits=64))
    sim.load_program(prog)
    sim.run(600)
    thr = sim.throughput(warmup=100)
    bound = 2 * ny          # ny links x 2 (fwd crosses one way, rev back)
    per_core = (nx * ny) / max(thr, 1e-9)
    return {"name": "bisection_bound", "mesh": f"{nx}x{ny}",
            "paper_bound_ops_per_cycle": bound,
            "measured_ops_per_cycle": round(thr, 2),
            "paper_cycles_per_core_op": 16 if nx == 16 else nx / 2,
            "measured_cycles_per_core_op": round(per_core, 1),
            "ok": thr <= bound + 1e-6 and thr > 0.5 * bound}


def bench_credit_bdp(hops: int = 14) -> Dict:
    """Store throughput vs max_out_credits has its knee at the round-trip
    BDP ('1 word/cycle x 128-cycle RTT = 128 credits')."""
    rtt = unloaded_rtt(hops)
    curve = {}
    cycles, warmup = 1000, 200
    for credits in (1, 2, 4, rtt // 2, rtt, rtt + 8, 2 * rtt):
        nx = hops + 1
        sim = Simulator(MeshConfig(nx=nx, ny=1, max_out_credits=credits,
                                router_fifo=max(4, credits)))
        L = cycles + 500            # never program-limited
        prog = _empty_prog(1, nx, L)
        prog["op"][0, 0, :] = OP_STORE
        prog["dst_x"][0, 0, :] = hops
        prog["addr"][0, 0, :] = np.arange(L) % 32
        sim.load_program(prog)
        sim.run(cycles)
        curve[credits] = round(sim.throughput(warmup=warmup), 3)
    # knee: throughput at credits=RTT ~ 1.0 word/cycle; below the knee it
    # scales like credits/RTT (the BDP starvation line)
    ok = curve[rtt] > 0.9 and abs(curve[rtt // 2] - 0.5) < 0.1
    return {"name": "credit_bdp_knee", "rtt_cycles": rtt,
            "paper_rule": "credits = RTT x issue rate",
            "throughput_vs_credits": curve, "ok": ok}


def bench_ordering() -> Dict:
    """Fig. 5: point-to-point order holds; responses from different
    destinations may return out of order."""
    sim = Simulator(MeshConfig(nx=8, ny=1, record_log=True))
    prog = _empty_prog(1, 8, 2)
    # master 0: load from far slave (x=7) THEN near slave (x=1)
    prog["op"][0, 0, 0] = OP_LOAD
    prog["dst_x"][0, 0, 0] = 7
    prog["addr"][0, 0, 0] = 0
    prog["op"][0, 0, 1] = OP_LOAD
    prog["dst_x"][0, 0, 1] = 1
    prog["addr"][0, 0, 1] = 1
    sim.mem[0, 7, 0] = 111    # first-issued
    sim.mem[0, 1, 1] = 222    # second-issued
    sim.load_program(prog)
    sim.run(60)
    order = [d for (*_r, d) in sim.log]
    cross_reordered = order == [222, 111]
    # same-destination: two stores then a load back, must commit in order
    sim2 = Simulator(MeshConfig(nx=4, ny=1))
    prog2 = _empty_prog(1, 4, 3)
    for i, (op, data) in enumerate([(OP_STORE, 5), (OP_STORE, 9), (OP_LOAD, 0)]):
        prog2["op"][0, 0, i] = op
        prog2["dst_x"][0, 0, i] = 2
        prog2["addr"][0, 0, i] = 3
        prog2["data"][0, 0, i] = data
    sim2.load_program(prog2)
    sim2.run_until_drained()
    p2p_ok = int(sim2.mem[0, 2, 3]) == 9
    return {"name": "fig5_transaction_ordering",
            "cross_dest_reordering_observed": cross_reordered,
            "same_dest_order_preserved": p2p_ok,
            "ok": cross_reordered and p2p_ok}


def bench_fence() -> Dict:
    """Transaction fence: the fence completes exactly when out_credits_o is
    back at max_out_credits_p (Appendix A)."""
    sim = Simulator(MeshConfig(nx=6, ny=6, max_out_credits=8))
    L = 16
    prog = _empty_prog(6, 6, L)
    rng = np.random.default_rng(0)
    prog["op"][:] = OP_STORE
    prog["dst_x"][:] = rng.integers(0, 6, (6, 6, L))
    prog["dst_y"][:] = rng.integers(0, 6, (6, 6, L))
    prog["addr"][:] = rng.integers(0, 32, (6, 6, L))
    sim.load_program(prog)
    drained_at = sim.run_until_drained()
    all_back = bool((sim.credits == 8).all())
    done = int(sim.completed.sum())
    return {"name": "store_fence_credit_drain",
            "fence_cycle": drained_at, "stores_committed": done,
            "credits_back_to_max": all_back,
            "ok": all_back and done == 36 * L}


def bench_jax_speedup(nx: int = 16, ny: int = 16, cycles: int = 2000) -> Dict:
    """The jitted JAX simulator vs this numpy oracle on a 16x16
    uniform-random run: bit-identical results, >= 10x faster steady-state
    (compile time reported separately)."""
    from repro.netsim_jax import init_state, load_program, simulate
    entries = make_traffic("uniform", nx, ny, 64, seed=0)
    sim = Simulator(MeshConfig(nx=nx, ny=ny))
    sim.load_program({k: v.copy() for k, v in entries.items()})
    t0 = time.perf_counter()
    sim.run(cycles)
    t_np = time.perf_counter() - t0

    cfg = MeshConfig(nx=nx, ny=ny).to_sim()
    prog = load_program(entries)
    t0 = time.perf_counter()
    compiled = simulate.lower(cfg, prog, init_state(cfg), cycles).compile()
    t_compile = time.perf_counter() - t0
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        final, per = compiled(prog, init_state(cfg))
        per.block_until_ready()
        times.append(time.perf_counter() - t0)
    t_jax = float(np.median(times))
    parity = (np.array_equal(sim.completed, np.asarray(final.completed))
              and np.array_equal(sim.mem, np.asarray(final.mem))
              and sim.completed_per_cycle == np.asarray(per).tolist())
    speedup = t_np / t_jax
    return {"name": "jax_sim_speedup_vs_oracle", "mesh": f"{nx}x{ny}",
            "cycles": cycles, "numpy_s": round(t_np, 2),
            "jax_steady_s": round(t_jax, 3),
            "compile_s": round(t_compile, 2), "run_s": round(t_jax, 3),
            "speedup": round(speedup, 1),
            "target_10x_met": speedup >= 10.0,
            "cycle_exact_parity": parity,
            # ok gates on correctness + a loose perf floor so slower CI
            # hardware does not fail the whole suite; the 10x target is
            # reported separately above
            "ok": parity and speedup >= 5.0}


def run() -> List[Dict]:
    out = []
    for fn in (bench_fig3_rtt, bench_bisection, bench_credit_bdp,
               bench_ordering, bench_fence, bench_jax_speedup):
        t0 = time.perf_counter()
        rec = fn()
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
