"""End-to-end throughput benchmarks (reduced configs, CPU container).

Two harnesses: training tokens/s through the full Trainer (data pipeline +
jitted step + async checkpointing), and serving tokens/s through the
continuous-batching server.  On a real pod the same code paths run the
full configs; the numbers here validate the plumbing, not TPU speed.
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

import numpy as np

__all__ = ["bench_train_throughput", "bench_serve_throughput", "run"]


def _mesh():
    import jax
    from repro.compat import make_auto_mesh
    return make_auto_mesh((2, 4), ("data", "model"))


def bench_train_throughput(steps: int = 10) -> Dict:
    from repro import optim
    from repro.configs import get_config, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import batch_iterator
    from repro.runtime import Trainer, TrainerConfig

    cfg = reduced_config(get_config("stablelm-3b"))
    shape = ShapeConfig("bench", seq_len=128, global_batch=16, kind="train")
    with tempfile.TemporaryDirectory() as td:
        tr = Trainer(cfg, shape, _mesh(),
                     optim.OptConfig(warmup_steps=2, total_steps=steps),
                     TrainerConfig(total_steps=steps, ckpt_every=steps,
                                   ckpt_dir=td, log_every=10 ** 9))
        tr.init()
        it = batch_iterator(cfg, shape)
        tr.tcfg.total_steps = 2
        tr.run(it)                       # warmup/compile
        t0 = time.perf_counter()
        tr.tcfg.total_steps = steps
        m = tr.run(it)
        dt = time.perf_counter() - t0
        tr.close()
    toks = (steps - 2) * shape.global_batch * shape.seq_len
    return {"name": "train_throughput_reduced",
            "tokens_per_s": round(toks / dt, 1),
            "step_ms": round(dt / (steps - 2) * 1e3, 1),
            "final_loss": round(m["loss"], 3), "ok": np.isfinite(m["loss"])}


def bench_serve_throughput() -> Dict:
    from repro.configs import get_config, reduced_config
    from repro.launch.serve import Request, Server

    cfg = reduced_config(get_config("stablelm-3b"))
    rng = np.random.default_rng(0)
    server = Server(cfg, _mesh(), slots=4, max_seq=64)
    for r in range(8):
        server.submit(Request(rid=r,
                              prompt=rng.integers(0, cfg.vocab_size, 4)
                              .astype(np.int32), max_new=8))
    t0 = time.perf_counter()
    ticks = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in server.completed)
    return {"name": "serve_throughput_reduced",
            "requests": len(server.completed), "ticks": ticks,
            "tokens_per_s": round(toks / dt, 1),
            "ok": len(server.completed) == 8}


def run() -> List[Dict]:
    out = []
    for fn in (bench_train_throughput, bench_serve_throughput):
        rec = fn()
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
