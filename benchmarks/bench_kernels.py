"""Pallas kernel benchmarks: correctness vs oracle + interpret-mode timing.

On this CPU container the kernels run under ``interpret=True`` (the kernel
body executed in Python), so wall times are NOT TPU times — the meaningful
outputs are (a) max|err| vs the pure-jnp oracle, (b) the VMEM working-set
per BlockSpec tile, which must fit the 128 MB v5e VMEM.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention_op, grouped_matmul, ssd_scan_op
from repro.kernels import ref as kref

__all__ = ["bench_flash_attention", "bench_ssd_scan", "bench_moe_gmm", "run"]


def _err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                 b.astype(jnp.float32))))


def bench_flash_attention() -> Dict:
    rng = np.random.default_rng(0)
    B, S, H, hd = 1, 256, 2, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    t0 = time.perf_counter()
    out = flash_attention_op(q, k, v, causal=True)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    want = kref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    bq, bk = 128, 128
    vmem = (bq * hd + 2 * bk * hd + bq * bk + bq * hd) * 4
    return {"name": "flash_attention", "shape": f"B{B} S{S} H{H} hd{hd}",
            "max_err": _err(out, want), "interpret_wall_s": round(dt, 2),
            "vmem_tile_bytes": vmem, "vmem_ok": vmem < 128 * 2**20,
            "ok": _err(out, want) < 2e-3}


def bench_ssd_scan() -> Dict:
    rng = np.random.default_rng(1)
    b, s, h, p, g, n = 1, 256, 2, 32, 1, 32
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt_ = jnp.asarray(rng.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    t0 = time.perf_counter()
    y = ssd_scan_op(x, dt_, B, C, A, chunk=64)
    y.block_until_ready()
    dt = time.perf_counter() - t0
    want = kref.ssd_scan_ref(x.transpose(0, 2, 1, 3), dt_.transpose(0, 2, 1),
                             B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3),
                             A).transpose(0, 2, 1, 3)
    return {"name": "ssd_scan", "shape": f"b{b} s{s} h{h} p{p} n{n}",
            "max_err": _err(y, want), "interpret_wall_s": round(dt, 2),
            "ok": _err(y, want) < 2e-3}


def bench_moe_gmm() -> Dict:
    rng = np.random.default_rng(2)
    E, cap, D, F = 4, 64, 128, 256
    lhs = jnp.asarray(rng.standard_normal((E, cap, D)), jnp.float32)
    rhs = jnp.asarray(rng.standard_normal((E, D, F)), jnp.float32)
    t0 = time.perf_counter()
    out = grouped_matmul(lhs, rhs)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    want = jnp.einsum("ecd,edf->ecf", lhs, rhs)
    return {"name": "moe_grouped_matmul", "shape": f"E{E} cap{cap} D{D} F{F}",
            "max_err": _err(out, want), "interpret_wall_s": round(dt, 2),
            "ok": _err(out, want) < 2e-2}


def run() -> List[Dict]:
    out = []
    for fn in (bench_flash_attention, bench_ssd_scan, bench_moe_gmm):
        rec = fn()
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
