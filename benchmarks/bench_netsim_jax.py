"""Celerity-scale network benchmarks on the jitted JAX simulator.

These runs are exactly the regime the numpy oracle cannot reach in
reasonable wall time: the 512-core (16x32) array of the paper's bisection
argument, full traffic-pattern sweeps, a vmapped credit sweep that
amortizes one compilation across every config, and the fused-step
throughput microbenchmark.

Every suite reports **compile time and run time separately**: the jitted
program is AOT-compiled via ``jitted.lower(...).compile()`` (timed), and
the workloads then execute through the compiled artifact (timed).  The
aggregate ``benchmarks/run.py`` folds these into the
``experiments/BENCH_netsim.json`` trajectory so speedups are tracked
PR-over-PR; ``experiments/bench_baseline.json`` is the frozen
pre-packed-header baseline the speedup fields compare against.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.netsim import MeshSim, unloaded_rtt
from repro.mesh import MeshConfig, PATTERNS, make_traffic
from repro.netsim_jax import (DEFAULT_SWEEP_RATES, compile_sweep,
                              curve_record, init_state, load_latency_sweep,
                              load_program, simulate, stack_rate_programs,
                              sweep_config)

__all__ = ["bench_pattern_sweep", "bench_bisection_16x32",
           "bench_credit_sweep_vmap", "bench_load_latency_8x8",
           "bench_step_throughput", "load_baseline", "run"]

BASELINE_PATH = Path(__file__).resolve().parents[1] / "experiments" / \
    "bench_baseline.json"


def load_baseline() -> Dict[str, Dict]:
    """The frozen pre-refactor benchmark record (one dict per benchmark
    name), for PR-over-PR speedup fields; empty when the snapshot is
    missing."""
    try:
        raw = json.loads(BASELINE_PATH.read_text())
    except (OSError, json.JSONDecodeError):
        return {}
    return {r["name"]: r for rs in raw.values() for r in rs
            if isinstance(r, dict) and "name" in r}


def _aot(jitted, *args) -> Tuple[object, float]:
    """AOT-compile ``jitted`` for ``args`` via ``lower(...).compile()``;
    returns (compiled_executable, compile_seconds).  The executable takes
    only the non-static arguments."""
    t0 = time.perf_counter()
    compiled = jitted.lower(*args).compile()
    return compiled, time.perf_counter() - t0


def _speedup(baseline_wall: Optional[float], wall: float) -> Optional[float]:
    if baseline_wall is None or wall <= 0:
        return None
    return round(float(baseline_wall) / wall, 2)


def bench_pattern_sweep(nx: int = 16, ny: int = 16,
                        cycles: int = 1500) -> Dict:
    """Saturation throughput (ops/cycle) of every traffic pattern on a
    16x16 array — the standard NoC evaluation battery.  One compile
    serves all six patterns (same shapes)."""
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=32).to_sim()
    warmup = cycles // 3
    progs = {name: load_program(make_traffic(name, nx, ny, cycles, seed=0))
             for name in sorted(PATTERNS)}
    first = next(iter(progs.values()))
    compiled, compile_s = _aot(simulate, cfg, first, init_state(cfg), cycles)
    thr: Dict[str, float] = {}
    run_s = 0.0
    for name, prog in progs.items():
        t0 = time.perf_counter()
        _, per = compiled(prog, init_state(cfg))
        per.block_until_ready()
        run_s += time.perf_counter() - t0
        thr[name] = round(float(np.asarray(per)[warmup:].mean()), 2)
    # adversarial patterns must not exceed the friendly ones
    ok = thr["neighbor"] >= thr["bit_complement"] and min(thr.values()) > 0
    wall = compile_s + run_s
    base = load_baseline().get("traffic_pattern_sweep", {})
    return {"name": "traffic_pattern_sweep", "mesh": f"{nx}x{ny}",
            "ops_per_cycle": thr, "compile_s": round(compile_s, 2),
            "run_s": round(run_s, 2),
            "wall_s_incl_compile": round(wall, 2),
            "baseline_wall_s": base.get("wall_s"),
            "speedup_vs_baseline": _speedup(base.get("wall_s"), wall),
            "ok": ok}


def bench_bisection_16x32(cycles: int = 1200) -> Dict:
    """The paper's 512-core bisection bound at Celerity scale: 'if every
    core sent a message across the median of the array, with 16 links
    crossing the bisection, only 32 remote operations can be sustained per
    cycle' — one op per 16 cycles per core.  Uniform-random destinations
    restricted to the opposite half keep path diversity high (a fixed
    permutation like bit-complement head-of-line blocks well below the
    bound)."""
    nx, ny = 16, 32
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=64, router_fifo=4).to_sim()
    entries = make_traffic("uniform", nx, ny, cycles, seed=0)
    # fold every destination into the source's opposite half of the array
    half = np.where(np.arange(ny)[:, None, None] < ny // 2, ny // 2, 0)
    entries["dst_y"] = entries["dst_y"] % (ny // 2) + half
    prog = load_program(entries)
    compiled, compile_s = _aot(simulate, cfg, prog, init_state(cfg), cycles)
    t0 = time.perf_counter()
    _, per = compiled(prog, init_state(cfg))
    per.block_until_ready()
    run_s = time.perf_counter() - t0
    per = np.asarray(per)
    thr = float(per[cycles // 3:].mean())
    bound = 2.0 * nx          # fwd + rev each cross the ny-median once
    per_core_cycles = (nx * ny) / max(thr, 1e-9)
    wall = compile_s + run_s
    base = load_baseline().get("bisection_bound_512core_jax", {})
    return {"name": "bisection_bound_512core_jax", "mesh": f"{nx}x{ny}",
            "paper_bound_ops_per_cycle": bound,
            "measured_ops_per_cycle": round(thr, 2),
            "paper_cycles_per_core_op": 16,
            "measured_cycles_per_core_op": round(per_core_cycles, 1),
            "compile_s": round(compile_s, 2), "run_s": round(run_s, 2),
            "wall_s_incl_compile": round(wall, 2),
            "baseline_wall_s": base.get("wall_s_incl_compile"),
            "speedup_vs_baseline": _speedup(base.get("wall_s_incl_compile"),
                                            wall),
            "ok": 0.35 * bound < thr <= bound + 1e-6}


def bench_credit_sweep_vmap(hops: int = 14) -> Dict:
    """The BDP credit knee, swept in ONE vmapped XLA program: throughput
    scales ~credits/RTT below the knee and saturates at the knee
    (credits = RTT x issue rate)."""
    import jax
    import jax.numpy as jnp

    rtt = unloaded_rtt(hops)
    nx = hops + 1
    cfg = MeshConfig(nx=nx, ny=1, max_out_credits=2 * rtt,
                     router_fifo=max(4, 2 * rtt)).to_sim()
    cycles, warmup = 1000, 200
    entries = make_traffic("neighbor", nx, 1, cycles + 500)
    # single long-haul stream: tile 0 hammers the far end; others idle
    entries["op"][:] = -1
    entries["op"][0, 0, :] = 1                      # OP_STORE
    entries["dst_x"][0, 0, :] = hops
    entries["not_before"][:] = 0
    prog = load_program(entries)
    sweep = jnp.asarray([1, 2, 4, rtt // 2, rtt, rtt + 8, 2 * rtt])
    states = jax.vmap(lambda c: init_state(cfg, max_credits=c))(sweep)
    sweep_fn = jax.jit(lambda p, s: jax.vmap(
        lambda st: simulate(cfg, p, st, cycles))(s))
    compiled, compile_s = _aot(sweep_fn, prog, states)
    t0 = time.perf_counter()
    _, per = compiled(prog, states)
    per.block_until_ready()
    run_s = time.perf_counter() - t0
    per = np.asarray(per)
    curve = {int(c): round(float(per[i, warmup:].mean()), 3)
             for i, c in enumerate(np.asarray(sweep))}
    ok = curve[rtt] > 0.9 and abs(curve[rtt // 2] - 0.5) < 0.1
    wall = compile_s + run_s
    base = load_baseline().get("credit_bdp_knee_vmap", {})
    return {"name": "credit_bdp_knee_vmap", "rtt_cycles": rtt,
            "throughput_vs_credits": curve,
            "configs_in_one_compile": len(curve),
            "compile_s": round(compile_s, 2), "run_s": round(run_s, 2),
            "wall_s_incl_compile": round(wall, 2),
            "baseline_wall_s": base.get("wall_s_incl_compile"),
            # the frozen baseline has no compile/run split, so this is the
            # baseline's TOTAL wall over the new compile time — an upper
            # bound on the true compile speedup, named to match
            "baseline_wall_over_new_compile": None if not base.get(
                "wall_s_incl_compile") else round(
                float(base["wall_s_incl_compile"]) / max(compile_s, 1e-9), 2),
            "speedup_vs_baseline": _speedup(base.get("wall_s_incl_compile"),
                                            wall),
            "ok": ok}


def bench_load_latency_8x8(nx: int = 8, ny: int = 8) -> Dict:
    """Full load–latency saturation curves (phased warmup/measure/drain
    methodology, per-packet latency histograms) for every traffic pattern
    on an 8x8 array — ONE AOT-compiled vmapped XLA program over offered
    loads, shared by all six patterns.

    Checks: every curve is monotone nondecreasing up to its saturation
    knee (and stays saturated past it), and the uniform-random saturation
    point lands within 10% of the analytic bisection bound — on a k x k
    mesh under XY routing, uniform traffic loads the busiest bisection
    channel at ``k/4`` x the injection rate, so saturation is at
    ``r = 4/k`` packets/cycle/tile (0.5 for k = 8)."""
    if nx != ny:
        raise ValueError(
            f"the 4/k bisection bound below assumes a square mesh, "
            f"got {nx}x{ny}")
    rates = DEFAULT_SWEEP_RATES
    cfg = sweep_config(nx, ny)
    warmup, measure, drain = 300, 500, 500
    bisection_rate = 4.0 / nx
    progs = stack_rate_programs("uniform", nx, ny, sorted(rates),
                                warmup + measure + drain, seed=0)
    compiled, compile_s = compile_sweep(cfg, progs, warmup=warmup,
                                        measure=measure, drain=drain)
    curves, ok = {}, True
    t0 = time.perf_counter()
    for name in sorted(PATTERNS):
        out = load_latency_sweep(name, nx, ny, rates, warmup=warmup,
                                 measure=measure, drain=drain, cfg=cfg,
                                 compiled=compiled, seed=0)
        curves[name] = curve_record(out)
        ok &= bool(out["monotone"])
    run_s = time.perf_counter() - t0
    sat_u = curves["uniform"]["saturation_rate"]
    sat_ok = sat_u is not None and \
        abs(sat_u - bisection_rate) <= 0.10 * bisection_rate
    ok &= sat_ok
    wall = compile_s + run_s
    base = load_baseline().get("load_latency_curves_8x8", {})
    return {"name": "load_latency_curves_8x8", "mesh": f"{nx}x{ny}",
            "bisection_saturation_rate": bisection_rate,
            "uniform_saturation_rate": sat_u,
            "uniform_within_10pct_of_bisection": sat_ok,
            "curves": curves, "compile_s": round(compile_s, 2),
            "run_s": round(run_s, 2),
            "wall_s_incl_compile": round(wall, 2),
            "baseline_wall_s": base.get("wall_s_incl_compile"),
            "speedup_vs_baseline": _speedup(base.get("wall_s_incl_compile"),
                                            wall),
            "ok": bool(ok)}


# ----------------------------------------------------------------------
# fused-step throughput microbenchmark
# ----------------------------------------------------------------------
def _baseline_cycles_per_s(mesh: str) -> Optional[float]:
    """Effective simulated cycles/second of the frozen baseline on
    ``mesh``.  Preferred source: the snapshot's own step-throughput
    record (direct, post-compile rate).  Fallback: derive from the
    pre-packed-header suite records (which include their one-off compile,
    so compare against ``incl_compile`` numbers)."""
    base = load_baseline()
    step = base.get("step_throughput_microbench", {})
    if mesh in step.get("meshes", {}):
        return step["meshes"][mesh].get("jax_cycles_per_s")
    if mesh == "16x16" and "traffic_pattern_sweep" in base:
        rec = base["traffic_pattern_sweep"]           # 6 patterns x 1500 cyc
        return round(6 * 1500 / float(rec["wall_s"]), 1)
    if mesh == "16x32" and "bisection_bound_512core_jax" in base:
        rec = base["bisection_bound_512core_jax"]     # 1200 cycles
        return round(1200 / float(rec["wall_s_incl_compile"]), 1)
    return None


def bench_step_throughput(shapes: Tuple[Tuple[int, int], ...] =
                          ((8, 8), (16, 16), (16, 32), (32, 32), (64, 64)),
                          cycles: int = 1500,
                          oracle_cycles: int = 120,
                          pallas_cycles_per_call: int = 8) -> Dict:
    """Cycles/second of the per-cycle router step on uniform-random
    traffic, per mesh shape and per implementation: the fused-XLA step
    vs the Pallas router kernel (``impl="pallas"``, multi-cycle inner
    loop), each with AOT compile time and post-compile steady-state rate
    (median of 3) reported separately, plus speedup vs the numpy oracle
    and — where the frozen baseline has a comparable record — vs the
    snapshot in ``experiments/bench_baseline.json``.

    The 32x32 and 64x64 rows run a reduced cycle count (and a shorter
    oracle probe) so the suite stays CI-sane; rates are per-cycle, so the
    columns remain comparable.  On hosts without a compiled Pallas
    backend the kernel executes in interpret mode — a correctness
    fallback, so the ``>= 2x`` kernel-throughput expectation only gates
    ``ok`` where Pallas actually compiles (``pallas_mode`` says which)."""
    from repro.kernels.backend import has_compiled_backend
    pallas_mode = "compiled" if has_compiled_backend() else "interpret"
    meshes: Dict[str, Dict] = {}
    ok = True
    for nx, ny in shapes:
        big = nx * ny > 512
        cyc = max(cycles // 5, 1) if big else cycles
        ocyc = max(oracle_cycles // 6, 10) if big else oracle_cycles
        cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=32)
        entries = make_traffic("uniform", nx, ny, cyc, seed=0)
        prog = load_program(entries)
        scfg = cfg.to_sim()

        def timed(impl: str, cycles_per_call: int = 1,
                  _cyc=cyc, _prog=prog, _scfg=scfg):
            compiled, compile_s = _aot(simulate, _scfg, _prog,
                                       init_state(_scfg), _cyc, 1, impl,
                                       cycles_per_call)
            times = []
            for _ in range(3):
                t0 = time.perf_counter()
                _, per = compiled(_prog, init_state(_scfg))
                per.block_until_ready()
                times.append(time.perf_counter() - t0)
            run_s = float(np.median(times))
            return compile_s, run_s, _cyc / run_s

        f_compile, f_run, f_cps = timed("fused")
        p_compile, p_run, p_cps = timed("pallas", pallas_cycles_per_call)
        oracle = MeshSim(cfg.to_net())
        oracle.load_program({k: v.copy() for k, v in entries.items()})
        t0 = time.perf_counter()
        oracle.run(ocyc)
        oracle_cps = ocyc / (time.perf_counter() - t0)
        base_cps = _baseline_cycles_per_s(f"{nx}x{ny}")
        incl_cps = cyc / (f_compile + f_run)
        rec = {"cycles": cyc,
               "jax_cycles_per_s": round(f_cps, 1),
               "jax_cycles_per_s_incl_compile": round(incl_cps, 1),
               "compile_s": round(f_compile, 2),
               "run_s": round(f_run, 3),
               "pallas_cycles_per_s": round(p_cps, 1),
               "pallas_compile_s": round(p_compile, 2),
               "pallas_run_s": round(p_run, 3),
               "pallas_vs_fused": round(p_cps / f_cps, 2),
               "oracle_cycles_per_s": round(oracle_cps, 1),
               "speedup_vs_oracle": round(f_cps / oracle_cps, 1),
               "baseline_cycles_per_s": base_cps,
               "speedup_vs_baseline": None if base_cps is None
               else round(f_cps / float(base_cps), 2)}
        ok &= rec["speedup_vs_oracle"] >= 5.0
        if pallas_mode == "compiled":
            ok &= rec["pallas_vs_fused"] >= 2.0
        meshes[f"{nx}x{ny}"] = rec
    return {"name": "step_throughput_microbench", "pattern": "uniform",
            "cycles": cycles, "pallas_mode": pallas_mode,
            "pallas_cycles_per_call": pallas_cycles_per_call,
            "meshes": meshes,
            "compile_s": round(sum(m["compile_s"] + m["pallas_compile_s"]
                                   for m in meshes.values()), 2),
            "run_s": round(sum(m["run_s"] + m["pallas_run_s"]
                               for m in meshes.values()), 2),
            "ok": bool(ok)}


def run() -> List[Dict]:
    out = []
    for fn in (bench_pattern_sweep, bench_bisection_16x32,
               bench_credit_sweep_vmap, bench_load_latency_8x8,
               bench_step_throughput):
        t0 = time.perf_counter()
        rec = fn()
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
