"""Celerity-scale network benchmarks on the jitted JAX simulator.

These runs are exactly the regime the numpy oracle cannot reach in
reasonable wall time: the 512-core (16x32) array of the paper's bisection
argument, full traffic-pattern sweeps, and a vmapped credit sweep that
amortizes one compilation across every config.

Scenario driving goes through the backend-agnostic
:class:`repro.mesh.Simulator` facade; the vmapped sweep drops to the
functional ``repro.netsim_jax`` layer, which is what the facade compiles
to anyway.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.netsim import unloaded_rtt
from repro.mesh import MeshConfig, PATTERNS, Simulator, make_traffic
from repro.netsim_jax import (DEFAULT_SWEEP_RATES, curve_record,
                              init_state, load_latency_sweep, load_program,
                              simulate, sweep_config)

__all__ = ["bench_pattern_sweep", "bench_bisection_16x32",
           "bench_credit_sweep_vmap", "bench_load_latency_8x8", "run"]


def bench_pattern_sweep(nx: int = 16, ny: int = 16,
                        cycles: int = 1500) -> Dict:
    """Saturation throughput (ops/cycle) of every traffic pattern on a
    16x16 array — the standard NoC evaluation battery."""
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=32)
    thr = {}
    warmup = cycles // 3
    for name in sorted(PATTERNS):
        sim = Simulator(cfg, backend="jax")
        sim.attach(make_traffic(name, nx, ny, cycles, seed=0))
        sim.run(cycles)
        thr[name] = round(sim.telemetry().throughput(warmup=warmup), 2)
    # adversarial patterns must not exceed the friendly ones
    ok = thr["neighbor"] >= thr["bit_complement"] and min(thr.values()) > 0
    return {"name": "traffic_pattern_sweep", "mesh": f"{nx}x{ny}",
            "ops_per_cycle": thr, "ok": ok}


def bench_bisection_16x32(cycles: int = 1200) -> Dict:
    """The paper's 512-core bisection bound at Celerity scale: 'if every
    core sent a message across the median of the array, with 16 links
    crossing the bisection, only 32 remote operations can be sustained per
    cycle' — one op per 16 cycles per core.  Uniform-random destinations
    restricted to the opposite half keep path diversity high (a fixed
    permutation like bit-complement head-of-line blocks well below the
    bound)."""
    nx, ny = 16, 32
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=64, router_fifo=4)
    entries = make_traffic("uniform", nx, ny, cycles, seed=0)
    # fold every destination into the source's opposite half of the array
    half = np.where(np.arange(ny)[:, None, None] < ny // 2, ny // 2, 0)
    entries["dst_y"] = entries["dst_y"] % (ny // 2) + half
    sim = Simulator(cfg, backend="jax").attach(entries)
    t0 = time.perf_counter()
    sim.run(cycles)
    per = np.asarray(sim.telemetry().completed_per_cycle)
    wall = time.perf_counter() - t0
    thr = float(per[cycles // 3:].mean())
    bound = 2.0 * nx          # fwd + rev each cross the ny-median once
    per_core_cycles = (nx * ny) / max(thr, 1e-9)
    return {"name": "bisection_bound_512core_jax", "mesh": f"{nx}x{ny}",
            "paper_bound_ops_per_cycle": bound,
            "measured_ops_per_cycle": round(thr, 2),
            "paper_cycles_per_core_op": 16,
            "measured_cycles_per_core_op": round(per_core_cycles, 1),
            "wall_s_incl_compile": round(wall, 2),
            "ok": 0.35 * bound < thr <= bound + 1e-6}


def bench_credit_sweep_vmap(hops: int = 14) -> Dict:
    """The BDP credit knee, swept in ONE vmapped XLA program: throughput
    scales ~credits/RTT below the knee and saturates at the knee
    (credits = RTT x issue rate)."""
    import jax
    import jax.numpy as jnp

    rtt = unloaded_rtt(hops)
    nx = hops + 1
    cfg = MeshConfig(nx=nx, ny=1, max_out_credits=2 * rtt,
                     router_fifo=max(4, 2 * rtt)).to_sim()
    cycles, warmup = 1000, 200
    entries = make_traffic("neighbor", nx, 1, cycles + 500)
    # single long-haul stream: tile 0 hammers the far end; others idle
    entries["op"][:] = -1
    entries["op"][0, 0, :] = 1                      # OP_STORE
    entries["dst_x"][0, 0, :] = hops
    entries["not_before"][:] = 0
    prog = load_program(entries)
    sweep = jnp.asarray([1, 2, 4, rtt // 2, rtt, rtt + 8, 2 * rtt])
    t0 = time.perf_counter()
    states = jax.vmap(lambda c: init_state(cfg, max_credits=c))(sweep)
    _, per = jax.vmap(lambda s: simulate(cfg, prog, s, cycles))(states)
    per = np.asarray(per)
    wall = time.perf_counter() - t0
    curve = {int(c): round(float(per[i, warmup:].mean()), 3)
             for i, c in enumerate(np.asarray(sweep))}
    ok = curve[rtt] > 0.9 and abs(curve[rtt // 2] - 0.5) < 0.1
    return {"name": "credit_bdp_knee_vmap", "rtt_cycles": rtt,
            "throughput_vs_credits": curve,
            "configs_in_one_compile": len(curve),
            "wall_s_incl_compile": round(wall, 2), "ok": ok}


def bench_load_latency_8x8(nx: int = 8, ny: int = 8) -> Dict:
    """Full load–latency saturation curves (phased warmup/measure/drain
    methodology, per-packet latency histograms) for every traffic pattern
    on an 8x8 array, each a single vmapped XLA program over offered loads.

    Checks: every curve is monotone nondecreasing up to its saturation
    knee (and stays saturated past it), and the uniform-random saturation
    point lands within 10% of the analytic bisection bound — on a k x k
    mesh under XY routing, uniform traffic loads the busiest bisection
    channel at ``k/4`` x the injection rate, so saturation is at
    ``r = 4/k`` packets/cycle/tile (0.5 for k = 8)."""
    if nx != ny:
        raise ValueError(
            f"the 4/k bisection bound below assumes a square mesh, "
            f"got {nx}x{ny}")
    rates = DEFAULT_SWEEP_RATES
    cfg = sweep_config(nx, ny)
    bisection_rate = 4.0 / nx
    curves, ok = {}, True
    t0 = time.perf_counter()
    for name in sorted(PATTERNS):
        out = load_latency_sweep(name, nx, ny, rates, warmup=300,
                                 measure=500, drain=500, cfg=cfg, seed=0)
        curves[name] = curve_record(out)
        ok &= bool(out["monotone"])
    wall = time.perf_counter() - t0
    sat_u = curves["uniform"]["saturation_rate"]
    sat_ok = sat_u is not None and \
        abs(sat_u - bisection_rate) <= 0.10 * bisection_rate
    ok &= sat_ok
    return {"name": "load_latency_curves_8x8", "mesh": f"{nx}x{ny}",
            "bisection_saturation_rate": bisection_rate,
            "uniform_saturation_rate": sat_u,
            "uniform_within_10pct_of_bisection": sat_ok,
            "curves": curves, "wall_s_incl_compile": round(wall, 2),
            "ok": bool(ok)}


def run() -> List[Dict]:
    out = []
    for fn in (bench_pattern_sweep, bench_bisection_16x32,
               bench_credit_sweep_vmap, bench_load_latency_8x8):
        t0 = time.perf_counter()
        rec = fn()
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
