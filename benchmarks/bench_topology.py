"""Cross-topology saturation benchmark: the same uniform-random sweep on
every supported topology of one 16x16 array.

One load–latency saturation curve (phased warmup/measure/drain
methodology, vmapped over offered loads) per topology — mesh, torus,
ring-mesh hybrid, and the two-chip multi-chip mesh — each annotated with
its analytic uniform-saturation bound from
:meth:`repro.mesh.topology.Topology.uniform_saturation_bound` (a
path-walk over the actual routing function, so the torus tie-break bias
is priced in rather than hand-waved to the textbook ``2 x mesh``).

Checks (the paper's wraparound argument, made executable):

* every curve is monotone nondecreasing up to its knee;
* the torus saturates at a strictly higher offered load than the mesh
  (halved average hop count / doubled bisection);
* mesh and torus knees each land within 15% of their analytic bound —
  the acceptance bar for ``experiments/topology_saturation.json``;
* the ring-mesh bound equals the mesh bound (wrapped rows do not move
  the N/S bisection that uniform traffic saturates first) and its knee
  matches the mesh knee to one grid step;
* the multi-chip mesh saturates no later than the mesh (the serialized
  boundary column is strictly tighter than the full bisection).

``benchmarks/run.py`` writes the per-topology records to
``experiments/topology_saturation.json`` and gates the mesh row against
the frozen ``experiments/bench_baseline.json`` snapshot (vacuously when
the snapshot predates topology support).
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.mesh import Topology
from repro.netsim_jax import (DEFAULT_SWEEP_RATES, compile_sweep,
                              curve_record, load_latency_sweep,
                              stack_rate_programs, sweep_config)

from benchmarks.bench_netsim_jax import load_baseline, _speedup

__all__ = ["bench_topology_saturation", "run"]

# bound-vs-measured acceptance window; the rate grid steps by 0.05 near
# the torus knee, so anything tighter would gate on grid resolution
BOUND_TOLERANCE = 0.15

TOPOLOGIES = (
    ("mesh", Topology.mesh),
    ("torus", Topology.torus),
    ("ring_mesh", Topology.ring_mesh),
    ("multi_chip", Topology.multi_chip),
)


def bench_topology_saturation(nx: int = 16, ny: int = 16) -> Dict:
    """Uniform-random saturation curve per topology on one nx x ny array.

    16x16 is the smallest square where the torus bound (~0.44 with the
    tie-break bias) still sits inside the standard sweep grid — on 8x8
    the torus bound (~0.79) is beyond any rate the open-loop methodology
    can offer cleanly."""
    rates = sorted(DEFAULT_SWEEP_RATES)
    warmup, measure, drain = 300, 500, 500
    topologies: Dict[str, Dict] = {}
    compile_s = run_s = 0.0
    ok = True
    for kind, make in TOPOLOGIES:
        topo = make()
        cfg = sweep_config(nx, ny, topology=topo)
        progs = stack_rate_programs("uniform", nx, ny, rates,
                                    warmup + measure + drain, seed=0,
                                    topology=topo)
        compiled, cs = compile_sweep(cfg, progs, warmup=warmup,
                                     measure=measure, drain=drain)
        compile_s += cs
        t0 = time.perf_counter()
        out = load_latency_sweep("uniform", nx, ny, rates, warmup=warmup,
                                 measure=measure, drain=drain, cfg=cfg,
                                 compiled=compiled, seed=0)
        run_s += time.perf_counter() - t0
        rec = curve_record(out)
        bound = topo.uniform_saturation_bound(nx, ny)
        rec["analytic_saturation_bound"] = round(float(bound), 4)
        sat = rec["saturation_rate"]
        rec["within_bound_tolerance"] = (
            sat is not None and
            abs(sat - bound) <= BOUND_TOLERANCE * bound)
        topologies[kind] = rec
        ok &= bool(out["monotone"])

    mesh, torus = topologies["mesh"], topologies["torus"]
    ring, multi = topologies["ring_mesh"], topologies["multi_chip"]
    grid_step = max(b - a for a, b in zip(rates, rates[1:]))
    checks = {
        "curves_monotone": bool(ok),
        "torus_saturates_above_mesh":
            torus["saturation_rate"] is not None and
            mesh["saturation_rate"] is not None and
            torus["saturation_rate"] > mesh["saturation_rate"],
        "mesh_within_15pct_of_bound": bool(mesh["within_bound_tolerance"]),
        "torus_within_15pct_of_bound": bool(torus["within_bound_tolerance"]),
        "ring_mesh_bound_equals_mesh_bound":
            ring["analytic_saturation_bound"] ==
            mesh["analytic_saturation_bound"],
        "ring_mesh_knee_matches_mesh":
            ring["saturation_rate"] is not None and
            abs(ring["saturation_rate"] - mesh["saturation_rate"])
            <= grid_step + 1e-9,
        "multi_chip_saturates_no_later_than_mesh":
            multi["saturation_rate"] is not None and
            multi["saturation_rate"] <= mesh["saturation_rate"],
    }
    wall = compile_s + run_s
    base = load_baseline().get("topology_saturation_16x16", {})
    return {"name": "topology_saturation_16x16", "mesh": f"{nx}x{ny}",
            "pattern": "uniform", "bound_tolerance": BOUND_TOLERANCE,
            "topologies": topologies, "checks": checks,
            "compile_s": round(compile_s, 2), "run_s": round(run_s, 2),
            "wall_s_incl_compile": round(wall, 2),
            "baseline_wall_s": base.get("wall_s_incl_compile"),
            "speedup_vs_baseline": _speedup(base.get("wall_s_incl_compile"),
                                            wall),
            "ok": all(checks.values())}


def run() -> List[Dict]:
    out = []
    for fn in (bench_topology_saturation,):
        t0 = time.perf_counter()
        rec = fn()
        rec["wall_s"] = round(time.perf_counter() - t0, 2)
        out.append(rec)
        status = "OK " if rec.get("ok") else "FAIL"
        print(f"[{status}] {rec['name']:32s} {rec}", flush=True)
    return out


if __name__ == "__main__":
    run()
