"""Per-architecture smoke tests (deliverable f).

Each of the 10 assigned architectures is instantiated at a REDUCED
same-family config and runs one forward + one gradient step on CPU,
asserting output shapes and finiteness; decoder archs also run two serve
steps.  The FULL configs are exercised by the dry-run only.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced_config
from repro.models import get_model

ARCHS = list_archs()
B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[1], (B, cfg.encdec.encoder_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    return batch


def test_all_ten_archs_assigned():
    assert len(ARCHS) == 10, ARCHS
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = reduced_config(get_config(arch))
    M = get_model(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss(p):
        return M.loss_fn(p, batch, cfg)[0]

    l, g = jax.jit(jax.value_and_grad(loss))(params)
    assert jnp.isfinite(l), f"{arch} loss not finite"
    for k, v in g.items():
        assert jnp.isfinite(v).all(), f"{arch} grad {k} not finite"
    logits, _ = jax.jit(lambda p: M.forward(
        p, batch["tokens"], cfg,
        positions=batch.get("positions"),
        **({"frames": batch["frames"]} if cfg.family == "audio" else {})))(params)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_steps(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:  # avoid capacity-drop nondeterminism in smoke
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    M = get_model(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 4), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        enc = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.encdec.encoder_seq, cfg.d_model)) * 0.1
        enc_out = M.encode(params, enc, cfg)
        cache = M.init_cache(cfg, B, 8, enc_out=enc_out, params=params)
    else:
        cache = M.init_cache(cfg, B, 8)
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    for i in range(2):
        logits, cache = step(params, cache, tokens[:, i])
        assert logits.shape == (B, cfg.vocab_size)
        assert jnp.isfinite(logits).all(), f"{arch} decode not finite"
    assert int(cache["len"][0]) == 2
