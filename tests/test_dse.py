"""Design-space-exploration service tests.

Covers the spec (validation, expansion, feasibility pruning, cache
keys), the batched/bucketed measurement path (bit-identity of the
vmapped telemetry against a Python loop of single-point runs, under the
simulator's donated scan), the shard_map fan-out and its single-host
graceful degradation, the resumable on-disk cache, and the cost/Pareto
post-passes.  Simulation-heavy tests stay on 4x4 arrays with short
phases — the methodology, not the numbers, is under test here.
"""
import jax
import numpy as np
import pytest

from repro.dse import (CostModel, ResultCache, SweepPoint, SweepSpec,
                       ascii_frontier, config_hash, frontier_artifact,
                       frontier_ascii, frontier_is_monotone, pareto_front,
                       run_sweep, workload_entries)
from repro.mesh.config import MeshConfig
from repro.mesh.topology import Topology
from repro.mesh.traffic import make_traffic
from repro.netsim_jax.measure import (SweepKey, batched_phased_stats,
                                      clear_sweep_cache, phased_stats)
from repro.netsim_jax.sim import init_state, load_program

PHASES = dict(warmup=50, measure=100, drain=100)


def small_spec(**kw):
    base = dict(nx=4, ny=4, fifo_depths=(2, 4), credits=(4, 16),
                patterns=("uniform",), loads=(0.1, 0.3),
                topologies=("mesh",), name="t", **PHASES)
    base.update(kw)
    return SweepSpec(**base)


# -- spec ---------------------------------------------------------------

class TestSweepSpec:
    def test_expansion_is_cross_product(self):
        spec = small_spec(topologies=("mesh", "torus"),
                          workloads=("allreduce",))
        pts = spec.points()
        # 2 topo x 2 depth x 2 cred x (1 pattern x 2 loads + 1 workload)
        assert len(pts) == 2 * 2 * 2 * 3
        assert len(set(pts)) == len(pts)
        assert pts == spec.points()  # deterministic order

    def test_axes_dedupe_and_sort(self):
        spec = small_spec(fifo_depths=(8, 2, 8), loads=(0.3, 0.1, 0.3))
        assert spec.fifo_depths == (2, 8)
        assert spec.loads == (0.1, 0.3)

    @pytest.mark.parametrize("kw,match", [
        (dict(fifo_depths=()), "fifo_depths"),
        (dict(fifo_depths=(0,)), "fifo_depths"),
        (dict(credits=(-1,)), "credits"),
        (dict(patterns=("nope",)), "unknown traffic pattern"),
        (dict(loads=(0.0,)), "offered loads"),
        (dict(loads=(1.5,)), "offered loads"),
        (dict(workloads=("nope",)), "unknown workload family"),
        (dict(patterns=(), workloads=()), "at least one"),
        (dict(topologies=()), "at least one topology"),
        (dict(topologies=("klein_bottle",)), "unknown topology"),
    ])
    def test_validation_names_the_axis(self, kw, match):
        with pytest.raises(ValueError, match=match):
            small_spec(**kw)

    def test_infeasible_points_pruned_and_reported(self):
        spec = small_spec(fifo_depths=(1, 2, 4),
                          topologies=("mesh", "torus"))
        bad = spec.infeasible()
        assert len(bad) == 1
        topo, depth, why = bad[0]
        assert (topo.spec, depth) == ("torus", 1)
        assert "bubble" in why
        # torus points skip depth 1; mesh keeps it
        assert spec.feasible_depths(Topology.parse("torus")) == (2, 4)
        assert spec.feasible_depths(Topology.parse("mesh")) == (1, 2, 4)

    def test_bucket_capacity_covers_every_point(self):
        spec = small_spec(fifo_depths=(2, 4, 8), credits=(4, 64))
        cfg = spec.bucket_config(spec.topologies[0])
        assert cfg.router_fifo == 8 and cfg.max_out_credits == 64
        key = spec.sweep_key(spec.topologies[0])
        assert isinstance(key, SweepKey)
        assert key.horizon == spec.horizon

    def test_point_key_distinguishes_configs_not_buckets(self):
        spec = small_spec()
        wide = small_spec(fifo_depths=(2, 4, 16))  # bigger bucket capacity
        p = spec.points()[0]
        assert spec.point_key(p) == wide.point_key(p)
        q = SweepPoint(p.nx, p.ny, p.topology, p.fifo_depth, p.credits,
                       p.traffic, p.load, seed=7)
        assert spec.point_key(p) != spec.point_key(q)

    def test_workload_entries_shapes(self):
        ent = workload_entries("allreduce", 4, 4)
        assert ent["op"].shape[:2] == (4, 4)


# -- batched measurement bit-identity (satellite 3) ---------------------

@pytest.mark.parametrize("topology", ["mesh", "torus"])
def test_batched_phased_stats_matches_loop(topology):
    """Vmapped telemetry under the donated scan must be bit-identical to
    a Python loop of single-point ``phased_stats`` runs, across >=2
    topologies x >=2 fifo depths with distinct credit allowances."""
    depths = [2, 6]
    credits = [8, 24]
    cfg = MeshConfig(nx=4, ny=4, router_fifo=max(depths),
                     max_out_credits=max(credits),
                     topology=Topology.parse(topology)).to_sim()
    key = SweepKey(cfg, **PHASES)
    progs = [load_program(make_traffic("uniform", 4, 4, 40, rate=0.2,
                                       seed=s)) for s in range(4)]
    batched = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *progs)
    out = batched_phased_stats(
        key, batched, fifo_depths=np.array(depths * 2, np.int32),
        max_credits=np.array(credits + credits[::-1], np.int32))
    for i, (d, c) in enumerate(zip(depths * 2, credits + credits[::-1])):
        single = phased_stats(cfg, progs[i], init_state(cfg, d, c), **PHASES)
        for f in single._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(out, f))[i],
                np.asarray(getattr(single, f)),
                err_msg=f"{topology} point {i} field {f}")


def test_clear_sweep_cache_runs():
    clear_sweep_cache()  # idempotent, clears all jit caches
    clear_sweep_cache()


# -- runner -------------------------------------------------------------

class TestRunSweep:
    def test_records_follow_spec_order_and_schema(self, tmp_path):
        spec = small_spec()
        res = run_sweep(spec, cache_dir=tmp_path, chunk=4)
        assert res.n_points == len(spec.points()) == len(res.records)
        assert res.simulated == res.n_points and res.cache_hits == 0
        assert res.buckets == 1  # one topology, one program shape
        for rec, pt in zip(res.records, spec.points()):
            assert rec["point"]["fifo_depth"] == pt.fifo_depth
            assert rec["point"]["topology"] == pt.topology.spec
            assert set(rec["stats"]) >= {"offered", "accepted", "lat_mean",
                                         "hops", "peak_link_util"}

    def test_cache_resume_simulates_nothing(self, tmp_path):
        spec = small_spec()
        r1 = run_sweep(spec, cache_dir=tmp_path, chunk=4)
        r2 = run_sweep(spec, cache_dir=tmp_path, chunk=4)
        assert r2.simulated == 0 and r2.cache_hits == r1.n_points
        assert r2.compiles == 0 and r2.buckets == 0
        assert r1.records == r2.records

    def test_cache_partial_resume(self, tmp_path):
        narrow = small_spec(loads=(0.1,))
        run_sweep(narrow, cache_dir=tmp_path, chunk=4)
        wide = small_spec(loads=(0.1, 0.3))
        r = run_sweep(wide, cache_dir=tmp_path, chunk=4)
        assert r.cache_hits == len(narrow.points())
        assert r.simulated == len(wide.points()) - len(narrow.points())

    def test_sharded_matches_single_device(self):
        spec = small_spec()
        base = run_sweep(spec, cache_dir=None, chunk=4)
        assert base.devices == 1
        ndev = min(2, jax.device_count())
        if ndev < 2:
            pytest.skip("needs >= 2 devices (conftest sets 8)")
        sharded = run_sweep(spec, cache_dir=None, devices=ndev, chunk=4)
        assert sharded.devices == ndev
        assert sharded.records == base.records

    def test_graceful_degradation_warns_and_matches(self):
        """devices > jax.device_count() must fall back to chunked vmap
        with one warning — never a shard_map crash."""
        spec = small_spec(loads=(0.1,))
        base = run_sweep(spec, cache_dir=None, chunk=4)
        with pytest.warns(UserWarning, match="falling back"):
            degraded = run_sweep(spec, cache_dir=None,
                                 devices=jax.device_count() + 1, chunk=4)
        assert degraded.devices == 1
        assert degraded.records == base.records

    def test_infeasible_reported_in_result(self, tmp_path):
        spec = small_spec(fifo_depths=(1, 2), topologies=("torus",))
        res = run_sweep(spec, cache_dir=tmp_path, chunk=4)
        assert len(res.infeasible) == 1 and "fifo_depth=1" in res.infeasible[0]
        assert all(r["point"]["fifo_depth"] >= 2 for r in res.records)


# -- on-disk cache ------------------------------------------------------

class TestResultCache:
    def test_round_trip_and_len(self, tmp_path):
        cache = ResultCache(tmp_path)
        assert len(cache) == 0 and cache.get("k") is None
        cache.put("k", {"stats": {"x": 1.5}})
        assert cache.get("k") == {"stats": {"x": 1.5}}
        assert len(cache) == 1

    def test_disabled_cache(self):
        cache = ResultCache(None)
        cache.put("k", {"a": 1})
        assert cache.get("k") is None and len(cache) == 0

    def test_key_verified_on_read(self, tmp_path):
        cache = ResultCache(tmp_path)
        cache.put("k1", {"a": 1})
        path = cache.path_for("k1")
        hijack = cache.path_for("k2")
        hijack.parent.mkdir(parents=True, exist_ok=True)
        path.rename(hijack)  # wrong content under k2's filename
        assert cache.get("k2") is None  # collision degrades to a miss

    def test_config_hash_is_stable_hex(self):
        h = config_hash()
        assert h == config_hash() and len(h) == 16
        int(h, 16)


# -- cost model ---------------------------------------------------------

class TestCostModel:
    def test_area_scales_with_depth_and_tiles(self):
        cost = CostModel()
        small = MeshConfig(nx=4, ny=4, router_fifo=2)
        deep = MeshConfig(nx=4, ny=4, router_fifo=8)
        big = MeshConfig(nx=8, ny=8, router_fifo=2)
        assert cost.buffer_area_mm2(deep) > cost.buffer_area_mm2(small)
        assert cost.buffer_area_mm2(big) == pytest.approx(
            4 * cost.buffer_area_mm2(small))
        # 2 networks x 5 ports x depth + ep_fifo flits, 160 bits each
        assert cost.tile_buffer_bits(2, ep_fifo=4) == (2 * 5 * 2 + 4) * 160

    def test_energy_accounting(self):
        cost = CostModel()
        assert cost.hop_energy_pj(0) == 0.0
        assert cost.energy_per_packet_pj(100.0, 0.0) == 0.0
        assert cost.energy_per_packet_pj(100.0, 50.0) == pytest.approx(
            2 * 160 * cost.link_pj_per_bit_hop)


# -- pareto -------------------------------------------------------------

class TestPareto:
    def test_front_drops_dominated(self):
        recs = [{"area_mm2": 1.0, "throughput": 0.3},
                {"area_mm2": 2.0, "throughput": 0.2},   # dominated
                {"area_mm2": 2.0, "throughput": 0.5},
                {"area_mm2": 3.0, "throughput": 0.5}]   # dominated (tie y)
        front = pareto_front(recs)
        assert [(r["area_mm2"], r["throughput"]) for r in front] == \
            [(1.0, 0.3), (2.0, 0.5)]
        assert frontier_is_monotone(front)

    def test_front_skips_unsaturated_points(self):
        recs = [{"area_mm2": 1.0, "throughput": None},
                {"area_mm2": 2.0, "throughput": 0.4}]
        assert len(pareto_front(recs)) == 1

    def test_empty_front_is_not_monotone(self):
        assert not frontier_is_monotone([])

    def test_ascii_marks_frontier(self):
        recs = [{"area_mm2": 1.0, "throughput": 0.3},
                {"area_mm2": 2.0, "throughput": 0.2},
                {"area_mm2": 2.0, "throughput": 0.5}]
        fig = ascii_frontier(recs, pareto_front(recs))
        assert fig.count("*") == 2 and "." in fig
        assert "buffer area" in fig


# -- frontier artifact --------------------------------------------------

def test_frontier_artifact_end_to_end(tmp_path):
    spec = small_spec(loads=(0.05, 0.2, 0.4), topologies=("mesh", "torus"))
    res = run_sweep(spec, cache_dir=tmp_path, chunk=8)
    art = frontier_artifact(res)
    assert art["pattern"] == "uniform"
    assert set(art["frontiers"]) == {"mesh", "torus"}
    for f in art["frontiers"].values():
        assert f["frontier"] and f["monotone"]
        assert len(f["points"]) == 4  # 2 depths x 2 credits
        for p in f["points"]:
            assert p["area_mm2"] > 0 and p["throughput"] > 0
    fig = frontier_ascii(art)
    assert "mesh" in fig and "torus" in fig
    # re-pricing uses cached telemetry only — no new simulation
    res2 = run_sweep(spec, cache_dir=tmp_path, chunk=8)
    assert res2.simulated == 0
    cheap = frontier_artifact(res2, cost=CostModel(sram_um2_per_bit=0.1))
    a1 = art["frontiers"]["mesh"]["points"][0]["area_mm2"]
    a2 = cheap["frontiers"]["mesh"]["points"][0]["area_mm2"]
    assert a2 < a1


def test_frontier_requires_synthetic_pattern(tmp_path):
    spec = small_spec(patterns=(), workloads=("broadcast",),
                      fifo_depths=(2,), credits=(4,))
    res = run_sweep(spec, cache_dir=tmp_path, chunk=4)
    with pytest.raises(ValueError, match="workload"):
        frontier_artifact(res)


# -- topology spec strings (tentpole plumbing) --------------------------

class TestTopologyParse:
    @pytest.mark.parametrize("s", ["mesh", "torus", "ring_mesh",
                                   "multi_chip:2:4"])
    def test_round_trip(self, s):
        assert Topology.parse(s).spec == s

    def test_passthrough_and_default_params(self):
        t = Topology.parse("torus")
        assert Topology.parse(t) is t
        assert Topology.parse("multi_chip").spec == "multi_chip:2:4"

    @pytest.mark.parametrize("s,match", [
        ("klein_bottle", "unknown topology"),
        ("mesh:2", "takes no .* parameters"),
        ("multi_chip:x", "ints"),
        ("multi_chip:2:4:8", "at most"),
    ])
    def test_parse_errors(self, s, match):
        with pytest.raises(ValueError, match=match):
            Topology.parse(s)

    def test_min_router_fifo(self):
        assert Topology.parse("mesh").min_router_fifo == 1
        assert Topology.parse("torus").min_router_fifo == 2
        assert Topology.parse("ring_mesh").min_router_fifo == 2


def test_mesh_config_cache_token_distinguishes_fields():
    a = MeshConfig(nx=4, ny=4, router_fifo=2)
    b = MeshConfig(nx=4, ny=4, router_fifo=4)
    c = MeshConfig(nx=4, ny=4, router_fifo=2,
                   topology=Topology.parse("torus"))
    tokens = {cfg.cache_token() for cfg in (a, b, c)}
    assert len(tokens) == 3
    assert a.cache_token() == MeshConfig(nx=4, ny=4,
                                         router_fifo=2).cache_token()
