"""Behavioral tests for the built-in reactive endpoints — the paper's
user-design integration story (request/reply memory-controller client,
remote-store DMA engine) running on BOTH backends of the facade.

These complement ``tests/test_mesh_api.py`` (which fuzzes telemetry
parity): here the *semantics* of each endpoint are pinned down — data
landing in remote memory in order, windowed outstanding stores, a
pointer chase that provably followed the seeded chain, response latency
consistent with the analytic RTT.
"""
import numpy as np
import pytest

from repro.core.netsim import OP_LOAD, unloaded_rtt
from repro.mesh import (DmaEndpoint, MemoryControllerEndpoint, MeshConfig,
                        Request, Simulator)

BACKENDS = ["numpy", "jax"]


# ----------------------------------------------------------------------
# DMA engine
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_dma_streams_buffer_into_remote_memory(backend):
    cfg = MeshConfig(nx=5, ny=2, mem_words=32)
    sim = Simulator(cfg, backend=backend)
    data = [7 * i + 1 for i in range(20)]
    dma = DmaEndpoint(dst_x=4, dst_y=1, data=data, addr=3)
    sim.attach(dma, at=(0, 0))
    sim.run_until_drained()
    assert dma.done() and dma.acked == len(data)
    np.testing.assert_array_equal(np.asarray(sim.mem)[1, 4, 3:3 + 20], data)


def test_dma_window_bounds_outstanding_stores():
    cfg = MeshConfig(nx=6, ny=1, max_out_credits=16)
    sim = Simulator(cfg, backend="numpy")
    dma = DmaEndpoint(dst_x=5, dst_y=0, data=range(30), max_inflight=2)
    sim.attach(dma, at=(0, 0))
    sim.run_until_drained()
    assert dma.peak_inflight <= 2
    # the window throttles below the credit limit: per-tile credit debt
    # never exceeded the DMA's own window either
    assert dma.acked == 30


def test_dma_rejects_empty_window():
    with pytest.raises(ValueError, match="at least one outstanding"):
        DmaEndpoint(dst_x=1, dst_y=0, data=[1], max_inflight=0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_dma_throughput_scales_with_window(backend):
    """A 1-deep window serializes on the RTT; a BDP-deep window streams —
    the bandwidth-delay law observed through the attach interface."""
    cfg = MeshConfig(nx=9, ny=1, max_out_credits=64, router_fifo=32)
    n = 40
    cycles = {}
    for win in (1, 32):
        sim = Simulator(cfg, backend=backend)
        sim.attach(DmaEndpoint(dst_x=8, dst_y=0, data=range(n),
                               max_inflight=win), at=(0, 0))
        cycles[win] = sim.run_until_drained()
    rtt = unloaded_rtt(8)
    # window=1: one store per RTT; window=BDP: ~line rate
    assert cycles[1] >= n * (rtt - 2)
    assert cycles[32] < cycles[1] / 4


# ----------------------------------------------------------------------
# request/reply memory-controller client
# ----------------------------------------------------------------------
def _ring_mem(cfg: MeshConfig, tile_xy, stride: int) -> np.ndarray:
    """Seed tile (x, y) with the pointer ring mem[a] = (a+stride) % W."""
    x, y = tile_xy
    mem = np.zeros((cfg.ny, cfg.nx, cfg.mem_words), np.int64)
    mem[y, x, :] = (np.arange(cfg.mem_words) + stride) % cfg.mem_words
    return mem


@pytest.mark.parametrize("backend", BACKENDS)
def test_memory_controller_pointer_chase(backend):
    """Each reply's data selects the next request address: the visited
    sequence must follow the seeded chain exactly — proof the endpoint
    really consumed responses (request/reply, not fire-and-forget)."""
    cfg = MeshConfig(nx=4, ny=4, mem_words=16)
    sim = Simulator(cfg, backend=backend, seed=0)
    sim.set_mem(_ring_mem(cfg, (3, 2), stride=5))
    mc = MemoryControllerEndpoint(dst_x=3, dst_y=2, start_addr=1,
                                  n_requests=7, mem_words=16)
    sim.attach(mc, at=(0, 0))
    sim.run_until_drained()
    want = [(1 + 5 * i) % 16 for i in range(7)]
    assert mc.visited == want
    assert mc.done() and len(mc.latencies) == 7


def test_memory_controller_latency_is_analytic_on_idle_mesh():
    """With nothing else on the mesh, every link of the chase takes
    exactly the unloaded RTT for its hop distance."""
    cfg = MeshConfig(nx=6, ny=1, mem_words=8)
    sim = Simulator(cfg, backend="numpy")
    sim.set_mem(_ring_mem(cfg, (5, 0), stride=1))
    mc = MemoryControllerEndpoint(dst_x=5, dst_y=0, start_addr=0,
                                  n_requests=4, mem_words=8)
    sim.attach(mc, at=(0, 0))
    sim.run_until_drained()
    assert mc.latencies == [unloaded_rtt(5)] * 4


def test_memory_controller_serializes_requests():
    """At most one request outstanding: issued count can never exceed
    replies + 1 at any point, so the drain cycle is ~n * RTT."""
    cfg = MeshConfig(nx=4, ny=1, mem_words=8)
    sim = Simulator(cfg, backend="numpy")
    sim.set_mem(_ring_mem(cfg, (3, 0), stride=3))
    n = 5
    mc = MemoryControllerEndpoint(dst_x=3, dst_y=0, start_addr=0,
                                  n_requests=n, mem_words=8)
    sim.attach(mc, at=(0, 0))
    cycle = sim.run_until_drained()
    assert cycle >= n * unloaded_rtt(3)


# ----------------------------------------------------------------------
# protocol plumbing
# ----------------------------------------------------------------------
def test_offer_only_called_when_ready_and_injection_guaranteed():
    """The valid/ready contract: offer() fires only with a credit + FIFO
    space in hand, and every offered packet injects that same cycle."""
    calls = []

    class Probe:
        def __init__(self):
            self.sent = 0

        def offer(self, cycle, credits):
            assert credits > 0, "offered with no credit"
            calls.append((cycle, credits))
            if self.sent >= 3:
                return None
            self.sent += 1
            return Request(dst_x=1, dst_y=0, addr=self.sent, data=self.sent)

        def deliver(self, response):
            pass

        def done(self):
            return self.sent >= 3

    cfg = MeshConfig(nx=2, ny=1, max_out_credits=2)
    sim = Simulator(cfg, backend="numpy")
    probe = Probe()
    sim.attach(probe, at=(0, 0))
    sim.run_until_drained()
    assert probe.sent == 3
    # conservation through the facade: every offered packet completed
    assert int(np.asarray(sim.completed).sum()) == 3
    # with max_out_credits=2 the probe was never offered more than 2
    assert max(c for (_cyc, c) in calls) <= 2


def test_deliver_receives_load_data_and_latency_fields():
    seen = []

    class Collector:
        def __init__(self):
            self.issued = 0

        def offer(self, cycle, credits):
            if self.issued:
                return None
            self.issued = 1
            return Request(dst_x=2, dst_y=0, addr=4, op=OP_LOAD)

        def deliver(self, response):
            seen.append(response)

        def done(self):
            return bool(self.issued)

    cfg = MeshConfig(nx=3, ny=1, mem_words=8)
    sim = Simulator(cfg, backend="numpy")
    mem = np.zeros((1, 3, 8), np.int64)
    mem[0, 2, 4] = 1234
    sim.set_mem(mem)
    sim.attach(Collector(), at=(0, 0))
    sim.run_until_drained()
    (resp,) = seen
    assert resp.data == 1234 and resp.op == OP_LOAD and resp.addr == 4
    assert resp.src_x == 2 and resp.src_y == 0
    assert resp.latency == unloaded_rtt(2)
