"""The packed-header packet encoding and the fused router datapath knobs.

* header encode/decode round-trips at field extremes (max mesh
  coordinates, every opcode), as scalars and as arrays;
* response-header swap and source-OR identities;
* negative payload data passes through the unpacked int32 lanes
  unchanged, end to end through a real simulation on both backends;
* packed in-flight packets match the numpy oracle **field-for-field**
  mid-flight (not just after drain), via
  :func:`repro.netsim_jax.testing.assert_packets_equal`;
* program-domain validation names the offending field, on
  ``load_program`` and on the facade attach path of BOTH backends;
* the ``unroll`` / ``check_every`` performance knobs change no result.
"""
import numpy as np
import pytest

from repro.core.netsim import OP_CAS, OP_LOAD, OP_STORE
from repro.mesh import MeshConfig, Simulator, empty_program, make_traffic
from repro.mesh.encoding import (COORD_LIMIT, HEADER_FIELDS, OP_LIMIT,
                                 decode_header, pack_dst_op, pack_header,
                                 swap_for_response, validate_program,
                                 with_src)
from repro.netsim_jax.testing import assert_packets_equal, assert_state_equal

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 — placeholder strategies, never evaluated
        @staticmethod
        def integers(*_a, **_k):
            return None


# ----------------------------------------------------------------------
# encode/decode round trips
# ----------------------------------------------------------------------
def _roundtrip(dst_x, dst_y, src_x, src_y, op):
    hdr = pack_header(dst_x, dst_y, src_x, src_y, op)
    assert 0 <= int(np.max(hdr)) < 2**30, "header must be a positive int32"
    got = decode_header(hdr)
    for k, want in zip(HEADER_FIELDS, (dst_x, dst_y, src_x, src_y, op)):
        np.testing.assert_array_equal(got[k], want,
                                      err_msg=f"field {k} corrupted")


def test_roundtrip_field_extremes():
    """Every corner of the field domains, including the max mesh coords
    and all four opcode values."""
    ext = [0, 1, COORD_LIMIT // 2, COORD_LIMIT - 2, COORD_LIMIT - 1]
    for op in range(OP_LIMIT):
        for c in ext:
            _roundtrip(c, ext[-1 - ext.index(c)], c, 0, op)
            _roundtrip(COORD_LIMIT - 1, c, 0, c, op)


def test_roundtrip_random_arrays():
    rng = np.random.default_rng(0)
    n = 4096
    args = [rng.integers(0, COORD_LIMIT, n) for _ in range(4)]
    args.append(rng.integers(0, OP_LIMIT, n))
    _roundtrip(*args)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, COORD_LIMIT - 1), st.integers(0, COORD_LIMIT - 1),
       st.integers(0, COORD_LIMIT - 1), st.integers(0, COORD_LIMIT - 1),
       st.integers(0, OP_LIMIT - 1))
def test_roundtrip_hypothesis(dst_x, dst_y, src_x, src_y, op):
    _roundtrip(dst_x, dst_y, src_x, src_y, op)


def test_with_src_and_response_swap_identities():
    """pack_dst_op + with_src == pack_header, and the response header is
    the src<->dst swap with the servicing tile as the new source."""
    rng = np.random.default_rng(1)
    for _ in range(200):
        dx, dy, sx, sy = rng.integers(0, COORD_LIMIT, 4)
        ex, ey = rng.integers(0, COORD_LIMIT, 2)     # servicing tile
        op = int(rng.integers(0, OP_LIMIT))
        hdr = with_src(pack_dst_op(dx, dy, op), sx, sy)
        assert hdr == pack_header(dx, dy, sx, sy, op)
        resp = swap_for_response(hdr, ex, ey)
        assert resp == pack_header(sx, sy, ex, ey, op), \
            "response must route back to the requester with op preserved"


def test_jax_side_decode_matches_numpy():
    """The same shift/mask helpers produce identical values on jax arrays
    (the in-kernel decode path)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    f = [rng.integers(0, COORD_LIMIT, 64) for _ in range(4)]
    f.append(rng.integers(0, OP_LIMIT, 64))
    hdr_np = pack_header(*f)
    hdr_j = pack_header(*[jnp.asarray(v, jnp.int32) for v in f])
    np.testing.assert_array_equal(hdr_np, np.asarray(hdr_j, np.int64))
    dec = decode_header(jnp.asarray(hdr_np, jnp.int32))
    for k, want in zip(HEADER_FIELDS, f):
        np.testing.assert_array_equal(np.asarray(dec[k], np.int64), want)


# ----------------------------------------------------------------------
# negative payload passthrough on the unpacked lanes
# ----------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_negative_data_passthrough(backend):
    """data/cmp are full int32 lanes: negative stores commit negative
    values and negative loads return them — header packing must not
    touch payload sign bits."""
    nx = ny = 3
    prog = empty_program(nx, ny, 2)
    prog["op"][0, 0, :] = [OP_STORE, OP_LOAD]
    prog["dst_x"][0, 0, :] = 2
    prog["dst_y"][0, 0, :] = 1
    prog["addr"][0, 0, :] = 5
    prog["data"][0, 0, 0] = -123456789
    sim = Simulator(MeshConfig(nx=nx, ny=ny), backend=backend)
    sim.attach(prog)
    sim.run_until_drained()
    assert int(sim.mem[1, 2, 5]) == -123456789


def test_negative_cas_parity():
    """CAS with negative compare/swap values: bit-identical across
    backends (cmp is an unpacked lane too)."""
    nx = ny = 3
    prog = empty_program(nx, ny, 2)
    prog["op"][0, 0, :] = [OP_STORE, OP_CAS]
    prog["dst_x"][0, 0, :] = 1
    prog["addr"][0, 0, :] = 3
    prog["data"][0, 0, :] = [-7, -9]
    prog["cmp"][0, 0, 1] = -7                      # hits: -7 was stored
    cfg = MeshConfig(nx=nx, ny=ny)
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in prog.items()})
    b = Simulator(cfg, backend="jax")
    b.attach(prog)
    a.run_until_drained()
    b.run_until_drained()
    assert_state_equal(a, b)
    assert int(a.mem[0, 1, 3]) == -9


# ----------------------------------------------------------------------
# packed vs oracle packets, field for field, MID-flight
# ----------------------------------------------------------------------
@pytest.mark.parametrize("pattern", ["uniform", "hotspot"])
def test_packed_packets_match_oracle_midflight(pattern):
    """Stop the clock while traffic is in flight and compare every queued
    packet (router FIFOs, endpoint FIFOs, response delay line, registered
    port) decoded-field by decoded-field against the oracle."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=8, router_fifo=3,
                     resp_latency=2)
    entries = make_traffic(pattern, 4, 4, 12, rate=0.9, seed=5)
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax")
    b.attach(entries)
    saw_inflight = False
    for cycles in (3, 5, 8, 13, 21):
        a.run(cycles)
        b.run(cycles)
        assert_packets_equal(a, b)
        inflight = int(np.asarray(a.fwd.count).sum()
                       + np.asarray(a.rev.count).sum()
                       + np.asarray(a.ep_in.count).sum())
        saw_inflight |= inflight > 0
    assert saw_inflight, "test never observed an in-flight packet"


# ----------------------------------------------------------------------
# domain validation: one clear error naming the offending field
# ----------------------------------------------------------------------
def _prog_with(field, value, nx=4, ny=4):
    prog = make_traffic("neighbor", nx, ny, 2, seed=0)
    prog[field] = prog[field].copy()
    prog[field][0, 0, 0] = value
    return prog


def test_load_program_rejects_wide_coords_and_op():
    from repro.netsim_jax import load_program
    with pytest.raises(ValueError, match=r"'dst_x'.*packed header"):
        load_program(_prog_with("dst_x", COORD_LIMIT))
    with pytest.raises(ValueError, match=r"'dst_y'.*packed header"):
        load_program(_prog_with("dst_y", -1))
    with pytest.raises(ValueError, match=r"'op'.*opcode"):
        load_program(_prog_with("op", OP_LIMIT))


def test_load_program_rejects_int32_overflow_naming_field():
    from repro.netsim_jax import load_program
    for field in ("addr", "data", "cmp", "not_before"):
        with pytest.raises(ValueError, match=f"'{field}'.*int32"):
            load_program(_prog_with(field, 2**40))


def test_load_program_ignores_padding_entries():
    """Out-of-width values on op<0 padding rows are never injected and
    must not be rejected."""
    from repro.netsim_jax import load_program
    prog = make_traffic("neighbor", 3, 3, 2, seed=0)
    prog["op"][0, 0, :] = -1
    prog["dst_x"][0, 0, :] = 10_000
    load_program(prog)                               # no raise


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_facade_attach_validates_both_backends(backend):
    """One packet-domain contract at the front door: the numpy oracle
    rejects the same programs the packed jax path cannot represent —
    including in-width coordinates that fall outside the mesh."""
    sim = Simulator(MeshConfig(nx=4, ny=4), backend=backend)
    with pytest.raises(ValueError, match=r"'dst_x'.*4x4 mesh"):
        sim.attach(_prog_with("dst_x", 5))           # < COORD_LIMIT, > nx
    with pytest.raises(ValueError, match=r"'data'.*int32"):
        sim.attach(_prog_with("data", 2**35))


def test_validate_program_accepts_full_domain():
    prog = make_traffic("uniform", 8, 8, 4, seed=3)
    prog["data"][:] = np.iinfo(np.int32).min         # extreme but legal
    validate_program(prog)
    validate_program(prog, nx=8, ny=8)


def test_simconfig_rejects_oversize_mesh():
    from repro.netsim_jax import SimConfig
    with pytest.raises(ValueError, match="packed header"):
        SimConfig(nx=COORD_LIMIT + 1, ny=1)
    SimConfig(nx=COORD_LIMIT, ny=1)                  # boundary accepted


# ----------------------------------------------------------------------
# multi-chip coordinates: the chip id rides inside the x coordinate
# ----------------------------------------------------------------------
def test_chip_coordinate_roundtrip_extremes():
    """chip_split/chip_join are exact inverses for every representable
    coordinate — including the largest mesh (COORD_LIMIT wide) and the
    columns straddling every chip boundary — and the header round-trips
    the chip id bits because it round-trips the global coordinate."""
    from repro.mesh import Topology
    from repro.mesh.encoding import chip_join, chip_split
    for chips in (2, 4):
        topo = Topology.multi_chip(chips_x=chips)
        for nx in (chips, 2 * chips, COORD_LIMIT):
            w = topo.chip_width(nx)
            edges = {0, 1, w - 1, w, nx - w, nx - 1}
            xs = np.asarray(sorted(x for x in edges if 0 <= x < nx))
            chip, local = chip_split(xs, topo, nx)
            assert (0 <= chip).all() and (chip < chips).all()
            assert (0 <= local).all() and (local < w).all()
            np.testing.assert_array_equal(chip_join(chip, local, topo, nx),
                                          xs)
            # through the packed header: global x survives, so the chip
            # id (its high part) survives
            hdr = pack_header(xs, 0, xs, 0, 1)
            got = decode_header(hdr)
            np.testing.assert_array_equal(
                chip_split(got["dst_x"], topo, nx)[0], chip)
            np.testing.assert_array_equal(
                chip_split(got["src_x"], topo, nx)[0], chip)


def test_validate_program_checks_topology_layout():
    """validate_program(topology=...) rejects arrays the topology cannot
    be laid onto, and the facade attach path applies it for both
    backends."""
    from repro.mesh import Topology
    prog = make_traffic("uniform", 4, 4, 2, seed=0)
    validate_program(prog, nx=4, ny=4,
                     topology=Topology.multi_chip(chips_x=2))  # no raise
    with pytest.raises(ValueError, match="divisible"):
        validate_program(prog, nx=5, ny=4,
                         topology=Topology.multi_chip(chips_x=2))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_multichip_extreme_coords_deliver(backend):
    """Cross-boundary packets addressed at the extreme columns (0 and
    nx-1, opposite chips) deliver correctly — coordinates are global, no
    chip-local translation leaks into the datapath."""
    from repro.mesh import Topology
    nx, ny = 8, 2
    cfg = MeshConfig(nx=nx, ny=ny,
                     topology=Topology.multi_chip(chips_x=2,
                                                  boundary_period=3))
    prog = empty_program(nx, ny, 1)
    prog["op"][0, 0, 0] = OP_STORE       # chip 0 edge -> chip 1 far edge
    prog["dst_x"][0, 0, 0] = nx - 1
    prog["dst_y"][0, 0, 0] = 1
    prog["addr"][0, 0, 0] = 7
    prog["data"][0, 0, 0] = 42
    prog["op"][1, nx - 1, 0] = OP_STORE  # chip 1 edge -> chip 0 far edge
    prog["dst_x"][1, nx - 1, 0] = 0
    prog["dst_y"][1, nx - 1, 0] = 0
    prog["addr"][1, nx - 1, 0] = 3
    prog["data"][1, nx - 1, 0] = -42
    sim = Simulator(cfg, backend=backend)
    sim.attach(prog)
    sim.run_until_drained(max_cycles=500)
    assert int(sim.mem[1, nx - 1, 7]) == 42
    assert int(sim.mem[0, 0, 3]) == -42


# ----------------------------------------------------------------------
# unroll / check_every: speed knobs, never results
# ----------------------------------------------------------------------
def test_unroll_is_bit_identical():
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=4)
    entries = make_traffic("uniform", 4, 4, 8, rate=0.6, seed=7)
    a = Simulator(cfg, backend="jax")
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax", unroll=4)
    b.attach(entries)
    a.run(100)
    b.run(100)
    from repro.netsim_jax.testing import assert_telemetry_equal
    assert_telemetry_equal(a, b)
    np.testing.assert_array_equal(np.asarray(a.mem), np.asarray(b.mem))


@pytest.mark.parametrize("check_every", [3, 8])
def test_check_every_reports_exact_drain_cycle(check_every):
    """The K-cycle fence cadence must still report the exact drain cycle
    (the state may coast past it; the fence cycle may not move)."""
    cfg = MeshConfig(nx=4, ny=3, max_out_credits=4)
    entries = make_traffic("tornado", 4, 3, 6, seed=9)
    exact = Simulator(cfg, backend="numpy")
    exact.attach({k: v.copy() for k, v in entries.items()})
    coarse = Simulator(cfg, backend="jax", check_every=check_every)
    coarse.attach(entries)
    ce = exact.run_until_drained()
    cc = coarse.run_until_drained()
    assert ce == cc, f"check_every={check_every} moved the drain cycle"
    np.testing.assert_array_equal(np.asarray(exact.mem),
                                  np.asarray(coarse.mem))
    np.testing.assert_array_equal(np.asarray(exact.completed),
                                  np.asarray(coarse.completed))
    # completion traces agree on the drained prefix
    assert list(exact.completed_per_cycle) == \
        list(coarse.completed_per_cycle)[:len(exact.completed_per_cycle)]


def test_knob_validation():
    with pytest.raises(ValueError, match="unroll and check_every"):
        Simulator(MeshConfig(nx=2, ny=2), backend="jax", unroll=0)
