"""Optimizer: AdamW + ZeRO-1 specs + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.parallel.sharding import Rules


def test_adamw_converges_quadratic():
    cfg = optim.OptConfig(lr_peak=0.1, lr_min=0.01, warmup_steps=5,
                          total_steps=200, weight_decay=0.0, clip_norm=1e9)
    target = jnp.array([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = optim.init(params)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, m = optim.apply(cfg, params, grads, state)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)
    assert m["grad_norm"] > 0


def test_weight_decay_mask():
    assert optim.no_decay("layers/attn_norm")
    assert optim.no_decay("layers/bq")
    assert optim.no_decay("layers/A_log")
    assert not optim.no_decay("layers/wq")
    assert not optim.no_decay("embed")


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(norm, 20.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(clipped["a"]), 1.0, rtol=1e-6)


def test_schedule_shape():
    cfg = optim.OptConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                          total_steps=100)
    lrs = [float(optim.schedule(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    np.testing.assert_allclose(lrs[10], 1e-3, rtol=1e-5)
    assert lrs[100] == pytest.approx(1e-4, rel=1e-3)
    assert all(a >= b - 1e-12 for a, b in zip(lrs[10:], lrs[11:]))  # decay


def test_zero1_specs_divisible(mesh_dm):
    rules = Rules(mesh=mesh_dm)
    p_specs = {"w": NamedSharding(mesh_dm, P(None, "model"))}
    p_shapes = {"w": jax.ShapeDtypeStruct((6, 8), jnp.float32)}
    s = optim.state_specs(p_specs, p_shapes, rules)
    # dim0=6 divisible by data=2 -> banked over data
    assert s["m"]["w"].spec == P("data", "model")
    # not divisible anywhere -> unchanged
    p_shapes2 = {"w": jax.ShapeDtypeStruct((5, 4), jnp.float32)}
    p_specs2 = {"w": NamedSharding(mesh_dm, P(None, "model"))}
    s2 = optim.state_specs(p_specs2, p_shapes2, rules)
    assert s2["m"]["w"].spec == P(None, "model")
    # dim already fully sharded but further divisible -> extended in place
    p_shapes3 = {"w": jax.ShapeDtypeStruct((5, 8), jnp.float32)}
    p_specs3 = {"w": NamedSharding(mesh_dm, P(None, "model"))}
    s3 = optim.state_specs(p_specs3, p_shapes3, rules)
    assert s3["m"]["w"].spec == P(None, ("model", "data"))


def test_zero1_reduces_state_bytes(mesh_dm):
    """ZeRO-1 banking shrinks the per-device optimizer state."""
    rules = Rules(mesh=mesh_dm)
    shape = (8, 16)
    spec = NamedSharding(mesh_dm, P(None, "model"))
    sds = jax.ShapeDtypeStruct(shape, jnp.float32)
    s = optim.state_specs({"w": spec}, {"w": sds}, rules)
    assert np.prod(s["m"]["w"].shard_shape(shape)) == \
        np.prod(shape) // 8  # data(2) x model(4)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["bf16", "int8"]))
def test_compression_bounded_error(seed, mode):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(300).astype(np.float32) *
                    rng.uniform(0.01, 10))
    out, _ = optim.compress_decompress(g, mode)
    scale = float(jnp.max(jnp.abs(g)))
    tol = scale / 100 if mode == "int8" else scale / 64
    assert float(jnp.max(jnp.abs(out - g))) <= tol


def test_error_feedback_telescopes():
    """With error feedback, the running SUM of compressed grads tracks the
    true sum (bias telescopes instead of accumulating)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64, np.float32)
    ef_sum = np.zeros(64, np.float32)
    plain_sum = np.zeros(64, np.float32)
    err = jnp.zeros(64)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 0.01)
        true_sum += np.asarray(g)
        out_ef, err = optim.compress_decompress(g, "int8", err)
        ef_sum += np.asarray(out_ef)
        out_plain, _ = optim.compress_decompress(g, "int8")
        plain_sum += np.asarray(out_plain)
    ef_err = np.abs(ef_sum - true_sum).max()
    plain_err = np.abs(plain_sum - true_sum).max()
    assert ef_err <= plain_err + 1e-6
    assert ef_err < 0.01 * np.abs(true_sum).max() + 1e-3


def test_cross_pod_psum_error_feedback(mesh_dm):
    """Compressed psum inside shard_map matches the exact psum closely."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    x = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((2, 8)).astype(np.float32))

    def island(g):
        out, _ = optim.cross_pod_psum(g, "data", "int8")
        return out

    got = shard_map(island, mesh=mesh_dm, in_specs=P("data", None),
                    out_specs=P("data", None), axis_names={"data"})(x)
    want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), (2, 8))
    np.testing.assert_allclose(np.asarray(got), want, atol=0.05)
