"""Parallelism-path equivalence: every distribution strategy must compute
the same function (the sharding is an implementation detail).

These guard the §Perf hillclimb changes: the explicit Megatron islands and
the MoE dispatch modes must agree with the plain GSPMD lowering.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.api import get_model
from repro.parallel.sharding import Rules


def _forward(cfg, mesh, tokens, seed=0, **rule_kw):
    model = get_model(cfg)
    rules = Rules(mesh=mesh, **rule_kw)
    with mesh:
        params = jax.jit(model.init_params, static_argnums=0)(
            cfg, jax.random.key(seed))
        logits, aux = jax.jit(
            lambda p, t: model.forward(p, t, cfg, rules))(params, tokens)
    return np.asarray(logits, np.float32)


@pytest.fixture(scope="module")
def toks():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 512, (4, 32)).astype(np.int32))


def test_manual_tp_matches_gspmd(mesh_dm, toks):
    """Explicit Megatron islands == GSPMD auto placement (dense GQA)."""
    cfg = reduced_config(get_config("qwen2-72b"))
    a = _forward(cfg, mesh_dm, toks, manual_tp=True)
    b = _forward(cfg, mesh_dm, toks, manual_tp=False)
    np.testing.assert_allclose(a, b, rtol=3e-2, atol=3e-2)  # bf16 model
    # tighter check on fp32 reduced config
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    a32 = _forward(cfg32, mesh_dm, toks, manual_tp=True)
    b32 = _forward(cfg32, mesh_dm, toks, manual_tp=False)
    np.testing.assert_allclose(a32, b32, rtol=2e-4, atol=2e-4)


def test_manual_tp_with_qkv_bias(mesh_dm, toks):
    cfg = dataclasses.replace(reduced_config(get_config("qwen1.5-32b")),
                              dtype="float32")
    a = _forward(cfg, mesh_dm, toks, manual_tp=True)
    b = _forward(cfg, mesh_dm, toks, manual_tp=False)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode_pair", [("local", "tp"), ("ep", "tp"),
                                       ("xy", "ep"), ("x", "xy")])
def test_moe_dispatch_modes_agree(mesh_dm, mode_pair):
    """All MoE dispatch modes compute the same tokens->experts function.

    Uses a generous capacity factor so no tokens drop (drops are the only
    legitimate divergence between layouts — different FIFO arrival
    orders).  xy/x need seq sharding and E % columns == 0.
    """
    cfg = reduced_config(get_config("moonshot-v1-16b-a3b"))
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                capacity_factor=8.0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 512, (4, 32)).astype(np.int32))
    m1, m2 = mode_pair
    a = _forward(cfg, mesh_dm, toks, dispatch=m1)
    b = _forward(cfg, mesh_dm, toks, dispatch=m2)
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_gqa_grouped_matches_repeat(mesh_dm, toks):
    """The grouped-GQA ablation equals the repeated-KV default."""
    cfg = dataclasses.replace(reduced_config(get_config("mixtral-8x7b")),
                              dtype="float32")
    a = _forward(cfg, mesh_dm, toks, gqa_grouped=True, manual_tp=False)
    b = _forward(cfg, mesh_dm, toks, gqa_grouped=False, manual_tp=False)
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)