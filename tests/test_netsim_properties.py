"""Property-based invariants of the mesh simulators.

These are the system's conservation laws, checked under randomized
traffic — the netsim equivalents of "packets are neither lost nor
duplicated" and "credits are conserved":

* every issued transaction eventually completes (conservation);
* at *every* cycle, packets injected == delivered + in-flight (summed
  over the forward FIFOs, endpoint FIFOs, response delay line, reverse
  FIFOs and the registered response port);
* credits never go negative nor exceed max_out_credits_p, and the
  credit debt equals the per-tile in-flight count;
* every finite program drains within an analytic serialization bound
  (deadlock freedom of XY routing + the sink reverse network);
* stores commit the last-written value per (src, dst, addr) program order;
* the structural N->E/W turn restriction never fires (asserted inside the
  router; any violation would abort the step);
* **differential fuzz**: random injection programs (random mesh shape,
  ops, pacing, FIFO depth, credit allowance, response latency) produce
  identical memory, completion traces, drain cycles *and telemetry
  counters* on the numpy oracle vs the JAX simulator.

The differential fuzz and the invariants run from a deterministic seed
corpus even without hypothesis installed; with hypothesis available the
same properties are additionally explored adaptively.
"""
import numpy as np
import pytest

from repro.core.netsim import (MeshSim, NetConfig, OP_CAS, OP_LOAD,
                               OP_STORE, unloaded_rtt)
from repro.mesh import MeshConfig, Simulator
from repro.netsim_jax.testing import assert_state_equal

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 — placeholder strategies, never evaluated
        @staticmethod
        def integers(*_a, **_k):
            return None


def _random_prog(rng, ny, nx, L, ops=(OP_STORE, OP_LOAD)):
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = rng.choice(ops, size=(ny, nx, L))
    # ragged program lengths: pad tails with -1
    lens = rng.integers(0, L + 1, size=(ny, nx))
    tail = np.arange(L)[None, None, :] >= lens[..., None]
    prog["op"][tail] = -1
    prog["dst_x"][:] = rng.integers(0, nx, (ny, nx, L))
    prog["dst_y"][:] = rng.integers(0, ny, (ny, nx, L))
    prog["addr"][:] = rng.integers(0, 16, (ny, nx, L))
    prog["data"][:] = rng.integers(0, 1 << 20, (ny, nx, L))
    return prog, lens


# ----------------------------------------------------------------------
# differential fuzz: numpy oracle vs JAX simulator
# ----------------------------------------------------------------------
# Shapes are drawn from a small pool and the program length is fixed so
# the JAX path's XLA compilations are amortized across the whole corpus
# (effective FIFO depth / credits are *state*, not shape, in the JAX sim).
FUZZ_MESHES = ((2, 2), (3, 2), (4, 3))
FUZZ_L = 6


def _differential_case(seed, mesh_idx, fifo, credits, resp_latency,
                       rate_pct, use_cas):
    """One fuzzed program, run to drain on both simulators, compared on
    memory, stats, per-cycle completion trace, drain cycle, telemetry."""
    rng = np.random.default_rng(seed)
    nx, ny = FUZZ_MESHES[mesh_idx]
    ops = (OP_STORE, OP_LOAD, OP_CAS) if use_cas else (OP_STORE, OP_LOAD)
    prog, lens = _random_prog(rng, ny, nx, FUZZ_L, ops=ops)
    prog["cmp"][:] = rng.integers(0, 4, (ny, nx, FUZZ_L))
    # randomized pacing, entry i no earlier than floor(i / rate)
    rate = rate_pct / 100.0
    prog["not_before"][:] = np.floor(np.arange(FUZZ_L) / rate).astype(np.int64)

    cfg = MeshConfig(nx=nx, ny=ny, router_fifo=fifo, ep_fifo=4,
                     max_out_credits=credits, mem_words=16,
                     resp_latency=resp_latency)
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in prog.items()})
    # identical dynamics, but drive the JAX backend through its *capacity*
    # config with the effective depth/credits as (vmap-able) state
    jcfg = MeshConfig(nx=nx, ny=ny, router_fifo=4, ep_fifo=4,
                      max_out_credits=8, mem_words=16,
                      resp_latency=resp_latency)
    b = Simulator(jcfg, backend="jax", fifo_depth=fifo, max_credits=credits)
    b.attach(prog)

    ca = a.run_until_drained(max_cycles=4000)
    cb = b.run_until_drained(max_cycles=4000)
    assert ca == cb, "drain cycle diverged"
    assert_state_equal(a, b)  # mem/stats/traces + every telemetry field
    assert int(a.completed.sum()) == int(lens.sum())


@pytest.mark.parametrize("seed", range(10))
def test_differential_fuzz_corpus(seed):
    """Deterministic slice of the fuzz corpus — runs without hypothesis."""
    rng = np.random.default_rng(1000 + seed)
    _differential_case(seed=int(rng.integers(0, 2**31)),
                       mesh_idx=int(rng.integers(0, len(FUZZ_MESHES))),
                       fifo=int(rng.integers(2, 5)),
                       credits=int(rng.integers(1, 9)),
                       resp_latency=int(rng.integers(1, 3)),
                       rate_pct=int(rng.integers(10, 101)),
                       use_cas=bool(rng.integers(0, 2)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(FUZZ_MESHES) - 1),
       st.integers(2, 4), st.integers(1, 8), st.integers(1, 2),
       st.integers(10, 100), st.integers(0, 1))
def test_differential_fuzz_hypothesis(seed, mesh_idx, fifo, credits,
                                      resp_latency, rate_pct, use_cas):
    """Hypothesis-driven exploration of the same differential property."""
    _differential_case(seed, mesh_idx, fifo, credits, resp_latency,
                       rate_pct, bool(use_cas))


# ----------------------------------------------------------------------
# per-cycle conservation / deadlock-freedom invariants
# ----------------------------------------------------------------------
def _assert_conservation(sim: MeshSim, credits: int):
    """Packet and credit conservation at a cycle boundary."""
    injected = int(sim.prog_ptr.sum())
    in_flight = (int(sim.fwd.count.sum()) + int(sim.ep_in.count.sum())
                 + int(sim.resp_valid.sum()) + int(sim.rev.count.sum())
                 + int(sim.reg_valid.sum()))
    delivered = int(sim.completed.sum())
    assert injected == delivered + in_flight, \
        f"packet leak: injected {injected} != delivered {delivered} " \
        f"+ in-flight {in_flight}"
    assert (sim.credits >= 0).all(), "endpoint sent while out of credit"
    assert (sim.credits <= credits).all(), "credit over-return"
    # credit debt == per-tile in-flight count (credits return at absorb,
    # one cycle before the response is counted as completed)
    debt = credits - sim.credits
    per_tile_inflight = sim.prog_ptr - sim.completed - sim.reg_valid
    np.testing.assert_array_equal(debt, per_tile_inflight,
                                  err_msg="credit debt != in-flight")


def _invariant_case(seed, nx, ny, L, credits, fifo):
    rng = np.random.default_rng(seed)
    prog, lens = _random_prog(rng, ny, nx, L,
                              ops=(OP_STORE, OP_LOAD, OP_CAS))
    prog["not_before"][:] = rng.integers(0, 20, (ny, nx, L))
    cfg = NetConfig(nx=nx, ny=ny, router_fifo=fifo, mem_words=16,
                    max_out_credits=credits)
    sim = MeshSim(cfg)
    sim.load_program(prog)

    # analytic drain bound: XY routing is deadlock free and the reverse
    # network is a sink, so at worst transactions fully serialize — each
    # completes within one max-RTT of the previous, after the last
    # injection gate opens
    total = int(lens.sum())
    bound = int(prog["not_before"].max()) + \
        (total + 1) * unloaded_rtt(nx + ny)
    cycles = 0
    while cycles < bound:
        if (sim.prog_ptr >= sim.prog_len).all() and \
                (sim.credits == credits).all() and not sim.reg_valid.any():
            break
        sim.step()
        cycles += 1
        _assert_conservation(sim, credits)
    else:
        pytest.fail(f"program did not drain within the analytic bound "
                    f"({bound} cycles for {total} packets)")
    assert int(sim.completed.sum()) == total


@pytest.mark.parametrize("seed", range(6))
def test_conservation_and_drain_bound_corpus(seed):
    rng = np.random.default_rng(2000 + seed)
    _invariant_case(seed=int(rng.integers(0, 2**31)),
                    nx=int(rng.integers(2, 5)), ny=int(rng.integers(2, 5)),
                    L=int(rng.integers(1, 9)),
                    credits=int(rng.integers(1, 9)),
                    fifo=int(rng.integers(2, 5)))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 4), st.integers(2, 4),
       st.integers(1, 8), st.integers(1, 8), st.integers(2, 4))
def test_conservation_and_drain_bound_hypothesis(seed, nx, ny, L, credits,
                                                 fifo):
    _invariant_case(seed, nx, ny, L, credits, fifo)


# ----------------------------------------------------------------------
# the original oracle-only properties
# ----------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 4), st.integers(2, 4),
       st.integers(1, 12), st.integers(1, 8))
def test_all_issued_transactions_complete(seed, ny, nx, L, credits):
    rng = np.random.default_rng(seed)
    prog, lens = _random_prog(rng, ny, nx, L)
    sim = MeshSim(NetConfig(nx=nx, ny=ny, mem_words=16,
                            max_out_credits=credits))
    sim.load_program(prog)
    sim.run_until_drained(max_cycles=20000)
    # conservation: one response per issued packet, no duplicates
    assert int(sim.completed.sum()) == int(lens.sum())
    # fence semantics: all credits returned
    assert (sim.credits == credits).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_credits_bounded_every_cycle(seed, credits):
    rng = np.random.default_rng(seed)
    prog, _ = _random_prog(rng, 3, 3, 6)
    sim = MeshSim(NetConfig(nx=3, ny=3, mem_words=16,
                            max_out_credits=credits))
    sim.load_program(prog)
    for _ in range(300):
        sim.step()
        assert (sim.credits >= 0).all(), "endpoint sent while out of credit"
        assert (sim.credits <= credits).all(), "credit over-return"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_same_source_store_order_is_program_order(seed):
    """Point-to-point ordering: the LAST store in program order wins at
    every (src, dst, addr) — for a single writer per address."""
    rng = np.random.default_rng(seed)
    ny = nx = 3
    L = 6
    # each tile writes only to ONE (dst, addr) pair -> single writer
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = OP_STORE
    dst_x = rng.integers(0, nx, (ny, nx))
    dst_y = rng.integers(0, ny, (ny, nx))
    # address = unique per source tile so writers never collide
    addr = (np.arange(ny * nx).reshape(ny, nx)) % 16
    for i in range(L):
        prog["dst_x"][..., i] = dst_x
        prog["dst_y"][..., i] = dst_y
        prog["addr"][..., i] = addr
        prog["data"][..., i] = rng.integers(0, 1 << 20, (ny, nx))
    sim = MeshSim(NetConfig(nx=nx, ny=ny, mem_words=16))
    sim.load_program(prog)
    sim.run_until_drained(max_cycles=20000)
    for sy in range(ny):
        for sx in range(nx):
            got = sim.mem[dst_y[sy, sx], dst_x[sy, sx], addr[sy, sx]]
            assert got == prog["data"][sy, sx, L - 1], \
                "same-source stores committed out of program order"


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
def test_throughput_monotone_in_credits(seed, hops):
    """More credits never hurt throughput on an uncontended path (the BDP
    law's monotonicity)."""
    done = []
    for credits in (1, 4, 16):
        nx = hops + 1
        sim = MeshSim(NetConfig(nx=nx, ny=1, max_out_credits=credits,
                                router_fifo=max(4, credits), mem_words=16))
        L = 300
        prog = {k: np.zeros((1, nx, L), np.int64)
                for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                          "not_before")}
        prog["op"][:] = -1
        prog["op"][0, 0, :] = OP_STORE
        prog["dst_x"][0, 0, :] = hops
        prog["addr"][0, 0, :] = np.arange(L) % 16
        sim.load_program(prog)
        sim.run(250)
        done.append(int(sim.completed[0, 0]))
    assert done[0] <= done[1] <= done[2]
