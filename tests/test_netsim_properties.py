"""Property-based invariants of the mesh simulator (hypothesis).

These are the system's conservation laws, checked under randomized
traffic — the netsim equivalents of "packets are neither lost nor
duplicated" and "credits are conserved":

* every issued transaction eventually completes (conservation);
* credits never go negative nor exceed max_out_credits_p;
* stores commit the last-written value per (src, dst, addr) program order;
* the structural N->E/W turn restriction never fires (asserted inside the
  router; any violation would abort the step).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.netsim import MeshSim, NetConfig, OP_LOAD, OP_STORE


def _random_prog(rng, ny, nx, L, ops=(OP_STORE, OP_LOAD)):
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = rng.choice(ops, size=(ny, nx, L))
    # ragged program lengths: pad tails with -1
    lens = rng.integers(0, L + 1, size=(ny, nx))
    tail = np.arange(L)[None, None, :] >= lens[..., None]
    prog["op"][tail] = -1
    prog["dst_x"][:] = rng.integers(0, nx, (ny, nx, L))
    prog["dst_y"][:] = rng.integers(0, ny, (ny, nx, L))
    prog["addr"][:] = rng.integers(0, 16, (ny, nx, L))
    prog["data"][:] = rng.integers(0, 1 << 20, (ny, nx, L))
    return prog, lens


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 4), st.integers(2, 4),
       st.integers(1, 12), st.integers(1, 8))
def test_all_issued_transactions_complete(seed, ny, nx, L, credits):
    rng = np.random.default_rng(seed)
    prog, lens = _random_prog(rng, ny, nx, L)
    sim = MeshSim(NetConfig(nx=nx, ny=ny, mem_words=16,
                            max_out_credits=credits))
    sim.load_program(prog)
    sim.run_until_drained(max_cycles=20000)
    # conservation: one response per issued packet, no duplicates
    assert int(sim.completed.sum()) == int(lens.sum())
    # fence semantics: all credits returned
    assert (sim.credits == credits).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_credits_bounded_every_cycle(seed, credits):
    rng = np.random.default_rng(seed)
    prog, _ = _random_prog(rng, 3, 3, 6)
    sim = MeshSim(NetConfig(nx=3, ny=3, mem_words=16,
                            max_out_credits=credits))
    sim.load_program(prog)
    for _ in range(300):
        sim.step()
        assert (sim.credits >= 0).all(), "endpoint sent while out of credit"
        assert (sim.credits <= credits).all(), "credit over-return"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_same_source_store_order_is_program_order(seed):
    """Point-to-point ordering: the LAST store in program order wins at
    every (src, dst, addr) — for a single writer per address."""
    rng = np.random.default_rng(seed)
    ny = nx = 3
    L = 6
    # each tile writes only to ONE (dst, addr) pair -> single writer
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = OP_STORE
    dst_x = rng.integers(0, nx, (ny, nx))
    dst_y = rng.integers(0, ny, (ny, nx))
    # address = unique per source tile so writers never collide
    addr = (np.arange(ny * nx).reshape(ny, nx)) % 16
    for i in range(L):
        prog["dst_x"][..., i] = dst_x
        prog["dst_y"][..., i] = dst_y
        prog["addr"][..., i] = addr
        prog["data"][..., i] = rng.integers(0, 1 << 20, (ny, nx))
    sim = MeshSim(NetConfig(nx=nx, ny=ny, mem_words=16))
    sim.load_program(prog)
    sim.run_until_drained(max_cycles=20000)
    for sy in range(ny):
        for sx in range(nx):
            got = sim.mem[dst_y[sy, sx], dst_x[sy, sx], addr[sy, sx]]
            assert got == prog["data"][sy, sx, L - 1], \
                "same-source stores committed out of program order"


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
def test_throughput_monotone_in_credits(seed, hops):
    """More credits never hurt throughput on an uncontended path (the BDP
    law's monotonicity)."""
    done = []
    for credits in (1, 4, 16):
        nx = hops + 1
        sim = MeshSim(NetConfig(nx=nx, ny=1, max_out_credits=credits,
                                router_fifo=max(4, credits), mem_words=16))
        L = 300
        prog = {k: np.zeros((1, nx, L), np.int64)
                for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                          "not_before")}
        prog["op"][:] = -1
        prog["op"][0, 0, :] = OP_STORE
        prog["dst_x"][0, 0, :] = hops
        prog["addr"][0, 0, :] = np.arange(L) % 16
        sim.load_program(prog)
        sim.run(250)
        done.append(int(sim.completed[0, 0]))
    assert done[0] <= done[1] <= done[2]