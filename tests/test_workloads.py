"""Workload traffic compiler: placement, program lowering, conservation,
collective exactness, cross-backend parity, and the congestion-fed
roofline loop."""
import json

import numpy as np
import pytest

from repro.core.netsim import OP_STORE, MeshSim
from repro.launch import roofline as rl
from repro.mesh import MeshConfig, Simulator
from repro.workloads import (CongestionModel, Packet, Placement, Workload,
                             expected_memory, merge_workloads,
                             moe_all_to_all, parameter_broadcast,
                             pgas_from_batches, pgas_scatter, pipeline_p2p,
                             program_from_packets, ring_all_reduce,
                             run_workload, snake_order)
from repro.workloads.runner import default_workload_config


# ---------------------------------------------------------------- placement

def test_snake_order_neighbors_adjacent():
    coords = [tuple(c) for c in snake_order(4, 3)]
    assert len(coords) == 12 and len(set(coords)) == 12
    for (x0, y0), (x1, y1) in zip(coords, coords[1:]):
        assert abs(x0 - x1) + abs(y0 - y1) == 1


def test_placement_ring_hops():
    p = Placement.ring(4, 4)
    assert p.k == 16
    # consecutive snake ranks are mesh neighbors...
    assert all(p.ring_hop_length(r) == 1 for r in range(p.k - 1))
    # ...and the wrap-around hop walks back up the column
    assert p.ring_hop_length(p.k - 1) == 3


def test_placement_ring_hops_topology_aware():
    """On the torus the snake ring's long wrap-around link collapses to
    the single wraparound hop; every other link is unchanged."""
    from repro.mesh import Topology
    p = Placement.ring(4, 4)
    torus = Topology.torus()
    assert all(p.ring_hop_length(r, topology=torus) == 1
               for r in range(p.k - 1))
    assert p.ring_hop_length(p.k - 1) == 3
    assert p.ring_hop_length(p.k - 1, topology=torus) == 1


def test_ring_all_reduce_torus_beats_mesh_by_wrap_factor():
    """The same ring all-reduce on the same placement, mesh vs torus,
    both backends parity-checked (backend="both").  With one outstanding
    credit the per-step time is set by the slowest ring link's round
    trip, so cycles/step must improve by about the RTT ratio of the
    wrap-around link: unloaded_rtt(3) / unloaded_rtt(1) on a 4x4."""
    from repro.core.netsim import unloaded_rtt
    from repro.mesh import Topology
    w = ring_all_reduce(4, 4, 16, mem_words=16)
    cycles = {}
    for kind, topo in (("mesh", Topology.mesh()), ("torus", Topology.torus())):
        cfg = MeshConfig(nx=4, ny=4, max_out_credits=1, router_fifo=2,
                         mem_words=16, topology=topo)
        cycles[kind] = run_workload(w, cfg, backend="both").cycles
    assert cycles["torus"] < cycles["mesh"]
    pl = w.placement
    wrap = pl.k - 1
    expected = unloaded_rtt(pl.ring_hop_length(wrap)) \
        / unloaded_rtt(pl.ring_hop_length(wrap, topology=Topology.torus()))
    ratio = cycles["mesh"] / cycles["torus"]
    assert ratio >= 0.9 * expected, \
        f"torus speedup {ratio:.2f} below the wraparound factor {expected:.2f}"


def test_placement_validation():
    with pytest.raises(ValueError):
        Placement(2, 2, ((0, 0), (5, 0)))          # off-mesh
    with pytest.raises(ValueError):
        Placement(2, 2, ((0, 0), (0, 0)))          # duplicate tile


# ------------------------------------------------------- program lowering

def test_program_from_packets_sorts_and_pads():
    pkts = [Packet(src_x=0, src_y=0, dst_x=1, dst_y=0, addr=0,
                   not_before=9),
            Packet(src_x=0, src_y=0, dst_x=1, dst_y=0, addr=1,
                   not_before=2)]
    prog = program_from_packets(2, 1, pkts)
    # per-tile slots are sorted by not_before (injection is slot-order)
    assert prog["not_before"][0, 0, 0] == 2
    assert prog["not_before"][0, 0, 1] == 9
    assert prog["addr"][0, 0, 0] == 1
    # tiles with no packets are all padding
    assert (prog["op"][0, 1] < 0).all()


def test_workload_counts_validated():
    pkts = [Packet(src_x=0, src_y=0, dst_x=1, dst_y=0, addr=0)]
    prog = program_from_packets(2, 1, pkts)
    with pytest.raises(ValueError):
        Workload(name="bad", family="x", nx=2, ny=1, program=prog,
                 n_steps=1, n_packets=7)


# ----------------------------------------------------------- conservation

def test_conservation_mid_run_and_at_drain():
    """At every cycle boundary: injected == delivered + in-flight; at the
    drain fence every injected packet has been delivered."""
    w = ring_all_reduce(4, 4, 16)
    sim = MeshSim(default_workload_config(4, 4).to_net(), seed=0)
    sim.load_program({k: v.copy() for k, v in w.program.items()})
    for _ in range(30):
        for _ in range(5):
            sim.step()
        injected = int(sim.prog_ptr.sum())
        in_flight = (int(sim.fwd.count.sum()) + int(sim.ep_in.count.sum())
                     + int(sim.resp_valid.sum()) + int(sim.rev.count.sum())
                     + int(sim.reg_valid.sum()))
        delivered = int(sim.completed.sum())
        assert injected == delivered + in_flight, \
            f"packet leak at cycle {sim.cycle}: injected {injected} != " \
            f"delivered {delivered} + in-flight {in_flight}"
    sim.run_until_drained()
    assert int(sim.completed.sum()) == w.n_packets


# --------------------------------------------- all-reduce ring exactness

@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_allreduce_delivers_exact_ring_traffic(backend):
    """Each ring rank sends and receives exactly 2(k-1) chunks — i.e.
    2(k-1)/k of the padded payload — and total link traversals equal
    packets x ring-hop lengths (XY routes of neighbor hops are the hops
    themselves)."""
    nx = ny = 4
    w = ring_all_reduce(nx, ny, 16)          # k=16, chunk=1
    k, chunk = w.meta["k"], w.meta["chunk"]
    per_rank = 2 * (k - 1) * chunk
    assert per_rank == w.meta["per_rank_injected"]
    payload_padded = chunk * k
    assert per_rank * k == w.n_packets
    assert per_rank == 2 * (k - 1) / k * payload_padded

    sim = Simulator(default_workload_config(nx, ny), backend=backend)
    sim.attach({key: v.copy() for key, v in w.program.items()})
    sim.run_until_drained(50_000)
    t = sim.telemetry()
    # every rank's tile ejects exactly its received share (port P)
    for r in range(k):
        x, y = w.placement.tile(r)
        assert int(t.link_util_fwd[y, x, 0]) == per_rank, \
            f"rank {r} at ({x},{y}) ejected " \
            f"{int(t.link_util_fwd[y, x, 0])} != {per_rank}"
    # mesh-channel traversals == sum over ranks of packets x hop length
    hops = sum(per_rank * w.placement.ring_hop_length(r) for r in range(k))
    assert int(t.link_util_fwd[..., 1:].sum()) == hops


# ----------------------------------------------------- cross-backend parity

@pytest.mark.parametrize("make", [
    lambda: ring_all_reduce(4, 4, 12),
    lambda: parameter_broadcast(4, 4, 8),
    lambda: moe_all_to_all(4, 4, 3, imbalance=0.3, seed=2),
    lambda: pipeline_p2p(4, 4, n_micro=3, act_words=4, backward=True),
    lambda: pgas_scatter(4, 4, 3),
], ids=["allreduce", "broadcast", "moe", "pipeline", "pgas"])
def test_families_bit_identical_across_backends(make):
    w = make()
    r = run_workload(w, backend="both")      # raises on any divergence
    assert r.backend == "both"
    assert r.delivered == r.injected == w.n_packets
    json.dumps(r.to_json())                  # report is JSON-clean


def test_merged_workloads_run_and_count():
    a = ring_all_reduce(4, 4, 8)
    b = parameter_broadcast(4, 4, 8)
    m = merge_workloads("ar_then_bcast", [a, b], gap=4)
    assert m.n_packets == a.n_packets + b.n_packets
    r = run_workload(m, backend="numpy")
    assert r.delivered == m.n_packets


# ------------------------------------------------------------------- moe

def test_moe_token_accounting():
    w = moe_all_to_all(4, 4, 5, top_k=2, imbalance=0.5, seed=0)
    load = w.meta["expert_load"]
    assert sum(load) == w.n_packets == 16 * 5 * 2
    assert w.meta["hot_expert_share"] == load[0] / w.n_packets
    # skew concentrates on expert 0
    assert load[0] == max(load)
    with pytest.raises(ValueError):
        moe_all_to_all(4, 4, 2, imbalance=1.0)
    with pytest.raises(ValueError):
        moe_all_to_all(4, 4, 2, rate=0.0)


# ------------------------------------------------------------------ pgas

def test_pgas_memory_matches_expected_image():
    """Simulated end-state memory == the analytic commit image of the
    same store batch (collision-free addresses)."""
    T, S = 16, 3
    rng = np.random.default_rng(3)
    addr = np.zeros((T, T, S), np.int64)
    data = np.zeros((T, T, S), np.int64)
    mask = np.zeros((T, T, S), bool)
    for t in range(T):
        for s in range(S):
            d = (t + s + 1) % T
            # each destination hears from distinct sources (d is unique
            # per slot for one t), so addr=t is collision-free per tile
            addr[t, d, s] = t
            data[t, d, s] = int(rng.integers(1, 1000))
            mask[t, d, s] = True
    w = pgas_from_batches(addr, data, mask, 4, 4, mem_words=32)
    sim = Simulator(MeshConfig(nx=4, ny=4, mem_words=32), backend="numpy")
    sim.attach({k: v.copy() for k, v in w.program.items()})
    sim.run_until_drained(20_000)
    exp = expected_memory(addr, data, mask, 4, 4, mem_words=32)
    np.testing.assert_array_equal(np.asarray(sim.mem), exp)


def test_pgas_from_batches_validates_addresses():
    addr = np.full((16, 16, 1), 999)         # beyond mem_words
    data = np.zeros((16, 16, 1), np.int64)
    mask = np.ones((16, 16, 1), bool)
    with pytest.raises(ValueError):
        pgas_from_batches(addr, data, mask, 4, 4, mem_words=64)


# ------------------------------------------------- congestion -> roofline

def _mk_report(family, k, injected, cycles, mesh="4x4"):
    from repro.workloads import WorkloadReport
    return WorkloadReport(
        name=f"{family}_{injected}", family=family, mesh=mesh,
        backend="numpy", cycles=cycles, n_steps=1,
        cycles_per_step=float(cycles), injected=injected,
        delivered=injected, accepted_throughput=0.0, mean_latency=0.0,
        peak_link_util=0.0, hotspots=[], link_heatmap=[],
        meta={"k": k})


def test_congestion_fit_recovers_affine_law():
    # cycles = 3 * (injected/k) + 50, exactly
    reports = [_mk_report("allreduce", 4, inj, 3 * (inj // 4) + 50)
               for inj in (40, 80, 160)]
    cm = CongestionModel.fit(reports, clock_hz=1e9)
    a, b = cm.coeffs["allreduce"]
    assert a == pytest.approx(3.0) and b == pytest.approx(50.0)
    # pricing: wire_bytes -> words -> cycles -> seconds
    assert cm.op_cycles("all-reduce", wire_bytes=400, count=2) == \
        pytest.approx(3.0 * 100 + 2 * 50.0)
    cm2 = CongestionModel.from_json(cm.to_json())
    assert cm2.coeffs == cm.coeffs and cm2.clock_hz == cm.clock_hz


def test_congestion_family_fallback():
    cm = CongestionModel(mesh="4x4", coeffs={"allreduce": (2.0, 0.0)})
    # uncalibrated families price through the fallback chain
    assert cm.family_for("all-to-all") == "allreduce"
    assert cm.op_cycles("collective-permute", 40) == pytest.approx(20.0)


def test_roofline_netsim_vs_analytic_pinned():
    """The acceptance pin: netsim collective time comes from simulated
    cycles and differs from the analytic estimate on the same colls."""
    from repro.configs import SHAPES, get_config
    cfg = get_config("stablelm-3b")
    shape = SHAPES["train_4k"]
    cost = {"flops": 1e15, "bytes accessed": 1e12, "bytes adjusted": 5e11}
    colls = {"all-reduce": {"bytes": 8e8, "count": 2, "wire_bytes": 1.2e9}}
    cm = CongestionModel(mesh="4x4",
                         coeffs={"allreduce": (1.0, 10.0)}, clock_hz=1e9)

    a = rl.roofline(cfg, shape, "pod", 256, cost, dict(colls))
    n = rl.roofline(cfg, shape, "pod", 256, cost, dict(colls),
                    network="netsim", congestion=cm)
    assert a.network == "analytic" and n.network == "netsim"
    assert a.collective_s == pytest.approx(8e8 / rl.HW.ICI_BW)
    # 1.2e9 bytes / 4 B per word * 1 cycle/word + 2 * 10 cycles @ 1 GHz
    assert n.collective_s == pytest.approx((1.2e9 / 4 + 20) / 1e9)
    assert n.collective_s != a.collective_s
    det = n.coll_detail["all-reduce"]
    assert det["family"] == "allreduce"
    assert det["sim_s"] == pytest.approx(n.collective_s)
    # analytic fields survive next to the simulated ones
    assert det["wire_bytes"] == 1.2e9
    with pytest.raises(ValueError):
        rl.roofline(cfg, shape, "pod", 256, cost, dict(colls),
                    network="wrong")


def test_measure_cell_cost_annotation_helper():
    from repro.launch.costing import netsim_collectives
    cm = CongestionModel(mesh="4x4", coeffs={"moe": (2.0, 5.0)})
    colls = {"all-to-all": {"bytes": 80.0, "count": 1, "wire_bytes": 40.0}}
    out = netsim_collectives(colls, cm)
    assert out["all-to-all"]["sim_cycles"] == pytest.approx(
        2.0 * 10 + 5.0)
    assert out["all-to-all"]["bytes"] == 80.0
