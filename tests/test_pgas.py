"""PGAS remote memory ops over an 8-device mesh (paper C1/C3).

The oracle for delivery is simple: a remote store of value v to tile t's
address a must appear in tile t's memory at a; loads must return the
destination's memory contents in request order.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import endpoint as ep
from repro.core import pgas

T, S, MEM = 8, 2, 16


def _sm(mesh, fn, *args, in_specs, out_specs):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs))(*args)


def test_remote_store_delivers_and_credits(mesh2x4):
    """Every tile stores its id into every tile's memory at addr = src id."""
    def f(mem):
        me = pgas.tile_linear_index("x", "y")
        pkts = pgas.PacketBatch(
            addr=jnp.broadcast_to(me, (T, S)).astype(jnp.int32),
            data=jnp.broadcast_to(me.astype(jnp.float32) + 1, (T, S)),
            mask=jnp.ones((T, S), bool).at[:, 1].set(False),  # one slot used
        )
        mem2, credits = pgas.remote_store(mem[0], pkts, "x", "y")
        return mem2[None], credits[None]

    mem0 = jnp.zeros((T, MEM), jnp.float32)
    mem, credits = _sm(mesh2x4, f, mem0,
                       in_specs=P(("y", "x"), None),
                       out_specs=(P(("y", "x"), None), P(("y", "x"), None)))
    mem, credits = np.asarray(mem), np.asarray(credits)
    for t in range(T):
        np.testing.assert_array_equal(mem[t, :T], np.arange(T) + 1)
        np.testing.assert_array_equal(mem[t, T:], 0)
    # each tile sent 1 packet to each of T destinations -> T acks, one per dest
    np.testing.assert_array_equal(credits, np.ones((T, T)))


def test_remote_store_same_source_slot_order(mesh2x4):
    """Point-to-point ordering: two writes from one source to the same
    address commit in slot order (slot 1 wins)."""
    def f(mem):
        pkts = pgas.PacketBatch(
            addr=jnp.zeros((T, S), jnp.int32),
            data=jnp.stack([jnp.full((T,), 10.0), jnp.full((T,), 20.0)], 1),
            mask=jnp.ones((T, S), bool),
        )
        mem2, _ = pgas.remote_store(mem[0], pkts, "x", "y")
        return mem2[None]

    mem = _sm(mesh2x4, f, jnp.zeros((T, MEM), jnp.float32),
              in_specs=P(("y", "x"), None), out_specs=P(("y", "x"), None))
    np.testing.assert_array_equal(np.asarray(mem)[:, 0], np.full(T, 20.0))


def test_remote_load_gathers_in_request_order(mesh2x4):
    """Tile t loads addr 0 and 1 from every tile; must see dest-tile id
    based contents, responses indexed by destination."""
    def f(mem):
        me = pgas.tile_linear_index("x", "y")
        mem = mem[0].at[0].set(me.astype(jnp.float32) * 100)
        mem = mem.at[1].set(me.astype(jnp.float32) * 100 + 1)
        pkts = pgas.PacketBatch(
            addr=jnp.broadcast_to(jnp.array([0, 1], jnp.int32), (T, S)),
            data=jnp.zeros((T, S), jnp.float32),
            mask=jnp.ones((T, S), bool),
        )
        data, valid = pgas.remote_load(mem, pkts, "x", "y")
        return data[None], valid[None]

    data, valid = _sm(mesh2x4, f, jnp.zeros((T, MEM), jnp.float32),
                      in_specs=P(("y", "x"), None),
                      out_specs=(P(("y", "x"), None, None), P(("y", "x"), None, None)))
    data, valid = np.asarray(data), np.asarray(valid)
    assert valid.all()
    for t in range(T):
        np.testing.assert_array_equal(data[t, :, 0], np.arange(T) * 100)
        np.testing.assert_array_equal(data[t, :, 1], np.arange(T) * 100 + 1)


def test_remote_cas_single_winner(mesh2x4):
    """All 8 tiles CAS the same lock word on tile 3: exactly one must win
    (the paper's mutex building block)."""
    def f(mem):
        me = pgas.tile_linear_index("x", "y")
        pkts = pgas.PacketBatch(
            addr=jnp.zeros((T, 1), jnp.int32),
            data=jnp.broadcast_to(me.astype(jnp.float32) + 1, (T, 1)),
            mask=(jnp.arange(T) == 3)[:, None],
        )
        compare = jnp.zeros((T, 1), jnp.float32)
        mem2, old = pgas.remote_cas(mem[0], pkts, compare, "x", "y")
        won = old[3, 0] == 0.0
        return mem2[None], won[None]

    mem, won = _sm(mesh2x4, f, jnp.zeros((T, MEM), jnp.float32),
                   in_specs=P(("y", "x"), None),
                   out_specs=(P(("y", "x"), None), P(("y", "x"))))
    mem, won = np.asarray(mem), np.asarray(won)
    assert won.sum() == 1, f"expected exactly one mutex winner, got {won.sum()}"
    winner = int(np.nonzero(won)[0][0])
    # the lock word on tile 3 holds the winner's id + 1
    assert mem[3, 0] == winner + 1
    # deterministic arbitration: source 0 wins (round-robin from 0)
    assert winner == 0


def test_endpoint_credit_limit_and_fence(mesh2x4):
    """With max_out_credits=3, a 5-packet batch sends only 3; after the
    acks return the fence predicate holds again (credits drained back)."""
    def f(_):
        state = ep.make_endpoint(MEM, max_out_credits=3)
        pkts = pgas.PacketBatch(
            addr=jnp.arange(5, dtype=jnp.int32)[None, :].repeat(T, 0) * 0 +
                 jnp.arange(5, dtype=jnp.int32)[None, :],
            data=jnp.ones((T, 5), jnp.float32),
            mask=(jnp.arange(T) == 0)[:, None] & jnp.ones((T, 5), bool),
        )
        state, sent = ep.master_store(state, pkts, "x", "y")
        return sent.sum()[None], ep.fence(state)[None], state.mem[None]

    sent, fenced, mem = _sm(
        mesh2x4, f, jnp.zeros((T, 1)),
        in_specs=P(("y", "x"), None),
        out_specs=(P(("y", "x")), P(("y", "x")), P(("y", "x"), None)))
    sent, fenced, mem = np.asarray(sent), np.asarray(fenced), np.asarray(mem)
    assert (sent == 3).all(), sent  # grant clamped to credits
    assert fenced.all()            # acks returned within the same op => fence ok
    # only the first 3 slots committed on tile 0
    np.testing.assert_array_equal(mem[0, :5], [1, 1, 1, 0, 0])


def test_frozen_endpoint_sends_nothing(mesh2x4):
    def f(_):
        state = ep.freeze(ep.make_endpoint(MEM, max_out_credits=8))
        pkts = pgas.PacketBatch(
            addr=jnp.zeros((T, 1), jnp.int32),
            data=jnp.ones((T, 1), jnp.float32),
            mask=jnp.ones((T, 1), bool),
        )
        state, sent = ep.master_store(state, pkts, "x", "y")
        return sent.sum()[None], state.mem[None]

    sent, mem = _sm(mesh2x4, f, jnp.zeros((T, 1)),
                    in_specs=P(("y", "x"), None),
                    out_specs=(P(("y", "x")), P(("y", "x"), None)))
    assert (np.asarray(sent) == 0).all()
    np.testing.assert_array_equal(np.asarray(mem), 0)
