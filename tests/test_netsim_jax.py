"""Differential validation of the JAX mesh simulator against the numpy
oracle, plus traffic-library properties — driven through the
backend-agnostic :class:`repro.mesh.Simulator` facade.

The contract is *cycle-exact* equivalence: same delivered memory, same
completion counts, same per-cycle completion trace, same credit state,
same drain cycle, and a bit-identical :class:`repro.mesh.Telemetry`
record — for every traffic pattern and several mesh shapes.
"""
import numpy as np
import pytest

from repro.core.netsim import MeshSim, NetConfig, OP_LOAD, unloaded_rtt
from repro.mesh import MeshConfig, PATTERNS, Simulator, make_traffic
from repro.netsim_jax.testing import assert_state_equal as _assert_state_equal

MESHES = [(2, 2), (4, 4), (3, 5)]          # (nx, ny); incl. non-square


def _pair(cfg: MeshConfig, entries):
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax")
    b.attach(entries)
    return a, b


@pytest.mark.parametrize("pattern", sorted(PATTERNS))
@pytest.mark.parametrize("nx,ny", MESHES)
def test_parity_fixed_horizon(pattern, nx, ny):
    """Cycle-for-cycle equality over a fixed horizon, all six patterns —
    including the unified Telemetry record, field for field."""
    if pattern == "transpose" and nx != ny:
        pytest.skip("transpose is undefined on non-square meshes")
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=6)
    entries = make_traffic(pattern, nx, ny, 8, rate=0.7, seed=11)
    a, b = _pair(cfg, entries)
    a.run(120)
    b.run(120)
    _assert_state_equal(a, b)
    a.telemetry().assert_bit_identical(b.telemetry())
    assert a.telemetry() == b.telemetry()


@pytest.mark.parametrize("pattern", ["uniform", "transpose", "hotspot"])
@pytest.mark.parametrize("nx,ny", MESHES)
def test_parity_drain_cycle(pattern, nx, ny):
    """The global fence closes on exactly the same cycle."""
    if pattern == "transpose" and nx != ny:
        pytest.skip("transpose is undefined on non-square meshes")
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=4)
    entries = make_traffic(pattern, nx, ny, 6, seed=3)
    a, b = _pair(cfg, entries)
    ca = a.run_until_drained()
    cb = b.run_until_drained()
    assert ca == cb
    _assert_state_equal(a, b)
    assert int(a.completed.sum()) == nx * ny * 6


def test_parity_loads_and_cas():
    """Loads and CAS (not just the stores the patterns default to)."""
    nx = ny = 4
    cfg = MeshConfig(nx=nx, ny=ny)
    entries = make_traffic("uniform", nx, ny, 6, op=OP_LOAD, seed=7)
    # sprinkle CAS on the first entry of every tile
    from repro.core.netsim import OP_CAS
    entries["op"][..., 0] = OP_CAS
    entries["cmp"][..., 0] = 0
    a, b = _pair(cfg, entries)
    a.run_until_drained()
    b.run_until_drained()
    _assert_state_equal(a, b)


def test_parity_under_backpressure():
    """Tiny FIFOs + few credits: heavy contention, stalls, HoL blocking."""
    cfg = MeshConfig(nx=4, ny=4, router_fifo=2, ep_fifo=2, max_out_credits=2)
    entries = make_traffic("hotspot", 4, 4, 10, fraction=0.9, seed=1)
    a, b = _pair(cfg, entries)
    a.run(300)
    b.run(300)
    _assert_state_equal(a, b)


def test_parity_resp_latency_2():
    cfg = MeshConfig(nx=3, ny=3, resp_latency=2)
    entries = make_traffic("tornado", 3, 3, 5, seed=2)
    a, b = _pair(cfg, entries)
    a.run(100)
    b.run(100)
    _assert_state_equal(a, b)


@pytest.mark.parametrize("hops", [0, 1, 3, 5])
def test_jax_unloaded_rtt_formula(hops):
    """Analytic check on the JAX path alone: RTT = 2*hops + 5."""
    nx = max(hops + 1, 2)
    sim = Simulator(MeshConfig(nx=nx, ny=2), backend="jax")
    prog = make_traffic("neighbor", nx, 2, 1, op=OP_LOAD)
    prog["op"][:] = -1
    prog["op"][0, 0, 0] = OP_LOAD
    prog["dst_x"][0, 0, 0] = hops
    prog["dst_y"][0, 0, 0] = 0
    sim.attach(prog)
    sim.run(unloaded_rtt(hops) + 5)
    assert int(sim.completed[0, 0]) == 1
    assert int(sim.lat_sum[0, 0]) == unloaded_rtt(hops)


def test_vmap_credit_sweep_matches_sequential():
    """A vmapped credit sweep equals per-value sequential runs (and the
    oracle), demonstrating the no-recompile sweep path under the facade's
    functional layer."""
    import jax
    import jax.numpy as jnp
    from repro.netsim_jax import init_state, load_program, simulate

    scfg = MeshConfig(nx=5, ny=1, max_out_credits=16).to_sim()
    entries = make_traffic("neighbor", 5, 1, 30)
    prog = load_program(entries)
    credits = jnp.array([1, 2, 4, 8])
    states = jax.vmap(lambda c: init_state(scfg, max_credits=c))(credits)
    finals, per = jax.vmap(lambda s: simulate(scfg, prog, s, 200))(states)
    for i, c in enumerate([1, 2, 4, 8]):
        m = MeshSim(NetConfig(nx=5, ny=1, max_out_credits=c))
        m.load_program({k: v.copy() for k, v in entries.items()})
        m.run(200)
        np.testing.assert_array_equal(m.completed,
                                      np.asarray(finals.completed[i]))
        assert m.completed_per_cycle == np.asarray(per[i]).tolist()


def test_facade_effective_overrides_match_folded_config():
    """Simulator(fifo_depth=, max_credits=) means the same thing on both
    backends: JAX keeps them as vmappable state, numpy folds them into
    the config — dynamics identical."""
    cfg = MeshConfig(nx=3, ny=3, router_fifo=4, max_out_credits=8)
    entries = make_traffic("uniform", 3, 3, 6, seed=5)
    a = Simulator(cfg, backend="numpy", fifo_depth=2, max_credits=3)
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax", fifo_depth=2, max_credits=3)
    b.attach(entries)
    assert a.run_until_drained() == b.run_until_drained()
    _assert_state_equal(a, b)


# ----------------------------------------------------------------------
# traffic-library properties
# ----------------------------------------------------------------------
@pytest.mark.parametrize("pattern", sorted(PATTERNS))
def test_traffic_destinations_in_range(pattern):
    # non-square mesh catches x/y mixups; transpose requires square
    nx, ny = (4, 4) if pattern == "transpose" else (6, 3)
    L = 9
    prog = make_traffic(pattern, nx, ny, L, seed=4)
    assert prog["op"].shape == (ny, nx, L)
    assert (prog["dst_x"] >= 0).all() and (prog["dst_x"] < nx).all()
    assert (prog["dst_y"] >= 0).all() and (prog["dst_y"] < ny).all()


def test_traffic_uniform_never_self():
    nx, ny, L = 4, 4, 50
    prog = make_traffic("uniform", nx, ny, L, seed=9)
    ys, xs = np.mgrid[0:ny, 0:nx]
    self_hit = (prog["dst_x"] == xs[..., None]) & (prog["dst_y"] == ys[..., None])
    assert not self_hit.any()


def test_traffic_rate_pacing():
    prog = make_traffic("transpose", 4, 4, 10, rate=0.25)
    np.testing.assert_array_equal(prog["not_before"][0, 0],
                                  np.arange(10) * 4)
    full = make_traffic("transpose", 4, 4, 10, rate=1.0)
    assert (full["not_before"] == np.arange(10)).all()


def test_traffic_bit_complement_crosses_bisection():
    prog = make_traffic("bit_complement", 8, 8, 1)
    # west-half sources all target the east half and vice versa
    assert (prog["dst_x"][:, :4, 0] >= 4).all()
    assert (prog["dst_x"][:, 4:, 0] < 4).all()


def test_traffic_unknown_pattern_raises():
    with pytest.raises(ValueError, match="unknown pattern"):
        make_traffic("nope", 4, 4, 1)


@pytest.mark.parametrize("rate", [0.0, -0.5, 1.5, 2.0])
def test_traffic_invalid_rate_raises(rate):
    with pytest.raises(ValueError, match="rate must be in"):
        make_traffic("uniform", 4, 4, 4, rate=rate)


def test_traffic_transpose_non_square_raises():
    with pytest.raises(ValueError, match="non-square"):
        make_traffic("transpose", 3, 5, 4)
    # square meshes still work and are an exact involution
    prog = make_traffic("transpose", 4, 4, 2)
    ys, xs = np.mgrid[0:4, 0:4]
    assert (prog["dst_x"] == ys[..., None]).all()
    assert (prog["dst_y"] == xs[..., None]).all()


@pytest.mark.parametrize("fraction", [0.0, -0.1, 1.5])
def test_traffic_hotspot_invalid_fraction_raises(fraction):
    with pytest.raises(ValueError, match="fraction must be in"):
        make_traffic("hotspot", 4, 4, 4, fraction=fraction)


@pytest.mark.parametrize("spot", [(-1, 0), (4, 0), (0, 4), (7, 7)])
def test_traffic_hotspot_spot_outside_mesh_raises(spot):
    with pytest.raises(ValueError, match="inside the"):
        make_traffic("hotspot", 4, 4, 4, spot=spot)


def test_traffic_hotspot_valid_params():
    prog = make_traffic("hotspot", 4, 4, 32, spot=(3, 1), fraction=1.0,
                        seed=2)
    assert (prog["dst_x"] == 3).all() and (prog["dst_y"] == 1).all()
    # boundary spot coordinates are accepted
    make_traffic("hotspot", 4, 4, 2, spot=(0, 0))
    make_traffic("hotspot", 4, 4, 2, spot=(3, 3))
