"""Token queue semantics (paper C6): credit-bounded producer/consumer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import token_queue as tq


def test_send_recv_fifo_order():
    q = tq.tq_make(4, (2,))
    for i in range(3):
        q = tq.tq_send(q, jnp.full((2,), float(i)))
    out = []
    for _ in range(3):
        q, item, ok = tq.tq_recv(q)
        assert bool(ok)
        out.append(float(item[0]))
    assert out == [0.0, 1.0, 2.0]
    q, _, ok = tq.tq_recv(q)
    assert not bool(ok)  # empty


def test_tokens_bound_inflight():
    q = tq.tq_make(2, ())
    q = tq.tq_send(q, jnp.asarray(1.0))
    q = tq.tq_send(q, jnp.asarray(2.0))
    assert int(q.tokens) == 0
    q2 = tq.tq_send(q, jnp.asarray(3.0))  # masked no-op: out of tokens
    assert int(q2.count) == 2
    q2, item, ok = tq.tq_recv(q2)
    assert bool(ok) and float(item) == 1.0
    assert int(q2.tokens) == 1  # token returned on dequeue


def test_wraparound():
    q = tq.tq_make(2, ())
    for v in [1.0, 2.0]:
        q = tq.tq_send(q, jnp.asarray(v))
    q, a, _ = tq.tq_recv(q)
    q = tq.tq_send(q, jnp.asarray(3.0))
    q, b, _ = tq.tq_recv(q)
    q, c, _ = tq.tq_recv(q)
    assert [float(a), float(b), float(c)] == [1.0, 2.0, 3.0]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=40), st.integers(1, 6))
def test_property_never_overflows_or_underflows(ops, depth):
    """Random interleaving of sends/recvs: occupancy stays within [0, depth],
    tokens + count == depth always (credit conservation), and data is FIFO."""
    q = tq.tq_make(depth, ())
    sent, got = [], []
    counter = 0
    for is_send in ops:
        if is_send:
            before = int(q.count)
            q = tq.tq_send(q, jnp.asarray(float(counter)))
            if int(q.count) > before:
                sent.append(float(counter))
            counter += 1
        else:
            q, item, ok = tq.tq_recv(q)
            if bool(ok):
                got.append(float(item))
        assert 0 <= int(q.count) <= depth
        assert int(q.tokens) + int(q.count) == depth
    assert got == sent[:len(got)]


def test_distributed_channel_ring(mesh2x4):
    """channel_send moves payload one hop along x; channel_recv returns the
    credit the other way — a full loop is the identity."""
    T = 8
    data = jnp.arange(T, dtype=jnp.float32).reshape(T, 1)

    def f(local):
        fwd = tq.channel_send(local, "x")
        back = tq.channel_recv(fwd, "x")
        return fwd, back

    fwd, back = jax.jit(shard_map(
        f, mesh=mesh2x4, in_specs=P(("y", "x")),
        out_specs=(P(("y", "x")), P(("y", "x")))))(data)
    fwd = np.asarray(fwd).reshape(2, 4)
    want = np.arange(T, dtype=np.float32).reshape(2, 4)
    np.testing.assert_array_equal(fwd, np.roll(want, 1, axis=1))
    np.testing.assert_array_equal(np.asarray(back).reshape(2, 4), want)
