"""Simulation-service contract tests.

The load-bearing claim is *bit-identity*: whatever the service does to
amortize cost — pow2 program padding, lane-replication width padding,
per-fence-block chunked execution, a separately jitted vmapped reduce —
every :class:`PhaseStats` field of a service response equals the direct
one-shot :func:`repro.netsim_jax.measure.phased_stats` run exactly.
Plus the service mechanics: bucketing/compile accounting, streamed
chunk-concatenation consistency, cold-vs-warm compile counts,
bounded-queue backpressure, and the async surface.
"""
import asyncio

import numpy as np
import pytest

from repro.mesh.config import MeshConfig
from repro.netsim_jax.measure import (load_latency_sweep, phased_stats,
                                      _as_simconfig)
from repro.netsim_jax.sim import init_state, load_program
from repro.netsim_jax.traffic import make_traffic
from repro.sim_service import (ServiceOverloaded, SimRequest, SimServer,
                               SimService, SweepRequest,
                               clear_service_cache)

PHASES = dict(warmup=50, measure=100, drain=100, check_every=50)
HORIZON = 250


def _direct_stats(req: SimRequest):
    """The ground truth: one-shot phased_stats on the request's exact
    program and dynamic buffer knobs, no service in the loop."""
    cfg = _as_simconfig(req.cfg)
    if req.entries is not None:
        prog = load_program(dict(req.entries))
    else:
        length = int(np.ceil(req.load * req.horizon)) + 1
        prog = load_program(make_traffic(
            req.pattern, req.cfg.nx, req.cfg.ny, length, rate=req.load,
            seed=req.seed, topology=req.cfg.topology))
    d, c = req.fifo_depth, req.max_credits
    return phased_stats(cfg, prog, init_state(cfg, d, c), req.warmup,
                        req.measure, req.drain, req.unroll, req.impl,
                        req.cycles_per_call)


def _assert_stats_equal(direct, served, ctx=""):
    for f in direct._fields:
        a, b = np.asarray(getattr(direct, f)), np.asarray(getattr(served, f))
        assert a.shape == b.shape and (a == b).all(), \
            f"{ctx}: PhaseStats.{f} differs: direct={a} served={b}"


def test_batched_service_bit_identical_to_direct_runs():
    """Heterogeneous same-shape batch (different seeds AND different
    dynamic fifo/credit knobs in one vmapped call) == sequential direct
    runs, every field, every lane."""
    svc = SimService(max_batch=8)
    cfg = MeshConfig(nx=4, ny=4, router_fifo=8, max_out_credits=32)
    reqs = [SimRequest(cfg=cfg, pattern="uniform", load=0.3, seed=s,
                       fifo_depth=d, max_credits=c, **PHASES)
            for s, d, c in [(0, None, None), (1, 2, 8), (2, 8, 32),
                            (3, 4, 16)]]
    tickets = [svc.submit(r) for r in reqs]
    svc.server.run_until_idle()
    # same bucket -> ONE batch, every request in the same vmapped call
    assert svc.metrics.batches == 1
    for r, t in zip(reqs, tickets):
        _assert_stats_equal(_direct_stats(r), t.response.stats,
                            f"seed={r.seed} fifo={r.fifo_depth}")
        assert t.response.metrics["batch_lanes"] == len(reqs)


def test_mixed_shapes_bucket_and_compile_counts():
    """Distinct compiled shapes (mesh size / padded program length /
    cadence) land in distinct buckets; same-shape requests share one."""
    clear_service_cache()
    svc = SimService(max_batch=8)
    reqs = (
        # 2x same shape -> 1 bucket (pow2 program padding merges them)
        [SimRequest(cfg=MeshConfig(nx=4, ny=4), load=0.3, seed=s, **PHASES)
         for s in (0, 1)]
        # different mesh -> new bucket
        + [SimRequest(cfg=MeshConfig(nx=4, ny=2), load=0.3, **PHASES)]
        # different cadence -> new bucket (different block schedule)
        + [SimRequest(cfg=MeshConfig(nx=4, ny=4), load=0.3, warmup=50,
                      measure=100, drain=100, check_every=125)])
    tickets = [svc.submit(r) for r in reqs]
    svc.server.run_until_idle()
    assert svc.metrics.batches == 3
    # bucket 1 (width 2): one 50-cycle block shape; bucket 2: same cycles
    # but a new cfg; bucket 3 (width 1): blocks never cross a phase
    # boundary, so ce=125 yields 50- and 100-cycle blocks — two fresh
    # shapes (width 1 differs from bucket 1's width 2) -> 4 total
    assert svc.metrics.sim_compiles == 4
    for r, t in zip(reqs, tickets):
        _assert_stats_equal(_direct_stats(r), t.response.stats)


def test_streamed_chunks_concatenate_to_final_stats():
    """Chunk deltas are exact: summed counters/histograms reproduce the
    response totals, cover the full horizon, and phase labels follow the
    warmup/measure/drain schedule."""
    svc = SimService()
    req = SimRequest(cfg=MeshConfig(nx=4, ny=4), load=0.3, **PHASES)
    chunks = []
    gen = svc.stream(req)
    while True:
        try:
            chunks.append(next(gen))
        except StopIteration as stop:
            resp = stop.value
            break
    assert [c.chunk.phase for c in chunks] == \
        ["warmup", "measure", "measure", "drain", "drain"]
    assert chunks[0].chunk.start == 0 and chunks[-1].chunk.stop == HORIZON
    assert all(a.chunk.stop == b.chunk.start
               for a, b in zip(chunks, chunks[1:]))
    hist = np.asarray(resp.stats.hist)
    assert sum(c.chunk.delivered for c in chunks) == int(hist.sum())
    assert (sum(c.chunk.hist for c in chunks) == hist).all()
    # measure-window injected counts match the offered rate the reduce saw
    inj_meas = sum(c.chunk.injected for c in chunks
                   if c.chunk.phase == "measure")
    assert inj_meas == round(float(resp.stats.offered) * 16 * 100)


def test_cold_vs_warm_service_compile_counts():
    """A second service instance in the same process re-serves a seen
    shape with ZERO fresh executables (sim and aux), and identical
    results."""
    clear_service_cache()
    req = SimRequest(cfg=MeshConfig(nx=4, ny=4), load=0.25, **PHASES)
    cold = SimService(max_batch=4)
    r_cold = cold.run_one(req)
    assert cold.metrics.sim_compiles == 1
    assert cold.metrics.aux_compiles == 2   # init + reduce
    warm = SimService(max_batch=4)
    r_warm = warm.run_one(req)
    assert warm.metrics.sim_compiles == 0
    assert warm.metrics.aux_compiles == 0
    assert r_warm.metrics["new_sim_compiles"] == 0
    _assert_stats_equal(r_cold.stats, r_warm.stats, "cold vs warm")


def test_bounded_queue_backpressure():
    """submit() past queue_limit raises ServiceOverloaded (and counts the
    rejection); draining the queue re-opens admission."""
    svc = SimService(queue_limit=3)
    req = SimRequest(cfg=MeshConfig(nx=4, ny=4), load=0.3, **PHASES)
    for _ in range(3):
        svc.submit(req)
    with pytest.raises(ServiceOverloaded):
        svc.submit(req)
    assert svc.metrics.rejected == 1
    assert svc.metrics.peak_pending == 3
    svc.server.run_until_idle()
    ticket = svc.submit(req)           # space again once drained
    svc.server.run_until_idle()
    assert ticket.done


def test_sweep_request_matches_load_latency_sweep():
    """A service-side sweep == the library's load_latency_sweep, field by
    field per rate, and the curve summary agrees on the knee."""
    rates = (0.05, 0.2, 0.4, 0.6)
    cfg = MeshConfig(nx=4, ny=4, router_fifo=16, max_out_credits=128)
    svc = SimService(max_batch=4)
    resp = svc.run_one(SweepRequest(cfg=cfg, rates=rates, **PHASES))
    # the whole curve is one bucket -> one batch
    assert svc.metrics.batches == 1
    direct = load_latency_sweep("uniform", 4, 4, rates, cfg=cfg,
                                warmup=50, measure=100, drain=100)
    for i in range(len(rates)):
        for f in resp.stats[0]._fields:
            a = np.asarray(direct[f])[i]
            b = np.asarray(getattr(resp.stats[i], f))
            assert (a == b).all(), f"rate={rates[i]} field={f}"
    assert resp.curve["saturation_index"] == direct["saturation_index"]
    assert resp.curve["zero_load_latency"] == direct["zero_load_latency"]
    assert resp.curve["monotone"] == direct["monotone"]


def test_async_server_streams_and_resolves():
    """The asyncio surface: serve() + Ticket.stream()/result() deliver
    the same chunks and stats as the sync facade."""
    req = SimRequest(cfg=MeshConfig(nx=4, ny=4), load=0.3, **PHASES)

    async def scenario():
        server = SimServer(max_batch=4)
        t1, t2 = server.submit(req), server.submit(req)
        serve = asyncio.ensure_future(server.serve(until_idle=True))

        async def consume(t):
            return [c async for c in t.stream()], await t.result()
        (c1, r1), (c2, r2) = await asyncio.gather(consume(t1), consume(t2))
        await serve
        return server, c1, r1, c2, r2

    server, c1, r1, c2, r2 = asyncio.run(scenario())
    assert server.metrics.batches == 1      # both rode one vmapped batch
    assert len(c1) == len(c2) == 5
    _assert_stats_equal(r1.stats, r2.stats, "identical async twins")
    _assert_stats_equal(_direct_stats(req), r1.stats, "async vs direct")
