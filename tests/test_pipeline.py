"""Pipeline parallelism via token-queue channels (paper C6)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.pipeline import bubble_fraction, pipeline_apply


def _body(lp, x):
    """Per-stage compute: scan this stage's layer slice."""
    def one(h, w):
        return jnp.tanh(h @ w), None
    y, _ = jax.lax.scan(one, x, lp)
    return y


def _reference(w_all, x):
    def one(h, w):
        return jnp.tanh(h @ w), None
    y, _ = jax.lax.scan(one, x, w_all)
    return y


@pytest.fixture
def setup(mesh_dm):
    rng = np.random.default_rng(0)
    L, D = 8, 16            # 8 layers over 4 stages = 2 layers/stage
    n_micro, mb = 6, 4
    w = jnp.asarray(rng.standard_normal((L, D, D)) * 0.3, dtype=jnp.float32)
    x = jnp.asarray(rng.standard_normal((n_micro, mb, D)), dtype=jnp.float32)
    return mesh_dm, w, x


def test_pipeline_matches_sequential(setup):
    mesh, w, x = setup
    n_stages = mesh.shape["model"]
    w_staged = w.reshape(n_stages, -1, *w.shape[1:])  # (S, L/S, D, D)
    got = pipeline_apply(_body, w_staged, x, mesh, stage_axis="model",
                         batch_axis="data")
    want = jax.vmap(lambda xm: _reference(w, xm))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_pipeline_grads_flow(setup):
    mesh, w, x = setup
    n_stages = mesh.shape["model"]
    w_staged = w.reshape(n_stages, -1, *w.shape[1:])

    def loss(ws, xm):
        return pipeline_apply(_body, ws, xm, mesh, stage_axis="model",
                              batch_axis="data").sum()

    def loss_ref(wf, xm):
        return jax.vmap(lambda m: _reference(wf, m))(xm).sum()

    g = jax.grad(loss)(w_staged, x).reshape(w.shape)
    g_ref = jax.grad(loss_ref)(w, x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(12, 4) == pytest.approx(3 / 15)
    assert bubble_fraction(100, 2) < 0.01


def test_inflight_bound_is_stage_count():
    """The schedule keeps at most n_stages microbatches in flight — the
    token-queue depth = BDP rule (C3/C6)."""
    # structural property of the rotating schedule: microbatch m enters at
    # tick m and leaves at tick m + S - 1 -> in flight at tick t are the
    # microbatches in (t - S, t] — at most S of them.
    S = 4
    for t in range(20):
        inflight = [m for m in range(16) if m <= t < m + S]
        assert len(inflight) <= S
