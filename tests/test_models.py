"""Model-level correctness: decode==forward, attention impl equivalence,
MoE dispatch-mode equivalence, distributed decode attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.models import attention as attn
from repro.models import get_model
from repro.models import moe as moe_mod
from repro.models import mamba2
from repro.parallel.sharding import Rules
from repro.kernels import ref as kref

DECODE_ARCHS = ["qwen2-72b", "yi-34b", "stablelm-3b", "moonshot-v1-16b-a3b",
                "mixtral-8x7b", "mamba2-370m", "jamba-v0.1-52b",
                "whisper-large-v3", "qwen2-vl-72b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Step-by-step decode must reproduce teacher-forced forward logits."""
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    M = get_model(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encdec.encoder_seq, cfg.d_model)) * 0.1
        kw["frames"] = frames
    if cfg.family == "vlm":
        kw["positions"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                           (3, B, S))
    logits, _ = jax.jit(lambda p, t: M.forward(p, t, cfg, **kw))(params, tokens)

    if cfg.family == "audio":
        enc_out = M.encode(params, kw["frames"], cfg)
        cache = M.init_cache(cfg, B, S, enc_out=enc_out, params=params)
    else:
        cache = M.init_cache(cfg, B, S)
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    outs = []
    for i in range(S):
        lg, cache = step(params, cache, tokens[:, i])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_decode_wraps():
    """Mixtral-style SWA: a wrapped window cache must agree with forward
    logits at the final position (the only position both paths share once
    the window binds)."""
    cfg = reduced_config(get_config("mixtral-8x7b"), sliding_window=6)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    M = get_model(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits, _ = jax.jit(lambda p, t: M.forward(p, t, cfg))(params, tokens)
    cache = M.init_cache(cfg, B, S)  # allocates only window slots
    assert cache["k"].shape[2] == 6
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    for i in range(S):
        lg, cache = step(params, cache, tokens[:, i])
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_equals_reference():
    B, S, H, hd = 2, 50, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    for window in [None, 7]:
        a = attn.chunked_attention(q, k, v, causal=True, window=window,
                                   chunk=16)
        b = attn.reference_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_flash_impl_equals_reference_in_model_layout():
    B, S, H, hd = 1, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    a = attn.attention(q, k, v, impl="flash", causal=True)
    b = attn.attention(q, k, v, impl="ref", causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_distributed_decode_attention_matches_local(mesh_dm):
    """Seq-sharded KV decode (paper C7) == single-device decode."""
    rules = Rules(mesh=mesh_dm, batch="data", kv_seq="model")
    B, S, H, K, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, S, K, hd))
    vc = jax.random.normal(ks[2], (B, S, K, hd))
    lens = jnp.array([20, 32], jnp.int32)
    got = jax.jit(lambda *a: attn.decode_attention(rules, *a))(q, kc, vc, lens)
    want = attn._local_decode(q, kc, vc, lens, 0, None)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_distributed_decode_attention_multiaxis(mesh_dm):
    """kv_seq spanning BOTH mesh axes (the long_500k layout)."""
    rules = Rules(mesh=mesh_dm, batch=None, kv_seq=("data", "model"))
    B, S, H, K, hd = 1, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, S, K, hd))
    vc = jax.random.normal(ks[2], (B, S, K, hd))
    lens = jnp.array([50], jnp.int32)
    got = jax.jit(lambda *a: attn.decode_attention(rules, *a))(q, kc, vc, lens)
    want = attn._local_decode(q, kc, vc, lens, 0, None)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch modes
# ---------------------------------------------------------------------------

def _moe_setup(E=4, k=2, D=16, Fe=32, T=24, cf=8.0):
    from repro.configs.base import MoEConfig, ModelConfig
    cfg = dataclasses.replace(
        reduced_config(get_config("moonshot-v1-16b-a3b")),
        d_model=D,
        moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=Fe,
                      capacity_factor=cf))
    key = jax.random.PRNGKey(0)
    params = moe_mod.init_moe(key, cfg, cfg.moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T // 2, D)) * 0.3
    return cfg, params, x


def test_moe_tp_equals_dense_computation():
    """With capacity ample, the MoE block must equal the explicit per-token
    top-k mixture computed densely."""
    cfg, params, x = _moe_setup()
    out, _aux = moe_mod.moe_block(x, params, cfg, None)
    # dense oracle
    x2d = x.reshape(-1, x.shape[-1])
    idx, w, _ = moe_mod.router_topk(x2d, params["router"], cfg.moe.top_k)
    def expert(e, t):
        h = x2d[t]
        g = jax.nn.silu((h @ params["w_gate"][e]).astype(jnp.float32))
        u = h @ params["w_up"][e]
        return (g * u) @ params["w_down"][e]
    want = jnp.zeros_like(x2d)
    for t in range(x2d.shape[0]):
        acc = sum(w[t, j] * expert(int(idx[t, j]), t)
                  for j in range(cfg.moe.top_k))
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, x.shape[-1])),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_moe_ep_equals_tp(mesh_dm):
    """EP over the model axis must equal the local TP computation."""
    cfg, params, x = _moe_setup(E=4)
    rules_tp = Rules(mesh=mesh_dm, batch="data", dispatch="tp")
    rules_ep = Rules(mesh=mesh_dm, batch="data", dispatch="ep")
    out_tp, _ = jax.jit(lambda x, p: moe_mod.moe_block(x, p, cfg, rules_tp))(x, params)
    out_ep, _ = jax.jit(lambda x, p: moe_mod.moe_block(x, p, cfg, rules_ep))(x, params)
    np.testing.assert_allclose(np.asarray(out_tp), np.asarray(out_ep),
                               rtol=1e-4, atol=1e-4)


def test_moe_xy_dispatch_matches_local(mesh_dm):
    """The paper-faithful two-phase (dimension-ordered) dispatch must equal
    the local computation when capacities are ample."""
    cfg, params, x = _moe_setup(E=4, T=32)
    out_ref, _ = moe_mod.moe_block(x, params, cfg, None)
    rules = Rules(mesh=mesh_dm, batch="data", seq="model", dispatch="xy")
    # x: (B, S, D) with S sharded over model
    out_xy, _ = jax.jit(lambda x, p: moe_mod.moe_block(x, p, cfg, rules))(x, params)
    np.testing.assert_allclose(np.asarray(out_xy), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """ssd_chunked must not depend on the chunk size (inter-chunk carry)."""
    b, s, h, p, g, n = 1, 48, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.2
    B = jax.random.normal(ks[2], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    A = -jnp.abs(jax.random.normal(ks[4], (h,)))
    y8 = mamba2.ssd_chunked(x, dt, B, C, A, chunk=8)
    y48 = mamba2.ssd_chunked(x, dt, B, C, A, chunk=48)
    yref = kref.ssd_scan_ref(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                             B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3),
                             A).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(yref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y48), np.asarray(yref),
                               rtol=1e-5, atol=1e-5)
