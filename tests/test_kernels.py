"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention_op, grouped_matmul, ref,
                           ssd_scan_op)

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    # (B, S, H, K, hd)
    (1, 64, 2, 2, 128),    # aligned, MHA
    (2, 96, 4, 2, 48),     # padded seq + padded hd + GQA
    (1, 128, 8, 1, 64),    # MQA
    (1, 300, 4, 4, 80),    # stablelm-like hd=80
    (2, 48, 4, 2, 128),    # seq < block
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, dtype, causal):
    B, S, H, K, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention_op(q, k, v, causal, None, 64, 64)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_sliding_window():
    B, S, H, K, hd = 1, 128, 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention_op(q, k, v, True, 32, 32, 32)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_matches_ref_grad():
    B, S, H, K, hd = 1, 64, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))

    def loss_kernel(q, k, v):
        return (flash_attention_op(q, k, v, True, None) ** 2).sum()

    def loss_ref(q, k, v):
        o = ref.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                    k.transpose(0, 2, 1, 3),
                                    v.transpose(0, 2, 1, 3), causal=True)
        return (o.transpose(0, 2, 1, 3) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, g, n, chunk)
    (1, 64, 2, 16, 1, 16, 16),
    (2, 96, 4, 16, 2, 24, 32),    # padded seq, grouped B/C
    (1, 128, 2, 64, 1, 128, 64),  # mamba2-370m-like head
    (1, 33, 2, 8, 1, 8, 16),      # ragged seq
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(shape, dtype):
    b, s, h, p, g, n, chunk = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 5)
    x = (jax.random.normal(ks[0], (b, s, h, p)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1).astype(dtype)
    B = (jax.random.normal(ks[2], (b, s, g, n)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[3], (b, s, g, n)) * 0.5).astype(dtype)
    A = -jnp.abs(jax.random.normal(ks[4], (h,)))
    y = ssd_scan_op(x, dt, B, C, A, chunk)
    want = ref.ssd_scan_ref(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                            B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3),
                            A).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ssd_scan_state_continuity():
    """The carried VMEM state must make chunked == unchunked."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    B = jax.random.normal(ks[2], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    A = -jnp.abs(jax.random.normal(ks[4], (h,)))
    y_16 = ssd_scan_op(x, dt, B, C, A, 16)
    y_64 = ssd_scan_op(x, dt, B, C, A, 64)
    np.testing.assert_allclose(np.asarray(y_16), np.asarray(y_64),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

GMM_SHAPES = [
    (1, 8, 16, 8), (3, 24, 40, 56), (4, 128, 128, 128), (2, 130, 257, 64),
]


@pytest.mark.parametrize("shape", GMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(shape, dtype):
    e, m, k, n = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 2)
    lhs = jax.random.normal(ks[0], (e, m, k), dtype)
    rhs = jax.random.normal(ks[1], (e, k, n), dtype)
    out = grouped_matmul(lhs, rhs, impl="pallas")
    want = ref.grouped_matmul_ref(lhs, rhs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_gmm_grad_exact():
    e, m, k, n = 2, 16, 24, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    lhs = jax.random.normal(ks[0], (e, m, k))
    rhs = jax.random.normal(ks[1], (e, k, n))

    def f_pal(a, b):
        return (grouped_matmul(a, b, impl="pallas") ** 2).sum()

    def f_ref(a, b):
        return (ref.grouped_matmul_ref(a, b) ** 2).sum()

    gp = jax.grad(f_pal, argnums=(0, 1))(lhs, rhs)
    gr = jax.grad(f_ref, argnums=(0, 1))(lhs, rhs)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
