"""XY dimension-ordered collectives vs flat references (paper C4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import make_auto_mesh, shard_map

from repro.core.routing import (a2a_phase_cost, allreduce_cost, shift,
                                xy_all_gather, xy_all_reduce,
                                xy_all_to_all, xy_reduce_scatter)

T = 8  # tiles in the 2x4 test mesh


def _run(mesh, fn, x, in_spec, out_spec):
    sm = shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec)
    return np.asarray(jax.jit(sm)(x))


def test_xy_all_to_all_matches_flat_transpose(mesh2x4):
    """Tile t's outgoing block b must land on tile b — i.e. the combined
    operation is the block transpose a flat all-to-all performs."""
    data = jnp.arange(T * T * 3, dtype=jnp.float32).reshape(T, T, 3)

    def f(local):  # local: (1, T, 3)
        return xy_all_to_all(local[0], "x", "y", split_axis=0)[None]

    res = _run(mesh2x4, f, data, P(("y", "x"), None, None), P(("y", "x"), None, None))
    np.testing.assert_array_equal(res, np.transpose(np.asarray(data), (1, 0, 2)))


def test_xy_all_to_all_nonzero_split_axis(mesh2x4):
    data = jnp.arange(2 * T * T, dtype=jnp.int32).reshape(T, 2, T)

    def f(local):
        return xy_all_to_all(local[0], "x", "y", split_axis=1)[None]

    res = _run(mesh2x4, f, data, P(("y", "x"), None, None), P(("y", "x"), None, None))
    np.testing.assert_array_equal(res, np.transpose(np.asarray(data), (2, 1, 0)))


def test_xy_all_to_all_multiple_blocks_per_tile(mesh2x4):
    # 2 blocks per destination tile: split dim = 16
    data = jnp.arange(T * 2 * T, dtype=jnp.int32).reshape(T, 2 * T)

    def f(local):
        return xy_all_to_all(local[0], "x", "y", split_axis=0)[None]

    res = _run(mesh2x4, f, data, P(("y", "x"), None), P(("y", "x"), None))
    # reference: flat all-to-all with 2-row blocks
    ref = np.asarray(data).reshape(T, T, 2).transpose(1, 0, 2).reshape(T, 2 * T)
    np.testing.assert_array_equal(res, ref)


def test_xy_all_reduce_equals_psum(mesh2x4):
    data = jnp.arange(T * 4, dtype=jnp.float32).reshape(T, 4)

    def f(local):
        return xy_all_reduce(local, "x", "y")

    res = _run(mesh2x4, f, data, P(("y", "x"), None), P(("y", "x"), None))
    np.testing.assert_allclose(res, np.broadcast_to(np.asarray(data).sum(0), (1, 4)).repeat(T, 0) / 1.0)


def test_xy_reduce_scatter_then_gather_is_allreduce(mesh2x4):
    data = jnp.arange(T * T * 2, dtype=jnp.float32).reshape(T, T * 2)

    def f(local):
        rs = xy_reduce_scatter(local[0], "x", "y", scatter_dim=0)
        return xy_all_gather(rs, "x", "y", gather_dim=0)[None]

    res = _run(mesh2x4, f, data, P(("y", "x"), None), P(("y", "x"), None))
    expect = np.broadcast_to(np.asarray(data).sum(0), (T, T * 2))
    # every tile holds the full reduced vector
    for t in range(T):
        np.testing.assert_allclose(res[t], expect[t % 1] if False else np.asarray(data).sum(0))


def test_shift_is_ring_permute(mesh2x4):
    data = jnp.arange(T, dtype=jnp.int32).reshape(T, 1)

    def f(local):
        return shift(local, "x", 1)

    res = _run(mesh2x4, f, data, P(("y", "x")), P(("y", "x")))
    # within each row of 4 tiles, values rotate by one
    got = res.reshape(2, 4)
    want = np.asarray(data).reshape(2, 4)
    np.testing.assert_array_equal(got, np.roll(want, 1, axis=1))


def test_cost_model_monotone_and_zero_for_singleton():
    assert a2a_phase_cost(1e6, 1, 50e9) == 0.0
    assert allreduce_cost(1e6, 1, 50e9) == 0.0
    c4 = a2a_phase_cost(1e6, 4, 50e9)
    c16 = a2a_phase_cost(1e6, 16, 50e9)
    assert 0 < c4 < c16
    r4 = allreduce_cost(1e6, 4, 50e9)
    r16 = allreduce_cost(1e6, 16, 50e9)
    assert 0 < r4 < r16 < 2 * 1e6 / 50e9  # bounded by 2B/links


def test_xy_a2a_rejects_bad_split():
    import jax
    mesh = make_auto_mesh((2, 4), ("y", "x"))

    def f(local):
        return xy_all_to_all(local[0], "x", "y", split_axis=0)[None]

    data = jnp.zeros((T, 7))  # 7 not divisible by 8
    with pytest.raises(ValueError):
        jax.jit(shard_map(f, mesh=mesh, in_specs=P(("y", "x"), None),
                          out_specs=P(("y", "x"), None))).lower(data)
