"""FSDP (ZeRO-3) strategy: banked params + equal semantics + less memory."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.launch import step as step_mod

SHAPE = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
OPT = optim.OptConfig(warmup_steps=2, total_steps=10)


def _param_bytes(cell):
    total = 0
    for k, sds in cell.args[0].items():
        local = cell.in_shardings[0][k].shard_shape(sds.shape)
        total += int(np.prod(local)) * sds.dtype.itemsize
    return total


def test_fsdp_banks_params(mesh_dm):
    cfg = reduced_config(get_config("qwen2-72b"))
    base = step_mod.build_cell(cfg, SHAPE, mesh_dm, "baseline", OPT)
    fsdp = step_mod.build_cell(cfg, SHAPE, mesh_dm, "fsdp", OPT)
    assert _param_bytes(fsdp) < _param_bytes(base)


@pytest.mark.xfail(
    tuple(map(int, jax.__version__.split(".")[:2])) < (0, 5),
    reason="ZeRO-3 banked params cross a partial-manual shard_map boundary; "
           "auto-mode resharding is unreliable on jax<0.5 and check_rep is "
           "off there (no lax.pcast); passes on jax>=0.6",
    strict=False)
def test_fsdp_compiles_and_matches(mesh_dm):
    cfg = dataclasses.replace(reduced_config(get_config("stablelm-3b")),
                              dtype="float32")
    from repro.models.api import get_model
    model = get_model(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (8, 33)).astype(np.int32)
    batch = {"tokens": jax.numpy.asarray(toks[:, :-1]),
             "labels": jax.numpy.asarray(toks[:, 1:]),
             "mask": jax.numpy.ones((8, 32), jax.numpy.float32)}
    losses = {}
    for strat in ("baseline", "fsdp"):
        cell = step_mod.build_cell(cfg, SHAPE, mesh_dm, strat, OPT)
        with mesh_dm:
            params = jax.jit(model.init_params, static_argnums=0,
                             out_shardings=cell.in_shardings[0])(
                cfg, jax.random.key(0))
            opt_state = jax.jit(optim.init,
                                out_shardings=cell.in_shardings[1])(params)
            _, _, m = cell.jitted()(params, opt_state, batch)
        losses[strat] = float(m["loss"])
    assert losses["fsdp"] == pytest.approx(losses["baseline"], rel=1e-5)