"""Cycle-level simulator vs the paper's published numbers (C9).

These tests ARE the paper-claims validation: every number asserted here is
stated in the paper text (see DESIGN.md §2).
"""
import numpy as np
import pytest

from repro.core.netsim import (MeshSim, NetConfig, OP_CAS, OP_LOAD, OP_STORE,
                               unloaded_rtt)


def _empty_prog(ny, nx, L):
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op", "not_before")}
    prog["op"][:] = -1
    return prog


def test_paper_fig3_seven_cycle_round_trip():
    """mesh_master_example.v: 'the delay of the first response is exactly 7
    clock cycles', then 1/cycle pipelined: cycles 7, 8, 9."""
    sim = MeshSim(NetConfig(nx=4, ny=4, record_log=True))
    prog = _empty_prog(4, 4, 3)
    sim.mem[0, 1, :3] = [0, 1, 2]
    for i in range(3):
        prog["op"][0, 0, i] = OP_LOAD
        prog["dst_x"][0, 0, i] = 1
        prog["addr"][0, 0, i] = i
    sim.load_program(prog)
    sim.run(40)
    cycles = [c for (c, *_rest) in sim.log]
    data = [d for (*_rest, d) in sim.log]
    assert cycles == [7, 8, 9], f"paper says 7,8,9; got {cycles}"
    assert data == [0, 1, 2]


@pytest.mark.parametrize("hops", [0, 1, 2, 3, 5])
def test_unloaded_rtt_formula(hops):
    """Each FIFO crossing adds one cycle (Fig. 3): RTT = 2*hops + 5."""
    nx = max(hops + 1, 2)
    sim = MeshSim(NetConfig(nx=nx, ny=2))
    prog = _empty_prog(2, nx, 1)
    prog["op"][0, 0, 0] = OP_LOAD
    prog["dst_x"][0, 0, 0] = hops
    sim.load_program(prog)
    sim.run(unloaded_rtt(hops) + 5)
    assert int(sim.completed[0, 0]) == 1
    assert int(sim.lat_sum[0, 0]) == unloaded_rtt(hops)


def test_store_commit_and_load_back():
    """Remote stores commit at the destination memory; a later load from a
    third tile observes them (PGAS semantics end to end)."""
    sim = MeshSim(NetConfig(nx=4, ny=4))
    prog = _empty_prog(4, 4, 2)
    # tile (0,0) stores 42 to tile (2,1) addr 5
    prog["op"][0, 0, 0] = OP_STORE
    prog["dst_x"][0, 0, 0] = 2
    prog["dst_y"][0, 0, 0] = 1
    prog["addr"][0, 0, 0] = 5
    prog["data"][0, 0, 0] = 42
    # tile (3,3) loads the same word later
    prog["op"][3, 3, 0] = OP_LOAD
    prog["dst_x"][3, 3, 0] = 2
    prog["dst_y"][3, 3, 0] = 1
    prog["addr"][3, 3, 0] = 5
    prog["not_before"][3, 3, 0] = 20  # after the store surely committed
    sim.load_program(prog)
    sim.run_until_drained()
    assert sim.mem[1, 2, 5] == 42
    # the observer's registered response carried 42 (log not enabled: check
    # via memory + completion counters)
    assert int(sim.completed[3, 3]) == 1


def test_point_to_point_ordering():
    """'load or store requests received at a destination node from a
    particular source node are committed in sequential order' — last write
    from the same source wins."""
    sim = MeshSim(NetConfig(nx=4, ny=2))
    L = 8
    prog = _empty_prog(2, 4, L)
    for i in range(L):
        prog["op"][0, 0, i] = OP_STORE
        prog["dst_x"][0, 0, i] = 3
        prog["addr"][0, 0, i] = 0
        prog["data"][0, 0, i] = i + 1
    sim.load_program(prog)
    sim.run_until_drained()
    assert sim.mem[0, 3, 0] == L  # the last store committed last


def test_fig5_cross_destination_reordering_possible():
    """Fig. 5: master 0 loads from a FAR slave then a NEAR slave; the near
    response returns first — out-of-order across destinations."""
    sim = MeshSim(NetConfig(nx=8, ny=2, record_log=True))
    prog = _empty_prog(2, 8, 2)
    # far load (7 hops), then near load (1 hop), issued back to back
    prog["op"][0, 0, 0] = OP_LOAD
    prog["dst_x"][0, 0, 0] = 7
    prog["data"][0, 0, 0] = 0
    prog["op"][0, 0, 1] = OP_LOAD
    prog["dst_x"][0, 0, 1] = 1
    sim.mem[0, 7, 0] = 111  # far tile's value
    sim.mem[0, 1, 0] = 222  # near tile's value
    sim.load_program(prog)
    sim.run_until_drained()
    returned = [d for (*_r, d) in sim.log]
    assert returned == [222, 111], f"near response must overtake far: {returned}"


def test_fence_drains_credits():
    """'wait until the credit counter is back to max_out_credits_p'."""
    cfg = NetConfig(nx=4, ny=4, max_out_credits=8)
    sim = MeshSim(cfg)
    prog = _empty_prog(4, 4, 6)
    for i in range(6):
        prog["op"][0, 0, i] = OP_STORE
        prog["dst_x"][0, 0, i] = 3
        prog["dst_y"][0, 0, i] = 3
        prog["addr"][0, 0, i] = i
        prog["data"][0, 0, i] = i
    sim.load_program(prog)
    # mid-flight the counter is below max
    sim.run(8)
    assert sim.credits[0, 0] < cfg.max_out_credits
    sim.run_until_drained()
    assert (sim.credits == cfg.max_out_credits).all()
    np.testing.assert_array_equal(sim.mem[3, 3, :6], np.arange(6))


def test_credit_limit_stalls_injection():
    """With 1 credit the master can have only one packet in flight: issue
    rate collapses to 1/RTT."""
    cfg = NetConfig(nx=4, ny=2, max_out_credits=1)
    sim = MeshSim(cfg)
    L = 4
    prog = _empty_prog(2, 4, L)
    for i in range(L):
        prog["op"][0, 0, i] = OP_STORE
        prog["dst_x"][0, 0, i] = 1
        prog["addr"][0, 0, i] = i
        prog["data"][0, 0, i] = 7
    sim.load_program(prog)
    cycles = sim.run_until_drained()
    # serialized: ~RTT per op instead of 1/cycle
    assert cycles >= L * unloaded_rtt(1) - 2
    assert sim.out_of_credit_cycles[0, 0] > 0


def test_bdp_credits_restore_line_rate():
    """Paper's sizing rule: credits >= BDP lets the master issue at line
    rate (drain time ~ L + RTT, far below the 1-credit serial time)."""
    L = 16
    t = {}
    for credits in (1, unloaded_rtt(1) + 1):
        sim = MeshSim(NetConfig(nx=4, ny=2, max_out_credits=credits))
        prog = _empty_prog(2, 4, L)
        for i in range(L):
            prog["op"][0, 0, i] = OP_STORE
            prog["dst_x"][0, 0, i] = 1
            prog["addr"][0, 0, i] = i
        sim.load_program(prog)
        t[credits] = sim.run_until_drained()
    assert t[unloaded_rtt(1) + 1] <= L + 2 * unloaded_rtt(1)
    assert t[1] > 3 * t[unloaded_rtt(1) + 1] / 2


def test_remote_cas_mutex_single_winner():
    """All tiles CAS the lock at (0,0); exactly one wins; the lock holds the
    winner's id (paper C8)."""
    nx = ny = 4
    sim = MeshSim(NetConfig(nx=nx, ny=ny, record_log=True))
    prog = _empty_prog(ny, nx, 1)
    for y in range(ny):
        for x in range(nx):
            prog["op"][y, x, 0] = OP_CAS
            prog["data"][y, x, 0] = y * nx + x + 1  # my id + 1
            prog["cmp"][y, x, 0] = 0
    sim.load_program(prog)
    sim.run_until_drained()
    winners = [(sy, sx) for (_c, sy, sx, op, _t, d) in sim.log
               if op == OP_CAS and d == 0]  # observed unlocked value
    assert len(winners) == 1
    wy, wx = winners[0]
    assert sim.mem[0, 0, 0] == wy * nx + wx + 1


def test_bisection_bound_paper_example():
    """'with 16 links crossing the bisection, only 32 remote operations can
    be sustained per cycle' — uniform cross-median traffic on the
    512-core array never exceeds the bound and lands in its vicinity."""
    nx, ny = 16, 32  # 512 tiles, cut across the short dimension: 16 links/dir
    rng = np.random.default_rng(0)
    L = 12
    prog = _empty_prog(ny, nx, L)
    prog["op"][:] = OP_STORE
    for y in range(ny):
        oy = rng.integers(ny // 2, ny, (nx, L)) if y < ny // 2 else \
             rng.integers(0, ny // 2, (nx, L))
        prog["dst_y"][y] = oy
        prog["dst_x"][y] = rng.integers(0, nx, (nx, L))
        prog["addr"][y] = rng.integers(0, 64, (nx, L))
    sim = MeshSim(NetConfig(nx=nx, ny=ny, max_out_credits=48))
    sim.load_program(prog)
    cycles = sim.run_until_drained(50000)
    thr = nx * ny * L / cycles
    bound = 32.0  # ops/cycle, from the paper
    assert thr <= bound + 1e-9, f"throughput {thr:.1f} exceeds bisection bound"
    assert thr > 0.35 * bound, f"throughput {thr:.1f} implausibly far below bound"


def test_line_rate_single_stream():
    """One-to-one neighbor stream sustains ~1 word/cycle (the paper's line
    rate for remote stores)."""
    L = 64
    sim = MeshSim(NetConfig(nx=2, ny=1, max_out_credits=64))
    prog = _empty_prog(1, 2, L)
    for i in range(L):
        prog["op"][0, 0, i] = OP_STORE
        prog["dst_x"][0, 0, i] = 1
        prog["addr"][0, 0, i] = i % 64
    sim.load_program(prog)
    cycles = sim.run_until_drained()
    # L stores complete in ~L + RTT cycles
    assert cycles <= L + unloaded_rtt(1) + 4
