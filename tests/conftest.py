"""Shared fixtures.

We give the test process 8 CPU devices (NOT the dry-run's 512 — that flag is
set only inside launch/dryrun.py) so shard_map / PGAS tests exercise a real
2x4 mesh while smoke tests still run comfortably on CPU.
"""
import os

# Must run before jax initializes its backend; conftest import is early
# enough as long as no test module imports jax at collection time before us.
import jax

try:  # set the device count before first backend use
    jax.config.update("jax_num_cpu_devices", 8)
except Exception:  # pragma: no cover - older jax fallback
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest  # noqa: E402

from repro.compat import make_auto_mesh  # noqa: E402


@pytest.fixture(scope="session")
def mesh2x4():
    """A (y=2, x=4) tile grid — 8 tiles, one per CPU device."""
    return make_auto_mesh((2, 4), ("y", "x"))


@pytest.fixture(scope="session")
def mesh_dm():
    """A (data=2, model=4) mesh in the production axis naming."""
    return make_auto_mesh((2, 4), ("data", "model"))
