"""Cell builder: reduced-config lower+compile for all three step kinds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.launch import step as step_mod

T_SHAPE = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
P_SHAPE = ShapeConfig("p", seq_len=32, global_batch=8, kind="prefill")
D_SHAPE = ShapeConfig("d", seq_len=64, global_batch=8, kind="decode")

OPT = optim.OptConfig(warmup_steps=2, total_steps=10)


def _cfg(name, **kw):
    return reduced_config(get_config(name), **kw)


@pytest.mark.parametrize("arch", ["stablelm-3b", "mixtral-8x7b",
                                  "mamba2-370m", "whisper-large-v3"])
@pytest.mark.parametrize("shape", [T_SHAPE, P_SHAPE, D_SHAPE])
def test_cell_lowers_and_compiles(arch, shape, mesh_dm):
    cfg = _cfg(arch)
    cell = step_mod.build_cell(cfg, shape, mesh_dm, "baseline", OPT)
    with mesh_dm:
        compiled = cell.lower().compile()
    assert compiled.cost_analysis() is not None


def test_train_step_runs_and_learns(mesh_dm):
    cfg = _cfg("stablelm-3b")
    cell = step_mod.build_cell(cfg, T_SHAPE, mesh_dm, "baseline", OPT)
    from repro.models.api import get_model
    model = get_model(cfg)
    with mesh_dm:
        params = jax.jit(model.init_params, static_argnums=0,
                         out_shardings=cell.in_shardings[0])(
            cfg, jax.random.key(0))
        opt_state = jax.jit(optim.init,
                            out_shardings=cell.in_shardings[1])(params)
        fn = cell.jitted()
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (8, 33)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:]),
                 "mask": jnp.ones((8, 32), jnp.float32)}
        losses = []
        for _ in range(5):
            params, opt_state, m = fn(params, opt_state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]           # memorizes the fixed batch
    assert np.isfinite(losses).all()


def test_decode_cache_roundtrip(mesh_dm):
    """serve_step appends exactly one token to every sequence."""
    cfg = _cfg("stablelm-3b")
    cell = step_mod.build_cell(cfg, D_SHAPE, mesh_dm, "baseline")
    from repro.models.api import get_model
    model = get_model(cfg)
    with mesh_dm:
        params = jax.jit(model.init_params, static_argnums=0,
                         out_shardings=cell.in_shardings[0])(
            cfg, jax.random.key(0))
        cache = model.init_cache(cfg, 8, 64)
        fn = cell.jitted()
        toks = jnp.zeros((8,), jnp.int32)
        nxt, cache = fn(params, cache, toks)
    assert nxt.shape == (8,)
    np.testing.assert_array_equal(np.asarray(cache["len"]), np.ones(8))


def test_long_500k_applicability():
    ok, _ = step_mod.cell_applicable(get_config("qwen2-72b"),
                                     __import__("repro.configs",
                                                fromlist=["SHAPES"]).SHAPES["long_500k"])
    assert not ok
    ok2, _ = step_mod.cell_applicable(get_config("mamba2-370m"),
                                      __import__("repro.configs",
                                                 fromlist=["SHAPES"]).SHAPES["long_500k"])
    assert ok2


def test_cell_rules_drops_batch_for_b1(mesh_dm):
    cfg = get_config("mamba2-370m")
    shape = ShapeConfig("x", seq_len=128, global_batch=1, kind="decode")
    rules = step_mod.cell_rules(mesh_dm, cfg, shape)
    assert rules.batch is None


def test_input_specs_families(mesh_dm):
    from repro.configs import SHAPES
    au = get_config("whisper-large-v3")
    sp = step_mod.input_specs(au, SHAPES["train_4k"])
    assert "frames" in sp["batch"]
    vl = get_config("qwen2-vl-72b")
    sp2 = step_mod.input_specs(vl, SHAPES["train_4k"])
    assert sp2["batch"]["positions"].shape[0] == 3
    de = step_mod.input_specs(get_config("stablelm-3b"), SHAPES["decode_32k"])
    assert de["tokens"].shape == (128,)
    assert de["cache"]["k"].shape[2] == 32768
