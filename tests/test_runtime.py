"""Runtime: fault-tolerant trainer — retry, resume, straggler, reshard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.compat import make_auto_device_mesh
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import batch_iterator
from repro.runtime import FaultInjector, Trainer, TrainerConfig

SHAPE = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
OPT = optim.OptConfig(lr_peak=3e-3, warmup_steps=2, total_steps=20)


def _trainer(tmp_path, mesh, steps=6, **kw):
    cfg = reduced_config(get_config("stablelm-3b"),
                         num_layers=2, d_model=64, num_heads=2,
                         num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=128)
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=3,
                         ckpt_dir=str(tmp_path / "ck"), log_every=100)
    return cfg, Trainer(cfg, SHAPE, mesh, OPT, tcfg, **kw)


def test_train_loss_decreases(tmp_path, mesh_dm):
    cfg, tr = _trainer(tmp_path, mesh_dm, steps=12)
    tr.init()
    losses = []
    tr.run(batch_iterator(cfg, SHAPE),
           on_step=lambda s, m: losses.append(float(m["loss"])))
    assert len(losses) == 12
    # it is actually learning (mean of last 3 below mean of first 3)
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    assert np.isfinite(losses).all()
    tr.close()


def test_fault_retry_and_recovery(tmp_path, mesh_dm):
    cfg, tr = _trainer(tmp_path, mesh_dm, steps=6,
                       fault_injector=FaultInjector({2: 1, 4: 1}))
    tr.init()
    tr.run(batch_iterator(cfg, SHAPE))
    kinds = [e["kind"] for e in tr.events]
    assert kinds.count("step_failure") == 2
    assert tr.step == 6                    # completed despite faults
    tr.close()


def test_resume_from_checkpoint(tmp_path, mesh_dm):
    cfg, tr = _trainer(tmp_path, mesh_dm, steps=6)
    tr.init()
    tr.run(batch_iterator(cfg, SHAPE))
    tr.close()
    # new trainer resumes at the last committed step (6)
    cfg2, tr2 = _trainer(tmp_path, mesh_dm, steps=9)
    tr2.resume_or_init()
    assert tr2.step == 6
    assert any(e["kind"] == "resume" for e in tr2.events)
    tr2.run(batch_iterator(cfg2, SHAPE, start_step=tr2.step))
    assert tr2.step == 9
    tr2.close()


def test_elastic_reshard(tmp_path, mesh_dm):
    """Scale down from (2,4) to (1,4): same arrays, new shardings."""
    cfg, tr = _trainer(tmp_path, mesh_dm, steps=4)
    tr.init()
    it = batch_iterator(cfg, SHAPE)
    tr.tcfg.total_steps = 2
    tr.run(it)
    small = make_auto_device_mesh(
        np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model"))
    tr.reshard(small)
    assert tr.mesh.devices.size == 4
    tr.tcfg.total_steps = 4
    tr.run(it)                              # continues training on 4 chips
    assert tr.step == 4
    assert any(e["kind"] == "reshard" for e in tr.events)
    tr.close()


def test_straggler_detection(tmp_path, mesh_dm):
    import time
    cfg, tr = _trainer(tmp_path, mesh_dm, steps=1)
    tr.init()
    # synthesize a step-time history with one straggler
    for dt in [0.1] * 10:
        tr._heartbeat(dt)
    tr._heartbeat(1.0)
    assert any(e["kind"] == "straggler" for e in tr.events)
    tr.close()
