"""PGAS addressing + XY routing geometry (paper C1/C4)."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.coords import (GridSpec, decode_address, encode_address,
                               manhattan_hops, xy_route)


def test_address_roundtrip_basic():
    spec = GridSpec(nx=16, ny=16, addr_width=20)
    a = encode_address(spec, 3, 7, 0x1234)
    assert decode_address(spec, a) == (3, 7, 0x1234)


def test_address_fields_disjoint():
    spec = GridSpec(nx=4, ny=4, addr_width=8)
    # local address occupies the low addr_width bits exactly
    a0 = encode_address(spec, 0, 0, 0)
    a1 = encode_address(spec, 0, 0, (1 << 8) - 1)
    assert a1 - a0 == (1 << 8) - 1
    assert encode_address(spec, 1, 0, 0) == (1 << 8)


def test_address_bounds_checked():
    spec = GridSpec(nx=4, ny=4, addr_width=8)
    with pytest.raises(ValueError):
        encode_address(spec, 4, 0, 0)
    with pytest.raises(ValueError):
        encode_address(spec, 0, 0, 1 << 8)


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 31), st.integers(1, 31), st.integers(0, 30),
       st.integers(0, 30), st.integers(0, (1 << 20) - 1))
def test_address_roundtrip_property(nx, ny, x, y, local):
    spec = GridSpec(nx=max(nx, x + 1), ny=max(ny, y + 1), addr_width=20)
    assert decode_address(spec, encode_address(spec, x, y, local)) == (x, y, local)


def test_tile_id_row_major():
    spec = GridSpec(nx=4, ny=3)
    assert spec.tile_id(0, 0) == 0
    assert spec.tile_id(3, 0) == 3
    assert spec.tile_id(0, 1) == 4
    assert spec.tile_xy(7) == (3, 1)
    assert [spec.tile_id(x, y) for x, y in spec.tiles()] == list(range(12))


def test_xy_route_dimension_order():
    # X first, then Y — and the route length equals the Manhattan distance.
    path = xy_route((0, 0), (2, 2))
    assert path == [(0, 0), (1, 0), (2, 0), (2, 1), (2, 2)]
    assert len(path) - 1 == manhattan_hops((0, 0), (2, 2))


@settings(max_examples=200, deadline=None)
@given(st.tuples(st.integers(0, 15), st.integers(0, 15)),
       st.tuples(st.integers(0, 15), st.integers(0, 15)))
def test_xy_route_never_turns_from_y_to_x(src, dst):
    """The reduced-crossbar invariant: once a route moves in Y it never
    moves in X again (N->E/W turns are structurally impossible)."""
    path = xy_route(src, dst)
    assert len(path) - 1 == manhattan_hops(src, dst)
    moved_y = False
    for (x0, y0), (x1, y1) in zip(path, path[1:]):
        if y1 != y0:
            moved_y = True
        if x1 != x0:
            assert not moved_y, f"route {path} turned from Y back to X"


def test_bisection_links_paper_example():
    # 8x8 mesh: 16 links cross the median counting both directions.
    assert GridSpec(nx=8, ny=8).bisection_links("x") == 16
    # Celerity-scale: 16-wide array -> 16 links per direction across the
    # short cut (32 both ways), the "32 remote operations per cycle" bound.
    assert GridSpec(nx=16, ny=31).bisection_links("y") == 32
