"""The unified mesh-attach API: config round-trips, facade semantics,
deprecation shims, and the endpoint telemetry-parity contract.

* ``MeshConfig`` <-> ``NetConfig``/``SimConfig`` conversion is lossless
  (corpus + hypothesis property test);
* the facade's reactive path is cycle-identical to the native program
  path (``ProgramEndpoint`` grid == ``attach(program)``);
* a fuzzed corpus of reactive endpoint scenarios produces bit-identical
  :class:`repro.mesh.Telemetry` between the numpy backend (native
  execution) and the JAX backend (trace-to-program bridge);
* the deduplicated ``empty_program`` helper and the deprecated old names;
* ``benchmarks/run.py`` exits nonzero when a suite crashes.
"""
import json

import numpy as np
import pytest

from repro.core.netsim import NetConfig, OP_LOAD, OP_STORE
from repro.mesh import (DmaEndpoint, MemoryControllerEndpoint, MeshConfig,
                        ProgramEndpoint, Simulator, empty_program,
                        make_traffic, trace_to_program)
from repro.netsim_jax.sim import SimConfig
from repro.netsim_jax.testing import assert_telemetry_equal

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 — placeholder strategies, never evaluated
        @staticmethod
        def integers(*_a, **_k):
            return None

        @staticmethod
        def booleans(*_a, **_k):
            return None


# ----------------------------------------------------------------------
# MeshConfig round trips
# ----------------------------------------------------------------------
def _roundtrip_case(nx, ny, fifo, ep_fifo, credits, mem_words, resp_latency,
                    record_log):
    cfg = MeshConfig(nx=nx, ny=ny, router_fifo=fifo, ep_fifo=ep_fifo,
                     max_out_credits=credits, mem_words=mem_words,
                     resp_latency=resp_latency, record_log=record_log)
    # MeshConfig <-> NetConfig: lossless in both directions
    assert MeshConfig.from_net(cfg.to_net()) == cfg
    # MeshConfig <-> SimConfig: lossless except record_log (documented:
    # the jitted state cannot carry a Python response log)
    back = MeshConfig.from_sim(cfg.to_sim())
    assert back == cfg.replace(record_log=False)
    # the old configs round-trip through MeshConfig losslessly
    net = cfg.to_net()
    assert MeshConfig.from_net(net).to_net() == net
    sim = cfg.to_sim()
    assert MeshConfig.from_sim(sim).to_sim() == sim
    # coerce accepts all three spellings and lands on the same value
    assert MeshConfig.coerce(cfg) is cfg
    assert MeshConfig.coerce(net) == cfg
    assert MeshConfig.coerce(sim) == cfg.replace(record_log=False)


@pytest.mark.parametrize("seed", range(8))
def test_config_roundtrip_corpus(seed):
    rng = np.random.default_rng(3000 + seed)
    _roundtrip_case(nx=int(rng.integers(1, 33)), ny=int(rng.integers(1, 33)),
                    fifo=int(rng.integers(1, 17)),
                    ep_fifo=int(rng.integers(1, 17)),
                    credits=int(rng.integers(1, 129)),
                    mem_words=int(rng.integers(1, 257)),
                    resp_latency=int(rng.integers(1, 4)),
                    record_log=bool(rng.integers(0, 2)))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 32),
       st.integers(1, 32), st.integers(1, 256), st.integers(1, 512),
       st.integers(1, 4), st.booleans())
def test_config_roundtrip_hypothesis(nx, ny, fifo, ep_fifo, credits,
                                     mem_words, resp_latency, record_log):
    _roundtrip_case(nx, ny, fifo, ep_fifo, credits, mem_words, resp_latency,
                    record_log)


def test_config_validation_and_coerce_errors():
    with pytest.raises(ValueError, match="dimensions must be positive"):
        MeshConfig(nx=0, ny=4)
    with pytest.raises(TypeError, match="cannot interpret"):
        MeshConfig.coerce("4x4")


# ----------------------------------------------------------------------
# deprecated shims + the deduplicated empty-program helper
# ----------------------------------------------------------------------
def test_empty_program_old_names_deprecated_but_equivalent():
    import repro.netsim_jax.traffic as old_traffic
    from repro.netsim_jax import sim as jsim

    canonical = empty_program(3, 2, 4)
    with pytest.warns(DeprecationWarning, match="repro.mesh.empty_program"):
        legacy = old_traffic.empty_program(3, 2, 4)
    assert sorted(legacy) == sorted(canonical)
    for k in canonical:
        np.testing.assert_array_equal(legacy[k], canonical[k])

    cfg = SimConfig(nx=3, ny=2)
    with pytest.warns(DeprecationWarning, match="empty_program_for"):
        prog = jsim.empty_program_for(cfg)
    assert int(np.asarray(prog.length).sum()) == 0
    # the jitted twin of the canonical helper packs to the same no-op
    packed = jsim.load_program(empty_program(3, 2, 1))
    np.testing.assert_array_equal(np.asarray(packed.length),
                                  np.asarray(prog.length))


def test_simconfig_converters_deprecated():
    net = NetConfig(nx=5, ny=3, router_fifo=2)
    with pytest.warns(DeprecationWarning, match="MeshConfig.from_net"):
        scfg = SimConfig.from_netconfig(net)
    assert scfg == MeshConfig.from_net(net).to_sim()
    with pytest.warns(DeprecationWarning, match="MeshConfig.from_sim"):
        back = scfg.to_netconfig()
    assert back == MeshConfig.from_sim(scfg).to_net()


# ----------------------------------------------------------------------
# facade semantics
# ----------------------------------------------------------------------
def test_facade_rejects_bad_attachments():
    sim = Simulator(MeshConfig(nx=3, ny=3))
    with pytest.raises(ValueError, match="unknown backend"):
        Simulator(MeshConfig(nx=2, ny=2), backend="torch")
    with pytest.raises(TypeError, match="cannot attach"):
        sim.attach(42)
    ep = DmaEndpoint(dst_x=1, dst_y=1, data=[1])
    with pytest.raises(ValueError, match="needs its tile"):
        sim.attach(ep)
    with pytest.raises(ValueError, match="outside the"):
        sim.attach(ep, at=(3, 0))
    sim.attach(ep, at=(0, 0))
    with pytest.raises(ValueError, match="one master"):
        sim.attach(DmaEndpoint(dst_x=1, dst_y=1, data=[1]), at=(0, 0))
    # a program with entries on the endpoint's tile is rejected too
    prog = make_traffic("uniform", 3, 3, 2, seed=0)
    with pytest.raises(ValueError, match="one master"):
        sim.attach(prog)


def test_facade_program_accepts_netconfig_and_simconfig():
    """The facade front door takes any config flavor."""
    entries = make_traffic("neighbor", 3, 2, 3)
    for cfg in (NetConfig(nx=3, ny=2), SimConfig(nx=3, ny=2),
                MeshConfig(nx=3, ny=2)):
        sim = Simulator(cfg, backend="numpy")
        sim.attach({k: v.copy() for k, v in entries.items()})
        sim.run_until_drained()
        assert int(sim.completed.sum()) == 3 * 2 * 3


def test_program_endpoint_grid_matches_native_program_path():
    """Driving a whole injection program through per-tile ProgramEndpoints
    (the reactive valid/ready interface) is cycle-identical to the native
    vectorized program path — injection is the same stage, same rules."""
    cfg = MeshConfig(nx=4, ny=3, max_out_credits=3, router_fifo=2)
    entries = make_traffic("uniform", 4, 3, 7, rate=0.6, seed=13)

    native = Simulator(cfg, backend="numpy")
    native.attach({k: v.copy() for k, v in entries.items()})
    reactive = Simulator(cfg, backend="numpy")
    for (x, y), ep in ProgramEndpoint.grid(entries).items():
        reactive.attach(ep, at=(x, y))

    cn = native.run_until_drained()
    cr = reactive.run_until_drained()
    assert cn == cr
    assert_telemetry_equal(native, reactive)
    np.testing.assert_array_equal(native.mem, reactive.mem)
    np.testing.assert_array_equal(native.credits, reactive.credits)


def test_trace_program_replays_bit_identically_on_the_oracle():
    """The exported injection-trace program reproduces a reactive run on
    a fresh simulator — the bridge invariant, checked oracle-vs-oracle."""
    cfg = MeshConfig(nx=4, ny=4, mem_words=16)
    live = Simulator(cfg, backend="numpy", seed=0)
    live.attach(DmaEndpoint(dst_x=3, dst_y=3, data=range(8),
                            max_inflight=2), at=(0, 0))
    live.run_until_drained()

    replay = Simulator(cfg, backend="numpy", seed=0)
    replay.attach(live.injection_trace_program())
    replay.run_until_drained()
    assert_telemetry_equal(live, replay)
    np.testing.assert_array_equal(live.mem, replay.mem)


def test_trace_to_program_rejects_double_master():
    prog = make_traffic("neighbor", 2, 2, 2)
    from repro.mesh import Request
    trace = [(0, 0, 5, Request(dst_x=1, dst_y=0, addr=0))]
    with pytest.raises(ValueError, match="one master"):
        trace_to_program(trace, 2, 2, base=prog)


# ----------------------------------------------------------------------
# fuzzed endpoint corpus: telemetry parity numpy (native) vs jax (bridge)
# ----------------------------------------------------------------------
FUZZ_MESHES = ((3, 2), (4, 3))
FUZZ_MAX_CYCLES = 3000      # one value -> one XLA compile per mesh shape


def _random_endpoint_scenario(backend, seed):
    rng = np.random.default_rng(seed)
    nx, ny = FUZZ_MESHES[int(rng.integers(0, len(FUZZ_MESHES)))]
    mem_words = 16
    cfg = MeshConfig(nx=nx, ny=ny, mem_words=mem_words,
                     max_out_credits=int(rng.integers(2, 9)),
                     router_fifo=int(rng.integers(2, 5)))
    sim = Simulator(cfg, backend=backend, seed=0)
    # a random (but fixed-seed) pointer soup for the chasers
    sim.set_mem(rng.integers(0, mem_words, (ny, nx, mem_words)))

    tiles = [(x, y) for y in range(ny) for x in range(nx)]
    rng.shuffle(tiles)
    n_dma = int(rng.integers(1, 3))
    n_mc = int(rng.integers(1, 3))
    for _ in range(n_dma):
        x, y = tiles.pop()
        dx, dy = tiles[int(rng.integers(0, len(tiles)))]
        sim.attach(DmaEndpoint(dst_x=dx, dst_y=dy,
                               data=rng.integers(0, 1000,
                                                 int(rng.integers(1, 12))),
                               max_inflight=int(rng.integers(1, 5))),
                   at=(x, y))
    for _ in range(n_mc):
        x, y = tiles.pop()
        dx, dy = tiles[int(rng.integers(0, len(tiles)))]
        sim.attach(MemoryControllerEndpoint(
            dst_x=dx, dst_y=dy, start_addr=int(rng.integers(0, mem_words)),
            n_requests=int(rng.integers(1, 8)), mem_words=mem_words),
            at=(x, y))
    return sim


@pytest.mark.parametrize("seed", range(6))
def test_endpoint_telemetry_parity_fuzz(seed):
    """Reactive scenarios — DMA engines + pointer-chasing controllers at
    random tiles — drain on both backends at the same cycle with a
    bit-identical Telemetry record (the trace-to-program bridge
    contract), and the endpoints observe the same replies."""
    case_seed = int(np.random.default_rng(4000 + seed).integers(0, 2**31))
    a = _random_endpoint_scenario("numpy", case_seed)
    b = _random_endpoint_scenario("jax", case_seed)
    ca = a.run_until_drained(FUZZ_MAX_CYCLES)
    cb = b.run_until_drained(FUZZ_MAX_CYCLES)
    assert ca == cb, "drain cycle diverged between backends"
    a.telemetry().assert_bit_identical(b.telemetry())
    np.testing.assert_array_equal(np.asarray(a.mem), np.asarray(b.mem))
    for at, (ea, eb) in {k: (a.endpoints[k], b.endpoints[k])
                         for k in a.endpoints}.items():
        if isinstance(ea, MemoryControllerEndpoint):
            assert ea.visited == eb.visited, f"chase diverged at {at}"
            assert ea.latencies == eb.latencies


def test_jax_backend_rejects_endpoint_attach_after_run():
    """The trace bridge replays from cycle 0, so attaching an endpoint to
    a jax Simulator that already ran would silently drop history — it
    must raise instead.  The numpy backend supports it natively."""
    cfg = MeshConfig(nx=3, ny=3)
    prog = make_traffic("neighbor", 3, 3, 2)
    for k in prog:
        prog[k][0, 0] = -1 if k == "op" else 0
    jax_sim = Simulator(cfg, backend="jax")
    jax_sim.attach({k: v.copy() for k, v in prog.items()})
    jax_sim.run(10)
    with pytest.raises(ValueError, match="already run"):
        jax_sim.attach(DmaEndpoint(dst_x=2, dst_y=2, data=[1]), at=(0, 0))
    # mid-run attach on the oracle backend stays legal (native execution)
    np_sim = Simulator(cfg, backend="numpy")
    np_sim.attach({k: v.copy() for k, v in prog.items()})
    np_sim.run(10)
    np_sim.attach(DmaEndpoint(dst_x=2, dst_y=2, data=[1]), at=(0, 0))
    np_sim.run_until_drained()


def test_facade_step_services_endpoints():
    """Manual cycle-by-cycle stepping through the facade must deliver
    responses to endpoints (same path as run()), and the jit backend
    refuses per-cycle driving outright."""
    cfg = MeshConfig(nx=4, ny=1, mem_words=8)
    sim = Simulator(cfg, backend="numpy")
    mc = MemoryControllerEndpoint(dst_x=3, dst_y=0, start_addr=0,
                                  n_requests=3, mem_words=8)
    sim.attach(mc, at=(0, 0))
    for _ in range(200):
        sim.step()
    assert len(mc.latencies) == 3, "manual stepping starved deliver()"
    with pytest.raises(NotImplementedError, match="numpy-backend feature"):
        Simulator(cfg, backend="jax").step()


def test_mixed_program_and_endpoint_parity():
    """A base injection program on most tiles plus a reactive endpoint on
    one: both backends agree (the bridge merges the trace with the base
    program)."""
    nx, ny = 3, 3
    cfg = MeshConfig(nx=nx, ny=ny, mem_words=16)
    entries = make_traffic("uniform", nx, ny, 4, rate=0.5, seed=2)
    for k in entries:               # silence tile (0, 0): the endpoint's
        entries[k][0, 0] = -1 if k == "op" else 0

    def build(backend):
        sim = Simulator(cfg, backend=backend, seed=0)
        sim.attach({k: v.copy() for k, v in entries.items()})
        sim.attach(DmaEndpoint(dst_x=2, dst_y=2, data=range(6),
                               max_inflight=2), at=(0, 0))
        return sim

    a, b = build("numpy"), build("jax")
    assert a.run_until_drained(FUZZ_MAX_CYCLES) == \
        b.run_until_drained(FUZZ_MAX_CYCLES)
    a.telemetry().assert_bit_identical(b.telemetry())


# ----------------------------------------------------------------------
# benchmarks/run.py: a crashed suite must fail the process
# ----------------------------------------------------------------------
def test_bench_run_exits_nonzero_on_suite_crash(tmp_path, monkeypatch):
    run = pytest.importorskip(
        "benchmarks.run",
        reason="benchmarks namespace needs the repo root on sys.path")

    def boom(name):
        if name == "netsim":
            raise RuntimeError("suite exploded")
        return [{"name": f"{name}_fake", "ok": True}]

    monkeypatch.setattr(run, "run_suite", boom)
    rc = run.main(["--suite", "netsim", "--out", str(tmp_path)])
    assert rc == 1
    # artifacts are still written, with the failure recorded
    results = json.loads((tmp_path / "bench_results.json").read_text())
    assert results["netsim"][0]["ok"] is False
    assert "suite exploded" in results["netsim"][0]["error"]

    # and a clean suite run exits zero through the same path
    monkeypatch.setattr(run, "run_suite",
                        lambda name: [{"name": "fake", "ok": True}])
    assert run.main(["--suite", "netsim", "--out", str(tmp_path)]) == 0


def test_bench_run_exits_nonzero_on_failed_benchmark(tmp_path, monkeypatch):
    run = pytest.importorskip("benchmarks.run")
    monkeypatch.setattr(run, "run_suite",
                        lambda name: [{"name": "bad", "ok": False}])
    assert run.main(["--suite", "netsim", "--out", str(tmp_path)]) == 1
