"""Tests for the telemetry subsystem and the phased load–latency
measurement methodology (repro.netsim_jax.measure).

* zero-load: measured per-packet latency (the telemetry histogram) equals
  the analytic ``unloaded_rtt(hops)`` on BOTH simulators, hops 1..6;
* an idle mesh measures exactly zero channel utilization;
* the oracle's histogram is consistent with its own per-response log;
* histogram quantiles / saturation detection / curve validation units;
* the phased methodology at low load: accepted == offered == rate and the
  measured latency window is clean;
* a small vmapped load–latency sweep produces a monotone curve that
  saturates.
"""
import numpy as np
import pytest

from repro.core.netsim import LAT_BINS, MeshSim, NetConfig, OP_LOAD, unloaded_rtt
from repro.mesh import MeshConfig, Simulator, empty_program, make_traffic
from repro.netsim_jax import (curve_is_monotone, hist_quantile,
                              load_latency_sweep, measure_program,
                              saturation_point)

NX, NY = 7, 2          # one mesh shape for all hop counts: one XLA compile
RUN_CYCLES = unloaded_rtt(6) + 5


def _single_packet_prog(hops):
    prog = empty_program(NX, NY, 1)
    prog["op"][0, 0, 0] = OP_LOAD
    prog["dst_x"][0, 0, 0] = hops
    prog["dst_y"][0, 0, 0] = 0
    return prog


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_zero_load_latency_matches_analytic(backend):
    """One packet on an idle mesh: the telemetry histogram holds exactly
    one sample, in the bin ``unloaded_rtt(hops)``, for hops 1..6."""
    for hops in range(1, 7):
        sim = Simulator(MeshConfig(nx=NX, ny=NY), backend=backend)
        sim.attach(_single_packet_prog(hops))
        sim.run(RUN_CYCLES)
        t = sim.telemetry()
        assert int(t.completed[0, 0]) == 1
        expect = np.zeros(LAT_BINS, np.int64)
        expect[unloaded_rtt(hops)] = 1
        np.testing.assert_array_equal(t.lat_hist, expect)
        # the request crossed exactly `hops` forward links + 1 ejection,
        # and the response the same coming back
        assert int(t.link_util_fwd.sum()) == hops + 1
        assert int(t.link_util_rev.sum()) == hops + 1


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_idle_mesh_zero_utilization(backend):
    """No program -> every telemetry counter stays exactly 0."""
    sim = Simulator(MeshConfig(nx=4, ny=3), backend=backend)
    sim.attach(empty_program(4, 3, 1))
    sim.run(50)
    t = sim.telemetry()
    for f in ("link_util_fwd", "link_util_rev", "fifo_hwm_fwd",
              "fifo_hwm_rev", "ep_hwm", "lat_hist"):
        assert int(getattr(t, f).sum()) == 0, f"{f} nonzero on idle mesh"


def test_oracle_histogram_consistent_with_response_log():
    """The histogram is exactly the binned per-response log latencies."""
    cfg = NetConfig(nx=4, ny=4, record_log=True)
    sim = MeshSim(cfg)
    sim.load_program(make_traffic("uniform", 4, 4, 6, rate=0.5, seed=3))
    sim.run_until_drained()
    want = np.zeros(LAT_BINS, np.int64)
    for (cycle, _sy, _sx, _op, tag, _data) in sim.log:
        want[min(cycle - tag, LAT_BINS - 1)] += 1
    np.testing.assert_array_equal(sim.lat_hist, want)


def test_measure_window_gates_by_injection_cycle():
    """Only packets *injected* inside [start, stop) are histogrammed."""
    cfg = NetConfig(nx=4, ny=4, record_log=True)
    sim = MeshSim(cfg)
    sim.set_measure_window(5, 12)
    sim.load_program(make_traffic("uniform", 4, 4, 8, rate=0.4, seed=1))
    sim.run_until_drained()
    in_win = sum(1 for (_c, _sy, _sx, _op, tag, _d) in sim.log
                 if 5 <= tag < 12)
    assert 0 < int(sim.lat_hist.sum()) == in_win < int(sim.completed.sum())


# ----------------------------------------------------------------------
# measure-module units
# ----------------------------------------------------------------------
def test_hist_quantile():
    hist = np.zeros(LAT_BINS)
    hist[10] = 50
    hist[20] = 49
    hist[400] = 1
    import jax.numpy as jnp
    h = jnp.asarray(hist)
    assert float(hist_quantile(h, 0.5)) == 10
    assert float(hist_quantile(h, 0.95)) == 20
    assert float(hist_quantile(h, 1.0)) == 400
    assert float(hist_quantile(jnp.zeros(LAT_BINS), 0.5)) == 0


def test_saturation_point_and_monotone():
    lat = np.array([10.0, 10.5, 12.0, 31.0, 90.0])
    assert saturation_point(lat) == 3          # 31 >= 3 * 10
    assert saturation_point(np.array([10.0, 11.0, 12.0])) is None
    assert curve_is_monotone(lat)
    # a small pre-saturation dip within tolerance is fine…
    assert curve_is_monotone(np.array([10.0, 9.9, 12.0, 31.0, 90.0]))
    # …a real pre-saturation dip is not
    assert not curve_is_monotone(np.array([10.0, 8.0, 12.0, 31.0, 90.0]))
    # collapsing back *below* saturation after the knee is malformed
    assert not curve_is_monotone(np.array([10.0, 11.0, 35.0, 12.0]))
    # post-knee wobble that stays saturated is accepted
    assert curve_is_monotone(np.array([10.0, 11.0, 35.0, 90.0, 80.0]))


def test_phased_measure_low_load_is_clean():
    """Well below saturation: accepted == offered == the injection rate,
    latency ~ zero-load, and every window packet is delivered."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=32)
    entries = make_traffic("uniform", 4, 4, 200, rate=0.1, seed=0)
    stats = measure_program(cfg, entries, warmup=100, measure=200,
                            drain=200)
    assert stats["offered"] == pytest.approx(0.1, rel=0.1)
    assert stats["accepted"] == pytest.approx(0.1, rel=0.1)
    assert stats["delivered"] == pytest.approx(stats["offered"], rel=0.05)
    # 4x4 uniform zero-load mean RTT is a bit above the 1-hop 7 cycles
    assert 7 <= stats["lat_mean"] <= 25
    assert stats["lat_p50"] <= stats["lat_p95"] <= stats["lat_p99"] \
        <= stats["lat_max"]
    assert int(stats["hist"].sum()) == round(stats["delivered"] * 200 * 16)


def test_load_latency_sweep_monotone_and_saturates():
    """Small vmapped sweep: latency rises monotonically with offered load
    and crosses the saturation threshold at high load."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=64, router_fifo=8)
    out = load_latency_sweep("transpose", 4, 4, [0.05, 0.3, 0.6, 1.0],
                             warmup=100, measure=250, drain=300, cfg=cfg,
                             seed=0)
    assert list(out["rates"]) == sorted(out["rates"])
    assert out["monotone"], f"non-monotone curve: {out['lat_mean']}"
    assert out["saturation_rate"] is not None
    assert out["zero_load_latency"] < 30
    # below saturation the network delivers what is offered
    assert out["accepted"][0] == pytest.approx(out["offered"][0], rel=0.05)
