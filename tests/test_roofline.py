"""Roofline machinery: HLO collective parsing + term arithmetic."""
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl

HLO = """
HloModule test
  %x = f32[1024,512]{1,0} parameter(0)
  %ar = f32[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = bf16[64,2048]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={1}
  %rs = f32[32,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = f32[16,256]{1,0} all-to-all(%w), replica_groups={{0,1}}
  %cp = f32[8,8]{1,0} collective-permute(%v), source_target_pairs={{0,1},{1,0}}
  %ars = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-reduce-start(%u), replica_groups={{0,1}}
  %ard = f32[4,4]{1,0} all-reduce-done(%ars)
"""


def test_parse_collectives_ops_and_bytes():
    c = rl.parse_collectives(HLO)
    assert c["all-reduce"]["count"] == 2            # sync + start (done skipped)
    assert c["all-reduce"]["bytes"] == 1024 * 512 * 4 + 4 * 4 * 4
    # all-gather: result/group -> operand
    assert c["all-gather"]["bytes"] == pytest.approx(64 * 2048 * 2 / 8)
    # reduce-scatter: result*group
    assert c["reduce-scatter"]["bytes"] == 32 * 128 * 4 * 4
    assert c["all-to-all"]["bytes"] == 16 * 256 * 4
    assert c["collective-permute"]["bytes"] == 8 * 8 * 4


def test_parse_group_sizes():
    assert rl._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert rl._group_size("replica_groups=[2,8]<=[16]") == 8
    assert rl._group_size("no groups here") == 1


def test_wire_bytes_ring_model():
    c = rl.parse_collectives(HLO)
    # ring all-reduce: 2(k-1)/k x operand
    big = 1024 * 512 * 4
    small = 4 * 4 * 4
    assert c["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * 3 / 4 * big + 2 * 1 / 2 * small)


def test_roofline_terms_and_bottleneck():
    cfg = get_config("stablelm-3b")
    shape = SHAPES["train_4k"]
    cost = {"flops": 1e15, "bytes accessed": 1e12, "bytes adjusted": 5e11}
    rep = rl.roofline(cfg, shape, "pod", 256, cost, {})
    assert rep.compute_s == pytest.approx(1e15 / rl.HW.PEAK_FLOPS_BF16)
    assert rep.memory_adj_s == pytest.approx(5e11 / rl.HW.HBM_BW)
    assert rep.collective_s == 0.0
    assert rep.bottleneck == "compute"
    assert 0 < rep.useful_ratio
    assert rep.roofline_frac == pytest.approx(1.0)  # compute-bound => at roof


def test_model_flops_moe_uses_active():
    moe = get_config("mixtral-8x7b")
    t = SHAPES["train_4k"]
    assert rl.model_flops(moe, t) == pytest.approx(
        6.0 * moe.active_param_count() * t.global_batch * t.seq_len)
    dense = get_config("yi-34b")
    assert rl.model_flops(dense, t) == pytest.approx(
        6.0 * dense.param_count() * t.global_batch * t.seq_len)


def test_decode_prefill_flops_forward_only():
    cfg = get_config("yi-34b")
    p, d = SHAPES["prefill_32k"], SHAPES["decode_32k"]
    assert rl.model_flops(cfg, p) == pytest.approx(
        2.0 * cfg.param_count() * p.global_batch * p.seq_len)
    assert rl.model_flops(cfg, d) == pytest.approx(
        2.0 * cfg.param_count() * d.global_batch)
