"""Parity suite for the Pallas router-step kernel (``impl="pallas"``).

The kernel's contract is *bit-identity* with the fused-XLA step: both
paths trace the same ``_step_core`` cycle function, so any divergence —
one-hot rewrites of the scatter/gather ops, the multi-cycle launch
decomposition, the drain-fence bookkeeping — is a bug.  The suite checks
the **entire** ``SimState`` pytree leaf-for-leaf (FIFO ring buffers and
all), not just the externally visible contract, because the kernel is an
implementation swap: internal layout must match too.

Covered, per the acceptance criteria:

* mid-flight state parity fused-vs-pallas across the 3-shape x 6-pattern
  grid and the 10-seed randomized-program corpus (telemetry and drain
  cycles included);
* ``cycles_per_call`` bit-identity for {1, 4, remainder launches} and
  for unroll-mismatched drain fences (``check_every % cycles_per_call
  != 0`` and ``cycles_per_call > check_every``);
* ``check_every`` interaction: the exact drain cycle is invariant;
* the single-``step`` entry point and the measurement layer
  (:func:`load_latency_sweep`) under ``impl="pallas"``;
* facade-boundary telemetry snapshots stay immutable after further runs
  (the donation/aliasing regression: ``simulate`` donates its state and
  the kernel aliases inputs to outputs, so a zero-copy snapshot would
  silently mutate).

On hosts without a compiled Pallas backend the kernel runs in interpret
mode (see :mod:`repro.kernels.backend`) — same semantics, so this suite
is the correctness gate CI runs on CPU.
"""
import jax
import numpy as np
import pytest

from repro.core.netsim import OP_CAS, OP_LOAD, OP_STORE
from repro.mesh import MeshConfig, PATTERNS, Simulator, make_traffic
from repro.netsim_jax import (init_state, load_latency_sweep, load_program,
                              simulate, step)
from repro.netsim_jax.testing import assert_state_equal

MESHES = [(2, 2), (4, 4), (3, 5)]          # (nx, ny); incl. non-square


def _pair_impls(cfg, entries, *, cycles_per_call=1, check_every=1,
                fifo_depth=None, max_credits=None):
    """Two jax-backend facades over the same program: the fused-XLA
    reference and the Pallas kernel under test."""
    kw = dict(backend="jax", fifo_depth=fifo_depth, max_credits=max_credits,
              check_every=check_every)
    a = Simulator(cfg, **kw)
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, impl="pallas", cycles_per_call=cycles_per_call, **kw)
    b.attach(entries)
    return a, b


def _assert_states_identical(a, b):
    """Every leaf of the packed SimState pytree is bit-identical (plus the
    unified telemetry record, which also pins the cycle counter)."""
    sa, sb = a._sim.state, b._sim.state
    la, ta = jax.tree_util.tree_flatten(sa)
    lb, tb = jax.tree_util.tree_flatten(sb)
    assert ta == tb, "SimState tree structure diverged"
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"SimState leaf {i} (fused vs pallas)")
    a.telemetry().assert_bit_identical(b.telemetry())


# ----------------------------------------------------------------------
# mid-flight parity grid: 3 shapes x 6 patterns
# ----------------------------------------------------------------------
@pytest.mark.parametrize("pattern", sorted(PATTERNS))
@pytest.mark.parametrize("nx,ny", MESHES)
def test_parity_grid_midflight(pattern, nx, ny):
    """Fused vs Pallas, stopped mid-flight (packets still in FIFOs, in
    the response delay line, waiting on credits): the full state pytree
    must match leaf-for-leaf, not just after drain."""
    if pattern == "transpose" and nx != ny:
        pytest.skip("transpose is undefined on non-square meshes")
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=6)
    entries = make_traffic(pattern, nx, ny, 8, rate=0.7, seed=11)
    a, b = _pair_impls(cfg, entries)
    a.run(40)
    b.run(40)
    _assert_states_identical(a, b)


# ----------------------------------------------------------------------
# randomized-program corpus (the properties-suite generator, pallas side)
# ----------------------------------------------------------------------
def _random_prog(rng, ny, nx, L, ops):
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = rng.choice(ops, size=(ny, nx, L))
    lens = rng.integers(0, L + 1, size=(ny, nx))
    prog["op"][np.arange(L)[None, None, :] >= lens[..., None]] = -1
    prog["dst_x"][:] = rng.integers(0, nx, (ny, nx, L))
    prog["dst_y"][:] = rng.integers(0, ny, (ny, nx, L))
    prog["addr"][:] = rng.integers(0, 16, (ny, nx, L))
    prog["data"][:] = rng.integers(0, 1 << 20, (ny, nx, L))
    prog["cmp"][:] = rng.integers(0, 4, (ny, nx, L))
    return prog


FUZZ_MESHES = ((2, 2), (3, 2), (4, 3))
FUZZ_L = 6


@pytest.mark.parametrize("seed", range(10))
def test_parity_fuzz_corpus(seed):
    """Random programs (shape, ops incl. CAS, pacing, effective FIFO
    depth / credit allowance as state), run to drain on both impls:
    identical drain cycle and identical state.  Odd seeds run the kernel
    with a multi-cycle inner loop that does not divide the drain-fence
    cadence, so remainder launches are exercised across the corpus."""
    rng = np.random.default_rng(1000 + seed)
    nx, ny = FUZZ_MESHES[int(rng.integers(0, len(FUZZ_MESHES)))]
    fifo = int(rng.integers(2, 5))
    credits = int(rng.integers(1, 9))
    resp_latency = int(rng.integers(1, 3))
    rate = int(rng.integers(10, 101)) / 100.0
    ops = (OP_STORE, OP_LOAD, OP_CAS) if rng.integers(0, 2) \
        else (OP_STORE, OP_LOAD)
    prog = _random_prog(rng, ny, nx, FUZZ_L, ops)
    prog["not_before"][:] = np.floor(np.arange(FUZZ_L) / rate).astype(np.int64)

    # capacity config with the effective depth/credits as state, as the
    # differential fuzz does — amortizes compilations across the corpus
    cfg = MeshConfig(nx=nx, ny=ny, router_fifo=4, ep_fifo=4,
                     max_out_credits=8, mem_words=16,
                     resp_latency=resp_latency)
    a, b = _pair_impls(cfg, prog, cycles_per_call=3 if seed % 2 else 1,
                       check_every=4, fifo_depth=fifo, max_credits=credits)
    ca = a.run_until_drained(max_cycles=4000)
    cb = b.run_until_drained(max_cycles=4000)
    assert ca == cb, "drain cycle diverged"
    _assert_states_identical(a, b)


# ----------------------------------------------------------------------
# cycles_per_call bit-identity
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cycles_per_call", [1, 4, 7])
def test_cycles_per_call_bit_identity(cycles_per_call):
    """A fixed 96-cycle horizon decomposes as 96x1, 24x4 and 13x7+5 (the
    last exercising the remainder launch); every decomposition must land
    on the same state as the fused reference."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=6)
    entries = make_traffic("uniform", 4, 4, 12, rate=0.6, seed=5)
    a, b = _pair_impls(cfg, entries, cycles_per_call=cycles_per_call)
    a.run(96)
    b.run(96)
    _assert_states_identical(a, b)


@pytest.mark.parametrize("check_every,cycles_per_call",
                         [(1, 4),   # kernel loop longer than the fence block
                          (8, 3)])  # fence block not a multiple of the loop
def test_unroll_mismatched_drain_fences(check_every, cycles_per_call):
    """Drain fences that do not line up with the kernel's inner loop:
    the launch decomposition clamps/splits per fence block, and the drain
    cycle plus the full final state still match the fused path run with
    the same fence cadence."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=4)
    entries = make_traffic("tornado", 4, 4, 6, seed=3)
    a, b = _pair_impls(cfg, entries, cycles_per_call=cycles_per_call,
                       check_every=check_every)
    ca = a.run_until_drained()
    cb = b.run_until_drained()
    assert ca == cb, "drain cycle diverged"
    _assert_states_identical(a, b)


def test_check_every_leaves_drain_cycle_unchanged():
    """The exact drain cycle is a property of the network, not of the
    fence-check cadence or the kernel's launch decomposition: every
    (check_every, cycles_per_call) combination reports the same cycle as
    the fused check_every=1 reference — and the same delivered memory."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=4)
    entries = make_traffic("hotspot", 4, 4, 6, fraction=0.8, seed=9)
    ref = Simulator(cfg, backend="jax")
    ref.attach({k: v.copy() for k, v in entries.items()})
    c_ref = ref.run_until_drained()
    for check_every, cycles_per_call in [(1, 1), (5, 2), (8, 3)]:
        sim = Simulator(cfg, backend="jax", impl="pallas",
                        cycles_per_call=cycles_per_call,
                        check_every=check_every)
        sim.attach({k: v.copy() for k, v in entries.items()})
        assert sim.run_until_drained() == c_ref, \
            f"drain cycle moved at check_every={check_every}, " \
            f"cycles_per_call={cycles_per_call}"
        np.testing.assert_array_equal(np.asarray(ref.mem),
                                      np.asarray(sim.mem))


# ----------------------------------------------------------------------
# oracle anchor + functional entry points
# ----------------------------------------------------------------------
def test_pallas_matches_numpy_oracle():
    """Transitivity made explicit: the kernel path agrees with the numpy
    oracle directly (memory, stats, traces, telemetry, packet fields)."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=4)
    entries = make_traffic("uniform", 4, 4, 6, op=OP_LOAD, seed=7)
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in entries.items()})
    b = Simulator(cfg, backend="jax", impl="pallas", cycles_per_call=2)
    b.attach(entries)
    assert a.run_until_drained() == b.run_until_drained()
    assert_state_equal(a, b)


def test_single_step_parity():
    """The raw functional ``step(..., impl="pallas")`` advances exactly
    one cycle, bit-identically, including the per-cycle completion
    count it returns."""
    cfg = MeshConfig(nx=3, ny=3, max_out_credits=4).to_sim()
    prog = load_program(make_traffic("neighbor", 3, 3, 4, seed=2))
    st_f = init_state(cfg)
    st_p = init_state(cfg)
    for cyc in range(12):
        st_f, done_f = step(cfg, prog, st_f)
        st_p, done_p = step(cfg, prog, st_p, impl="pallas")
        assert int(done_f) == int(done_p), f"completions diverged at {cyc}"
    la = jax.tree_util.tree_leaves(st_f)
    lb = jax.tree_util.tree_leaves(st_p)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"SimState leaf {i}")


def test_load_latency_sweep_impl_invariant():
    """The measurement layer under ``impl="pallas"`` (vmapped over rates)
    reproduces the fused sweep bit-for-bit — histograms included."""
    kw = dict(warmup=20, measure=40, drain=40,
              cfg=MeshConfig(nx=2, ny=2, max_out_credits=8), seed=1)
    ref = load_latency_sweep("uniform", 2, 2, (0.1, 0.3), **kw)
    out = load_latency_sweep("uniform", 2, 2, (0.1, 0.3), impl="pallas",
                             cycles_per_call=5, **kw)
    for k in ("offered", "accepted", "delivered", "lat_mean", "lat_p99",
              "peak_link_util", "hist"):
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(out[k]),
                                      err_msg=f"sweep field {k!r}")


# ----------------------------------------------------------------------
# knob validation + facade-boundary snapshot semantics
# ----------------------------------------------------------------------
def test_invalid_knobs_rejected():
    cfg = MeshConfig(nx=2, ny=2)
    with pytest.raises(ValueError, match="impl"):
        Simulator(cfg, backend="jax", impl="bogus")
    with pytest.raises(ValueError, match="cycles_per_call"):
        Simulator(cfg, backend="jax", impl="pallas", cycles_per_call=0)
    with pytest.raises(ValueError, match="impl"):
        simulate(cfg.to_sim(), load_program(make_traffic("uniform", 2, 2, 2)),
                 init_state(cfg.to_sim()), 4, 1, "bogus")


@pytest.mark.parametrize("impl", ["fused", "pallas"])
def test_telemetry_snapshot_survives_donation(impl):
    """The aliasing regression: ``simulate`` donates its SimState and the
    Pallas kernel aliases inputs to outputs, so the backing buffers of a
    telemetry record taken mid-run get overwritten by the next ``run``.
    ``Telemetry.of`` must copy at the facade boundary — the snapshot is a
    point in time, whatever runs afterwards."""
    cfg = MeshConfig(nx=4, ny=4, max_out_credits=6)
    entries = make_traffic("uniform", 4, 4, 12, rate=0.8, seed=4)
    sim = Simulator(cfg, backend="jax", impl=impl,
                    cycles_per_call=2 if impl == "pallas" else 1)
    sim.attach(entries)
    sim.run(40)
    snap = sim.telemetry()
    frozen = {f: np.asarray(getattr(snap, f)).copy()
              for f in ("completed", "lat_sum", "completed_per_cycle",
                        "link_util_fwd", "lat_hist")}
    sim.run(80)
    after = sim.telemetry()
    assert int(after.completed.sum()) > int(snap.completed.sum()), \
        "the second run delivered nothing — the regression check is vacuous"
    for f, want in frozen.items():
        np.testing.assert_array_equal(
            np.asarray(getattr(snap, f)), want,
            err_msg=f"telemetry snapshot field {f!r} mutated by a later run")
