"""Cross-topology differential test grid — the verification layer for the
pluggable-topology refactor (mesh / torus / ring-mesh / multi-chip).

Four layers, all parameterized by topology:

* **parity grid** — {topology} x {3 shapes} x {patterns}: the numpy
  oracle and the fused-XLA step must match leaf-for-leaf *mid-flight*
  (``assert_state_equal`` at chunk boundaries), not just at drain;
* **pallas leg** — on the 4x4 of every topology the Pallas router kernel
  must match the fused step (and the oracle) the same way, multi-cycle
  launches included;
* **fuzz corpus** — 10 deterministic random programs per wrapped/gated
  topology, run to drain on both backends and compared on memory, stats,
  traces and telemetry;
* **invariants & properties** — per-cycle conservation and an analytic
  drain bound per topology (deadlock freedom of the ring bubble rule and
  the boundary gate), plus topology-specific properties: torus hops never
  exceed mesh hops, wraparound routes walked through the real routing
  function are minimal, the boundary link serializes at exactly its
  configured width, ring-mesh row traffic stays on the row ring, and the
  analytic uniform saturation bounds are self-consistent.
"""
import numpy as np
import pytest

from repro.core.netsim import (E, MeshSim, N, NetConfig, OP_CAS, OP_LOAD,
                               OP_STORE, P, S, W, unloaded_rtt)
from repro.mesh import MeshConfig, Simulator, Topology, make_traffic
from repro.netsim_jax.testing import assert_state_equal

TOPOLOGIES = {
    "mesh": Topology.mesh(),
    "torus": Topology.torus(),
    "ring_mesh": Topology.ring_mesh(),
    "multi_chip": Topology.multi_chip(chips_x=2, boundary_period=4),
}
# every shape has even nx so the two-chip topology fits on all of them
GRID_SHAPES = ((4, 4), (4, 3), (6, 2))
GRID_PATTERNS = ("uniform", "transpose", "bit_complement", "tornado",
                 "hotspot", "neighbor")


def _cfg(nx, ny, topo, **kw):
    kw.setdefault("max_out_credits", 4)
    kw.setdefault("router_fifo", 2)
    kw.setdefault("mem_words", 16)
    return MeshConfig(nx=nx, ny=ny, topology=topo, **kw)


def _pair(cfg, prog, backends=("numpy", "jax"), seed=3, **sim_kw):
    sims = []
    for b in backends:
        kw = dict(sim_kw)
        if b != "jax":
            kw.pop("impl", None)
        s = Simulator(cfg, backend=b, seed=seed, **kw)
        s.load_program({k: v.copy() for k, v in prog.items()})
        sims.append(s)
    return sims


# ----------------------------------------------------------------------
# the differential parity grid: oracle vs fused, mid-flight
# ----------------------------------------------------------------------
@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("shape", GRID_SHAPES)
@pytest.mark.parametrize("pattern", GRID_PATTERNS)
def test_parity_grid(topo_name, shape, pattern):
    nx, ny = shape
    if pattern == "transpose" and nx != ny:
        pytest.skip("transpose undefined off-square")
    topo = TOPOLOGIES[topo_name]
    cfg = _cfg(nx, ny, topo)
    prog = make_traffic(pattern, nx, ny, 12, rate=0.7, seed=11,
                        topology=topo)
    a, b = _pair(cfg, prog)
    for _ in range(4):                      # mid-flight, not just at drain
        a.run(40)
        b.run(40)
        assert_state_equal(a, b)
    ca = a.run_until_drained(max_cycles=4000)
    cb = b.run_until_drained(max_cycles=4000)
    assert ca == cb, "drain cycle diverged"
    assert_state_equal(a, b)
    assert int(a.completed.sum()) == nx * ny * 12


# ----------------------------------------------------------------------
# the pallas leg: fused vs kernel (and oracle), every topology
# ----------------------------------------------------------------------
@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("cycles_per_call", (1, 3))
def test_pallas_parity(topo_name, cycles_per_call):
    topo = TOPOLOGIES[topo_name]
    cfg = _cfg(4, 4, topo)
    prog = make_traffic("uniform", 4, 4, 12, rate=0.7, seed=11,
                        topology=topo)
    a = Simulator(cfg, backend="numpy", seed=3)
    b = Simulator(cfg, backend="jax", seed=3)                # fused
    c = Simulator(cfg, backend="jax", seed=3, impl="pallas",
                  cycles_per_call=cycles_per_call)
    for s in (a, b, c):
        s.load_program({k: v.copy() for k, v in prog.items()})
    for _ in range(4):
        for s in (a, b, c):
            s.run(12)                       # multiple of cycles_per_call
        assert_state_equal(a, b)
        assert_state_equal(b, c)            # fused <-> pallas, leaf-for-leaf
    for s in (a, b, c):
        s.run_until_drained(max_cycles=4000)
    assert_state_equal(a, b)
    assert_state_equal(b, c)


# ----------------------------------------------------------------------
# 10-seed randomized drain corpus per (non-mesh) topology
# (the plain-mesh corpus lives in tests/test_netsim_properties.py)
# ----------------------------------------------------------------------
def _random_prog(rng, ny, nx, L, ops=(OP_STORE, OP_LOAD, OP_CAS)):
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = rng.choice(ops, size=(ny, nx, L))
    lens = rng.integers(0, L + 1, size=(ny, nx))
    tail = np.arange(L)[None, None, :] >= lens[..., None]
    prog["op"][tail] = -1
    prog["dst_x"][:] = rng.integers(0, nx, (ny, nx, L))
    prog["dst_y"][:] = rng.integers(0, ny, (ny, nx, L))
    prog["addr"][:] = rng.integers(0, 16, (ny, nx, L))
    prog["data"][:] = rng.integers(0, 1 << 20, (ny, nx, L))
    prog["cmp"][:] = rng.integers(0, 4, (ny, nx, L))
    return prog, lens


FUZZ_SHAPES = {"torus": (4, 3), "ring_mesh": (4, 3), "multi_chip": (4, 2)}
FUZZ_L = 6


@pytest.mark.parametrize("topo_name", sorted(FUZZ_SHAPES))
@pytest.mark.parametrize("seed", range(10))
def test_differential_fuzz_corpus(topo_name, seed):
    rng = np.random.default_rng(1000 + seed)
    topo = TOPOLOGIES[topo_name]
    nx, ny = FUZZ_SHAPES[topo_name]
    prog, lens = _random_prog(rng, ny, nx, FUZZ_L)
    rate = int(rng.integers(10, 101)) / 100.0
    prog["not_before"][:] = np.floor(np.arange(FUZZ_L) / rate).astype(np.int64)
    fifo = int(rng.integers(2, 5))
    credits = int(rng.integers(1, 9))
    resp_latency = int(rng.integers(1, 3))

    cfg = _cfg(nx, ny, topo, router_fifo=fifo, max_out_credits=credits,
               resp_latency=resp_latency)
    a = Simulator(cfg, backend="numpy")
    a.attach({k: v.copy() for k, v in prog.items()})
    # drive the JAX backend through its capacity config with the effective
    # depth/credits as (vmap-able) state, as the mesh corpus does
    jcfg = _cfg(nx, ny, topo, router_fifo=4, max_out_credits=8,
                resp_latency=resp_latency)
    b = Simulator(jcfg, backend="jax", fifo_depth=fifo, max_credits=credits)
    b.attach(prog)

    ca = a.run_until_drained(max_cycles=4000)
    cb = b.run_until_drained(max_cycles=4000)
    assert ca == cb, "drain cycle diverged"
    assert_state_equal(a, b)
    assert int(a.completed.sum()) == int(lens.sum())


# ----------------------------------------------------------------------
# invariants: conservation every cycle + analytic drain bound
# ----------------------------------------------------------------------
def _assert_conservation(sim: MeshSim, credits: int):
    injected = int(sim.prog_ptr.sum())
    in_flight = (int(sim.fwd.count.sum()) + int(sim.ep_in.count.sum())
                 + int(sim.resp_valid.sum()) + int(sim.rev.count.sum())
                 + int(sim.reg_valid.sum()))
    delivered = int(sim.completed.sum())
    assert injected == delivered + in_flight, \
        f"packet leak: injected {injected} != delivered {delivered} " \
        f"+ in-flight {in_flight}"
    assert (sim.credits >= 0).all(), "endpoint sent while out of credit"
    assert (sim.credits <= credits).all(), "credit over-return"
    debt = credits - sim.credits
    per_tile_inflight = sim.prog_ptr - sim.completed - sim.reg_valid
    np.testing.assert_array_equal(debt, per_tile_inflight,
                                  err_msg="credit debt != in-flight")


def _drain_bound(topo, nx, ny, total, not_before_max):
    """Serialization bound: deadlock freedom (XY + ring bubble) means each
    transaction completes within one worst-case RTT of the previous; a
    boundary link adds up to (period - 1) stall cycles per crossing, each
    way, per boundary."""
    per_hop_stall = 2 * (topo.chips_x - 1) * (topo.boundary_period - 1)
    return not_before_max + \
        (total + 1) * (unloaded_rtt(topo.diameter(nx, ny) + 2)
                       + per_hop_stall)


@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("seed", range(4))
def test_conservation_and_drain_bound(topo_name, seed):
    rng = np.random.default_rng(2000 + seed)
    topo = TOPOLOGIES[topo_name]
    nx, ny = (4, 3) if topo_name != "multi_chip" else (4, 4)
    L = int(rng.integers(1, 9))
    credits = int(rng.integers(1, 9))
    fifo = int(rng.integers(2, 5))
    prog, lens = _random_prog(rng, ny, nx, L)
    prog["not_before"][:] = rng.integers(0, 20, (ny, nx, L))
    cfg = NetConfig(nx=nx, ny=ny, router_fifo=fifo, mem_words=16,
                    max_out_credits=credits, topology=topo)
    sim = MeshSim(cfg)
    sim.load_program(prog)

    total = int(lens.sum())
    bound = _drain_bound(topo, nx, ny, total, int(prog["not_before"].max()))
    cycles = 0
    while cycles < bound:
        if (sim.prog_ptr >= sim.prog_len).all() and \
                (sim.credits == credits).all() and not sim.reg_valid.any():
            break
        sim.step()
        cycles += 1
        _assert_conservation(sim, credits)
    else:
        pytest.fail(f"program did not drain within the analytic bound "
                    f"({bound} cycles for {total} packets on {topo_name})")
    assert int(sim.completed.sum()) == total


def test_wrapped_topologies_require_fifo_two():
    with pytest.raises(ValueError, match="router_fifo >= 2"):
        NetConfig(nx=4, ny=4, router_fifo=1, topology=Topology.torus())
    with pytest.raises(ValueError, match="router_fifo >= 2"):
        MeshConfig(nx=4, ny=4, router_fifo=1,
                   topology=Topology.ring_mesh()).to_sim()


# ----------------------------------------------------------------------
# topology properties
# ----------------------------------------------------------------------
def _all_pairs(nx, ny):
    n = nx * ny
    ys, xs = np.mgrid[0:ny, 0:nx]
    fx, fy = xs.reshape(-1), ys.reshape(-1)
    return (np.repeat(fx, n), np.repeat(fy, n),
            np.tile(fx, n), np.tile(fy, n))


@pytest.mark.parametrize("shape", ((4, 4), (5, 3), (6, 2)))
def test_torus_hops_never_exceed_mesh_hops(shape):
    nx, ny = shape
    sx, sy, dx, dy = _all_pairs(nx, ny)
    mesh_h = Topology.mesh().hops(sx, sy, dx, dy, nx, ny)
    ring_h = Topology.ring_mesh().hops(sx, sy, dx, dy, nx, ny)
    torus_h = Topology.torus().hops(sx, sy, dx, dy, nx, ny)
    assert (torus_h <= ring_h).all() and (ring_h <= mesh_h).all()
    # wraparound actually helps somewhere on every extent > 2
    if nx > 2:
        assert (torus_h < mesh_h).any()


@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("shape", ((4, 4), (5, 3), (6, 2)))
def test_routes_walked_through_route_are_minimal(topo_name, shape):
    """Walk every (src, dst) pair through the actual routing function:
    the walk must deliver in exactly ``hops`` steps (wraparound routes
    minimal, tie-break stable — an inconsistent tie-break would loop)."""
    nx, ny = shape
    topo = TOPOLOGIES[topo_name]
    if topo.chips_x > 1 and nx % topo.chips_x:
        pytest.skip("chip width must divide nx")
    px, py, dx, dy = _all_pairs(nx, ny)
    expect = topo.hops(px, py, dx, dy, nx, ny)
    steps = np.zeros_like(expect)
    for _ in range(topo.diameter(nx, ny) + 1):
        d = topo.route(dx, dy, px, py, nx, ny, xp=np)
        alive = d != P
        if not alive.any():
            break
        steps += alive
        px = np.where(d == E, (px + 1) % nx,
                      np.where(d == W, (px - 1) % nx, px))
        py = np.where(d == S, (py + 1) % ny,
                      np.where(d == N, (py - 1) % ny, py))
    assert ((px == dx) & (py == dy)).all(), "a route failed to terminate"
    np.testing.assert_array_equal(steps, expect,
                                  err_msg="non-minimal route")


def test_boundary_link_serializes_at_configured_width():
    """K packets streaming across one chip boundary cannot beat the 1-per-
    period link: the drain time carries the (K-1)*period serialization."""
    period, K = 4, 8
    topo = Topology.multi_chip(chips_x=2, boundary_period=period)
    results = {}
    for kind, t in (("mesh", Topology.mesh()), ("multi_chip", topo)):
        cfg = _cfg(4, 1, t, max_out_credits=16, router_fifo=4)
        prog = {k: np.zeros((1, 4, K), np.int64)
                for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                          "not_before")}
        prog["op"][:] = -1
        prog["op"][0, 0, :] = OP_STORE       # (0,0) -> (3,0), crosses x=2
        prog["dst_x"][0, 0, :] = 3
        prog["addr"][0, 0, :] = np.arange(K)
        a, b = _pair(cfg, prog)
        ca = a.run_until_drained(max_cycles=2000)
        cb = b.run_until_drained(max_cycles=2000)
        assert ca == cb
        assert_state_equal(a, b)
        results[kind] = ca
    # both directions of the round trip cross the boundary: the gated run
    # must carry at least the serialization of the narrower link...
    assert results["multi_chip"] >= results["mesh"] + (K - 1) * (period - 1)
    # ...and the analytic upper bound: full serialization at one flit per
    # period each way plus the ungated drain time
    assert results["multi_chip"] <= results["mesh"] + \
        2 * (K + 1) * (period - 1)


def test_ring_mesh_row_traffic_stays_on_row_ring():
    """Same-row traffic on the ring-mesh never touches a N/S channel, and
    the x=edge wrap neighbour is one hop away (unloaded RTT of a single
    wrap hop == unloaded_rtt(1))."""
    nx, ny = 6, 3
    cfg = _cfg(nx, ny, Topology.ring_mesh(), max_out_credits=1,
               router_fifo=2)
    # every tile sends to its east neighbour with wraparound: on the ring
    # mesh the x=5 tile reaches x=0 over the wrap link (1 hop)
    L = 4
    prog = {k: np.zeros((ny, nx, L), np.int64)
            for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                      "not_before")}
    prog["op"][:] = OP_STORE
    ys, xs = np.mgrid[0:ny, 0:nx]
    prog["dst_x"][:] = ((xs + 1) % nx)[..., None]
    prog["dst_y"][:] = ys[..., None]
    prog["addr"][:] = np.arange(L)[None, None, :]
    a, b = _pair(cfg, prog)
    ca = a.run_until_drained(max_cycles=2000)
    cb = b.run_until_drained(max_cycles=2000)
    assert ca == cb
    assert_state_equal(a, b)
    t = a.telemetry()
    hm = np.asarray(t.link_util_fwd)
    assert hm[..., N].sum() == 0 and hm[..., S].sum() == 0, \
        "row traffic leaked onto column channels"
    assert hm[..., [E, W]].sum() > 0
    # single-packet wrap RTT: credits=1 serializes, so mean latency is the
    # unloaded single-hop RTT for every (east-neighbour) transaction
    assert t.lat_sum.sum() / t.completed.sum() == unloaded_rtt(1)


def test_torus_wrap_route_is_single_hop():
    """A packet from (nx-1, y) to (0, y) takes the wrap link on the torus
    (1 hop) but the full row on the mesh (nx-1 hops)."""
    nx, ny = 5, 3
    for topo, hops in ((Topology.mesh(), nx - 1), (Topology.torus(), 1)):
        cfg = _cfg(nx, ny, topo, max_out_credits=1)
        prog = {k: np.zeros((ny, nx, 1), np.int64)
                for k in ("dst_x", "dst_y", "addr", "data", "cmp", "op",
                          "not_before")}
        prog["op"][:] = -1
        prog["op"][1, nx - 1, 0] = OP_STORE
        prog["dst_x"][1, nx - 1, 0] = 0
        prog["dst_y"][1, nx - 1, 0] = 1
        a, b = _pair(cfg, prog)
        a.run_until_drained(max_cycles=200)
        b.run_until_drained(max_cycles=200)
        assert_state_equal(a, b)
        t = a.telemetry()
        assert int(t.lat_sum.sum()) == unloaded_rtt(hops)


def test_uniform_saturation_bounds_are_ordered():
    """The analytic channel-load bounds recover the classic results: the
    16x16 mesh saturates near 4/k = 0.25 of line rate under uniform
    traffic, the torus near twice that, the ring-mesh matches the mesh
    (the y bisection still binds), and the gated multi-chip sits near
    1/period of the mesh's x-bisection bound."""
    mesh = Topology.mesh().uniform_saturation_bound(16, 16)
    torus = Topology.torus().uniform_saturation_bound(16, 16)
    ring = Topology.ring_mesh().uniform_saturation_bound(16, 16)
    multi = Topology.multi_chip(
        chips_x=2, boundary_period=4).uniform_saturation_bound(16, 16)
    assert abs(mesh - 0.25) < 0.01
    assert 1.7 * mesh < torus <= 2.0 * mesh
    assert abs(ring - mesh) < 1e-9
    assert multi < mesh / 3
    # hold on the actual routed crossings: route() walks, not formulas,
    # so the tie-break bias is priced in — the bound must stay positive
    # and <= 1 everywhere
    for t in TOPOLOGIES.values():
        b = t.uniform_saturation_bound(8, 8)
        assert 0.0 < b <= 1.0


def test_tornado_is_torus_relative():
    """Classic tornado on a wrapped dimension shifts floor(k/2) with
    wraparound; the mesh keeps the near-half offset (bit-identical
    baselines)."""
    mesh_prog = make_traffic("tornado", 8, 8, 1, topology=Topology.mesh())
    torus_prog = make_traffic("tornado", 8, 8, 1, topology=Topology.torus())
    no_topo = make_traffic("tornado", 8, 8, 1)
    xs = np.arange(8)
    np.testing.assert_array_equal(mesh_prog["dst_x"][0, :, 0], (xs + 3) % 8)
    np.testing.assert_array_equal(no_topo["dst_x"][0, :, 0], (xs + 3) % 8)
    np.testing.assert_array_equal(torus_prog["dst_x"][0, :, 0], (xs + 4) % 8)
    # ring-mesh: x wraps, y does not
    rm = make_traffic("tornado", 8, 8, 1, topology=Topology.ring_mesh())
    np.testing.assert_array_equal(rm["dst_x"][0, :, 0], (xs + 4) % 8)
    np.testing.assert_array_equal(rm["dst_y"][:, 0, 0], (xs + 3) % 8)


def test_topology_validation_errors():
    with pytest.raises(ValueError, match="divisible"):
        MeshConfig(nx=5, ny=4, topology=Topology.multi_chip(chips_x=2))
    with pytest.raises(ValueError, match="divisible"):
        make_traffic("uniform", 5, 4, 4,
                     topology=Topology.multi_chip(chips_x=2))
    with pytest.raises(ValueError, match="non-square"):
        make_traffic("transpose", 4, 3, 4, topology=Topology.torus())
    with pytest.raises(ValueError, match="unknown topology kind"):
        Topology("hypercube")
    with pytest.raises(ValueError, match="constructor"):
        Topology("mesh", wrap_x=True)
    with pytest.raises(ValueError, match="chips_x >= 2"):
        Topology.multi_chip(chips_x=1)


def test_topology_round_trips_through_configs():
    """The topology survives MeshConfig <-> NetConfig <-> SimConfig
    round-trips and reaches both backends' configs."""
    topo = Topology.multi_chip(chips_x=2, boundary_period=3)
    cfg = MeshConfig(nx=4, ny=4, topology=topo)
    assert cfg.to_net().topology == topo
    assert cfg.to_sim().topology == topo
    assert MeshConfig.from_net(cfg.to_net()).topology == topo
    assert MeshConfig.from_sim(cfg.to_sim()).topology == topo
    assert MeshConfig(nx=4, ny=4).topology == Topology.mesh()
    assert hash(cfg.to_sim()) == hash(cfg.to_sim())  # jit-static
