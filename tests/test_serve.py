"""Serving: continuous batching correctness (slot isolation)."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.launch.serve import Request, Server


@pytest.fixture(scope="module")
def cfg():
    return reduced_config(get_config("stablelm-3b"),
                          num_layers=2, d_model=64, num_heads=2,
                          num_kv_heads=2, head_dim=32, d_ff=128,
                          vocab_size=128)


def _serve(cfg, mesh, prompts, slots, max_new=6):
    server = Server(cfg, mesh, slots=slots, max_seq=64)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=np.asarray(p, np.int32),
                              max_new=max_new))
    server.run(tick_limit=500)
    done = sorted(server.completed, key=lambda r: r.rid)
    return [r.out for r in done], server


def test_all_requests_complete(cfg, mesh_dm):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, size=5) for _ in range(6)]
    outs, server = _serve(cfg, mesh_dm, prompts, slots=2)
    assert len(outs) == 6
    assert all(len(o) == 6 for o in outs)


@pytest.mark.xfail(
    tuple(map(int, jax.__version__.split(".")[:2])) < (0, 5),
    reason="partial-manual shard_map resharding (auto mode) is unreliable "
           "on jax<0.5 and check_rep is off there (no lax.pcast), so the "
           "packed decode path miscomputes; passes on jax>=0.6",
    strict=False)
def test_continuous_batching_matches_isolated(cfg, mesh_dm):
    """Outputs must be identical whether a request runs alone (1 slot) or
    packed with others (2 slots, staggered admission) — proves slot/cache
    isolation under continuous batching."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 128, size=4) for _ in range(4)]
    outs_iso = []
    for p in prompts:
        o, _ = _serve(cfg, mesh_dm, [p], slots=1)
        outs_iso.append(o[0])
    outs_packed, _ = _serve(cfg, mesh_dm, prompts, slots=2)
    assert outs_packed == outs_iso


def test_slot_reuse_after_completion(cfg, mesh_dm):
    """More requests than slots: slots recycle (credits return)."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 128, size=3) for _ in range(5)]
    outs, server = _serve(cfg, mesh_dm, prompts, slots=2, max_new=4)
    assert len(outs) == 5
    assert server.ticks < 500
