"""Packet-based synchronization primitives (paper C8)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import sync

T, MEM = 8, 16


def _sm(mesh, fn, *args, in_specs, out_specs):
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs))(*args)


def test_mutex_exactly_one_winner_and_release(mesh2x4):
    def f(mem):
        owner = jnp.asarray(5, jnp.int32)  # lock lives on tile 5
        mem1, acquired = sync.mutex_try_acquire(mem[0], owner, 0, "x", "y", T)
        mem2 = sync.mutex_release(mem1, owner, 0, acquired[None, None], "x", "y", T)
        return mem1[None], mem2[None], acquired[None]

    m1, m2, acq = _sm(mesh2x4, f, jnp.zeros((T, MEM), jnp.float32),
                      in_specs=P(("y", "x"), None),
                      out_specs=(P(("y", "x"), None), P(("y", "x"), None), P(("y", "x"))))
    acq = np.asarray(acq)
    assert acq.sum() == 1
    winner = int(np.nonzero(acq)[0][0])
    assert np.asarray(m1)[5, 0] == winner + 1  # locked with winner id
    assert np.asarray(m2)[5, 0] == sync.MUTEX_UNLOCKED  # released


def test_barrier_all_arrive(mesh2x4):
    def f(mem):
        root = jnp.asarray(0, jnp.int32)
        mem = sync.barrier_arrive(mem[0], root, 0, "x", "y", T)
        return mem[None], sync.barrier_done(mem, 0, T)[None]

    mem, done = _sm(mesh2x4, f, jnp.zeros((T, MEM), jnp.float32),
                    in_specs=P(("y", "x"), None),
                    out_specs=(P(("y", "x"), None), P(("y", "x"))))
    # on the root tile all T arrival slots are set
    np.testing.assert_array_equal(np.asarray(mem)[0, :T], np.ones(T))
    assert np.asarray(done)[0]


def test_spmd_barrier_counts_tiles(mesh2x4):
    def f(_):
        return sync.spmd_barrier("x", "y")[None]

    n = _sm(mesh2x4, f, jnp.zeros((T, 1)),
            in_specs=P(("y", "x"), None), out_specs=P(("y", "x")))
    assert (np.asarray(n) == T).all()
