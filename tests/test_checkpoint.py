"""Checkpointing: atomic save/restore, integrity, async credits."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                    dtype=jnp.float32),
                   "b": jnp.asarray(rng.standard_normal(8),
                                    dtype=jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 7, tree, extra={"loss": 1.25})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got, step, extra = ckpt.restore(tmp_path, like)
    assert step == 7 and extra["loss"] == 1.25
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, tree)


def test_latest_step_ignores_tmp(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    ckpt.save(tmp_path, 5, _tree())
    (tmp_path / "step_00000009.tmp").mkdir()   # crashed save
    assert ckpt.latest_step(tmp_path) == 5


def test_corruption_detected(tmp_path):
    tree = _tree()
    d = ckpt.save(tmp_path, 3, tree)
    target = d / "params__w.npy"
    arr = np.load(target)
    arr[0, 0] += 1.0
    np.save(target, arr)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    with pytest.raises(IOError, match="crc"):
        ckpt.restore(tmp_path, like)


def test_missing_leaf_detected(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 3, tree)
    like = dict(tree)
    like["extra_leaf"] = jnp.zeros(3)
    with pytest.raises(KeyError):
        ckpt.restore(tmp_path, like)


def test_async_checkpointer_fence(tmp_path):
    ac = ckpt.AsyncCheckpointer(tmp_path, credits=2)
    for s in (10, 20, 30):
        ac.submit(s, _tree(s))
    ac.fence()
    assert ckpt.latest_step(tmp_path) == 30
    ac.close()


def test_async_snapshot_semantics(tmp_path):
    """The submitted tree is snapshotted at submit time; later mutation of
    the live arrays must not leak into the checkpoint."""
    ac = ckpt.AsyncCheckpointer(tmp_path, credits=1)
    arr = np.ones(4, np.float32)
    ac.submit(1, {"w": arr})
    arr[:] = -1                      # mutate after submit
    ac.fence()
    got, _, _ = ckpt.restore(tmp_path, {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones(4))
    ac.close()


def test_restore_with_shardings(tmp_path, mesh_dm):
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(tmp_path, 2, tree)
    sh = {"w": NamedSharding(mesh_dm, P("data", "model"))}
    got, step, _ = ckpt.restore(
        tmp_path, {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)},
        shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
