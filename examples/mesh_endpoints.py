"""Attach user designs to the mesh — the paper's integration story.

The BaseJump manycore's headline interface is the standardized endpoint
(`bsg_manycore_link`): any design that speaks the valid/ready forward
link + credit-based reverse link can occupy a tile.  This example plugs
two *reactive* user designs into the array through the
:class:`repro.mesh.Endpoint` protocol:

* a **remote-store DMA engine** streaming a buffer into a far tile's
  memory with a bounded outstanding-store window, and
* a **request/reply memory-controller client** pointer-chasing a linked
  ring seeded in the controller tile's memory — each request depends on
  the previous reply, so the traffic cannot be precomputed.

Both run natively on the numpy oracle and, via the trace-to-program
bridge, on the jitted JAX backend — with bit-identical telemetry, which
this script asserts.

  PYTHONPATH=src python examples/mesh_endpoints.py
  PYTHONPATH=src python examples/mesh_endpoints.py --nx 4 --ny 4
"""
import argparse

import numpy as np

from repro.mesh import (DmaEndpoint, MemoryControllerEndpoint, MeshConfig,
                        Simulator)


def build(cfg: MeshConfig, backend: str) -> Simulator:
    """One scenario, constructible on either backend: endpoints are
    stateful, so each backend gets fresh instances."""
    nx, ny, mw = cfg.nx, cfg.ny, cfg.mem_words
    sim = Simulator(cfg, backend=backend, seed=0)

    # seed a pointer ring in the memory-controller tile (far corner):
    # mem[a] = (a + 3) % mw, so the chase hops addresses 5, 8, 11, ...
    mem = np.zeros((ny, nx, mw), np.int64)
    mem[ny - 1, nx - 1, :] = (np.arange(mw) + 3) % mw
    sim.set_mem(mem)

    sim.attach(DmaEndpoint(dst_x=nx - 1, dst_y=0,
                           data=[100 + i for i in range(12)],
                           max_inflight=4), at=(0, 0))
    sim.attach(MemoryControllerEndpoint(dst_x=nx - 1, dst_y=ny - 1,
                                        start_addr=5, n_requests=8,
                                        mem_words=mw), at=(0, ny - 1))
    return sim


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=8)
    ap.add_argument("--ny", type=int, default=8)
    args = ap.parse_args()
    cfg = MeshConfig(nx=args.nx, ny=args.ny, mem_words=32)

    print(f"== reactive endpoints on the {cfg.nx}x{cfg.ny} mesh ==")
    sims = {}
    for backend in ("numpy", "jax"):
        sim = build(cfg, backend)
        cycle = sim.run_until_drained()
        sims[backend] = sim
        print(f"  [{backend:5s}] drained at cycle {cycle}")

    # the facade contract: one Telemetry record, bit-identical across
    # backends — including for endpoint-driven (bridged) scenarios
    t_np = sims["numpy"].telemetry()
    t_jax = sims["jax"].telemetry()
    t_np.assert_bit_identical(t_jax)
    print("  telemetry bit-identical across backends")

    dma = sims["numpy"].endpoints[(0, 0)]
    landed = np.asarray(sims["numpy"].mem)[0, cfg.nx - 1, :12]
    print(f"\n  DMA: {dma.sent} stores sent, {dma.acked} acked, "
          f"peak window {dma.peak_inflight}")
    print(f"       destination memory now {landed.tolist()}")

    mc = sims["numpy"].endpoints[(0, cfg.ny - 1)]
    print(f"\n  memory-controller client: chased {mc.visited} "
          f"({len(mc.latencies)} replies, "
          f"round-trip {min(mc.latencies)}..{max(mc.latencies)} cycles)")

    # endpoint scenarios still export as injection programs -> vmap fodder
    prog = sims["numpy"].injection_trace_program()
    n = int((prog["op"] >= 0).sum())
    print(f"\n  exported injection-trace program: {n} packets, "
          f"replayable/vmappable on the JAX path")


if __name__ == "__main__":
    main()
