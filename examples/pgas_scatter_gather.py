"""PGAS example: remote scatter/gather + a CAS mutex over the device mesh.

The paper's core programming model — every tile owns a memory region in
one global <X, Y, local> address space — exercised directly on an 8-device
mesh: a random scatter (remote stores, "the architecture is very good at
random scatter"), a gather-back (remote loads), and a distributed mutex
built on remote compare-and-swap.

The same scatter is ALSO compiled to cycle-level mesh traffic
(``repro.workloads.pgas_from_batches``) and replayed packet-by-packet on
the simulator; the example asserts the simulator's end-state memory
matches the SPMD ``remote_store`` result word for word — the one-shot
collective and the flit-level network agree on the program's semantics.

  PYTHONPATH=src python examples/pgas_scatter_gather.py
"""
import jax

from repro.compat import set_host_device_count
set_host_device_count(8)

import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402
from jax import lax                                            # noqa: E402
from repro.compat import make_auto_mesh, shard_map             # noqa: E402
from jax.sharding import PartitionSpec as P                    # noqa: E402

from repro.core import pgas                                    # noqa: E402
from repro.mesh import MeshConfig, Simulator                   # noqa: E402
from repro.workloads import (expected_memory,                  # noqa: E402
                             pgas_from_batches)

NY, NX = 2, 4
T = NY * NX
WORDS = 32
SLOTS = 4


def main():
    mesh = make_auto_mesh((NY, NX), ("y", "x"))
    mem0 = jnp.zeros((T, WORDS), jnp.float32)   # one region per tile

    def island(mem):
        mem = mem[0]                             # (WORDS,) local region
        me = pgas.tile_linear_index("x", "y")

        # --- random scatter: each tile stores me*100+s to tile (me+s)%T --
        pkts = pgas.make_packet_batch(T, SLOTS)
        for s in range(SLOTS):
            dst = (me + s + 1) % T
            pkts = pgas.PacketBatch(
                addr=pkts.addr.at[dst, s].set(s),
                data=pkts.data.at[dst, s].set(me * 100.0 + s),
                mask=pkts.mask.at[dst, s].set(True))
        mem, credits = pgas.remote_store(mem, pkts, "x", "y")
        fence_ok = credits.sum() == SLOTS        # all stores committed
        scatter_mem = mem                        # snapshot before CAS mutates

        # --- gather back: read word s of tile (me+s)%T -------------------
        lpk = pgas.make_packet_batch(T, SLOTS)
        for s in range(SLOTS):
            dst = (me + s + 1) % T
            lpk = pgas.PacketBatch(
                addr=lpk.addr.at[dst, s].set(s),
                data=lpk.data,
                mask=lpk.mask.at[dst, s].set(True))
        data, valid = pgas.remote_load(mem, lpk, "x", "y")
        got = jnp.where(valid, data, 0.0).sum()

        # --- CAS mutex at tile 0, word 0: exactly one winner -------------
        cpk = pgas.PacketBatch(
            addr=jnp.zeros((T, 1), jnp.int32),
            data=jnp.full((T, 1), (me + 1).astype(jnp.float32)),
            mask=jnp.zeros((T, 1), bool).at[0, 0].set(True))
        mem = mem.at[0].set(0.0)                 # unlock
        mem, old = pgas.remote_cas(mem, cpk, jnp.zeros((T, 1)), "x", "y")
        i_won = (old[0, 0] == 0.0)
        winners = lax.psum(i_won.astype(jnp.int32), ("x", "y"))

        return (mem[None], scatter_mem[None], credits[None], got[None],
                fence_ok[None], winners[None])

    mem, scatter_mem, credits, got, fence, winners = shard_map(
        island, mesh=mesh,
        in_specs=P(("y", "x"), None),
        out_specs=(P(("y", "x"), None), P(("y", "x"), None),
                   P(("y", "x"), None),
                   P(("y", "x")), P(("y", "x")), P(("y", "x"))),
        axis_names={"x", "y"})(mem0)

    mem = np.asarray(mem)
    print("memory regions after scatter (tile, first 4 words):")
    for t in range(T):
        print(f"  tile {t}: {mem[t, :SLOTS]}")
    print("store credits per tile:", np.asarray(credits).tolist())
    print("fence ok (credits == issued):", bool(np.asarray(fence).all()))
    print("gather sums per tile:", np.asarray(got).round(1).tolist())
    print("CAS mutex winners (must be 1):", int(np.asarray(winners)[0]))
    assert bool(np.asarray(fence).all())
    assert int(np.asarray(winners)[0]) == 1

    # --- the same scatter, packet by packet through the mesh ------------
    # Global (T_src, T_dst, S) view of the island's PacketBatch: source t
    # stores t*100+s to word s of tile (t+s+1)%T.
    addr = np.zeros((T, T, SLOTS), np.int64)
    data = np.zeros((T, T, SLOTS), np.int64)
    mask = np.zeros((T, T, SLOTS), bool)
    for t in range(T):
        for s in range(SLOTS):
            dst = (t + s + 1) % T
            addr[t, dst, s] = s
            data[t, dst, s] = t * 100 + s
            mask[t, dst, s] = True
    w = pgas_from_batches(addr, data, mask, NX, NY, mem_words=WORDS)
    sim = Simulator(MeshConfig(nx=NX, ny=NY, mem_words=WORDS),
                    backend="numpy")
    sim.attach({k: v.copy() for k, v in w.program.items()})
    drain = sim.run_until_drained(20_000)
    sim_mem = np.asarray(sim.mem).reshape(T, WORDS)   # row-major == tile id
    spmd_mem = np.asarray(scatter_mem).astype(np.int64)
    np.testing.assert_array_equal(
        sim_mem, spmd_mem,
        err_msg="cycle-level scatter disagrees with SPMD remote_store")
    np.testing.assert_array_equal(
        sim_mem, expected_memory(addr, data, mask, NX, NY,
                                 mem_words=WORDS).reshape(T, WORDS),
        err_msg="simulator memory disagrees with the analytic image")
    print(f"cycle-level replay: {w.n_packets} store packets drained at "
          f"cycle {drain}; simulator memory == remote_store memory")
    print("OK")


if __name__ == "__main__":
    main()
