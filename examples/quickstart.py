"""Quickstart: the framework's public API in ~60 lines.

Builds a reduced mixtral (MoE) on a 2x4 device mesh, runs a few training
steps through the fault-tolerant runtime, checkpoints, and decodes a few
tokens from the trained weights.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import jax

from repro.compat import set_host_device_count
set_host_device_count(8)

import numpy as np                                             # noqa: E402

from repro import optim                                        # noqa: E402
from repro.configs import get_config, reduced_config           # noqa: E402
from repro.configs.base import ShapeConfig                     # noqa: E402
from repro.data.pipeline import batch_iterator                 # noqa: E402
from repro.launch import step as step_mod                      # noqa: E402
from repro.launch.mesh import make_test_mesh                   # noqa: E402
from repro.models.api import get_model                         # noqa: E402
from repro.runtime import Trainer, TrainerConfig               # noqa: E402


def main():
    # 1. an architecture from the registry, reduced for CPU
    cfg = reduced_config(get_config("mixtral-8x7b"))
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=8,
                        kind="train")
    mesh = make_test_mesh((2, 4), ("data", "model"))
    print(f"arch={cfg.name}  params={cfg.param_count():,}  mesh=2x4")

    # 2. train for a few steps through the fault-tolerant runtime
    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(cfg, shape, mesh,
                          optim.OptConfig(warmup_steps=2, total_steps=20),
                          TrainerConfig(total_steps=20, ckpt_every=10,
                                        ckpt_dir=ckdir, log_every=5))
        trainer.init()
        metrics = trainer.run(batch_iterator(cfg, shape))
        print("final:", {k: round(v, 4) for k, v in metrics.items()})

        # 3. decode greedily from the trained weights
        model = get_model(cfg)
        rules = step_mod.cell_rules(
            mesh, cfg, ShapeConfig("d", 64, 8, "decode"))
        serve = jax.jit(step_mod.make_serve_step(cfg, rules),
                        donate_argnums=(1,))
        with mesh:
            cache = model.init_cache(cfg, 8, 64)
            toks = np.full((8,), 7, np.int32)
            outs = []
            for _ in range(8):
                toks, cache = serve(trainer.params, cache, toks)
                outs.append(np.asarray(toks).copy())
        print("decoded token ids (seq 0):", [int(o[0]) for o in outs])
        trainer.close()


if __name__ == "__main__":
    main()
