"""Pipeline parallelism via token-queue channels (paper C6 / Option 2).

Trains a small LM with its layer stack split across 4 pipeline stages
(the ``model`` mesh axis), microbatches flowing stage-to-stage through
one-hop ppermute channels.  Compares against the sequential reference and
prints the bubble fraction.

  PYTHONPATH=src python examples/pipeline_parallel.py
"""
import jax

from repro.compat import set_host_device_count
set_host_device_count(8)

import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.compat import make_auto_mesh                        # noqa: E402
from repro.parallel.pipeline import bubble_fraction, pipeline_apply  # noqa: E402

L, D, V = 8, 64, 512
N_MICRO, MB, S = 8, 2, 32


def body(lp, x):
    """One stage = L/n_stages residual MLP blocks."""
    def blk(h, w):
        w1, w2 = w
        return h + jnp.tanh(h @ w1) @ w2, None
    y, _ = jax.lax.scan(blk, x, lp)
    return y


def main():
    mesh = make_auto_mesh((2, 4), ("data", "model"))
    n_stages = mesh.shape["model"]
    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.standard_normal((L, D, D)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((L, D, D)) * 0.1, jnp.float32)
    params = (w1.reshape(n_stages, -1, D, D), w2.reshape(n_stages, -1, D, D))
    x = jnp.asarray(rng.standard_normal((N_MICRO, MB * S, D)), jnp.float32)
    y_tgt = jnp.asarray(rng.standard_normal((N_MICRO, MB * S, D)),
                        jnp.float32)

    def loss(ps, xb):
        out = pipeline_apply(body, ps, xb, mesh, stage_axis="model",
                             batch_axis="data")
        return jnp.mean((out - y_tgt) ** 2)

    def loss_ref(ws, xb):
        w1f = ws[0].reshape(L, D, D)
        w2f = ws[1].reshape(L, D, D)
        out = jax.vmap(lambda m: body((w1f, w2f), m))(xb)
        return jnp.mean((out - y_tgt) ** 2)

    l_pipe = float(jax.jit(loss)(params, x))
    l_ref = float(jax.jit(loss_ref)(params, x))
    print(f"pipeline loss {l_pipe:.6f} == sequential {l_ref:.6f}")
    assert abs(l_pipe - l_ref) < 1e-5

    # a few SGD steps through the pipelined graph (bwd = reverse wave)
    lr = 0.05
    grad = jax.jit(jax.grad(loss))
    p = params
    losses = []
    for i in range(10):
        g = grad(p, x)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        losses.append(float(jax.jit(loss)(p, x)))
    print("pipelined training losses:", [round(l, 4) for l in losses])
    assert losses[-1] < losses[0]
    print(f"bubble fraction at {N_MICRO} microbatches x {n_stages} stages: "
          f"{bubble_fraction(N_MICRO, n_stages):.2%} "
          f"(in-flight <= {n_stages} = channel depth, the C3 BDP rule)")
    print("OK")


if __name__ == "__main__":
    main()
