"""Design-space exploration walkthrough: sweep the router buffer sizing
and print the Pareto frontier of buffer area vs. saturation throughput.

Declares a :class:`repro.dse.SweepSpec` over fifo depth x credit
allowance x offered load x topology, runs it through the bucketed/
batched/sharded service (one XLA compilation per topology covers every
point), extracts per-topology frontiers priced by the lumos-style cost
model, and writes the JSON artifact.  Results cache on disk — re-running
with the same spec (or re-pricing with different --sram-um2-per-bit)
simulates nothing.

  PYTHONPATH=src python examples/dse_sweep.py
  PYTHONPATH=src python examples/dse_sweep.py --nx 8 --ny 8 \
      --topologies mesh torus --devices 2
"""
import argparse
import json
from pathlib import Path

from repro.dse import (CostModel, SweepSpec, frontier_artifact,
                       frontier_ascii, run_sweep, write_frontier)
from repro.mesh.traffic import PATTERNS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=4)
    ap.add_argument("--ny", type=int, default=4)
    ap.add_argument("--fifo-depths", nargs="+", type=int,
                    default=[2, 4, 8])
    ap.add_argument("--credits", nargs="+", type=int, default=[4, 16, 64])
    ap.add_argument("--pattern", default="uniform",
                    choices=sorted(PATTERNS))
    ap.add_argument("--loads", nargs="+", type=float,
                    default=[0.05, 0.15, 0.25, 0.35, 0.45])
    ap.add_argument("--topologies", nargs="+",
                    default=["mesh", "torus"],
                    help='e.g. mesh torus ring_mesh multi_chip:2:4')
    ap.add_argument("--warmup", type=int, default=100)
    ap.add_argument("--measure", type=int, default=200)
    ap.add_argument("--drain", type=int, default=200)
    ap.add_argument("--devices", type=int, default=None,
                    help="shard the sweep over N devices (default: "
                         "single-device chunked vmap)")
    ap.add_argument("--cache", type=Path,
                    default=Path(__file__).resolve().parents[1]
                    / "experiments" / "dse_cache",
                    help="result-cache directory (resumable re-runs)")
    ap.add_argument("--sram-um2-per-bit", type=float, default=0.525,
                    help="cost-model knob: SRAM cell area per bit")
    ap.add_argument("--out", type=Path,
                    default=Path(__file__).resolve().parents[1]
                    / "experiments" / "dse_example.json")
    args = ap.parse_args()

    spec = SweepSpec(nx=args.nx, ny=args.ny,
                     fifo_depths=tuple(args.fifo_depths),
                     credits=tuple(args.credits),
                     patterns=(args.pattern,), loads=tuple(args.loads),
                     topologies=tuple(args.topologies),
                     warmup=args.warmup, measure=args.measure,
                     drain=args.drain, name="example")
    result = run_sweep(spec, cache_dir=args.cache, devices=args.devices,
                       progress=print)
    print(f"\n{result.n_points} points: {result.simulated} simulated, "
          f"{result.cache_hits} from cache, {result.buckets} bucket(s), "
          f"{result.compiles} compile(s), {result.wall_s}s")

    cost = CostModel(sram_um2_per_bit=args.sram_um2_per_bit)
    artifact = frontier_artifact(result, cost=cost)
    print()
    print(frontier_ascii(artifact))
    for topo, f in artifact["frontiers"].items():
        print(f"\n  {topo} frontier (monotone={f['monotone']}):")
        for p in f["frontier"]:
            print(f"    fifo={p['fifo_depth']:3d} credits={p['credits']:4d}"
                  f"  area={p['area_mm2']:.4f} mm^2"
                  f"  sat-throughput={p['throughput']:.3f}"
                  f"  knee={p['saturation_rate']}"
                  f"  {p['energy_pj_per_packet']:.1f} pJ/pkt")
    path = write_frontier(args.out, artifact)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
