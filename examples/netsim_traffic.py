"""Quickstart: the unified mesh API (facade + traffic-pattern library).

Runs every synthetic traffic pattern through the JAX backend of the
:class:`repro.mesh.Simulator` facade (default: Celerity scale, 16x32 =
512 cores, far beyond what the numpy oracle can sweep interactively),
checks one pattern's telemetry bit-for-bit between the two backends on a
small mesh, and sweeps the credit allowance in a single vmapped XLA
program via the functional layer.

  PYTHONPATH=src python examples/netsim_traffic.py
  PYTHONPATH=src python examples/netsim_traffic.py --nx 4 --ny 4 --cycles 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.mesh import MeshConfig, PATTERNS, Simulator, make_traffic
from repro.netsim_jax import init_state, load_program, simulate


def pattern_sweep(nx: int, ny: int, cycles: int) -> None:
    cfg = MeshConfig(nx=nx, ny=ny, max_out_credits=32)
    print(f"== traffic patterns on the {nx}x{ny} ({nx * ny}-core) array ==")
    for name in sorted(PATTERNS):
        try:
            entries = make_traffic(name, nx, ny, cycles, seed=0)
        except ValueError as e:        # e.g. transpose on a non-square mesh
            print(f"  {name:16s} skipped ({e})")
            continue
        sim = Simulator(cfg, backend="jax").attach(entries)
        t0 = time.perf_counter()
        sim.run(cycles)
        thr = sim.telemetry().throughput(warmup=cycles // 3)
        print(f"  {name:16s} {thr:8.2f} ops/cycle   "
              f"({time.perf_counter() - t0:.2f}s wall)")


def backend_parity_check(nx: int = 4, ny: int = 4) -> None:
    """Same program, both backends, one facade — telemetry bit-identical."""
    cfg = MeshConfig(nx=nx, ny=ny)
    entries = make_traffic("uniform", nx, ny, 8, rate=0.5, seed=1)
    oracle = Simulator(cfg, backend="numpy").attach(
        {k: v.copy() for k, v in entries.items()})
    fast = Simulator(cfg, backend="jax").attach(entries)
    c0, c1 = oracle.run_until_drained(), fast.run_until_drained()
    assert c0 == c1
    oracle.telemetry().assert_bit_identical(fast.telemetry())
    print(f"== backend parity == drain cycle {c0}, telemetry bit-identical")


def vmapped_credit_sweep(hops: int = 8, cycles: int = 400) -> None:
    nx = hops + 1
    cfg = MeshConfig(nx=nx, ny=1, max_out_credits=64,
                     router_fifo=32).to_sim()
    entries = make_traffic("neighbor", nx, 1, cycles + 200)
    entries["op"][:] = -1
    entries["op"][0, 0, :] = 1          # one long-haul store stream
    entries["dst_x"][0, 0, :] = hops
    prog = load_program(entries)
    rtt = 2 * hops + 5
    # keep a host-side copy: `simulate` donates its SimState, and the
    # vmapped states alias the `credits` buffer they were built from.
    # This is the raw functional API — callers snapshot to host
    # themselves; the `Simulator` facade does it for you
    # (`Telemetry.of` copies every counter at the boundary).
    credits = np.asarray([1, 2, 4, 8, 16, rtt, 32])
    states = jax.vmap(lambda c: init_state(cfg, max_credits=c))(
        jnp.asarray(credits))
    _, per = jax.vmap(lambda s: simulate(cfg, prog, s, cycles))(states)
    print(f"== credit sweep (one compile, {len(credits)} configs; "
          f"RTT = {rtt} cycles) ==")
    for c, row in zip(credits, np.asarray(per)):
        print(f"  credits={int(c):3d}  throughput={row[cycles // 4:].mean():.3f} "
              f"stores/cycle")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=16)
    ap.add_argument("--ny", type=int, default=32)
    ap.add_argument("--cycles", type=int, default=800)
    args = ap.parse_args()
    pattern_sweep(args.nx, args.ny, args.cycles)
    backend_parity_check()
    vmapped_credit_sweep()
