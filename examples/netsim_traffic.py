"""Quickstart: the JIT-compiled mesh simulator + traffic-pattern library.

Runs every synthetic traffic pattern through the JAX simulator at
Celerity scale (16x32 = 512 cores, far beyond what the numpy oracle can
sweep interactively), checks one pattern cycle-for-cycle against the
oracle on a small mesh, and sweeps the credit allowance in a single
vmapped XLA program.

  PYTHONPATH=src python examples/netsim_traffic.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netsim import MeshSim, NetConfig
from repro.netsim_jax import (PATTERNS, JaxMeshSim, SimConfig, init_state,
                              load_program, make_traffic, simulate)


def pattern_sweep_512_cores():
    nx, ny, cycles = 16, 32, 800
    cfg = SimConfig(nx=nx, ny=ny, max_out_credits=32)
    print(f"== traffic patterns on the {nx}x{ny} ({nx * ny}-core) array ==")
    for name in sorted(PATTERNS):
        try:
            prog = load_program(make_traffic(name, nx, ny, cycles, seed=0))
        except ValueError as e:        # e.g. transpose on a non-square mesh
            print(f"  {name:16s} skipped ({e})")
            continue
        t0 = time.perf_counter()
        _, per = simulate(cfg, prog, init_state(cfg), cycles)
        thr = float(np.asarray(per[cycles // 3:]).mean())
        print(f"  {name:16s} {thr:8.2f} ops/cycle   "
              f"({time.perf_counter() - t0:.2f}s wall)")


def oracle_parity_check():
    cfg = NetConfig(nx=4, ny=4)
    entries = make_traffic("transpose", 4, 4, 8, rate=0.5)
    oracle = MeshSim(cfg)
    oracle.load_program({k: v.copy() for k, v in entries.items()})
    fast = JaxMeshSim(cfg)
    fast.load_program(entries)
    c0, c1 = oracle.run_until_drained(), fast.run_until_drained()
    assert c0 == c1 and np.array_equal(oracle.mem, fast.mem)
    print(f"== oracle parity == drain cycle {c0}, memories identical")


def vmapped_credit_sweep():
    cfg = SimConfig(nx=9, ny=1, max_out_credits=64, router_fifo=32)
    entries = make_traffic("neighbor", 9, 1, 600)
    entries["op"][:] = -1
    entries["op"][0, 0, :] = 1          # one long-haul store stream
    entries["dst_x"][0, 0, :] = 8
    prog = load_program(entries)
    credits = jnp.asarray([1, 2, 4, 8, 16, 21, 32])
    states = jax.vmap(lambda c: init_state(cfg, max_credits=c))(credits)
    _, per = jax.vmap(lambda s: simulate(cfg, prog, s, 400))(states)
    print("== credit sweep (one compile, 7 configs; RTT = 21 cycles) ==")
    for c, row in zip(np.asarray(credits), np.asarray(per)):
        print(f"  credits={int(c):3d}  throughput={row[100:].mean():.3f} "
              f"stores/cycle")


if __name__ == "__main__":
    pattern_sweep_512_cores()
    oracle_parity_check()
    vmapped_credit_sweep()
