"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Exercises the full production path — synthetic data pipeline with
credit-bounded prefetch, DP+TP sharding, ZeRO-1 AdamW, async
checkpointing, fault injection + recovery, and a mid-run elastic
re-shard — on the CPU container.

  PYTHONPATH=src python examples/train_lm.py            # ~300 steps
  PYTHONPATH=src python examples/train_lm.py --steps 50 # quick pass
"""
import argparse
import dataclasses

import jax

from repro.compat import set_host_device_count
set_host_device_count(8)

import numpy as np                                             # noqa: E402

from repro import optim                                        # noqa: E402
from repro.configs import get_config                           # noqa: E402
from repro.configs.base import ShapeConfig                     # noqa: E402
from repro.data.pipeline import DataConfig, Prefetcher, batch_iterator  # noqa: E402
from repro.compat import make_auto_device_mesh                 # noqa: E402
from repro.launch.mesh import make_test_mesh                   # noqa: E402
from repro.runtime import FaultInjector, Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    # ~100M params: a 12-layer, 512-wide stablelm-family config
    cfg = dataclasses.replace(
        get_config("stablelm-3b"), name="stablelm-100m",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=50304, dtype="float32")
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    shape = ShapeConfig("train_lm", args.seq_len, args.batch, "train")
    mesh = make_test_mesh((2, 4), ("data", "model"))

    trainer = Trainer(
        cfg, shape, mesh,
        optim.OptConfig(lr_peak=6e-4, warmup_steps=30,
                        total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, log_every=20),
        # inject two transient faults to demonstrate retry/recovery
        fault_injector=FaultInjector({40: 1, 140: 1}))
    trainer.resume_or_init()

    data = Prefetcher(batch_iterator(cfg, shape, start_step=trainer.step,
                                     data_cfg=DataConfig(prefetch_credits=2)))
    losses = []
    try:
        half = args.steps // 2
        trainer.tcfg.total_steps = half
        trainer.run(iter(data),
                    on_step=lambda s, m: losses.append(float(m["loss"])))
        # elastic scale-down mid-run: 8 chips -> 4 chips, same run
        small = make_auto_device_mesh(
            np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model"))
        trainer.reshard(small)
        print(f"[elastic] resharded to 4 chips at step {trainer.step}")
        trainer.tcfg.total_steps = args.steps
        trainer.run(iter(data),
                    on_step=lambda s, m: losses.append(float(m["loss"])))
    finally:
        data.close()
        trainer.close()

    print(f"\nloss: first10={np.mean(losses[:10]):.3f} "
          f"last10={np.mean(losses[-10:]):.3f}")
    print("events:", [e["kind"] for e in trainer.events])
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "did not learn"
    print("OK: loss decreased through faults and re-sharding")


if __name__ == "__main__":
    main()
