"""Serving example: continuous-batching decode server.

Submits a wave of requests with staggered lengths against a reduced
qwen1.5 (QKV-bias GQA) model; shows slot recycling (credits), inline
prefill, and per-request latency.

  PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.compat import set_host_device_count
set_host_device_count(8)

import numpy as np                                             # noqa: E402

from repro.configs import get_config, reduced_config           # noqa: E402
from repro.launch.mesh import make_test_mesh                   # noqa: E402
from repro.launch.serve import Request, Server                 # noqa: E402


def main():
    cfg = reduced_config(get_config("qwen1.5-32b"))
    mesh = make_test_mesh((2, 4), ("data", "model"))
    server = Server(cfg, mesh, slots=4, max_seq=96)

    rng = np.random.default_rng(0)
    for r in range(10):
        plen = int(rng.integers(3, 9))
        server.submit(Request(
            rid=r,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=int(rng.integers(4, 12))))

    ticks = server.run()
    print(f"{len(server.completed)} requests in {ticks} ticks "
          f"(4 slots, continuous batching)")
    for req in sorted(server.completed, key=lambda r: r.rid):
        lat = req.done_at - req.submitted_at
        print(f"  req {req.rid}: prompt[{len(req.prompt)}] -> "
              f"{len(req.out)} new tokens, {lat:.2f}s, ids={req.out[:6]}…")
    assert len(server.completed) == 10
    print("OK")


if __name__ == "__main__":
    main()
