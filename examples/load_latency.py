"""Reproduce the load–latency saturation-curve figure for the mesh NoC.

Runs the phased warmup -> measurement-window -> drain methodology (per-link
telemetry + per-packet latency histograms, see README "Load–latency
measurement") over a grid of offered loads for each traffic pattern, as one
vmapped XLA program per pattern, then prints the curves as an ASCII figure
and writes the raw data to experiments/load_latency.json.

  PYTHONPATH=src python examples/load_latency.py
  PYTHONPATH=src python examples/load_latency.py --nx 8 --ny 8 \
      --patterns uniform transpose --measure 500
"""
import argparse
import json
from pathlib import Path

from repro.mesh import Simulator, make_traffic
from repro.netsim_jax import (DEFAULT_SWEEP_RATES, PATTERNS, ascii_curve,
                              curve_record, load_latency_sweep, sweep_config)


def saturation_heatmap(pattern: str, cfg, rate: float,
                       cycles: int = 400, seed: int = 0) -> str:
    """Link-utilization heatmap at one offered load: rerun the pattern for
    a fixed window on the oracle and render the telemetry."""
    sim = Simulator(cfg, backend="numpy", seed=seed)
    length = int(rate * cycles) + 1
    sim.attach(make_traffic(pattern, cfg.nx, cfg.ny, length,
                            rate=rate, seed=seed))
    sim.run(cycles)
    return sim.telemetry().heatmap_str(
        "fwd", title=f"    link utilization at rate {rate:.2f} "
                     f"(fwd network, {cycles} cycles):")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=8)
    ap.add_argument("--ny", type=int, default=8)
    ap.add_argument("--patterns", nargs="+", default=sorted(PATTERNS),
                    choices=sorted(PATTERNS))
    ap.add_argument("--rates", nargs="+", type=float,
                    default=list(DEFAULT_SWEEP_RATES))
    ap.add_argument("--warmup", type=int, default=300)
    ap.add_argument("--measure", type=int, default=500)
    ap.add_argument("--drain", type=int, default=500)
    args = ap.parse_args()

    cfg = sweep_config(args.nx, args.ny)
    results = {}
    print(f"== load–latency curves, {args.nx}x{args.ny} mesh "
          f"(warmup {args.warmup} / measure {args.measure} / "
          f"drain {args.drain} cycles) ==")
    for name in args.patterns:
        try:
            out = load_latency_sweep(name, args.nx, args.ny, args.rates,
                                     warmup=args.warmup,
                                     measure=args.measure,
                                     drain=args.drain, cfg=cfg, seed=0)
        except ValueError as e:        # e.g. transpose on a non-square mesh
            print(f"\n  {name}: skipped ({e})")
            continue
        sat = out["saturation_index"]
        print(f"\n  {name}: zero-load {out['zero_load_latency']:.1f} cycles, "
              f"saturation rate {out['saturation_rate']}, peak accepted "
              f"{out['saturation_throughput']:.3f} pkts/cycle/tile")
        print("    rate  | mean round-trip latency (log scale, cycles)")
        print(ascii_curve(out["rates"], out["lat_mean"], sat))
        # where the congestion lives: per-link heatmap at the knee (or the
        # highest swept load if the pattern never saturated)
        hm_rate = out["saturation_rate"] if sat is not None \
            else float(out["rates"][-1])
        print(saturation_heatmap(name, cfg, hm_rate,
                                 cycles=args.measure, seed=0))
        results[name] = curve_record(out)

    dest = Path(__file__).resolve().parents[1] / "experiments"
    dest.mkdir(exist_ok=True)
    # same record shape (and per-curve schema, via curve_record) as the
    # bench_load_latency_8x8 CI artifact, so load_latency.json consumers
    # see one format regardless of which producer ran last
    record = {"name": "load_latency_curves", "mesh": f"{args.nx}x{args.ny}",
              "curves": results}
    with open(dest / "load_latency.json", "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"\nwrote {dest / 'load_latency.json'}")


if __name__ == "__main__":
    main()
