"""Reproduce the load–latency saturation-curve figure for the mesh NoC.

Runs the phased warmup -> measurement-window -> drain methodology (per-link
telemetry + per-packet latency histograms, see README "Load–latency
measurement") over a grid of offered loads for each traffic pattern, as one
vmapped XLA program per pattern, then prints the curves as an ASCII figure
and writes the raw data to experiments/load_latency.json.

  PYTHONPATH=src python examples/load_latency.py
  PYTHONPATH=src python examples/load_latency.py --nx 8 --ny 8 \
      --patterns uniform transpose --measure 500
"""
import argparse
import json
from pathlib import Path

import numpy as np

from repro.netsim_jax import (DEFAULT_SWEEP_RATES, PATTERNS, curve_record,
                              load_latency_sweep, sweep_config)


def ascii_curve(rates, lat, sat_idx, width: int = 50) -> str:
    """One bar per offered load, length ~ log latency, knee marked."""
    lat = np.asarray(lat, float)
    # a rate whose window delivered nothing measures lat 0; clamp the bar
    # scale so the log stays finite instead of aborting the whole figure
    clamped = np.maximum(lat, 1.0)
    scale = width / max(np.log10(clamped.max() / clamped.min()), 1e-9)
    rows = []
    for i, (r, l, lc) in enumerate(zip(rates, lat, clamped)):
        bar = "#" * max(int(np.log10(lc / clamped.min()) * scale), 1)
        mark = "  <- saturation" if i == sat_idx else ""
        rows.append(f"    {r:5.2f} | {bar:<{width}s} {l:8.1f}{mark}")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=8)
    ap.add_argument("--ny", type=int, default=8)
    ap.add_argument("--patterns", nargs="+", default=sorted(PATTERNS),
                    choices=sorted(PATTERNS))
    ap.add_argument("--rates", nargs="+", type=float,
                    default=list(DEFAULT_SWEEP_RATES))
    ap.add_argument("--warmup", type=int, default=300)
    ap.add_argument("--measure", type=int, default=500)
    ap.add_argument("--drain", type=int, default=500)
    args = ap.parse_args()

    cfg = sweep_config(args.nx, args.ny)
    results = {}
    print(f"== load–latency curves, {args.nx}x{args.ny} mesh "
          f"(warmup {args.warmup} / measure {args.measure} / "
          f"drain {args.drain} cycles) ==")
    for name in args.patterns:
        try:
            out = load_latency_sweep(name, args.nx, args.ny, args.rates,
                                     warmup=args.warmup,
                                     measure=args.measure,
                                     drain=args.drain, cfg=cfg, seed=0)
        except ValueError as e:        # e.g. transpose on a non-square mesh
            print(f"\n  {name}: skipped ({e})")
            continue
        sat = out["saturation_index"]
        print(f"\n  {name}: zero-load {out['zero_load_latency']:.1f} cycles, "
              f"saturation rate {out['saturation_rate']}, peak accepted "
              f"{out['saturation_throughput']:.3f} pkts/cycle/tile")
        print("    rate  | mean round-trip latency (log scale, cycles)")
        print(ascii_curve(out["rates"], out["lat_mean"], sat))
        results[name] = curve_record(out)

    dest = Path(__file__).resolve().parents[1] / "experiments"
    dest.mkdir(exist_ok=True)
    # same record shape (and per-curve schema, via curve_record) as the
    # bench_load_latency_8x8 CI artifact, so load_latency.json consumers
    # see one format regardless of which producer ran last
    record = {"name": "load_latency_curves", "mesh": f"{args.nx}x{args.ny}",
              "curves": results}
    with open(dest / "load_latency.json", "w") as f:
        json.dump(record, f, indent=1, default=str)
    print(f"\nwrote {dest / 'load_latency.json'}")


if __name__ == "__main__":
    main()
