from .trainer import FaultInjector, Trainer, TrainerConfig

__all__ = ["FaultInjector", "Trainer", "TrainerConfig"]
