"""Fault-tolerant training runtime.

The loop composes the framework's substrate exactly the way a pod-scale
deployment would, with the host-side control plane made explicit:

* **checkpoint/restart** — resume from the newest committed checkpoint;
  async saves every ``ckpt_every`` steps (credit-bounded, paper C3); a
  final fence guarantees durability before exit.
* **step retry** — a transient step failure (preempted worker, flaky
  link) is retried from the last known-good state; repeated failures
  trigger restore-from-checkpoint; a retry budget bounds the loop.
* **straggler detection** — per-step wall time against a rolling median
  (the paper's congestion signal: "the delay at router will increase");
  a straggling step beyond ``straggler_factor``× median is logged and
  counted — on a real pod this is where the scheduler would evict the
  slow host.
* **elastic re-shard** — :meth:`Trainer.reshard` moves params+optimizer
  onto a different (smaller/larger) mesh mid-run: same global arrays,
  new NamedShardings, recompiled step.  This is scale-down-on-failure /
  scale-up-on-recovery with no restart from disk.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro import optim
from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import step as step_mod
from repro.models.api import get_model

__all__ = ["TrainerConfig", "Trainer", "FaultInjector"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    ckpt_credits: int = 2
    max_retries_per_step: int = 2
    max_total_retries: int = 10
    straggler_factor: float = 3.0
    log_every: int = 10


class FaultInjector:
    """Deterministic fault schedule for tests/examples: raises
    ``RuntimeError`` the first ``times`` times ``step`` is executed."""

    def __init__(self, fail_at: Dict[int, int]):
        self.fail_at = dict(fail_at)

    def maybe_fail(self, step: int):
        n = self.fail_at.get(step, 0)
        if n > 0:
            self.fail_at[step] = n - 1
            raise RuntimeError(f"injected fault at step {step}")


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, mesh,
                 opt_cfg: Optional[optim.OptConfig] = None,
                 tcfg: Optional[TrainerConfig] = None,
                 strategy: str = "baseline",
                 fault_injector: Optional[FaultInjector] = None,
                 **rule_overrides):
        self.cfg, self.shape = cfg, shape
        self.tcfg = tcfg or TrainerConfig()
        self.opt_cfg = opt_cfg or optim.OptConfig()
        self.strategy, self.rule_overrides = strategy, rule_overrides
        self.fault_injector = fault_injector
        self.events: List[Dict] = []
        self.step_times: List[float] = []
        self._bind_mesh(mesh)
        self.ckpt = AsyncCheckpointer(self.tcfg.ckpt_dir,
                                      credits=self.tcfg.ckpt_credits)
        self.params = None
        self.opt_state = None
        self.step = 0

    # ------------------------------------------------------------------
    def _bind_mesh(self, mesh):
        """(Re)build rules, shardings and the jitted step for ``mesh``."""
        self.mesh = mesh
        cell = step_mod.build_cell(self.cfg, self.shape, mesh,
                                   self.strategy, self.opt_cfg,
                                   **self.rule_overrides)
        self.rules = cell.rules
        self.cell = cell
        self._jit_step = cell.jitted()
        self._batch_sh = cell.in_shardings[2]

    def init(self, seed: int = 0):
        model = get_model(self.cfg)
        with self.mesh:
            # the cell's shardings are authoritative (they include e.g.
            # the FSDP banking when strategy="fsdp")
            p_specs = self.cell.in_shardings[0]
            init_fn = jax.jit(model.init_params, static_argnums=0,
                              out_shardings=p_specs)
            self.params = init_fn(self.cfg, jax.random.key(seed))
            self.opt_state = jax.jit(
                optim.init, out_shardings=self.cell.in_shardings[1])(
                self.params)
        self.step = 0
        return self

    def resume_or_init(self, seed: int = 0):
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return self.init(seed)
        model = get_model(self.cfg)
        p_shapes = model.param_shapes(self.cfg)
        s_shapes = optim.state_shapes(p_shapes)
        tree, step, extra = restore(
            self.tcfg.ckpt_dir, {"params": p_shapes, "opt": s_shapes},
            shardings={"params": self.cell.in_shardings[0],
                       "opt": self.cell.in_shardings[1]})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        self.events.append({"kind": "resume", "step": step})
        return self

    # ------------------------------------------------------------------
    def reshard(self, new_mesh):
        """Elastic re-shard: move live state onto ``new_mesh``."""
        old_chips = self.mesh.devices.size
        self._bind_mesh(new_mesh)
        self.params = jax.device_put(self.params, self.cell.in_shardings[0])
        self.opt_state = jax.device_put(self.opt_state,
                                        self.cell.in_shardings[1])
        self.events.append({"kind": "reshard", "step": self.step,
                            "from_chips": old_chips,
                            "to_chips": new_mesh.devices.size})
        return self

    # ------------------------------------------------------------------
    def _put_batch(self, batch: Dict[str, np.ndarray]):
        return {k: jax.device_put(v, self._batch_sh[k])
                for k, v in batch.items()}

    def run(self, batches: Iterator[Dict[str, np.ndarray]],
            on_step: Optional[Callable[[int, Dict], None]] = None) -> Dict:
        assert self.params is not None, "call init()/resume_or_init() first"
        total_retries = 0
        metrics = {}
        while self.step < self.tcfg.total_steps:
            batch = next(batches)
            retries = 0
            while True:
                t0 = time.perf_counter()
                try:
                    if self.fault_injector is not None:
                        self.fault_injector.maybe_fail(self.step)
                    with self.mesh:
                        new_p, new_o, metrics = self._jit_step(
                            self.params, self.opt_state,
                            self._put_batch(batch))
                    jax.block_until_ready(metrics["loss"])
                    self.params, self.opt_state = new_p, new_o
                    break
                except Exception as e:
                    retries += 1
                    total_retries += 1
                    self.events.append({"kind": "step_failure",
                                        "step": self.step, "error": str(e)})
                    if total_retries > self.tcfg.max_total_retries:
                        raise RuntimeError("retry budget exhausted") from e
                    if retries > self.tcfg.max_retries_per_step:
                        # fall back to last durable state
                        self.ckpt.fence()
                        self.resume_or_init()
                        retries = 0
                dt = time.perf_counter() - t0
                self._heartbeat(dt)
            dt = time.perf_counter() - t0
            self._heartbeat(dt)
            self.step += 1
            if self.step % self.tcfg.ckpt_every == 0 or \
                    self.step == self.tcfg.total_steps:
                self.ckpt.submit(self.step, {"params": self.params,
                                             "opt": self.opt_state},
                                 extra={"loss": float(metrics["loss"])})
            if on_step is not None:
                on_step(self.step, metrics)
            if self.step % self.tcfg.log_every == 0:
                print(f"step {self.step:5d}  loss {float(metrics['loss']):.4f}"
                      f"  ({dt*1e3:.0f} ms)", flush=True)
        self.ckpt.fence()   # durability barrier (paper C3 fence)
        return {k: float(v) for k, v in metrics.items()}

    def _heartbeat(self, dt: float):
        self.step_times.append(dt)
        hist = self.step_times[-20:-1]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.tcfg.straggler_factor * med:
                self.events.append({"kind": "straggler", "step": self.step,
                                    "dt": dt, "median": med})

    def close(self):
        self.ckpt.close()
