"""Differential-testing helpers for the two mesh simulators.

The JAX simulator's contract is bit-identical externally visible state
vs the numpy oracle (`repro.core.netsim.MeshSim`) — memory, statistics,
per-cycle completion traces, and every telemetry counter.  The assertion
lives here (not in a test module) so the parity suite, the property
fuzz, and any downstream user can share one definition of "equal".
"""
from __future__ import annotations

import numpy as np

__all__ = ["TELEMETRY_FIELDS", "assert_state_equal"]

TELEMETRY_FIELDS = ("link_util_fwd", "link_util_rev", "fifo_hwm_fwd",
                    "fifo_hwm_rev", "ep_hwm", "lat_hist")


def assert_state_equal(a, b) -> None:
    """Assert the oracle ``a`` and JAX sim ``b`` agree on all externally
    visible state: memory, stats, completion trace, telemetry."""
    np.testing.assert_array_equal(a.mem, b.mem)
    np.testing.assert_array_equal(a.completed, b.completed)
    np.testing.assert_array_equal(a.lat_sum, b.lat_sum)
    np.testing.assert_array_equal(a.credits, b.credits)
    np.testing.assert_array_equal(a.out_of_credit_cycles,
                                  b.out_of_credit_cycles)
    assert a.completed_per_cycle == b.completed_per_cycle
    for f in TELEMETRY_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f"telemetry mismatch: {f}")
