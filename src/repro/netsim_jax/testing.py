"""Differential-testing helpers for the two mesh simulators.

The JAX simulator's contract is bit-identical externally visible state
vs the numpy oracle (`repro.core.netsim.MeshSim`) — memory, statistics,
per-cycle completion traces, and every telemetry counter.  The assertion
lives here (not in a test module) so the parity suite, the property
fuzz, and any downstream user can share one definition of "equal".

The telemetry half of the contract is expressed through the unified
:class:`repro.mesh.Telemetry` record, so any pair of oracle-shaped
objects — raw ``MeshSim`` / ``JaxMeshSim`` or two
:class:`repro.mesh.Simulator` facades on different backends — compares
with the same code path users have.

:func:`assert_packets_equal` goes one level deeper: it *decodes* the JAX
path's packed header words (:mod:`repro.mesh.encoding`) for every
in-flight packet — router FIFOs, endpoint request FIFOs, the response
delay line and the registered response port — and compares them
field-for-field against the oracle's unpacked int64 packets.  This is
the direct witness that header packing loses no information mid-flight.
"""
from __future__ import annotations

import numpy as np

from repro.mesh.encoding import HEADER_FIELDS, decode_header
from repro.mesh.telemetry import Telemetry

__all__ = ["TELEMETRY_FIELDS", "PACKET_FIELDS", "assert_state_equal",
           "assert_telemetry_equal", "assert_packets_equal"]

TELEMETRY_FIELDS = ("link_util_fwd", "link_util_rev", "fifo_hwm_fwd",
                    "fifo_hwm_rev", "ep_hwm", "lat_hist")

# every field of the oracle's packet schema; on the JAX side the first
# five live packed inside the `hdr` lane
PACKET_FIELDS = HEADER_FIELDS + ("addr", "data", "cmp", "tag")


def assert_telemetry_equal(a, b) -> None:
    """Assert the unified telemetry records of two simulators (or
    facades) are bit-identical."""
    Telemetry.of(a).assert_bit_identical(Telemetry.of(b))


def _unpack_jax_packets(buf: np.ndarray) -> dict:
    """Packed (F, ...) JAX packet lanes -> oracle-schema field dict."""
    from repro.netsim_jax.sim import _FI
    out = {k: v.astype(np.int64)
           for k, v in decode_header(buf[_FI["hdr"]].astype(np.int64)).items()}
    for k in ("addr", "data", "cmp", "tag"):
        out[k] = buf[_FI[k]].astype(np.int64)
    return out


def _inflight(fields: dict, head: np.ndarray, count: np.ndarray,
              depth: int) -> dict:
    """Logical FIFO contents (queue order, padded with zeros past
    ``count``) — normalizes away ring-buffer layout, which legitimately
    differs when the oracle's capacity equals the effective depth but the
    JAX buffer keeps the full static capacity."""
    idx = (head[..., None] + np.arange(depth)) % depth
    live = np.arange(depth) < count[..., None]
    return {k: np.where(live, np.take_along_axis(v, idx, axis=-1), 0)
            for k, v in fields.items()}


def assert_packets_equal(a, b) -> None:
    """Assert every in-flight packet matches field-for-field between the
    oracle ``a`` and the JAX sim ``b`` (packed headers decoded): forward
    and reverse router FIFOs, endpoint request FIFOs, the response delay
    line, and the registered response port."""
    from repro.netsim_jax.sim import FWD, REV
    st = b.state if hasattr(b, "state") else b._sim.state
    depth = int(np.asarray(st.fifo_depth))
    jbuf = np.asarray(st.net.buf)
    jhead, jcount = np.asarray(st.net.head), np.asarray(st.net.count)
    for net_i, name in ((FWD, "fwd"), (REV, "rev")):
        onet = getattr(a, name)
        jf = _inflight(_unpack_jax_packets(jbuf[:, net_i]),
                       jhead[net_i], jcount[net_i], depth)
        of = _inflight({k: onet.f[k] for k in PACKET_FIELDS},
                       np.asarray(onet.head), np.asarray(onet.count),
                       onet.depth)
        np.testing.assert_array_equal(np.asarray(onet.count), jcount[net_i],
                                      err_msg=f"{name} FIFO count")
        for k in PACKET_FIELDS:
            np.testing.assert_array_equal(
                of[k], jf[k], err_msg=f"{name} FIFO packet field {k!r}")
    # endpoint request FIFO
    jf = _inflight(_unpack_jax_packets(np.asarray(st.ep_in.buf)),
                   np.asarray(st.ep_in.head), np.asarray(st.ep_in.count),
                   a.ep_in.depth)
    of = _inflight({k: a.ep_in.f[k] for k in PACKET_FIELDS},
                   np.asarray(a.ep_in.head), np.asarray(a.ep_in.count),
                   a.ep_in.depth)
    for k in PACKET_FIELDS:
        np.testing.assert_array_equal(of[k], jf[k],
                                      err_msg=f"ep_in packet field {k!r}")
    # response delay line + registered response port
    np.testing.assert_array_equal(np.asarray(a.resp_valid),
                                  np.asarray(st.resp_valid),
                                  err_msg="resp_valid")
    jresp = _unpack_jax_packets(np.asarray(st.resp_buf))
    jreg = _unpack_jax_packets(np.asarray(st.reg_buf))
    rv = np.asarray(a.resp_valid)
    np.testing.assert_array_equal(np.asarray(a.reg_valid),
                                  np.asarray(st.reg_valid),
                                  err_msg="reg_valid")
    for k in PACKET_FIELDS:
        np.testing.assert_array_equal(
            np.where(rv, np.asarray(a.resp_pkt[k]), 0),
            np.where(rv, jresp[k], 0),
            err_msg=f"response delay-line field {k!r}")
        np.testing.assert_array_equal(np.asarray(a.reg_pkt[k]), jreg[k],
                                      err_msg=f"registered port field {k!r}")


def assert_state_equal(a, b) -> None:
    """Assert the oracle ``a`` and JAX sim ``b`` agree on all externally
    visible state: memory, stats, completion trace, telemetry — and,
    when ``b`` exposes a packed ``SimState``, every in-flight packet
    field-for-field (packed vs oracle)."""
    np.testing.assert_array_equal(a.mem, b.mem)
    np.testing.assert_array_equal(a.credits, b.credits)
    np.testing.assert_array_equal(a.out_of_credit_cycles,
                                  b.out_of_credit_cycles)
    assert list(a.completed_per_cycle) == list(b.completed_per_cycle)
    assert_telemetry_equal(a, b)
    # packet-level compare when a is oracle-backed and b jax-backed (both
    # attribute accesses delegate through the Simulator facade)
    if hasattr(a, "resp_pkt") and hasattr(b, "state"):
        assert_packets_equal(a, b)
