"""Differential-testing helpers for the two mesh simulators.

The JAX simulator's contract is bit-identical externally visible state
vs the numpy oracle (`repro.core.netsim.MeshSim`) — memory, statistics,
per-cycle completion traces, and every telemetry counter.  The assertion
lives here (not in a test module) so the parity suite, the property
fuzz, and any downstream user can share one definition of "equal".

The telemetry half of the contract is now expressed through the unified
:class:`repro.mesh.Telemetry` record, so any pair of oracle-shaped
objects — raw ``MeshSim`` / ``JaxMeshSim`` or two
:class:`repro.mesh.Simulator` facades on different backends — compares
with the same code path users have.
"""
from __future__ import annotations

import numpy as np

from repro.mesh.telemetry import Telemetry

__all__ = ["TELEMETRY_FIELDS", "assert_state_equal", "assert_telemetry_equal"]

TELEMETRY_FIELDS = ("link_util_fwd", "link_util_rev", "fifo_hwm_fwd",
                    "fifo_hwm_rev", "ep_hwm", "lat_hist")


def assert_telemetry_equal(a, b) -> None:
    """Assert the unified telemetry records of two simulators (or
    facades) are bit-identical."""
    Telemetry.of(a).assert_bit_identical(Telemetry.of(b))


def assert_state_equal(a, b) -> None:
    """Assert the oracle ``a`` and JAX sim ``b`` agree on all externally
    visible state: memory, stats, completion trace, telemetry."""
    np.testing.assert_array_equal(a.mem, b.mem)
    np.testing.assert_array_equal(a.credits, b.credits)
    np.testing.assert_array_equal(a.out_of_credit_cycles,
                                  b.out_of_credit_cycles)
    assert list(a.completed_per_cycle) == list(b.completed_per_cycle)
    assert_telemetry_equal(a, b)
