"""JIT-compiled mesh-network simulation: JAX simulator + traffic library.

* :mod:`repro.netsim_jax.sim`     — the ``lax.scan`` cycle-level simulator
  (semantics validated cycle-for-cycle against ``repro.core.netsim.MeshSim``)
* :mod:`repro.netsim_jax.traffic` — deprecated re-export of the traffic
  library, whose canonical home is now :mod:`repro.mesh.traffic`

Prefer the backend-agnostic front door :mod:`repro.mesh`
(``MeshConfig`` / ``Simulator`` / ``Endpoint`` / ``Telemetry``) for new
code; this package remains the functional JAX layer it drives
(``simulate`` / ``vmap`` sweeps / ``measure``).
* :mod:`repro.netsim_jax.measure` — the phased warmup/measure/drain
  load–latency methodology over the per-link/per-packet telemetry, and
  the ``vmap``-ed saturation-curve sweep driver
"""
from . import measure, sim, traffic  # noqa: F401
from .measure import (DEFAULT_SWEEP_RATES, PhaseStats,  # noqa: F401
                      StreamChunk, SweepKey, ascii_curve, batch_stats_fn,
                      batched_phased_stats, clear_sweep_cache,
                      compile_sweep, curve_is_monotone,
                      curve_record, hist_quantile, load_latency_sweep,
                      measure_program, phase_schedule, phased_stats,
                      reduce_window_stats, saturation_point,
                      stack_rate_programs, stream_phased_stats,
                      sweep_config)
from .sim import (FWD, REV, JaxMeshSim, Program, SimConfig,  # noqa: F401
                  SimState, drained, empty_program_for, init_state,
                  load_program, run_until_drained, run_until_drained_traced,
                  simulate, step)
from .traffic import PATTERNS, empty_program, make_traffic  # noqa: F401

__all__ = ["JaxMeshSim", "Program", "SimConfig", "SimState", "drained",
           "FWD", "REV",
           "empty_program_for", "init_state", "load_program", "simulate",
           "step", "run_until_drained", "run_until_drained_traced",
           "PATTERNS", "empty_program", "make_traffic",
           "DEFAULT_SWEEP_RATES", "PhaseStats", "SweepKey", "compile_sweep",
           "curve_is_monotone",
           "ascii_curve", "batch_stats_fn", "batched_phased_stats",
           "clear_sweep_cache",
           "curve_record", "hist_quantile", "load_latency_sweep",
           "measure_program", "phased_stats", "saturation_point",
           "stack_rate_programs", "sweep_config",
           "StreamChunk", "phase_schedule", "reduce_window_stats",
           "stream_phased_stats"]
