"""Phased load–latency measurement on the JIT-compiled mesh simulator.

The standard NoC evaluation methodology (Dally & Towles §23.1; the same
battery Ring-Mesh and Epiphany-V report):

1. **warmup** — run the network to steady state; nothing is recorded;
2. **measurement window** — every packet *injected* during the window is
   tagged (via the telemetry gate ``SimState.measure_start/stop`` on the
   packet's injection-cycle tag) and its round-trip latency lands in the
   per-packet histogram; accepted throughput and channel utilization are
   the deltas of the ``completed`` / ``link_util`` counters across the
   window;
3. **drain** — the simulation keeps running for a fixed budget of cycles
   (and keeps *injecting*, so tagged packets experience real contention)
   so the tagged packets can be delivered; only their latencies are in
   the histogram.  A fixed budget keeps the whole program one bounded
   ``lax.scan`` — past saturation some tagged packets may still be in
   flight when it expires, so latency stats there are censored (biased
   low); ``delivered < offered`` in :class:`PhaseStats` exposes exactly
   how much.  Saturation itself is still located correctly: the knee is
   crossed while the network delivers what it is offered.

Everything here is pure JAX: :func:`phased_stats` is one jitted program
(three ``lax.scan`` phases + histogram reductions), and
:func:`load_latency_sweep` ``vmap``s it over a stack of injection
programs — one per offered load — so a full saturation curve for a
traffic pattern costs a single compilation.

The saturation point is located per the usual convention: the first
offered load whose mean latency reaches ``3x`` the zero-load latency
(the latency measured at the lowest swept rate).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netsim import LAT_BINS
from repro.mesh.config import MeshConfig
from .sim import (FWD, I32, Program, SimConfig, SimState, init_state,
                  load_program, simulate)
from .traffic import make_traffic

__all__ = ["PhaseStats", "phased_stats", "measure_program",
           "stack_rate_programs", "load_latency_sweep", "saturation_point",
           "curve_is_monotone", "curve_record", "hist_quantile",
           "compile_sweep", "SATURATION_FACTOR", "DEFAULT_SWEEP_RATES",
           "sweep_config", "ascii_curve", "SweepKey", "batch_stats_fn",
           "batched_phased_stats", "clear_sweep_cache",
           "StreamChunk", "phase_schedule", "reduce_window_stats",
           "stream_phased_stats"]

# mean latency >= SATURATION_FACTOR * zero-load latency <=> saturated
SATURATION_FACTOR = 3.0

# The canonical saturation-curve setup, shared by the benchmark
# (bench_load_latency_8x8) and examples/load_latency.py so "reproduce the
# figure" runs the exact grid the benchmark validates.
DEFAULT_SWEEP_RATES = (0.02, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.35,
                       0.4, 0.45, 0.5, 0.55)


def sweep_config(nx: int, ny: int, topology=None) -> MeshConfig:
    """Mesh configuration for saturation sweeps: buffering deep enough
    that flow control, not storage, is the limit.  ``topology`` selects
    the network topology (default: the plain mesh)."""
    return MeshConfig(nx=nx, ny=ny, max_out_credits=128, router_fifo=16,
                      topology=topology)


def _as_simconfig(cfg) -> SimConfig:
    """Public measure entry points take any config flavor (MeshConfig,
    NetConfig, SimConfig); the jitted internals want SimConfig."""
    if isinstance(cfg, SimConfig):
        return cfg
    return MeshConfig.coerce(cfg).to_sim()

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SweepKey:
    """Static identity of one compiled sweep program.

    Everything that determines the trace — the (hashable) simulator
    config, the phase lengths and the execution knobs — in one frozen
    dataclass.  It is the cache key for the jitted sweep programs below
    AND the design-space-exploration bucket key (:mod:`repro.dse` groups
    spec points that share a ``SweepKey`` + program shape so each bucket
    compiles exactly once).  ``cfg`` accepts any config flavor and is
    normalized to :class:`SimConfig`.
    """
    cfg: SimConfig
    warmup: int
    measure: int
    drain: int
    unroll: int = 1
    impl: str = "fused"
    cycles_per_call: int = 1

    def __post_init__(self):
        if not isinstance(self.cfg, SimConfig):
            object.__setattr__(self, "cfg", _as_simconfig(self.cfg))
        for name in ("warmup", "measure", "drain"):
            v = getattr(self, name)
            if not isinstance(v, int) or v < 0 or (name == "measure" and v == 0):
                raise ValueError(
                    f"SweepKey.{name} must be a nonnegative int (measure "
                    f"positive), got {v!r}")

    @property
    def horizon(self) -> int:
        """Total simulated cycles per point (warmup + measure + drain)."""
        return self.warmup + self.measure + self.drain


class PhaseStats(NamedTuple):
    """Measurement-window statistics (all jnp scalars except ``hist``).

    Rates are per tile per cycle; latencies are cycles (round trip,
    injection -> registered response)."""
    offered: jax.Array        # packets injected during the window
    accepted: jax.Array       # packets completed during the window
    delivered: jax.Array      # window-injected packets delivered by drain end
    lat_mean: jax.Array
    lat_p50: jax.Array
    lat_p95: jax.Array
    lat_p99: jax.Array
    lat_max: jax.Array
    peak_link_util: jax.Array  # busiest mesh channel (W/E/N/S), fwd network
    hops: jax.Array            # total link crossings (both networks, W/E/N/S)
    hist: jax.Array            # (LAT_BINS,) latency histogram of the window


def hist_quantile(hist: jax.Array, q: float) -> jax.Array:
    """The q-quantile (in bins == cycles) of a counts histogram: smallest
    bin b with cdf(b) >= ceil(q * total).  0 when the histogram is empty."""
    total = hist.sum()
    cdf = jnp.cumsum(hist)
    target = jnp.ceil(q * total).astype(hist.dtype)
    idx = jnp.searchsorted(cdf, jnp.maximum(target, 1))
    return jnp.where(total > 0, jnp.minimum(idx, LAT_BINS - 1), 0).astype(F32)


def reduce_window_stats(ntiles: int, measure: int, hist: jax.Array,
                        d_inj: jax.Array, d_comp: jax.Array,
                        d_util: jax.Array) -> PhaseStats:
    """Reduce raw measurement-window telemetry into :class:`PhaseStats`:
    ``hist`` is the (drain-complete) window latency histogram, ``d_inj`` /
    ``d_comp`` the injected/completed count deltas across the window and
    ``d_util`` the ``link_util`` delta (all int32, so they are exact no
    matter how the phases were chunked).  Traceable — :func:`phased_stats`
    applies it inline after its three phases, and the streaming paths
    (:func:`stream_phased_stats`, :mod:`repro.sim_service`) apply the same
    function under their own ``jit`` to snapshots accumulated block by
    block, which is what keeps streamed results bit-identical to the
    one-shot program."""
    total = hist.sum()
    bins = jnp.arange(LAT_BINS, dtype=F32)
    denom = jnp.maximum(total, 1).astype(F32)
    per_tile_cycle = float(measure * ntiles)
    return PhaseStats(
        offered=d_inj.astype(F32) / per_tile_cycle,
        accepted=d_comp.astype(F32) / per_tile_cycle,
        delivered=total.astype(F32) / per_tile_cycle,
        lat_mean=(bins * hist).sum() / denom,
        lat_p50=hist_quantile(hist, 0.50),
        lat_p95=hist_quantile(hist, 0.95),
        lat_p99=hist_quantile(hist, 0.99),
        lat_max=jnp.max(jnp.where(hist > 0,
                                  jnp.arange(LAT_BINS), 0)).astype(F32),
        peak_link_util=d_util[FWD, ..., 1:].max().astype(F32) / measure,
        # total W/E/N/S crossings on both networks during the window —
        # the hop count the DSE energy model prices (port 0 is P, the
        # tile's own processor port, which is not a mesh wire)
        hops=d_util[..., 1:].sum().astype(F32),
        hist=hist,
    )


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5, 6, 7, 8))
def phased_stats(cfg: SimConfig, prog: Program, state: SimState,
                 warmup: int, measure: int, drain: int,
                 unroll: int = 1, impl: str = "fused",
                 cycles_per_call: int = 1) -> PhaseStats:
    """Run warmup -> measurement window -> drain and reduce the telemetry
    into :class:`PhaseStats`.  ``state`` should be fresh (its histogram
    empty); the measurement window is cycles [warmup, warmup + measure).
    ``unroll`` / ``impl`` / ``cycles_per_call`` select how the underlying
    :func:`repro.netsim_jax.simulate` phases execute (scan unroll; fused
    XLA step vs the Pallas router kernel and its cycles-per-launch) —
    speed knobs that never change results.  No buffer donation here: the
    reduced stats are tiny, so the state has no output to alias with."""
    ntiles = cfg.nx * cfg.ny
    st = state._replace(
        measure_start=state.cycle + warmup,
        measure_stop=state.cycle + warmup + measure)
    st, _ = simulate(cfg, prog, st, warmup, unroll, impl, cycles_per_call)
    inj0, comp0 = st.prog_ptr.sum(), st.completed.sum()
    util0 = st.link_util
    st, _ = simulate(cfg, prog, st, measure, unroll, impl, cycles_per_call)
    inj1, comp1 = st.prog_ptr.sum(), st.completed.sum()
    util1 = st.link_util
    st, _ = simulate(cfg, prog, st, drain, unroll, impl, cycles_per_call)
    return reduce_window_stats(ntiles, measure, st.lat_hist,
                               inj1 - inj0, comp1 - comp0, util1 - util0)


# -- per-fence-block streaming -------------------------------------------

class StreamChunk(NamedTuple):
    """Telemetry delta of one fence block of a streamed phased run —
    everything is the *change* during cycles [start, stop), so
    concatenating chunks (summing their fields) reproduces the run's
    totals exactly (all counters are ints)."""
    phase: str          # "warmup" | "measure" | "drain"
    start: int          # first cycle of the block
    stop: int           # one past the last cycle
    injected: int       # program entries issued during the block (all tiles)
    completed: int      # requests completed during the block
    delivered: int      # window-tagged packets delivered during the block
    hist: np.ndarray    # (LAT_BINS,) latency-histogram delta of the block


def phase_schedule(warmup: int, measure: int, drain: int,
                   check_every: int) -> Tuple[Tuple[str, int], ...]:
    """The static fence-block schedule of a streamed phased run:
    ``(phase, cycles)`` per block, each phase split into
    ``check_every``-cycle blocks plus one remainder.  Phase boundaries
    always land on block boundaries, so the warmup/measure snapshots of
    the one-shot :func:`phased_stats` are reproducible from the stream."""
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    out = []
    for phase, total in (("warmup", warmup), ("measure", measure),
                         ("drain", drain)):
        left = total
        while left > 0:
            c = min(check_every, left)
            out.append((phase, c))
            left -= c
    return tuple(out)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _reduce_window_jit(ntiles: int, measure: int, hist, d_inj, d_comp,
                       d_util) -> PhaseStats:
    return reduce_window_stats(ntiles, measure, hist, d_inj, d_comp, d_util)


def stream_phased_stats(cfg, prog: Program, *, warmup: int = 200,
                        measure: int = 400, drain: int = 400,
                        check_every: int = 100, fifo_depth=None,
                        max_credits=None, unroll: int = 1,
                        impl: str = "fused", cycles_per_call: int = 1):
    """Streaming variant of :func:`phased_stats`: a generator yielding one
    :class:`StreamChunk` per ``check_every``-cycle fence block as the
    phases execute, and *returning* the final :class:`PhaseStats` (read it
    from ``StopIteration.value``, or drive the generator with
    ``yield from``).  The final stats are bit-identical to the one-shot
    :func:`phased_stats` — the phases run through the same
    :func:`repro.netsim_jax.simulate` in block-sized pieces (exact: the
    state transition is pure) and the same :func:`reduce_window_stats`.
    ``cfg`` may be any config flavor; the sim service streams the batched
    equivalent of this loop."""
    cfg = _as_simconfig(cfg)
    # validates the phase recipe exactly like the one-shot entry points
    SweepKey(cfg, warmup, measure, drain, unroll, impl, cycles_per_call)
    st = init_state(cfg, fifo_depth, max_credits)
    st = st._replace(measure_start=st.cycle + warmup,
                     measure_stop=st.cycle + warmup + measure)

    def snapshot(s: SimState) -> Tuple[int, int, np.ndarray]:
        return (int(np.asarray(s.prog_ptr, np.int64).sum()),
                int(np.asarray(s.completed, np.int64).sum()),
                np.asarray(s.link_util, np.int64))

    # phase-boundary snapshots: a zero-length warmup's boundary is the
    # fresh state (exactly what phased_stats' 0-cycle warmup scan sees)
    snap_w = snap_m = snapshot(st)
    prev_inj = prev_comp = prev_deliv = 0
    prev_hist = np.zeros_like(np.asarray(st.lat_hist))
    cycle = 0
    schedule = phase_schedule(warmup, measure, drain, check_every)
    for i, (phase, cycles) in enumerate(schedule):
        st, _ = simulate(cfg, prog, st, cycles, unroll, impl,
                         cycles_per_call)
        inj, comp, util = snapshot(st)
        hist = np.asarray(st.lat_hist)
        deliv = int(hist.sum())
        yield StreamChunk(phase=phase, start=cycle, stop=cycle + cycles,
                          injected=inj - prev_inj,
                          completed=comp - prev_comp,
                          delivered=deliv - prev_deliv,
                          hist=hist - prev_hist)
        prev_inj, prev_comp, prev_deliv = inj, comp, deliv
        prev_hist = hist
        cycle += cycles
        last_of_phase = i + 1 == len(schedule) or schedule[i + 1][0] != phase
        if last_of_phase and phase == "warmup":
            snap_w = snap_m = (inj, comp, util)
        elif last_of_phase and phase == "measure":
            snap_m = (inj, comp, util)
    return _reduce_window_jit(
        cfg.nx * cfg.ny, measure, st.lat_hist,
        jnp.asarray(snap_m[0] - snap_w[0], I32),
        jnp.asarray(snap_m[1] - snap_w[1], I32),
        jnp.asarray(snap_m[2] - snap_w[2], I32))


def measure_program(cfg, entries: Dict[str, np.ndarray], *,
                    warmup: int = 200, measure: int = 400,
                    drain: int = 400, unroll: int = 1,
                    impl: str = "fused",
                    cycles_per_call: int = 1) -> Dict[str, float]:
    """Convenience: phased measurement of one injection program; returns
    plain-python stats (``hist`` as a numpy array).  ``cfg`` may be a
    MeshConfig, NetConfig or SimConfig."""
    cfg = _as_simconfig(cfg)
    stats = phased_stats(cfg, load_program(entries), init_state(cfg),
                         warmup, measure, drain, unroll, impl,
                         cycles_per_call)
    out = {k: float(v) for k, v in stats._asdict().items() if k != "hist"}
    out["hist"] = np.asarray(stats.hist)
    return out


def stack_rate_programs(pattern: str, nx: int, ny: int,
                        rates: Sequence[float], horizon: int,
                        **traffic_kw) -> Program:
    """One injection program per offered load, stacked along a leading
    axis for ``vmap``.  Programs are sized so the *fastest* rate never
    exhausts its entries inside ``horizon`` cycles; slower rates simply
    schedule their tail entries past the horizon (never injected), which
    keeps every program the same shape."""
    length = int(np.ceil(max(rates) * horizon)) + 1
    progs = [load_program(make_traffic(pattern, nx, ny, length,
                                       rate=float(r), **traffic_kw))
             for r in rates]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *progs)


def ascii_curve(rates, lat, sat_idx, width: int = 50) -> str:
    """ASCII load–latency figure: one bar per offered load, bar length ~
    log latency, saturation knee marked.  Shared by
    ``examples/load_latency.py`` and anything else that prints a sweep."""
    lat = np.asarray(lat, float)
    # a rate whose window delivered nothing measures lat 0; clamp the bar
    # scale so the log stays finite instead of aborting the whole figure
    clamped = np.maximum(lat, 1.0)
    scale = width / max(np.log10(clamped.max() / clamped.min()), 1e-9)
    rows = []
    for i, (r, l, lc) in enumerate(zip(rates, lat, clamped)):
        bar = "#" * max(int(np.log10(lc / clamped.min()) * scale), 1)
        mark = "  <- saturation" if i == sat_idx else ""
        rows.append(f"    {r:5.2f} | {bar:<{width}s} {l:8.1f}{mark}")
    return "\n".join(rows)


def saturation_point(lat_mean: np.ndarray,
                     factor: float = SATURATION_FACTOR) -> Optional[int]:
    """Index of the first offered load whose mean latency is >= ``factor``
    times the zero-load latency (``lat_mean[0]``), or None if the sweep
    never saturates."""
    lat = np.asarray(lat_mean, float)
    hits = np.nonzero(lat >= factor * lat[0])[0]
    return int(hits[0]) if hits.size else None


def curve_is_monotone(lat_mean: np.ndarray, rel_tol: float = 0.02,
                      factor: float = SATURATION_FACTOR) -> bool:
    """Is a load–latency curve well formed?  Latency must be monotone
    nondecreasing (within ``rel_tol`` measurement tolerance) up to and
    including the saturation point, and must *stay* saturated
    (>= ``factor`` x zero-load) afterwards.  Beyond saturation the
    open-loop latency is unbounded, so finite-window measurements there
    are noise — the standard curve is only defined up to the knee."""
    lat = np.asarray(lat_mean, float)
    sat = saturation_point(lat, factor)
    knee = len(lat) - 1 if sat is None else sat
    pre = lat[:knee + 1]
    if not np.all(pre[1:] >= pre[:-1] * (1.0 - rel_tol)):
        return False
    return bool(np.all(lat[knee:] >= factor * lat[0] * (1.0 - rel_tol))) \
        if sat is not None else True


def curve_record(out: Dict[str, object]) -> Dict[str, object]:
    """JSON-ready per-pattern record of a :func:`load_latency_sweep`
    result — the one schema written to ``experiments/load_latency.json``
    by both ``benchmarks/run.py`` (the CI artifact) and
    ``examples/load_latency.py``."""
    return {
        "rates": [round(float(r), 3) for r in out["rates"]],
        "offered": np.round(out["offered"], 3).tolist(),
        "accepted": np.round(out["accepted"], 3).tolist(),
        "delivered": np.round(out["delivered"], 3).tolist(),
        "lat_mean": np.round(out["lat_mean"], 2).tolist(),
        "lat_p50": np.round(out["lat_p50"], 1).tolist(),
        "lat_p95": np.round(out["lat_p95"], 1).tolist(),
        "lat_p99": np.round(out["lat_p99"], 1).tolist(),
        "lat_max": np.round(out["lat_max"], 1).tolist(),
        "peak_link_util": np.round(out["peak_link_util"], 3).tolist(),
        "hops": np.asarray(out["hops"]).astype(int).tolist(),
        "zero_load_latency": round(float(out["zero_load_latency"]), 2),
        "saturation_index": out["saturation_index"],
        "saturation_rate": out["saturation_rate"],
        "saturation_throughput": round(float(out["saturation_throughput"]),
                                       3),
        "monotone": bool(out["monotone"]),
    }


@functools.lru_cache(maxsize=None)
def _sweep_jit(key: SweepKey):
    """The jitted, rate-vmapped phased-measurement program, cached per
    :class:`SweepKey` so every traffic pattern of a sweep suite shares
    ONE compilation instead of re-tracing per call."""
    cfg = key.cfg

    def f(progs: Program) -> PhaseStats:
        return jax.vmap(
            lambda p: phased_stats(cfg, p, init_state(cfg), key.warmup,
                                   key.measure, key.drain, key.unroll,
                                   key.impl, key.cycles_per_call))(progs)
    return jax.jit(f)


def batch_stats_fn(key: SweepKey):
    """The *traceable* batched phased-measurement function for ``key``:
    ``f(progs, fifo_depths, max_credits) -> PhaseStats``, every argument
    and result carrying a leading batch axis.  ``fifo_depths`` /
    ``max_credits`` are the per-point *dynamic* knobs (must not exceed
    the static ``key.cfg`` capacities); each batch element runs from a
    fresh state.  Returned untransformed so callers can compose it with
    ``jit`` / ``lax.map`` chunking / ``shard_map`` fan-out — the DSE
    runner (:mod:`repro.dse.runner`) does all three."""
    cfg = key.cfg

    def f(progs: Program, fifo_depths: jax.Array,
          max_credits: jax.Array) -> PhaseStats:
        return jax.vmap(
            lambda p, d, c: phased_stats(
                cfg, p, init_state(cfg, d, c), key.warmup, key.measure,
                key.drain, key.unroll, key.impl, key.cycles_per_call))(
            progs, fifo_depths, max_credits)
    return f


@functools.lru_cache(maxsize=None)
def _batched_jit(key: SweepKey):
    return jax.jit(batch_stats_fn(key))


def batched_phased_stats(key, progs: Program, fifo_depths=None,
                         max_credits=None) -> PhaseStats:
    """Batched phased measurement: one vmapped, jitted call over a stack
    of injection programs with per-point dynamic FIFO depths and credit
    allowances.  ``key`` is a :class:`SweepKey` (or any config flavor,
    wrapped with the default phase lengths); depths/credits default to
    the config capacities.  Bit-identical to a Python loop of
    :func:`phased_stats` calls (asserted in ``tests/test_dse.py``)."""
    if not isinstance(key, SweepKey):
        key = SweepKey(cfg=key, warmup=200, measure=400, drain=400)
    n = int(progs.length.shape[0])
    cfg = key.cfg
    depths = jnp.broadcast_to(
        jnp.asarray(cfg.router_fifo if fifo_depths is None else fifo_depths,
                    I32), (n,))
    credits = jnp.broadcast_to(
        jnp.asarray(cfg.max_out_credits if max_credits is None
                    else max_credits, I32), (n,))
    return _batched_jit(key)(progs, depths, credits)


def clear_sweep_cache() -> None:
    """Drop every cached/jitted sweep program (:func:`_sweep_jit` and the
    batched variant).  Long-running DSE services sweep many distinct
    :class:`SweepKey`\\ s; without an occasional clear the jit cache —
    and XLA's per-executable memory — grows without bound."""
    _sweep_jit.cache_clear()
    _batched_jit.cache_clear()


class CompiledSweep(NamedTuple):
    """An AOT-compiled sweep executable plus the :class:`SweepKey` it was
    built for (the shapes alone cannot detect a warmup/measure/drain
    permutation with the same total horizon, so the key is checked)."""
    executable: object
    key: SweepKey

    def __call__(self, progs: Program) -> "PhaseStats":
        return self.executable(progs)


def compile_sweep(cfg, progs: Program, *, warmup: int = 200,
                  measure: int = 400, drain: int = 400, unroll: int = 1,
                  impl: str = "fused", cycles_per_call: int = 1):
    """AOT-compile the vmapped sweep program for ``progs``-shaped input
    via ``jitted.lower(...).compile()``; returns
    ``(CompiledSweep, compile_seconds)``.  Pass the executable to
    :func:`load_latency_sweep` (``compiled=``, same phase lengths) to
    measure pure run time — the benchmark suite uses this to report
    compile and run time separately."""
    import time
    key = SweepKey(_as_simconfig(cfg), warmup, measure, drain, unroll,
                   impl, cycles_per_call)
    fn = _sweep_jit(key)
    t0 = time.perf_counter()
    compiled = fn.lower(progs).compile()
    return CompiledSweep(compiled, key), time.perf_counter() - t0


def load_latency_sweep(pattern: str, nx: int, ny: int,
                       rates: Sequence[float], *,
                       warmup: int = 200, measure: int = 400,
                       drain: int = 400, cfg=None, unroll: int = 1,
                       impl: str = "fused", cycles_per_call: int = 1,
                       compiled=None, **traffic_kw) -> Dict[str, object]:
    """Full load–latency saturation curve for one traffic pattern: the
    phased measurement ``vmap``-ed over offered loads in a single XLA
    program.  Returns numpy arrays keyed like :class:`PhaseStats`, plus
    the rate grid, zero-load latency, and the located saturation point.
    ``cfg`` may be a MeshConfig, NetConfig or SimConfig; ``compiled`` an
    executable from :func:`compile_sweep` (same config/phases/shapes);
    ``impl``/``cycles_per_call`` select the Pallas router kernel as in
    :func:`repro.netsim_jax.simulate` (results identical)."""
    rates = sorted(float(r) for r in rates)
    cfg = SimConfig(nx=nx, ny=ny) if cfg is None else _as_simconfig(cfg)
    # topology-aware patterns (tornado) must see the topology the sim
    # runs on; an explicit traffic_kw["topology"] still wins
    traffic_kw.setdefault("topology", cfg.topology)
    want = SweepKey(cfg, warmup, measure, drain, unroll, impl,
                    cycles_per_call)
    progs = stack_rate_programs(pattern, nx, ny, rates, want.horizon,
                                **traffic_kw)
    if compiled is None:
        run = _sweep_jit(want)
    else:
        key = getattr(compiled, "key", None)
        if key is not None and key != want:
            raise ValueError(
                f"compiled sweep was built for {key}, but "
                f"load_latency_sweep was called with {want}; matching "
                "shapes would execute silently with the wrong "
                "measurement windows")
        run = compiled
    stats = run(progs)
    out: Dict[str, object] = {k: np.asarray(v)
                              for k, v in stats._asdict().items()}
    out["rates"] = np.asarray(rates)
    out["pattern"] = pattern
    out["mesh"] = f"{nx}x{ny}"
    out["topology"] = cfg.topology.kind
    out["zero_load_latency"] = float(out["lat_mean"][0])
    sat = saturation_point(out["lat_mean"])
    out["saturation_index"] = sat
    out["monotone"] = curve_is_monotone(out["lat_mean"])
    out["saturation_rate"] = None if sat is None else float(rates[sat])
    # saturation (peak accepted) throughput, per tile per cycle
    out["saturation_throughput"] = float(np.max(out["accepted"]))
    return out
