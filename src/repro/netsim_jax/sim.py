"""JIT-compiled JAX reimplementation of the cycle-level mesh simulator.

Same semantics as :class:`repro.core.netsim.MeshSim` — 5-port routers with
input FIFOs only, per-output round-robin arbitration, XY dimension-ordered
routing with the reduced crossbar, independent forward/reverse physical
networks, credit-counted standard endpoints — but expressed as a *pure
per-cycle state transition* so the whole simulation compiles to one XLA
program:

* the per-cycle update is :func:`step` (``SimState -> SimState``), driven by
  ``lax.scan`` in :func:`simulate` / ``lax.while_loop`` in
  :func:`run_until_drained`;
* stateful circular FIFOs become index arithmetic + masked one-hot scatter
  (:func:`_fifo_push` / :func:`_fifo_pop`); round-robin arbitration is a
  fixed 5-iteration priority minimisation instead of a data-dependent loop;
* the *effective* router-FIFO depth and credit allowance live in
  ``SimState`` (as scalars) rather than in the static config, so sweeps
  over FIFO depth or ``max_out_credits`` are ``vmap``-able without
  recompiling — as are sweeps over seeds via a stacked injection program.

The numpy :class:`~repro.core.netsim.MeshSim` remains the oracle: the JAX
path is validated cycle-for-cycle against it in
``tests/test_netsim_jax.py``.  Keep the sub-step ordering here in lockstep
with ``MeshSim.step`` — it is load-bearing for exact parity.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.netsim import (LAT_BINS, NO_MEASURE, NetConfig, NUM_DIRS,
                               P, W, E, N, S)
from repro.core.netsim import OP_CAS, OP_LOAD, OP_STORE  # noqa: F401 (re-export)

__all__ = ["SimConfig", "SimState", "Fifo", "Program", "init_state",
           "load_program", "empty_program_for", "step", "simulate",
           "run_until_drained", "run_until_drained_traced", "drained",
           "JaxMeshSim"]

# packet field order — identical to netsim._PKT_FIELDS
FIELDS = ("dst_x", "dst_y", "src_x", "src_y", "addr", "data", "cmp", "op",
          "tag")
F = len(FIELDS)
_FI = {k: i for i, k in enumerate(FIELDS)}

PROG_FIELDS = ("dst_x", "dst_y", "addr", "data", "cmp", "op", "not_before")
_PI = {k: i for i, k in enumerate(PROG_FIELDS)}

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static (shape-determining) configuration; hashable for ``jax.jit``.

    ``router_fifo`` / ``max_out_credits`` here are *capacities*; the
    effective values used by the dynamics are ``SimState.fifo_depth`` /
    ``SimState.max_credits`` (<= capacity), which may be traced/vmapped.
    """
    nx: int
    ny: int
    router_fifo: int = 4
    ep_fifo: int = 4
    max_out_credits: int = 16
    mem_words: int = 64
    resp_latency: int = 1

    @classmethod
    def from_netconfig(cls, cfg: NetConfig) -> "SimConfig":
        """Deprecated shim — route conversions through
        :class:`repro.mesh.MeshConfig` (``MeshConfig.from_net(cfg).to_sim()``)."""
        warnings.warn(
            "SimConfig.from_netconfig is deprecated; use "
            "repro.mesh.MeshConfig.from_net(cfg).to_sim()",
            DeprecationWarning, stacklevel=2)
        return _simconfig_from_net(cfg)

    def to_netconfig(self, **kw) -> NetConfig:
        """Deprecated shim — route conversions through
        :class:`repro.mesh.MeshConfig` (``MeshConfig.from_sim(cfg).to_net()``)."""
        warnings.warn(
            "SimConfig.to_netconfig is deprecated; use "
            "repro.mesh.MeshConfig.from_sim(cfg).to_net()",
            DeprecationWarning, stacklevel=2)
        return NetConfig(nx=self.nx, ny=self.ny, router_fifo=self.router_fifo,
                         ep_fifo=self.ep_fifo,
                         max_out_credits=self.max_out_credits,
                         mem_words=self.mem_words,
                         resp_latency=self.resp_latency, **kw)


def _simconfig_from_net(cfg: NetConfig) -> "SimConfig":
    return SimConfig(nx=cfg.nx, ny=cfg.ny, router_fifo=cfg.router_fifo,
                     ep_fifo=cfg.ep_fifo, max_out_credits=cfg.max_out_credits,
                     mem_words=cfg.mem_words, resp_latency=cfg.resp_latency)


class Fifo(NamedTuple):
    """Struct-of-arrays circular FIFOs: ``buf`` (F, ny, nx, ports, cap)."""
    buf: jax.Array
    head: jax.Array    # (ny, nx, ports)
    count: jax.Array   # (ny, nx, ports)


class Program(NamedTuple):
    """Injection program, kept *outside* the scan carry (it is loop
    invariant; carrying it would copy it every cycle)."""
    buf: jax.Array      # (len(PROG_FIELDS), ny, nx, Lp)
    length: jax.Array   # (ny, nx) — entries with op >= 0


class SimState(NamedTuple):
    fwd: Fifo
    rev: Fifo
    ep_in: Fifo
    resp_valid: jax.Array      # (L, ny, nx) bool
    resp_buf: jax.Array        # (F, L, ny, nx)
    mem: jax.Array             # (ny, nx, mem_words)
    credits: jax.Array         # (ny, nx)
    rr: jax.Array              # (ny, nx, 5)
    rr_rev: jax.Array          # (ny, nx, 5)
    prog_ptr: jax.Array        # (ny, nx)
    reg_valid: jax.Array       # (ny, nx) bool
    reg_buf: jax.Array         # (F, ny, nx)
    completed: jax.Array       # (ny, nx)
    lat_sum: jax.Array         # (ny, nx)
    out_of_credit_cycles: jax.Array  # (ny, nx)
    cycle: jax.Array           # scalar
    fifo_depth: jax.Array      # scalar — effective router FIFO depth
    max_credits: jax.Array     # scalar — effective credit allowance
    # telemetry (cycle-exact twins of the MeshSim accumulators) ---------
    link_util_fwd: jax.Array   # (ny, nx, 5) — packets out of each port
    link_util_rev: jax.Array   # (ny, nx, 5)
    fifo_hwm_fwd: jax.Array    # (ny, nx, 5) — occupancy high-water marks
    fifo_hwm_rev: jax.Array    # (ny, nx, 5)
    ep_hwm: jax.Array          # (ny, nx)
    lat_hist: jax.Array        # (LAT_BINS,) — per-packet RTT histogram
    measure_start: jax.Array   # scalar — window gate on the packet tag
    measure_stop: jax.Array    # scalar


def _empty_fifo(ny: int, nx: int, ports: int, cap: int) -> Fifo:
    return Fifo(buf=jnp.zeros((F, ny, nx, ports, cap), I32),
                head=jnp.zeros((ny, nx, ports), I32),
                count=jnp.zeros((ny, nx, ports), I32))


def init_state(cfg: SimConfig,
               fifo_depth: Optional[jax.typing.ArrayLike] = None,
               max_credits: Optional[jax.typing.ArrayLike] = None) -> SimState:
    """Fresh all-idle state (no program loaded).

    ``fifo_depth`` / ``max_credits`` default to the config capacities and
    may be traced values (for ``vmap`` sweeps) as long as they never exceed
    the static capacity.
    """
    ny, nx = cfg.ny, cfg.nx
    L = cfg.resp_latency
    depth = jnp.asarray(cfg.router_fifo if fifo_depth is None else fifo_depth, I32)
    mc = jnp.asarray(cfg.max_out_credits if max_credits is None else max_credits, I32)
    return SimState(
        fwd=_empty_fifo(ny, nx, NUM_DIRS, cfg.router_fifo),
        rev=_empty_fifo(ny, nx, NUM_DIRS, cfg.router_fifo),
        ep_in=_empty_fifo(ny, nx, 1, cfg.ep_fifo),
        resp_valid=jnp.zeros((L, ny, nx), bool),
        resp_buf=jnp.zeros((F, L, ny, nx), I32),
        mem=jnp.zeros((ny, nx, cfg.mem_words), I32),
        credits=jnp.broadcast_to(mc, (ny, nx)).astype(I32),
        rr=jnp.zeros((ny, nx, NUM_DIRS), I32),
        rr_rev=jnp.zeros((ny, nx, NUM_DIRS), I32),
        prog_ptr=jnp.zeros((ny, nx), I32),
        reg_valid=jnp.zeros((ny, nx), bool),
        reg_buf=jnp.zeros((F, ny, nx), I32),
        completed=jnp.zeros((ny, nx), I32),
        lat_sum=jnp.zeros((ny, nx), I32),
        out_of_credit_cycles=jnp.zeros((ny, nx), I32),
        cycle=jnp.asarray(0, I32),
        fifo_depth=depth,
        max_credits=mc,
        link_util_fwd=jnp.zeros((ny, nx, NUM_DIRS), I32),
        link_util_rev=jnp.zeros((ny, nx, NUM_DIRS), I32),
        fifo_hwm_fwd=jnp.zeros((ny, nx, NUM_DIRS), I32),
        fifo_hwm_rev=jnp.zeros((ny, nx, NUM_DIRS), I32),
        ep_hwm=jnp.zeros((ny, nx), I32),
        lat_hist=jnp.zeros((LAT_BINS,), I32),
        measure_start=jnp.asarray(0, I32),
        measure_stop=jnp.asarray(NO_MEASURE, I32),
    )


def load_program(entries: Dict[str, np.ndarray]) -> Program:
    """Pack an injection program (same schema as ``MeshSim.load_program``:
    fields shaped (ny, nx, L), ``op`` < 0 marks padding)."""
    op = np.asarray(entries["op"])
    ny, nx, Lp = op.shape
    buf = np.zeros((len(PROG_FIELDS), ny, nx, Lp), np.int32)
    i32 = np.iinfo(np.int32)
    for k, i in _PI.items():
        if k in entries:
            v = np.asarray(entries[k])
            if v.min(initial=0) < i32.min or v.max(initial=0) > i32.max:
                raise ValueError(
                    f"program field {k!r} exceeds the JAX simulator's int32 "
                    "packet domain (the numpy oracle is int64); clamp values "
                    f"to [{i32.min}, {i32.max}]")
            buf[i] = v.astype(np.int32)
    return Program(buf=jnp.asarray(buf),
                   length=jnp.asarray((op >= 0).sum(-1), I32))


def _empty_program_for(cfg: SimConfig) -> Program:
    return Program(buf=jnp.full((len(PROG_FIELDS), cfg.ny, cfg.nx, 1), -1, I32),
                   length=jnp.zeros((cfg.ny, cfg.nx), I32))


def empty_program_for(cfg: SimConfig) -> Program:
    """Deprecated — ``load_program(repro.mesh.empty_program(nx, ny, 1))``
    (or simply don't load anything: a fresh state injects nothing)."""
    warnings.warn(
        "empty_program_for is deprecated; build programs with "
        "repro.mesh.empty_program and pack them with load_program",
        DeprecationWarning, stacklevel=2)
    return _empty_program_for(cfg)


# ----------------------------------------------------------------------
# FIFO primitives (pure)
# ----------------------------------------------------------------------
def _fifo_peek(f: Fifo) -> jax.Array:
    """Head packet of every FIFO: (F, ny, nx, ports).

    A select chain over the (small, static) depth axis rather than a
    gather — XLA CPU fuses the selects into one elementwise pass, while a
    gather lowers to a scalar loop."""
    cap = f.buf.shape[-1]
    out = f.buf[..., 0]
    for d in range(1, cap):
        out = jnp.where(f.head[None] == d, f.buf[..., d], out)
    return out


def _fifo_pop(f: Fifo, mask: jax.Array, depth: jax.Array) -> Fifo:
    m = mask.astype(I32)
    return f._replace(head=(f.head + m) % depth, count=f.count - m)


def _fifo_push(f: Fifo, mask: jax.Array, pkt: jax.Array,
               depth: jax.Array) -> Fifo:
    """Enqueue ``pkt`` (F, ny, nx, ports) where ``mask`` (ny, nx, ports);
    caller guarantees space.  A one-hot masked select over the (small)
    depth axis — fuses to a single elementwise pass on CPU, where XLA
    scatters are far slower."""
    cap = f.buf.shape[-1]
    tail = (f.head + f.count) % depth                       # (ny, nx, ports)
    onehot = (jnp.arange(cap, dtype=I32) == tail[..., None]) & mask[..., None]
    buf = jnp.where(onehot[None], pkt[..., None], f.buf)
    return f._replace(buf=buf, count=f.count + mask.astype(I32))


# ----------------------------------------------------------------------
# router
# ----------------------------------------------------------------------
def _route(heads: jax.Array, xs: jax.Array, ys: jax.Array) -> jax.Array:
    """XY dimension-ordered output port for each head packet
    (heads: (F, ny, nx, ports) -> (ny, nx, ports))."""
    dx, dy = heads[_FI["dst_x"]], heads[_FI["dst_y"]]
    x, y = xs[..., None], ys[..., None]
    return jnp.where(dx > x, E, jnp.where(dx < x, W,
           jnp.where(dy > y, S, jnp.where(dy < y, N, P)))).astype(I32)


def _arbitrate(net: Fifo, rr: jax.Array, deliver_space: jax.Array,
               xs: jax.Array, ys: jax.Array, depth: jax.Array,
               ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Routing + round-robin arbitration for one network, one cycle
    (mirrors the first half of ``MeshSim._router_step``).  Returns
    (rr', pop_mask (ny,nx,in), has (ny,nx,out), moved_pkt (F,ny,nx,out))."""
    ny, nx = deliver_space.shape
    heads = _fifo_peek(net)                     # (F, ny, nx, 5)
    valid = net.count > 0                       # (ny, nx, 5)
    want = _route(heads, xs, ys)                # (ny, nx, 5)

    # Destination space per output port (start-of-cycle, conservative),
    # assembled with shifts + one stack (cheaper than slice updates on CPU).
    space = net.count < depth                   # (ny, nx, 5)
    pad = functools.partial(jnp.pad, mode="constant", constant_values=False)
    out_space = jnp.stack([
        deliver_space,                                  # P
        pad(space[:, :-1, E], ((0, 0), (1, 0))),        # W out -> west nbr's E
        pad(space[:, 1:, W], ((0, 0), (0, 1))),         # E out -> east nbr's W
        pad(space[:-1, :, S], ((1, 0), (0, 0))),        # N out -> north nbr's S
        pad(space[1:, :, N], ((0, 1), (0, 0))),         # S out -> south nbr's N
    ], axis=-1)

    # Round-robin arbitration, all five output ports at once: per output
    # port o, the valid requester with minimal (in_port - rr[o]) mod 5 wins.
    io = jnp.arange(NUM_DIRS, dtype=I32)
    cand = (valid[..., :, None]                           # (ny, nx, in, out)
            & (want[..., :, None] == io[None, None, None, :])
            & out_space[..., None, :])
    prio = (io[:, None] - rr[..., None, :]) % NUM_DIRS
    prio = jnp.where(cand, prio, NUM_DIRS + 1)
    best = prio.min(-2)                                   # (ny, nx, out)
    win = jnp.where(best <= NUM_DIRS,
                    jnp.argmin(prio, axis=-2).astype(I32), -1)
    rr = jnp.where(win >= 0, (win + 1) % NUM_DIRS, rr)
    has = win >= 0                                        # (ny, nx, out)
    widx = jnp.clip(win, 0, NUM_DIRS - 1)
    # winning packet per output port: select along the *input* axis
    # (fusible select chain instead of a gather; see _fifo_peek)
    moved_pkt = jnp.broadcast_to(heads[..., :1], (F, ny, nx, NUM_DIRS))
    for i in range(1, NUM_DIRS):
        moved_pkt = jnp.where(widx[None] == i, heads[..., i:i + 1],
                              moved_pkt)                  # (F, ny, nx, out)
    pop = ((io[:, None] == widx[..., None, :]) & has[..., None, :]).any(-1)
    return rr, pop, has, moved_pkt


def _neighbor_push_masks(has: jax.Array, moved_pkt: jax.Array,
                         p_mask: jax.Array, p_pkt: jax.Array,
                         ) -> Tuple[jax.Array, jax.Array]:
    """Turn per-output winners into per-input push masks for the neighbour
    FIFOs, with the local port-P enqueue (endpoint response or program
    injection) folded into the same single write.  Every destination
    (tile, in_port) has exactly one feeder, so this is conflict-free."""
    padm = functools.partial(jnp.pad, mode="constant", constant_values=False)
    padp = jnp.pad
    # in-port k of tile t receives the opposite-direction output of the
    # adjacent tile: W <- west nbr's E, E <- east nbr's W, N <- north nbr's
    # S, S <- south nbr's N; port P is the local enqueue.
    mask_in = jnp.stack([
        p_mask,
        padm(has[:, :-1, E], ((0, 0), (1, 0))),
        padm(has[:, 1:, W], ((0, 0), (0, 1))),
        padm(has[:-1, :, S], ((1, 0), (0, 0))),
        padm(has[1:, :, N], ((0, 1), (0, 0))),
    ], axis=-1)
    z2 = ((0, 0), (0, 0))
    pkt_in = jnp.stack([
        p_pkt,
        padp(moved_pkt[:, :, :-1, E], z2 + ((1, 0),)),
        padp(moved_pkt[:, :, 1:, W], z2 + ((0, 1),)),
        padp(moved_pkt[:, :-1, :, S], ((0, 0), (1, 0), (0, 0))),
        padp(moved_pkt[:, 1:, :, N], ((0, 0), (0, 1), (0, 0))),
    ], axis=-1)
    return mask_in, pkt_in


# ----------------------------------------------------------------------
# the per-cycle transition
# ----------------------------------------------------------------------
def _coords(cfg: SimConfig) -> Tuple[np.ndarray, np.ndarray]:
    # host-side numpy constants (NOT jax arrays: a cached jax array created
    # inside one trace would leak into the next); XLA hoists them out of
    # the scan loop
    ys, xs = np.mgrid[0:cfg.ny, 0:cfg.nx]
    return xs.astype(np.int32), ys.astype(np.int32)


def step(cfg: SimConfig, prog: Program, st: SimState,
         ) -> Tuple[SimState, jax.Array]:
    """One simulator cycle; returns (state', completions_this_cycle).

    The sub-step order matches ``MeshSim.step`` exactly — do not reorder.
    """
    ny, nx = cfg.ny, cfg.nx
    xs, ys = _coords(cfg)
    c = st.cycle

    # ---- registered response port becomes visible (stats record) ----
    rv = st.reg_valid
    completed = st.completed + rv.astype(I32)
    lat = c - st.reg_buf[_FI["tag"]]
    lat_sum = st.lat_sum + jnp.where(rv, lat, 0)
    done_now = rv.sum().astype(I32)
    # latency histogram, gated to the measurement window by the packet's
    # injection cycle (its tag); scatter-add of 0 elsewhere is a no-op
    tag = st.reg_buf[_FI["tag"]]
    in_win = rv & (tag >= st.measure_start) & (tag < st.measure_stop)
    lat_hist = st.lat_hist.at[jnp.clip(lat, 0, LAT_BINS - 1)].add(
        in_win.astype(I32))

    # ---- reverse network: route; P deliveries are ALWAYS absorbed ----
    rr_rev, rpop, rhas, rmoved = _arbitrate(
        st.rev, st.rr_rev, jnp.ones((ny, nx), bool), xs, ys, st.fifo_depth)
    rev = _fifo_pop(st.rev, rpop, st.fifo_depth)
    absorbed, rpkt = rhas[..., P], rmoved[..., P]
    credits = st.credits + absorbed.astype(I32)
    reg_valid = absorbed
    reg_buf = jnp.where(absorbed[None], rpkt, 0)

    # ---- endpoint: inject pending responses into reverse P FIFO ----
    # (folded into the same buffer write as the neighbour enqueues; the
    # neighbour pushes never touch port P, so tails are independent)
    L = cfg.resp_latency
    if L == 1:                    # static fast path: slot is always 0
        slot = jnp.asarray(0, I32)
        inj = st.resp_valid[0]                              # (ny, nx)
        inj_pkt = st.resp_buf[:, 0]                         # (F, ny, nx)
    else:
        slot = (c % L).astype(I32)
        inj = jnp.take(st.resp_valid, slot, axis=0)
        inj_pkt = jnp.take(st.resp_buf, slot, axis=1)
    rmask_in, rpkt_in = _neighbor_push_masks(rhas, rmoved, inj, inj_pkt)
    rev = _fifo_push(rev, rmask_in, rpkt_in, st.fifo_depth)
    if L == 1:
        resp_valid = jnp.zeros_like(st.resp_valid)
    else:
        resp_valid = st.resp_valid.at[slot].set(False)
    resp_buf = st.resp_buf

    # ---- endpoint: service one request/cycle (line rate) ----------
    resp_inflight = resp_valid.sum(0).astype(I32)
    rev_space = (rev.count[..., P] + resp_inflight) < st.fifo_depth
    can = (st.ep_in.count[..., 0] > 0) & rev_space
    req = _fifo_peek(st.ep_in)[..., 0]                      # (F, ny, nx)
    addr = jnp.clip(req[_FI["addr"]], 0, cfg.mem_words - 1)
    addr_oh = jnp.arange(cfg.mem_words, dtype=I32) == addr[..., None]
    cur = jnp.take_along_axis(st.mem, addr[..., None], axis=-1)[..., 0]
    is_store = can & (req[_FI["op"]] == OP_STORE)
    is_load = can & (req[_FI["op"]] == OP_LOAD)
    is_cas = can & (req[_FI["op"]] == OP_CAS)
    cas_hit = is_cas & (cur == req[_FI["cmp"]])
    newval = jnp.where(is_store | cas_hit, req[_FI["data"]], cur)
    mem = jnp.where(addr_oh & can[..., None], newval[..., None], st.mem)
    ep_in = _fifo_pop(st.ep_in, can[..., None],
                      jnp.asarray(cfg.ep_fifo, I32))
    rdata = jnp.where(is_load | is_cas, cur, 0)
    # build the response packet: src<->dst swapped so it routes home
    resp = jnp.stack([
        req[_FI["src_x"]], req[_FI["src_y"]],   # dst <- requester
        xs, ys,                                 # src <- this tile
        req[_FI["addr"]], rdata, req[_FI["cmp"]], req[_FI["op"]],
        req[_FI["tag"]],
    ])
    if L == 1:                    # resp_valid[0] was just cleared above
        resp_valid = can[None]
        resp_buf = jnp.where(can[None, None], resp[:, None], resp_buf)
    else:
        wslot = slot              # c % L: inject and refill the same slot
        resp_valid = resp_valid.at[wslot].set(
            jnp.where(can, True, jnp.take(resp_valid, wslot, axis=0)))
        resp_buf = resp_buf.at[:, wslot].set(
            jnp.where(can[None], resp, jnp.take(resp_buf, wslot, axis=1)))

    # ---- forward network: route; P deliveries go to endpoint FIFO ----
    rr, fpop, fhas, fmoved = _arbitrate(
        st.fwd, st.rr, ep_in.count[..., 0] < cfg.ep_fifo, xs, ys,
        st.fifo_depth)
    fwd = _fifo_pop(st.fwd, fpop, st.fifo_depth)
    got, fpkt = fhas[..., P], fmoved[..., P]
    ep_in = _fifo_push(ep_in, got[..., None], fpkt[..., None],
                       jnp.asarray(cfg.ep_fifo, I32))

    # ---- master injection from the per-tile program -----------------
    # The injection enqueue targets port P of the post-pop forward FIFOs
    # (neighbour pushes never touch port P), so it folds into the same
    # buffer write as the neighbour enqueues.
    pending = st.prog_ptr < prog.length
    out_of_credit = st.out_of_credit_cycles + \
        (pending & (credits <= 0)).astype(I32)
    can_inj = pending & (credits > 0)
    Lp = prog.buf.shape[-1]
    pidx = jnp.clip(st.prog_ptr, 0, max(Lp - 1, 0))
    entry = jnp.take_along_axis(
        prog.buf, jnp.broadcast_to(pidx[None, ..., None],
                                   (len(PROG_FIELDS), ny, nx, 1)),
        axis=-1)[..., 0]                                    # (|PROG|, ny, nx)
    can_inj = can_inj & (entry[_PI["not_before"]] <= c)
    can_inj = can_inj & (fwd.count[..., P] < st.fifo_depth)
    pkt = jnp.stack([
        entry[_PI["dst_x"]], entry[_PI["dst_y"]],
        xs, ys,
        entry[_PI["addr"]], entry[_PI["data"]], entry[_PI["cmp"]],
        entry[_PI["op"]],
        jnp.full((ny, nx), c, I32),
    ])                                                      # (F, ny, nx)
    fmask_in, fpkt_in = _neighbor_push_masks(fhas, fmoved, can_inj, pkt)
    fwd = _fifo_push(fwd, fmask_in, fpkt_in, st.fifo_depth)
    credits = credits - can_inj.astype(I32)
    prog_ptr = st.prog_ptr + can_inj.astype(I32)

    # ---- telemetry: link counts + occupancy high-water marks ----------
    link_util_fwd = st.link_util_fwd + fhas.astype(I32)
    link_util_rev = st.link_util_rev + rhas.astype(I32)
    fifo_hwm_fwd = jnp.maximum(st.fifo_hwm_fwd, fwd.count)
    fifo_hwm_rev = jnp.maximum(st.fifo_hwm_rev, rev.count)
    ep_hwm = jnp.maximum(st.ep_hwm, ep_in.count[..., 0])

    st = SimState(fwd=fwd, rev=rev, ep_in=ep_in,
                  resp_valid=resp_valid, resp_buf=resp_buf, mem=mem,
                  credits=credits, rr=rr, rr_rev=rr_rev, prog_ptr=prog_ptr,
                  reg_valid=reg_valid, reg_buf=reg_buf,
                  completed=completed, lat_sum=lat_sum,
                  out_of_credit_cycles=out_of_credit,
                  cycle=c + 1, fifo_depth=st.fifo_depth,
                  max_credits=st.max_credits,
                  link_util_fwd=link_util_fwd, link_util_rev=link_util_rev,
                  fifo_hwm_fwd=fifo_hwm_fwd, fifo_hwm_rev=fifo_hwm_rev,
                  ep_hwm=ep_hwm, lat_hist=lat_hist,
                  measure_start=st.measure_start,
                  measure_stop=st.measure_stop)
    return st, done_now


def drained(st: SimState, prog: Program) -> jax.Array:
    """Global-fence condition: programs issued, credits home, nothing in
    the registered response port (same as ``MeshSim.run_until_drained``)."""
    return ((st.prog_ptr >= prog.length).all()
            & (st.credits == st.max_credits).all()
            & ~st.reg_valid.any())


@functools.partial(jax.jit, static_argnums=(0, 3))
def simulate(cfg: SimConfig, prog: Program, state: SimState, cycles: int,
             ) -> Tuple[SimState, jax.Array]:
    """Run ``cycles`` cycles under ``lax.scan``; returns
    (final_state, completions_per_cycle (cycles,))."""
    def body(st, _):
        return step(cfg, prog, st)
    return lax.scan(body, state, None, length=cycles)


@functools.partial(jax.jit, static_argnums=(0, 3))
def run_until_drained(cfg: SimConfig, prog: Program, state: SimState,
                      max_cycles: int = 100_000) -> Tuple[SimState, jax.Array]:
    """Step until the global fence closes (or after ``max_cycles`` further
    steps); returns (final_state, drain_cycle)."""
    def cond(carry):
        st, i = carry
        return (~drained(st, prog)) & (i < max_cycles)

    def body(carry):
        st, i = carry
        return step(cfg, prog, st)[0], i + 1

    final, _ = lax.while_loop(cond, body, (state, jnp.asarray(0, I32)))
    return final, final.cycle


@functools.partial(jax.jit, static_argnums=(0, 3))
def run_until_drained_traced(cfg: SimConfig, prog: Program, state: SimState,
                             max_cycles: int = 100_000,
                             ) -> Tuple[SimState, jax.Array, jax.Array]:
    """Like :func:`run_until_drained` but also records the per-cycle
    completion trace into a preallocated ``(max_cycles,)`` buffer; returns
    (final_state, steps_taken, trace) — ``trace[:steps_taken]`` is valid."""
    def cond(carry):
        st, _trace, i = carry
        return (~drained(st, prog)) & (i < max_cycles)

    def body(carry):
        st, trace, i = carry
        st2, done = step(cfg, prog, st)
        return st2, trace.at[i].set(done), i + 1

    trace0 = jnp.zeros((max_cycles,), I32)
    final, trace, steps = lax.while_loop(
        cond, body, (state, trace0, jnp.asarray(0, I32)))
    return final, steps, trace


# ----------------------------------------------------------------------
# convenience wrapper mirroring the MeshSim driving API
# ----------------------------------------------------------------------
class JaxMeshSim:
    """Thin stateful wrapper over the functional API, drop-in enough for
    the oracle's driving pattern::

        sim = JaxMeshSim(NetConfig(nx=4, ny=4))
        sim.load_program(prog)
        sim.run(100)            # or sim.run_until_drained()
        sim.mem, sim.completed, sim.completed_per_cycle, ...

    Each ``run*`` call dispatches one jitted XLA program; repeated calls
    with the same static config reuse the compilation cache.
    """

    def __init__(self, cfg, fifo_depth=None, max_credits=None):
        if not isinstance(cfg, SimConfig):
            # NetConfig / repro.mesh.MeshConfig share the field names
            cfg = _simconfig_from_net(cfg)
        self.cfg = cfg
        self.state = init_state(cfg, fifo_depth=fifo_depth,
                                max_credits=max_credits)
        self.program = _empty_program_for(cfg)
        self.completed_per_cycle: list = []

    def load_program(self, entries: Dict[str, np.ndarray]) -> None:
        self.program = load_program(entries)
        self.state = self.state._replace(
            prog_ptr=jnp.zeros((self.cfg.ny, self.cfg.nx), I32))

    def run(self, cycles: int) -> None:
        self.state, per_cycle = simulate(self.cfg, self.program, self.state,
                                         cycles)
        self.completed_per_cycle.extend(np.asarray(per_cycle).tolist())

    def run_until_drained(self, max_cycles: int = 100_000) -> int:
        self.state, steps, trace = run_until_drained_traced(
            self.cfg, self.program, self.state, max_cycles)
        steps = int(steps)
        self.completed_per_cycle.extend(np.asarray(trace[:steps]).tolist())
        if steps >= max_cycles and \
                not bool(drained(self.state, self.program)):
            raise RuntimeError(f"network did not drain in {max_cycles} cycles")
        return int(self.state.cycle)

    # oracle-shaped accessors -----------------------------------------
    @property
    def mem(self) -> np.ndarray:
        return np.asarray(self.state.mem, np.int64)

    @property
    def completed(self) -> np.ndarray:
        return np.asarray(self.state.completed, np.int64)

    @property
    def lat_sum(self) -> np.ndarray:
        return np.asarray(self.state.lat_sum, np.int64)

    @property
    def credits(self) -> np.ndarray:
        return np.asarray(self.state.credits, np.int64)

    @property
    def out_of_credit_cycles(self) -> np.ndarray:
        return np.asarray(self.state.out_of_credit_cycles, np.int64)

    # telemetry ---------------------------------------------------------
    @property
    def link_util_fwd(self) -> np.ndarray:
        return np.asarray(self.state.link_util_fwd, np.int64)

    @property
    def link_util_rev(self) -> np.ndarray:
        return np.asarray(self.state.link_util_rev, np.int64)

    @property
    def fifo_hwm_fwd(self) -> np.ndarray:
        return np.asarray(self.state.fifo_hwm_fwd, np.int64)

    @property
    def fifo_hwm_rev(self) -> np.ndarray:
        return np.asarray(self.state.fifo_hwm_rev, np.int64)

    @property
    def ep_hwm(self) -> np.ndarray:
        return np.asarray(self.state.ep_hwm, np.int64)

    @property
    def lat_hist(self) -> np.ndarray:
        return np.asarray(self.state.lat_hist, np.int64)

    def set_measure_window(self, start: int, stop: int) -> None:
        """Restrict the latency histogram to packets *injected* in cycle
        range [start, stop) — same contract as ``MeshSim.set_measure_window``."""
        self.state = self.state._replace(
            measure_start=jnp.asarray(start, I32),
            measure_stop=jnp.asarray(stop, I32))

    @property
    def cycle(self) -> int:
        return int(self.state.cycle)

    def mean_latency(self) -> float:
        done = int(self.completed.sum())
        return float(self.lat_sum.sum()) / max(done, 1)

    def throughput(self, warmup: int = 0) -> float:
        per = self.completed_per_cycle[warmup:]
        return float(np.sum(per)) / max(len(per), 1)
