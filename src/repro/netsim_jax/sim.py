"""JIT-compiled JAX reimplementation of the cycle-level mesh simulator.

Same semantics as :class:`repro.core.netsim.MeshSim` — 5-port routers with
input FIFOs only, per-output round-robin arbitration, XY dimension-ordered
routing with the reduced crossbar, independent forward/reverse physical
networks, credit-counted standard endpoints — but expressed as a *pure
per-cycle state transition* so the whole simulation compiles to one XLA
program:

* the per-cycle update is :func:`step` (``SimState -> SimState``), driven by
  ``lax.scan`` in :func:`simulate` / ``lax.while_loop`` in
  :func:`run_until_drained`;
* packets are **header-packed**: the five routing/control fields
  (dst/src coordinates + opcode) live in one int32 word
  (:mod:`repro.mesh.encoding`), so a packet is 5 lanes
  (``hdr, addr, data, cmp, tag``) instead of the oracle's 9 fields —
  nearly halving per-cycle FIFO buffer traffic;
* the forward and reverse networks are **fused** into one stacked
  ``(2, ny, nx, ...)`` FIFO pytree; routing, round-robin arbitration and
  the buffer write trace *once* per cycle over the stacked axis instead
  of twice, halving the emitted HLO (and with it XLA compile time).  The
  networks' only semantic difference — whether the port-P output may
  deliver this cycle — enters arbitration as a pure AND on the P output
  column, so it is applied *after* the fused pass (:func:`_finalize`)
  without changing any result bit;
* stateful circular FIFOs become index arithmetic + masked one-hot selects
  (:func:`_fifo_push` / :func:`_fifo_pop`); round-robin arbitration is a
  fixed 5-iteration priority minimisation instead of a data-dependent loop;
* the *effective* router-FIFO depth and credit allowance live in
  ``SimState`` (as scalars) rather than in the static config, so sweeps
  over FIFO depth or ``max_out_credits`` are ``vmap``-able without
  recompiling — as are sweeps over seeds via a stacked injection program;
* all three jitted entry points **donate** the ``SimState`` argument, so
  XLA updates the (large) FIFO buffers in place instead of copying them;
* :func:`simulate` takes a static ``unroll`` factor for its ``lax.scan``,
  and :func:`run_until_drained` a ``check_every`` cadence that evaluates
  the global drain fence every K cycles instead of every cycle (the
  reported drain cycle stays exact; with K > 1 the *state* may run up to
  K - 1 cycles past the fence, which only advances ``SimState.cycle`` —
  a drained network is quiescent);
* the whole per-cycle transition can alternatively run as ONE hand-tiled
  Pallas kernel (``impl="pallas"``, :mod:`repro.kernels.router_step`)
  with a static ``cycles_per_call`` inner loop, so several mesh cycles
  execute per kernel launch — same trace, same bits, amortized dispatch.
  On hosts without a compiled Pallas backend the kernel runs in
  interpret mode automatically (:mod:`repro.kernels.backend`), keeping
  results identical everywhere.

The numpy :class:`~repro.core.netsim.MeshSim` remains the oracle: the JAX
path is validated cycle-for-cycle against it in
``tests/test_netsim_jax.py``, including decoded packet-level state in
``tests/test_encoding.py``.  Keep the sub-step ordering here in lockstep
with ``MeshSim.step`` — it is load-bearing for exact parity.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.netsim import (LAT_BINS, NO_MEASURE, NetConfig, NUM_DIRS,
                               P, W, E, N, S)
from repro.core.netsim import OP_CAS, OP_LOAD, OP_STORE  # noqa: F401 (re-export)
from repro.mesh.encoding import (COORD_LIMIT, COORD_MASK, DST_Y_SHIFT,
                                 OP_MASK, OP_SHIFT, pack_dst_op,
                                 swap_for_response, validate_program,
                                 with_src)
from repro.mesh.topology import Topology

__all__ = ["SimConfig", "SimState", "Fifo", "Program", "FWD", "REV",
           "init_state", "load_program", "empty_program_for", "step",
           "simulate", "run_until_drained", "run_until_drained_traced",
           "drained", "JaxMeshSim"]

# packet lanes: the five header fields of netsim._PKT_FIELDS are packed
# into the single `hdr` word (see repro.mesh.encoding for the layout)
FIELDS = ("hdr", "addr", "data", "cmp", "tag")
F = len(FIELDS)
_FI = {k: i for i, k in enumerate(FIELDS)}

# injection-program lanes: `hdr` holds (dst_x, dst_y, op) with the source
# pair zero — the injecting tile ORs itself in at injection time
PROG_FIELDS = ("hdr", "addr", "data", "cmp", "not_before")
_PI = {k: i for i, k in enumerate(PROG_FIELDS)}

# the stacked physical-network axis: index 0 = forward (requests),
# index 1 = reverse (responses/credits)
FWD, REV = 0, 1

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static (shape-determining) configuration; hashable for ``jax.jit``.

    ``router_fifo`` / ``max_out_credits`` here are *capacities*; the
    effective values used by the dynamics are ``SimState.fifo_depth`` /
    ``SimState.max_credits`` (<= capacity), which may be traced/vmapped.
    """
    nx: int
    ny: int
    router_fifo: int = 4
    ep_fifo: int = 4
    max_out_credits: int = 16
    mem_words: int = 64
    resp_latency: int = 1
    # network topology; None is normalized to the plain mesh.  Topology is
    # frozen/hashable, so the config stays a valid jit static — the wrap
    # flags and boundary gating become *compile-time* branches and the
    # mesh trace is byte-identical to the pre-topology code.
    topology: Optional[Topology] = None

    def __post_init__(self):
        if not (0 < self.nx <= COORD_LIMIT and 0 < self.ny <= COORD_LIMIT):
            raise ValueError(
                f"mesh dimensions must be in [1, {COORD_LIMIT}] to fit the "
                f"packed header coordinate fields, got nx={self.nx}, "
                f"ny={self.ny}")
        if self.topology is None:
            object.__setattr__(self, "topology", Topology.mesh())
        self.topology.validate_for(self.nx, self.ny)
        if (self.topology.wrap_x or self.topology.wrap_y) \
                and self.router_fifo < 2:
            raise ValueError(
                "wrapped (ring/torus) topologies need router_fifo >= 2: "
                "the ring bubble flow control reserves one slot for "
                f"entering packets, got router_fifo={self.router_fifo}")

    @classmethod
    def from_netconfig(cls, cfg: NetConfig) -> "SimConfig":
        """Deprecated shim — route conversions through
        :class:`repro.mesh.MeshConfig` (``MeshConfig.from_net(cfg).to_sim()``)."""
        warnings.warn(
            "SimConfig.from_netconfig is deprecated; use "
            "repro.mesh.MeshConfig.from_net(cfg).to_sim()",
            DeprecationWarning, stacklevel=2)
        return _simconfig_from_net(cfg)

    def to_netconfig(self, **kw) -> NetConfig:
        """Deprecated shim — route conversions through
        :class:`repro.mesh.MeshConfig` (``MeshConfig.from_sim(cfg).to_net()``)."""
        warnings.warn(
            "SimConfig.to_netconfig is deprecated; use "
            "repro.mesh.MeshConfig.from_sim(cfg).to_net()",
            DeprecationWarning, stacklevel=2)
        return NetConfig(nx=self.nx, ny=self.ny, router_fifo=self.router_fifo,
                         ep_fifo=self.ep_fifo,
                         max_out_credits=self.max_out_credits,
                         mem_words=self.mem_words,
                         resp_latency=self.resp_latency,
                         topology=self.topology, **kw)


def _simconfig_from_net(cfg: NetConfig) -> "SimConfig":
    return SimConfig(nx=cfg.nx, ny=cfg.ny, router_fifo=cfg.router_fifo,
                     ep_fifo=cfg.ep_fifo, max_out_credits=cfg.max_out_credits,
                     mem_words=cfg.mem_words, resp_latency=cfg.resp_latency,
                     topology=getattr(cfg, "topology", None))


class Fifo(NamedTuple):
    """Struct-of-arrays circular FIFOs.

    The two router networks are stacked: ``buf`` is
    ``(F, 2, ny, nx, ports, cap)`` with ``head``/``count``
    ``(2, ny, nx, ports)``; the endpoint request FIFO keeps its unstacked
    ``(F, ny, nx, 1, cap)`` shape."""
    buf: jax.Array
    head: jax.Array
    count: jax.Array


class Program(NamedTuple):
    """Injection program, kept *outside* the scan carry (it is loop
    invariant; carrying it would copy it every cycle).  ``buf`` lanes are
    ``PROG_FIELDS`` — header-packed, 5 lanes."""
    buf: jax.Array      # (len(PROG_FIELDS), ny, nx, Lp)
    length: jax.Array   # (ny, nx) — entries with op >= 0


class SimState(NamedTuple):
    net: Fifo                  # stacked fwd/rev router FIFOs (see Fifo)
    ep_in: Fifo
    resp_valid: jax.Array      # (L, ny, nx) bool
    resp_buf: jax.Array        # (F, L, ny, nx)
    mem: jax.Array             # (ny, nx, mem_words)
    credits: jax.Array         # (ny, nx)
    rr: jax.Array              # (2, ny, nx, 5) round-robin ptrs per network
    prog_ptr: jax.Array        # (ny, nx)
    reg_valid: jax.Array       # (ny, nx) bool
    reg_buf: jax.Array         # (F, ny, nx)
    completed: jax.Array       # (ny, nx)
    lat_sum: jax.Array         # (ny, nx)
    out_of_credit_cycles: jax.Array  # (ny, nx)
    cycle: jax.Array           # scalar
    fifo_depth: jax.Array      # scalar — effective router FIFO depth
    max_credits: jax.Array     # scalar — effective credit allowance
    # telemetry (cycle-exact twins of the MeshSim accumulators) ---------
    link_util: jax.Array       # (2, ny, nx, 5) — packets out of each port
    fifo_hwm: jax.Array        # (2, ny, nx, 5) — occupancy high-water marks
    ep_hwm: jax.Array          # (ny, nx)
    lat_hist: jax.Array        # (LAT_BINS,) — per-packet RTT histogram
    measure_start: jax.Array   # scalar — window gate on the packet tag
    measure_stop: jax.Array    # scalar


def init_state(cfg: SimConfig,
               fifo_depth: Optional[jax.typing.ArrayLike] = None,
               max_credits: Optional[jax.typing.ArrayLike] = None) -> SimState:
    """Fresh all-idle state (no program loaded).

    ``fifo_depth`` / ``max_credits`` default to the config capacities and
    may be traced values (for ``vmap`` sweeps) as long as they never exceed
    the static capacity.
    """
    ny, nx = cfg.ny, cfg.nx
    L = cfg.resp_latency
    depth = jnp.asarray(cfg.router_fifo if fifo_depth is None else fifo_depth, I32)
    mc = jnp.asarray(cfg.max_out_credits if max_credits is None else max_credits, I32)
    return SimState(
        net=Fifo(buf=jnp.zeros((F, 2, ny, nx, NUM_DIRS, cfg.router_fifo), I32),
                 head=jnp.zeros((2, ny, nx, NUM_DIRS), I32),
                 count=jnp.zeros((2, ny, nx, NUM_DIRS), I32)),
        ep_in=Fifo(buf=jnp.zeros((F, ny, nx, 1, cfg.ep_fifo), I32),
                   head=jnp.zeros((ny, nx, 1), I32),
                   count=jnp.zeros((ny, nx, 1), I32)),
        resp_valid=jnp.zeros((L, ny, nx), bool),
        resp_buf=jnp.zeros((F, L, ny, nx), I32),
        mem=jnp.zeros((ny, nx, cfg.mem_words), I32),
        credits=jnp.broadcast_to(mc, (ny, nx)).astype(I32),
        rr=jnp.zeros((2, ny, nx, NUM_DIRS), I32),
        prog_ptr=jnp.zeros((ny, nx), I32),
        reg_valid=jnp.zeros((ny, nx), bool),
        reg_buf=jnp.zeros((F, ny, nx), I32),
        completed=jnp.zeros((ny, nx), I32),
        lat_sum=jnp.zeros((ny, nx), I32),
        out_of_credit_cycles=jnp.zeros((ny, nx), I32),
        cycle=jnp.asarray(0, I32),
        fifo_depth=depth,
        max_credits=mc,
        link_util=jnp.zeros((2, ny, nx, NUM_DIRS), I32),
        fifo_hwm=jnp.zeros((2, ny, nx, NUM_DIRS), I32),
        ep_hwm=jnp.zeros((ny, nx), I32),
        lat_hist=jnp.zeros((LAT_BINS,), I32),
        measure_start=jnp.asarray(0, I32),
        measure_stop=jnp.asarray(NO_MEASURE, I32),
    )


def load_program(entries: Dict[str, np.ndarray]) -> Program:
    """Pack an injection program (same schema as ``MeshSim.load_program``:
    fields shaped (ny, nx, L), ``op`` < 0 marks padding) into the
    header-packed 5-lane :class:`Program`.

    Validates the packet domain first: coordinates and opcode must fit
    the packed header field widths, payload lanes must fit int32 — see
    :func:`repro.mesh.encoding.validate_program` for the exact limits
    (the error names the offending field).
    """
    op = np.asarray(entries["op"])
    ny, nx, Lp = op.shape
    validate_program(entries)
    zero = np.zeros(op.shape, np.int64)

    def get(k):
        return np.asarray(entries[k]) if k in entries else zero

    buf = np.stack([
        pack_dst_op(get("dst_x").astype(np.int64), get("dst_y"), op),
        get("addr"), get("data"), get("cmp"), get("not_before"),
    ]).astype(np.int32)
    return Program(buf=jnp.asarray(buf),
                   length=jnp.asarray((op >= 0).sum(-1), I32))


def _empty_program_for(cfg: SimConfig) -> Program:
    return Program(buf=jnp.zeros((len(PROG_FIELDS), cfg.ny, cfg.nx, 1), I32),
                   length=jnp.zeros((cfg.ny, cfg.nx), I32))


def empty_program_for(cfg: SimConfig) -> Program:
    """Deprecated — ``load_program(repro.mesh.empty_program(nx, ny, 1))``
    (or simply don't load anything: a fresh state injects nothing)."""
    warnings.warn(
        "empty_program_for is deprecated; build programs with "
        "repro.mesh.empty_program and pack them with load_program",
        DeprecationWarning, stacklevel=2)
    return _empty_program_for(cfg)


def _iota_last(prefix_shape: Tuple[int, ...], n: int,
               kernel_safe: bool = False) -> jax.Array:
    """``0..n-1`` along a new trailing axis (for one-hot comparisons
    against ``x[..., None]``).  Normally a host ``np.arange`` constant
    (XLA hoists it); inside the Pallas router kernel a full-rank
    ``broadcasted_iota`` op instead — ``pallas_call`` rejects captured
    array constants, and 1-D iotas do not lower on Mosaic."""
    if kernel_safe:
        return lax.broadcasted_iota(I32, tuple(prefix_shape) + (n,),
                                    len(prefix_shape))
    return np.arange(n, dtype=np.int32)


# ----------------------------------------------------------------------
# FIFO primitives (pure)
# ----------------------------------------------------------------------
def _fifo_peek(f: Fifo) -> jax.Array:
    """Head packet of every FIFO: ``buf`` minus its capacity axis.

    A select chain over the (small, static) depth axis rather than a
    gather — XLA CPU fuses the selects into one elementwise pass, while a
    gather lowers to a scalar loop."""
    cap = f.buf.shape[-1]
    out = f.buf[..., 0]
    for d in range(1, cap):
        out = jnp.where(f.head[None] == d, f.buf[..., d], out)
    return out


def _fifo_pop(f: Fifo, mask: jax.Array, depth: jax.Array) -> Fifo:
    m = mask.astype(I32)
    return f._replace(head=(f.head + m) % depth, count=f.count - m)


def _fifo_push(f: Fifo, mask: jax.Array, pkt: jax.Array,
               depth: jax.Array, kernel_safe: bool = False) -> Fifo:
    """Enqueue ``pkt`` (buf shape minus capacity) where ``mask``; caller
    guarantees space.  A one-hot masked select over the (small) depth
    axis — fuses to a single elementwise pass on CPU, where XLA scatters
    are far slower."""
    cap = f.buf.shape[-1]
    tail = (f.head + f.count) % depth
    onehot = (_iota_last(tail.shape, cap, kernel_safe) == tail[..., None]) \
        & mask[..., None]
    buf = jnp.where(onehot[None], pkt[..., None], f.buf)
    return f._replace(buf=buf, count=f.count + mask.astype(I32))


# ----------------------------------------------------------------------
# router — one fused pass over the stacked (fwd, rev) network axis
# ----------------------------------------------------------------------
def _arbitrate_fused(cfg: SimConfig, net: Fifo, rr: jax.Array, xs, ys,
                     depth: jax.Array, cycle: jax.Array,
                     kernel_safe: bool = False,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Routing + round-robin arbitration for BOTH networks in one traced
    pass (mirrors the first half of ``MeshSim._router_step``, stacked).

    Returns ``(win, moved_pkt)`` where ``win`` (2, ny, nx, out) is the
    winning input port per output (-1 = none) with the port-P deliver
    gate NOT yet applied (computed as if the P output always had space),
    and ``moved_pkt`` (F, 2, ny, nx, out) the winner's packet.  The gate
    is a pure AND on the P output's candidate column, so
    :func:`_finalize` can apply it per network afterwards without
    changing any other column — this is what lets the two networks share
    one arbitration trace even though the forward network's deliver space
    depends on the endpoint service step that *reads* the reverse
    network's results.  (The topology's wrap/bubble/boundary terms below
    never touch the P column either, preserving that property.)
    """
    topo = cfg.topology
    heads = _fifo_peek(net)                     # (F, 2, ny, nx, 5)
    valid = net.count > 0                       # (2, ny, nx, 5)
    # dimension-ordered routing straight off the packed header word — the
    # pluggable decision shared verbatim with the numpy oracle
    h = heads[_FI["hdr"]]
    dx, dy = h & COORD_MASK, (h >> DST_Y_SHIFT) & COORD_MASK
    x, y = xs[None, :, :, None], ys[None, :, :, None]
    want = topo.route(dx, dy, x, y, cfg.nx, cfg.ny, xp=jnp).astype(I32)

    # Destination space per output port (start-of-cycle, conservative),
    # assembled with shifts + one stack; the P column is provisionally
    # True (the deliver gate is applied in _finalize).  Wrapped dimensions
    # connect the edges (static slice+concat — jnp.roll does not lower in
    # the Pallas kernel); non-wrapped dimensions keep the pad form.
    space = net.count < depth                   # (2, ny, nx, 5)
    pad = functools.partial(jnp.pad, mode="constant", constant_values=False)
    z1 = ((0, 0),)
    if topo.wrap_x:
        w_sp = jnp.concatenate([space[:, :, -1:, E], space[:, :, :-1, E]], axis=2)
        e_sp = jnp.concatenate([space[:, :, 1:, W], space[:, :, :1, W]], axis=2)
    else:
        w_sp = pad(space[:, :, :-1, E], z1 + ((0, 0), (1, 0)))  # W out -> west nbr's E
        e_sp = pad(space[:, :, 1:, W], z1 + ((0, 0), (0, 1)))   # E out -> east nbr's W
    if topo.wrap_y:
        n_sp = jnp.concatenate([space[:, -1:, :, S], space[:, :-1, :, S]], axis=1)
        s_sp = jnp.concatenate([space[:, 1:, :, N], space[:, :1, :, N]], axis=1)
    else:
        n_sp = pad(space[:, :-1, :, S], z1 + ((1, 0), (0, 0)))  # N out -> north nbr's S
        s_sp = pad(space[:, 1:, :, N], z1 + ((0, 1), (0, 0)))   # S out -> south nbr's N

    # Multi-chip boundary links accept one flit every boundary_period
    # cycles (the narrower off-chip channel): gate the E output of the
    # column west of each boundary and the W output east of it.
    if topo.gated:
        open_now = (cycle % topo.boundary_period) == 0
        cols = topo.boundary_cols(cfg.nx)
        if kernel_safe:
            iox = lax.broadcasted_iota(I32, (1, 1, cfg.nx), 2)
            e_gate = w_gate = jnp.zeros((1, 1, cfg.nx), bool)
            for c0 in cols:
                e_gate = e_gate | (iox == c0 - 1)
                w_gate = w_gate | (iox == c0)
        else:
            e_gate = np.zeros((1, 1, cfg.nx), bool)
            w_gate = np.zeros((1, 1, cfg.nx), bool)
            e_gate[0, 0, [c0 - 1 for c0 in cols]] = True
            w_gate[0, 0, [c0 for c0 in cols]] = True
        e_sp = e_sp & (open_now | ~e_gate)
        w_sp = w_sp & (open_now | ~w_gate)

    out_space = jnp.stack([
        jnp.ones(space.shape[:-1], bool),               # P (gated later)
        w_sp, e_sp, n_sp, s_sp,
    ], axis=-1)

    # Round-robin arbitration, all five output ports of both networks at
    # once: per output port o, the valid requester with minimal
    # (in_port - rr[o]) mod 5 wins.
    if kernel_safe:
        io_out = lax.broadcasted_iota(I32, (1, 1, 1, 1, NUM_DIRS), 4)
        io_in = lax.broadcasted_iota(I32, (NUM_DIRS, 1), 0)
    else:
        io = np.arange(NUM_DIRS, dtype=np.int32)
        io_out, io_in = io[None, None, None, None, :], io[:, None]
    cand = (valid[..., :, None]                 # (2, ny, nx, in, out)
            & (want[..., :, None] == io_out)
            & out_space[..., None, :])

    # Ring bubble flow control (see repro.mesh.topology): a packet
    # ENTERING a wrapped-dimension ring needs TWO free slots in the target
    # FIFO; the CONTINUING input (the opposite port of the same dimension,
    # in = ((out - 1) ^ 1) + 1) needs the usual one.  Compiled out on
    # non-wrapped topologies.
    if topo.wrap_x or topo.wrap_y:
        space2 = net.count < depth - 1          # >= 2 free slots
        ones2 = jnp.ones(space2.shape[:-1], bool)
        if topo.wrap_x:
            w2 = jnp.concatenate([space2[:, :, -1:, E], space2[:, :, :-1, E]], axis=2)
            e2 = jnp.concatenate([space2[:, :, 1:, W], space2[:, :, :1, W]], axis=2)
        else:
            w2 = e2 = ones2
        if topo.wrap_y:
            n2 = jnp.concatenate([space2[:, -1:, :, S], space2[:, :-1, :, S]], axis=1)
            s2 = jnp.concatenate([space2[:, 1:, :, N], space2[:, :1, :, N]], axis=1)
        else:
            n2 = s2 = ones2
        out_space2 = jnp.stack([ones2, w2, e2, n2, s2], axis=-1)
        bubble_out = None                       # which outputs enter rings
        if topo.wrap_x:
            bubble_out = (io_out == E) | (io_out == W)
        if topo.wrap_y:
            b_y = (io_out == N) | (io_out == S)
            bubble_out = b_y if bubble_out is None else (bubble_out | b_y)
        is_cont = io_in == (((io_out - 1) ^ 1) + 1)     # (in, out) broadcast
        need2 = bubble_out & ~is_cont
        cand = cand & (out_space2[..., None, :] | ~need2)
    prio = (io_in - rr[..., None, :]) % NUM_DIRS
    prio = jnp.where(cand, prio, NUM_DIRS + 1)
    best = prio.min(-2)                         # (2, ny, nx, out)
    # first input port attaining the minimum — argmin with its lowest-index
    # tie-break, written as a select chain over the static port axis so the
    # identical trace runs inside the Pallas router kernel
    winner = jnp.zeros(best.shape, I32)
    for i in range(NUM_DIRS - 1, -1, -1):
        winner = jnp.where(prio[..., i, :] == best, i, winner)
    win = jnp.where(best <= NUM_DIRS, winner, -1)
    # winning packet per output port: select along the *input* axis
    # (fusible select chain instead of a gather; see _fifo_peek).  The
    # P column is computed from the UNGATED winner — harmless, because
    # every consumer masks it with the gated `has`.
    widx = jnp.clip(win, 0, NUM_DIRS - 1)
    moved_pkt = jnp.broadcast_to(heads[..., :1], heads.shape)
    for i in range(1, NUM_DIRS):
        moved_pkt = jnp.where(widx[None] == i, heads[..., i:i + 1],
                              moved_pkt)        # (F, 2, ny, nx, out)
    return win, moved_pkt


def _finalize(win: jax.Array, rr: jax.Array, deliver_space: jax.Array,
              kernel_safe: bool = False,
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Apply one network's port-P deliver gate to its slice of the fused
    arbitration result; returns (rr', pop_mask (ny,nx,in), has (ny,nx,out))
    — bit-identical to arbitrating that network alone with the gate in
    its candidate mask."""
    win = win.at[..., P].set(jnp.where(deliver_space, win[..., P], -1))
    has = win >= 0
    rr = jnp.where(has, (win + 1) % NUM_DIRS, rr)
    widx = jnp.clip(win, 0, NUM_DIRS - 1)
    io_in = lax.broadcasted_iota(I32, (NUM_DIRS, 1), 0) if kernel_safe \
        else np.arange(NUM_DIRS, dtype=np.int32)[:, None]
    pop = ((io_in == widx[..., None, :]) & has[..., None, :]).any(-1)
    return rr, pop, has


def _neighbor_push_masks(has: jax.Array, moved_pkt: jax.Array,
                         p_mask: jax.Array, p_pkt: jax.Array,
                         topo: Topology,
                         ) -> Tuple[jax.Array, jax.Array]:
    """Turn per-output winners into per-input push masks for the neighbour
    FIFOs, with the local port-P enqueue (endpoint response or program
    injection) folded into the same single write.  Every destination
    (tile, in_port) has exactly one feeder, so this is conflict-free.
    Wrapped dimensions feed the opposite edge (static slice+concat)."""
    padm = functools.partial(jnp.pad, mode="constant", constant_values=False)
    padp = jnp.pad
    # in-port k of tile t receives the opposite-direction output of the
    # adjacent tile: W <- west nbr's E, E <- east nbr's W, N <- north nbr's
    # S, S <- south nbr's N; port P is the local enqueue.
    if topo.wrap_x:
        w_in = jnp.concatenate([has[:, -1:, E], has[:, :-1, E]], axis=1)
        e_in = jnp.concatenate([has[:, 1:, W], has[:, :1, W]], axis=1)
        w_pk = jnp.concatenate([moved_pkt[:, :, -1:, E], moved_pkt[:, :, :-1, E]], axis=2)
        e_pk = jnp.concatenate([moved_pkt[:, :, 1:, W], moved_pkt[:, :, :1, W]], axis=2)
    else:
        w_in = padm(has[:, :-1, E], ((0, 0), (1, 0)))
        e_in = padm(has[:, 1:, W], ((0, 0), (0, 1)))
        w_pk = padp(moved_pkt[:, :, :-1, E], ((0, 0), (0, 0), (1, 0)))
        e_pk = padp(moved_pkt[:, :, 1:, W], ((0, 0), (0, 0), (0, 1)))
    if topo.wrap_y:
        n_in = jnp.concatenate([has[-1:, :, S], has[:-1, :, S]], axis=0)
        s_in = jnp.concatenate([has[1:, :, N], has[:1, :, N]], axis=0)
        n_pk = jnp.concatenate([moved_pkt[:, -1:, :, S], moved_pkt[:, :-1, :, S]], axis=1)
        s_pk = jnp.concatenate([moved_pkt[:, 1:, :, N], moved_pkt[:, :1, :, N]], axis=1)
    else:
        n_in = padm(has[:-1, :, S], ((1, 0), (0, 0)))
        s_in = padm(has[1:, :, N], ((0, 1), (0, 0)))
        n_pk = padp(moved_pkt[:, :-1, :, S], ((0, 0), (1, 0), (0, 0)))
        s_pk = padp(moved_pkt[:, 1:, :, N], ((0, 0), (0, 1), (0, 0)))
    mask_in = jnp.stack([p_mask, w_in, e_in, n_in, s_in], axis=-1)
    pkt_in = jnp.stack([p_pkt, w_pk, e_pk, n_pk, s_pk], axis=-1)
    return mask_in, pkt_in


# ----------------------------------------------------------------------
# the per-cycle transition
# ----------------------------------------------------------------------
def _coords(cfg: SimConfig, kernel_safe: bool = False):
    # host-side numpy constants (NOT jax arrays: a cached jax array created
    # inside one trace would leak into the next); XLA hoists them out of
    # the scan loop.  In the Pallas kernel they become 2-D iota ops
    # instead (captured array constants do not lower).
    if kernel_safe:
        return (lax.broadcasted_iota(I32, (cfg.ny, cfg.nx), 1),
                lax.broadcasted_iota(I32, (cfg.ny, cfg.nx), 0))
    ys, xs = np.mgrid[0:cfg.ny, 0:cfg.nx]
    return xs.astype(np.int32), ys.astype(np.int32)


def _step_core(cfg: SimConfig, prog: Program, st: SimState, *,
               kernel_safe: bool = False) -> Tuple[SimState, jax.Array]:
    """One simulator cycle; returns (state', completions_this_cycle).

    The sub-step order matches ``MeshSim.step`` exactly — do not reorder.
    Both networks' FIFO *counts* advance at their original points in the
    cycle (the endpoint service step reads the reverse network's post-push
    port-P count), but the two (large) buffer writes are deferred and
    performed as ONE stacked write at the end — legal because nothing in
    between reads the router buffers, only the counts.

    ``kernel_safe=True`` is the variant traced inside the Pallas router
    kernel (:mod:`repro.kernels.router_step`): the four traced-index
    scatter/gather ops (the latency-histogram ``.at[].add``, the memory
    read, the program-entry fetch and the ``resp_latency > 1`` slot
    rotation) are swapped for one-hot select/sum forms — pure int32
    arithmetic, so any summation order is exact and the two variants are
    bit-identical.  The default keeps XLA's native scatter/gather, which
    is faster outside the kernel.
    """
    ny, nx = cfg.ny, cfg.nx
    xs, ys = _coords(cfg, kernel_safe)
    c = st.cycle

    # ---- registered response port becomes visible (stats record) ----
    rv = st.reg_valid
    completed = st.completed + rv.astype(I32)
    tag = st.reg_buf[_FI["tag"]]
    lat = c - tag
    lat_sum = st.lat_sum + jnp.where(rv, lat, 0)
    done_now = rv.sum().astype(I32)
    # latency histogram, gated to the measurement window by the packet's
    # injection cycle (its tag); scatter-add of 0 elsewhere is a no-op
    in_win = rv & (tag >= st.measure_start) & (tag < st.measure_stop)
    bin_idx = jnp.clip(lat, 0, LAT_BINS - 1)
    if kernel_safe:
        bin_oh = (_iota_last(bin_idx.shape, LAT_BINS, True)
                  == bin_idx[..., None]) & in_win[..., None]
        lat_hist = st.lat_hist + bin_oh.astype(I32).sum((0, 1))
    else:
        lat_hist = st.lat_hist.at[bin_idx].add(in_win.astype(I32))

    # ---- both networks: ONE fused routing + arbitration pass ----
    win2, moved2 = _arbitrate_fused(cfg, st.net, st.rr, xs, ys,
                                    st.fifo_depth, c, kernel_safe)

    # ---- reverse network: P deliveries are ALWAYS absorbed ----
    rr_rev, rpop, rhas = _finalize(win2[REV], st.rr[REV],
                                   jnp.ones((ny, nx), bool), kernel_safe)
    rmoved = moved2[:, REV]
    rev_head = (st.net.head[REV] + rpop.astype(I32)) % st.fifo_depth
    rev_count = st.net.count[REV] - rpop.astype(I32)
    absorbed, rpkt = rhas[..., P], rmoved[..., P]
    credits = st.credits + absorbed.astype(I32)
    reg_valid = absorbed
    reg_buf = jnp.where(absorbed[None], rpkt, 0)

    # ---- endpoint: inject pending responses into reverse P FIFO ----
    # (folded into the same stacked buffer write as the neighbour
    # enqueues; the neighbour pushes never touch port P, so tails are
    # independent)
    L = cfg.resp_latency
    if L == 1:                    # static fast path: slot is always 0
        slot = jnp.asarray(0, I32)
        slot_oh = None
        inj = st.resp_valid[0]                              # (ny, nx)
        inj_pkt = st.resp_buf[:, 0]                         # (F, ny, nx)
    else:
        slot = (c % L).astype(I32)
        if kernel_safe:
            # one-hot over the (static, small) slot axis instead of a
            # traced-index take: exact select, identical bits
            slot_oh = lax.broadcasted_iota(I32, (L, 1, 1), 0) == slot
            inj = (st.resp_valid & slot_oh).any(0)
            inj_pkt = jnp.where(slot_oh[None], st.resp_buf, 0).sum(1)
        else:
            slot_oh = None
            inj = jnp.take(st.resp_valid, slot, axis=0)
            inj_pkt = jnp.take(st.resp_buf, slot, axis=1)
    rmask_in, rpkt_in = _neighbor_push_masks(rhas, rmoved, inj, inj_pkt,
                                             cfg.topology)
    rev_tail = (rev_head + rev_count) % st.fifo_depth
    rev_count = rev_count + rmask_in.astype(I32)
    if L == 1:
        resp_valid = jnp.zeros_like(st.resp_valid)
    elif kernel_safe:
        resp_valid = st.resp_valid & ~slot_oh
    else:
        resp_valid = st.resp_valid.at[slot].set(False)
    resp_buf = st.resp_buf

    # ---- endpoint: service one request/cycle (line rate) ----------
    resp_inflight = resp_valid.sum(0).astype(I32)
    rev_space = (rev_count[..., P] + resp_inflight) < st.fifo_depth
    can = (st.ep_in.count[..., 0] > 0) & rev_space
    req = _fifo_peek(st.ep_in)[..., 0]                      # (F, ny, nx)
    req_hdr = req[_FI["hdr"]]
    req_op = (req_hdr >> OP_SHIFT) & OP_MASK
    addr = jnp.clip(req[_FI["addr"]], 0, cfg.mem_words - 1)
    addr_oh = _iota_last(addr.shape, cfg.mem_words, kernel_safe) \
        == addr[..., None]
    if kernel_safe:
        # one-hot read reusing the write mask (exact: int32, one hot bit)
        cur = jnp.where(addr_oh, st.mem, 0).sum(-1)
    else:
        cur = jnp.take_along_axis(st.mem, addr[..., None], axis=-1)[..., 0]
    is_store = can & (req_op == OP_STORE)
    is_load = can & (req_op == OP_LOAD)
    is_cas = can & (req_op == OP_CAS)
    cas_hit = is_cas & (cur == req[_FI["cmp"]])
    newval = jnp.where(is_store | cas_hit, req[_FI["data"]], cur)
    mem = jnp.where(addr_oh & can[..., None], newval[..., None], st.mem)
    ep_in = _fifo_pop(st.ep_in, can[..., None],
                      jnp.asarray(cfg.ep_fifo, I32))
    rdata = jnp.where(is_load | is_cas, cur, 0)
    # build the response packet: src<->dst swapped so it routes home
    resp = jnp.stack([
        swap_for_response(req_hdr, xs, ys),
        req[_FI["addr"]], rdata, req[_FI["cmp"]], req[_FI["tag"]],
    ])
    if L == 1:                    # resp_valid[0] was just cleared above
        resp_valid = can[None]
        resp_buf = jnp.where(can[None, None], resp[:, None], resp_buf)
    elif kernel_safe:
        # refill the just-cleared slot (so `where(can, True, False)` is
        # simply `can`) and overwrite its packet lanes where `can`
        resp_valid = jnp.where(slot_oh, can[None], resp_valid)
        resp_buf = jnp.where(slot_oh[None] & can[None, None],
                             resp[:, None], resp_buf)
    else:
        wslot = slot              # c % L: inject and refill the same slot
        resp_valid = resp_valid.at[wslot].set(
            jnp.where(can, True, jnp.take(resp_valid, wslot, axis=0)))
        resp_buf = resp_buf.at[:, wslot].set(
            jnp.where(can[None], resp, jnp.take(resp_buf, wslot, axis=1)))

    # ---- forward network: P deliveries go to endpoint FIFO ----
    rr_fwd, fpop, fhas = _finalize(win2[FWD], st.rr[FWD],
                                   ep_in.count[..., 0] < cfg.ep_fifo,
                                   kernel_safe)
    fmoved = moved2[:, FWD]
    fwd_head = (st.net.head[FWD] + fpop.astype(I32)) % st.fifo_depth
    fwd_count = st.net.count[FWD] - fpop.astype(I32)
    got, fpkt = fhas[..., P], fmoved[..., P]
    ep_in = _fifo_push(ep_in, got[..., None], fpkt[..., None],
                       jnp.asarray(cfg.ep_fifo, I32), kernel_safe)

    # ---- master injection from the per-tile program -----------------
    # The injection enqueue targets port P of the post-pop forward FIFOs
    # (neighbour pushes never touch port P), so it folds into the same
    # stacked buffer write as the neighbour enqueues.
    pending = st.prog_ptr < prog.length
    out_of_credit = st.out_of_credit_cycles + \
        (pending & (credits <= 0)).astype(I32)
    can_inj = pending & (credits > 0)
    Lp = prog.buf.shape[-1]
    pidx = jnp.clip(st.prog_ptr, 0, max(Lp - 1, 0))
    if kernel_safe:
        lp_oh = _iota_last(pidx.shape, Lp, True) == pidx[..., None]
        entry = jnp.where(lp_oh[None], prog.buf, 0).sum(-1)  # (|PROG|, ny, nx)
    else:
        entry = jnp.take_along_axis(
            prog.buf, jnp.broadcast_to(pidx[None, ..., None],
                                       (len(PROG_FIELDS), ny, nx, 1)),
            axis=-1)[..., 0]                                # (|PROG|, ny, nx)
    can_inj = can_inj & (entry[_PI["not_before"]] <= c)
    can_inj = can_inj & (fwd_count[..., P] < st.fifo_depth)
    pkt = jnp.stack([
        with_src(entry[_PI["hdr"]], xs, ys),
        entry[_PI["addr"]], entry[_PI["data"]], entry[_PI["cmp"]],
        jnp.full((ny, nx), c, I32),
    ])                                                      # (F, ny, nx)
    fmask_in, fpkt_in = _neighbor_push_masks(fhas, fmoved, can_inj, pkt,
                                             cfg.topology)
    fwd_tail = (fwd_head + fwd_count) % st.fifo_depth
    fwd_count = fwd_count + fmask_in.astype(I32)
    credits = credits - can_inj.astype(I32)
    prog_ptr = st.prog_ptr + can_inj.astype(I32)

    # ---- deferred stacked buffer write: both networks at once ----
    cap = st.net.buf.shape[-1]
    mask2 = jnp.stack([fmask_in, rmask_in])                 # (2, ny, nx, 5)
    pkt2 = jnp.stack([fpkt_in, rpkt_in], axis=1)            # (F, 2, ny, nx, 5)
    tail2 = jnp.stack([fwd_tail, rev_tail])
    onehot = (_iota_last(tail2.shape, cap, kernel_safe) == tail2[..., None]) \
        & mask2[..., None]
    net = Fifo(buf=jnp.where(onehot[None], pkt2[..., None], st.net.buf),
               head=jnp.stack([fwd_head, rev_head]),
               count=jnp.stack([fwd_count, rev_count]))

    # ---- telemetry: link counts + occupancy high-water marks ----------
    link_util = st.link_util + jnp.stack([fhas, rhas]).astype(I32)
    fifo_hwm = jnp.maximum(st.fifo_hwm, net.count)
    ep_hwm = jnp.maximum(st.ep_hwm, ep_in.count[..., 0])

    st = SimState(net=net, ep_in=ep_in,
                  resp_valid=resp_valid, resp_buf=resp_buf, mem=mem,
                  credits=credits, rr=jnp.stack([rr_fwd, rr_rev]),
                  prog_ptr=prog_ptr,
                  reg_valid=reg_valid, reg_buf=reg_buf,
                  completed=completed, lat_sum=lat_sum,
                  out_of_credit_cycles=out_of_credit,
                  cycle=c + 1, fifo_depth=st.fifo_depth,
                  max_credits=st.max_credits,
                  link_util=link_util, fifo_hwm=fifo_hwm,
                  ep_hwm=ep_hwm, lat_hist=lat_hist,
                  measure_start=st.measure_start,
                  measure_stop=st.measure_stop)
    return st, done_now


def _check_impl(impl: str, cycles_per_call: int = 1) -> None:
    if impl not in ("fused", "pallas"):
        raise ValueError(
            f"unknown step impl {impl!r}: expected 'fused' or 'pallas'")
    if cycles_per_call < 1:
        raise ValueError(
            f"cycles_per_call must be >= 1, got {cycles_per_call}")


def step(cfg: SimConfig, prog: Program, st: SimState, impl: str = "fused",
         ) -> Tuple[SimState, jax.Array]:
    """One simulator cycle; returns (state', completions_this_cycle).

    ``impl`` selects how the transition executes — never what it computes:

    * ``"fused"`` — the stacked single-trace XLA step (:func:`_step_core`);
    * ``"pallas"`` — the same transition as one Pallas kernel launch
      (:mod:`repro.kernels.router_step`; interpret mode on hosts without
      a compiled Pallas backend).  Bit-identical to ``"fused"`` by
      construction and by test (``tests/test_router_kernel.py``).
    """
    _check_impl(impl)
    if impl == "pallas":
        from repro.kernels.router_step import router_step_call
        st2, done, _drained_flags = router_step_call(cfg, prog, st, 1)
        return st2, done[0]
    return _step_core(cfg, prog, st)


def drained(st: SimState, prog: Program) -> jax.Array:
    """Global-fence condition: programs issued, credits home, nothing in
    the registered response port (same as ``MeshSim.run_until_drained``)."""
    return ((st.prog_ptr >= prog.length).all()
            & (st.credits == st.max_credits).all()
            & ~st.reg_valid.any())


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5, 6),
                   donate_argnums=(2,))
def simulate(cfg: SimConfig, prog: Program, state: SimState, cycles: int,
             unroll: int = 1, impl: str = "fused", cycles_per_call: int = 1,
             ) -> Tuple[SimState, jax.Array]:
    """Run ``cycles`` cycles; returns
    (final_state, completions_per_cycle (cycles,)).

    ``unroll`` is passed to ``lax.scan``: N copies of the cycle step per
    loop iteration trade compile time (more HLO) for lower loop overhead.
    ``impl="pallas"`` runs the cycle transition as the Pallas router
    kernel, ``cycles_per_call`` mesh cycles per kernel launch (a static
    inner ``fori_loop``; dispatch cost is amortized the way ``ssd_scan``
    chunks its recurrence).  All four knobs affect speed only — the
    per-cycle completion trace and final state are bit-identical across
    every (impl, unroll, cycles_per_call) combination.
    ``state`` is donated — do not reuse the argument after the call.
    """
    _check_impl(impl, cycles_per_call)
    if impl == "pallas":
        from repro.kernels.router_step import router_step_call
        C = min(cycles_per_call, cycles) if cycles else 1
        n_full, rem = divmod(cycles, C)

        def body(st, _):
            st2, done, _dr = router_step_call(cfg, prog, st, C)
            return st2, done

        state, dones = lax.scan(body, state, None, length=n_full)
        per_cycle = dones.reshape((-1,))
        if rem:
            state, done_r, _dr = router_step_call(cfg, prog, state, rem)
            per_cycle = jnp.concatenate([per_cycle, done_r])
        return state, per_cycle

    def body(st, _):
        return _step_core(cfg, prog, st)
    return lax.scan(body, state, None, length=cycles, unroll=unroll)


def _drain_loop(cfg: SimConfig, prog: Program, state: SimState,
                max_cycles: int, check_every: int, trace: bool,
                impl: str = "fused", cycles_per_call: int = 1):
    """Shared driver for the two drain entry points: run blocks of
    ``check_every`` cycles, checking the global fence once per block (and
    recording the *exact* fence cycle from inside the block)."""
    K = check_every
    blocks = -(-max_cycles // K)
    c0 = state.cycle
    d0 = jnp.where(drained(state, prog), c0, -1)
    trace0 = jnp.zeros((blocks * K if trace else 1,), I32)

    def cond(carry):
        _st, _tr, i, dcyc = carry
        return (dcyc < 0) & (i < blocks)

    if impl == "pallas":
        from repro.kernels.router_step import router_step_call
        # cover the K-cycle block with kernel launches; a check_every not
        # divisible by cycles_per_call gets a short remainder launch (its
        # own static compilation, shared across blocks)
        C = min(cycles_per_call, K)
        launches = [C] * (K // C) + ([K % C] if K % C else [])

        def body(carry):
            st, tr, i, dcyc = carry
            c_start = st.cycle
            dones, drains = [], []
            for c in launches:
                st, d, dr = router_step_call(cfg, prog, st, c)
                dones.append(d)
                drains.append(dr)
            done_vec = jnp.concatenate(dones)        # (K,)
            drain_vec = jnp.concatenate(drains) > 0  # (K,) post-cycle fence
            if trace:
                tr = lax.dynamic_update_slice(tr, done_vec, (i * K,))
            # exact fence cycle: first in-block cycle whose post-step
            # fence held (same recording point as the fused inner scan)
            first = jnp.argmax(drain_vec).astype(I32)
            dcyc = jnp.where((dcyc < 0) & drain_vec.any(),
                             c_start + first + 1, dcyc)
            return st, tr, i + 1, dcyc
    else:
        def body(carry):
            st, tr, i, dcyc = carry

            def inner(c2, j):
                st2, tr2, d2 = c2
                st3, done = _step_core(cfg, prog, st2)
                if trace:
                    tr2 = tr2.at[i * K + j].set(done)
                d2 = jnp.where((d2 < 0) & drained(st3, prog), st3.cycle, d2)
                return (st3, tr2, d2), None

            (st, tr, dcyc), _ = lax.scan(inner, (st, tr, dcyc),
                                         jnp.arange(K, dtype=I32))
            return st, tr, i + 1, dcyc

    final, tr, nblocks, dcyc = lax.while_loop(
        cond, body, (state, trace0, jnp.asarray(0, I32), d0))
    steps = jnp.where(dcyc >= 0, dcyc - c0, nblocks * K)
    return final, steps, dcyc, tr


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5, 6),
                   donate_argnums=(2,))
def run_until_drained(cfg: SimConfig, prog: Program, state: SimState,
                      max_cycles: int = 100_000, check_every: int = 1,
                      impl: str = "fused", cycles_per_call: int = 1,
                      ) -> Tuple[SimState, jax.Array]:
    """Step until the global fence closes (or after ``max_cycles`` further
    steps); returns (final_state, drain_cycle).

    ``check_every=K`` evaluates the fence once per K cycles: fewer
    reductions and a K-step ``scan`` body per ``while_loop`` iteration.
    The returned drain cycle is exact for any K; with K > 1 the *state*
    may have stepped up to K - 1 cycles past the fence (only
    ``SimState.cycle`` advances — a drained network is quiescent).
    ``impl``/``cycles_per_call`` select the Pallas router kernel as in
    :func:`simulate` (exact drain cycle for any combination).
    ``state`` is donated — do not reuse the argument after the call.
    """
    _check_impl(impl, cycles_per_call)
    final, _steps, dcyc, _ = _drain_loop(cfg, prog, state, max_cycles,
                                         check_every, False, impl,
                                         cycles_per_call)
    return final, jnp.where(dcyc >= 0, dcyc, final.cycle)


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5, 6),
                   donate_argnums=(2,))
def run_until_drained_traced(cfg: SimConfig, prog: Program, state: SimState,
                             max_cycles: int = 100_000, check_every: int = 1,
                             impl: str = "fused", cycles_per_call: int = 1,
                             ) -> Tuple[SimState, jax.Array, jax.Array]:
    """Like :func:`run_until_drained` but also records the per-cycle
    completion trace into a preallocated buffer; returns
    (final_state, steps_taken, trace) — ``trace[:steps_taken]`` is valid."""
    _check_impl(impl, cycles_per_call)
    final, steps, _dcyc, tr = _drain_loop(cfg, prog, state, max_cycles,
                                          check_every, True, impl,
                                          cycles_per_call)
    return final, steps, tr


# ----------------------------------------------------------------------
# convenience wrapper mirroring the MeshSim driving API
# ----------------------------------------------------------------------
class JaxMeshSim:
    """Thin stateful wrapper over the functional API, drop-in enough for
    the oracle's driving pattern::

        sim = JaxMeshSim(NetConfig(nx=4, ny=4))
        sim.load_program(prog)
        sim.run(100)            # or sim.run_until_drained()
        sim.mem, sim.completed, sim.completed_per_cycle, ...

    Each ``run*`` call dispatches one jitted XLA program; repeated calls
    with the same static config reuse the compilation cache.

    ``unroll`` / ``check_every`` / ``impl`` / ``cycles_per_call`` are the
    jit tuning knobs of :func:`simulate` / :func:`run_until_drained` (see
    their docstrings); they affect speed only, never results.
    """

    def __init__(self, cfg, fifo_depth=None, max_credits=None, *,
                 unroll: int = 1, check_every: int = 1,
                 impl: str = "fused", cycles_per_call: int = 1):
        if not isinstance(cfg, SimConfig):
            # NetConfig / repro.mesh.MeshConfig share the field names
            cfg = _simconfig_from_net(cfg)
        _check_impl(impl, cycles_per_call)
        self.cfg = cfg
        self.unroll = int(unroll)
        self.check_every = int(check_every)
        self.impl = impl
        self.cycles_per_call = int(cycles_per_call)
        self.state = init_state(cfg, fifo_depth=fifo_depth,
                                max_credits=max_credits)
        self.program = _empty_program_for(cfg)
        self.completed_per_cycle: list = []

    def load_program(self, entries: Dict[str, np.ndarray]) -> None:
        self.program = load_program(entries)
        self.state = self.state._replace(
            prog_ptr=jnp.zeros((self.cfg.ny, self.cfg.nx), I32))

    def run(self, cycles: int) -> None:
        self.state, per_cycle = simulate(self.cfg, self.program, self.state,
                                         cycles, self.unroll, self.impl,
                                         self.cycles_per_call)
        self.completed_per_cycle.extend(np.asarray(per_cycle).tolist())

    def run_until_drained(self, max_cycles: int = 100_000) -> int:
        cycle0 = int(self.state.cycle)
        self.state, steps, trace = run_until_drained_traced(
            self.cfg, self.program, self.state, max_cycles, self.check_every,
            self.impl, self.cycles_per_call)
        steps = int(steps)
        self.completed_per_cycle.extend(np.asarray(trace[:steps]).tolist())
        if steps >= max_cycles and \
                not bool(drained(self.state, self.program)):
            raise RuntimeError(f"network did not drain in {max_cycles} cycles")
        # exact fence cycle even when check_every > 1 overshoots the state
        return cycle0 + steps

    # oracle-shaped accessors -----------------------------------------
    @property
    def mem(self) -> np.ndarray:
        return np.asarray(self.state.mem, np.int64)

    @property
    def completed(self) -> np.ndarray:
        return np.asarray(self.state.completed, np.int64)

    @property
    def lat_sum(self) -> np.ndarray:
        return np.asarray(self.state.lat_sum, np.int64)

    @property
    def credits(self) -> np.ndarray:
        return np.asarray(self.state.credits, np.int64)

    @property
    def out_of_credit_cycles(self) -> np.ndarray:
        return np.asarray(self.state.out_of_credit_cycles, np.int64)

    # telemetry ---------------------------------------------------------
    @property
    def link_util_fwd(self) -> np.ndarray:
        return np.asarray(self.state.link_util[FWD], np.int64)

    @property
    def link_util_rev(self) -> np.ndarray:
        return np.asarray(self.state.link_util[REV], np.int64)

    @property
    def fifo_hwm_fwd(self) -> np.ndarray:
        return np.asarray(self.state.fifo_hwm[FWD], np.int64)

    @property
    def fifo_hwm_rev(self) -> np.ndarray:
        return np.asarray(self.state.fifo_hwm[REV], np.int64)

    @property
    def ep_hwm(self) -> np.ndarray:
        return np.asarray(self.state.ep_hwm, np.int64)

    @property
    def lat_hist(self) -> np.ndarray:
        return np.asarray(self.state.lat_hist, np.int64)

    def set_measure_window(self, start: int, stop: int) -> None:
        """Restrict the latency histogram to packets *injected* in cycle
        range [start, stop) — same contract as ``MeshSim.set_measure_window``."""
        self.state = self.state._replace(
            measure_start=jnp.asarray(start, I32),
            measure_stop=jnp.asarray(stop, I32))

    @property
    def cycle(self) -> int:
        return int(self.state.cycle)

    def mean_latency(self) -> float:
        done = int(self.completed.sum())
        return float(self.lat_sum.sum()) / max(done, 1)

    def throughput(self, warmup: int = 0) -> float:
        per = self.completed_per_cycle[warmup:]
        return float(np.sum(per)) / max(len(per), 1)
