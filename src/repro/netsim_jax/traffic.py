"""Deprecated location — the traffic library lives in :mod:`repro.mesh.traffic`.

Everything re-exports unchanged so existing imports keep working;
``empty_program`` additionally warns, since it was duplicated against
``repro.netsim_jax.sim.empty_program_for`` and both are now one helper
on the facade (``repro.mesh.empty_program``).
"""
from __future__ import annotations

import warnings
from typing import Dict

import numpy as np

from repro.mesh.traffic import (PATTERNS, PROG_KEYS,  # noqa: F401
                                bit_complement, hotspot, make_traffic,
                                nearest_neighbor, tornado, transpose,
                                uniform_random)
from repro.mesh.traffic import empty_program as _empty_program

__all__ = ["PATTERNS", "PROG_KEYS", "empty_program", "make_traffic",
           "uniform_random", "transpose", "bit_complement", "tornado",
           "hotspot", "nearest_neighbor"]


def empty_program(nx: int, ny: int, length: int = 1) -> Dict[str, np.ndarray]:
    """Deprecated alias of :func:`repro.mesh.empty_program`."""
    warnings.warn(
        "repro.netsim_jax.traffic.empty_program is deprecated; use "
        "repro.mesh.empty_program", DeprecationWarning, stacklevel=2)
    return _empty_program(nx, ny, length)
