from .adamw import (OptConfig, apply, clip_by_global_norm, init, no_decay,
                    schedule, state_shapes, state_specs)
from .compress import (compress_decompress, cross_pod_psum, dequantize_int8,
                       init_error_state, quantize_int8)

__all__ = ["OptConfig", "apply", "clip_by_global_norm", "init", "no_decay",
           "schedule", "state_shapes", "state_specs", "compress_decompress",
           "cross_pod_psum", "dequantize_int8", "init_error_state",
           "quantize_int8"]
