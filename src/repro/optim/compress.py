"""Gradient compression for slow (cross-pod) links, with error feedback.

The pod axis crosses the off-chip link ("the mesh extends over off-chip
links to an FPGA" — BSG Ten); it is the bandwidth-poorest hop of the
production mesh, so the cross-pod gradient reduction is where compression
pays.  Two codecs:

* ``bf16``  — round-to-nearest bf16 (2x), error feedback optional;
* ``int8``  — per-tensor-chunk scaled int8 (4x) with error feedback: the
  quantization residual is carried to the next step, so the compression
  bias telescopes instead of accumulating (Seide et al. 1-bit SGD lineage).

:func:`cross_pod_psum` is the drop-in reduction: called inside a shard_map
island whose manual axis is ``pod``; intra-pod reduction stays in full
precision (GSPMD/auto axes), only the inter-pod hop is compressed.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress",
           "cross_pod_psum", "init_error_state"]

_CHUNK = 1024  # int8 scale granularity (elements)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-chunk symmetric int8. Returns (q (flat,), scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, _CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    q = jnp.round(chunks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def init_error_state(params) -> Dict[str, jax.Array]:
    """Error-feedback residuals, one per parameter (fp32 zeros)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(g: jax.Array, mode: str,
                        err: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Quantize-then-dequantize ``g`` (the lossy channel), optionally
    carrying the residual in ``err`` (error feedback)."""
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    if mode == "none":
        out = gf
    elif mode == "bf16":
        out = gf.astype(jnp.bfloat16).astype(jnp.float32)
    elif mode == "int8":
        q, s = quantize_int8(gf)
        out = dequantize_int8(q, s, gf.shape, jnp.float32)
    else:
        raise ValueError(f"unknown compression mode {mode!r}")
    new_err = (gf - out) if err is not None else None
    return out.astype(g.dtype), new_err


def cross_pod_psum(g: jax.Array, axis_name: str, mode: str,
                   err: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Compressed all-reduce over the (slow) ``axis_name`` link.

    Must run inside shard_map with ``axis_name`` manual.  The wire format
    is the compressed tensor; the reduction itself happens on the
    decompressed values (psum of dequantized int8 is exact in fp32).
    """
    wire, new_err = compress_decompress(g, mode, err)
    return lax.psum(wire, axis_name), new_err
