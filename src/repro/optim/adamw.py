"""AdamW with ZeRO-1 sharded optimizer state.

The optimizer is functional: ``init`` builds the state pytree, ``apply``
consumes gradients and returns (new_params, new_state).  States carry an
fp32 master copy of the parameters (bf16 training) plus Adam ``m``/``v``.

ZeRO-1 maps onto the paper's "virtual mesh" idea (C7): the optimizer state
is a big memory that no single tile can hold, so it is banked across the
``zero1`` (= ``data``) axis — each data-parallel rank owns a slab.  In
GSPMD terms we express the banking as NamedShardings on the state
(:func:`state_specs`); XLA then inserts the reduce-scatter (grads -> owning
bank) and all-gather (updated master -> replicated bf16 params), which is
exactly the ZeRO-1 communication schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import Rules

__all__ = ["OptConfig", "init", "apply", "state_specs", "state_shapes",
           "clip_by_global_norm", "no_decay"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def no_decay(name: str) -> bool:
    """Norm scales / biases / SSM scalars are excluded from weight decay."""
    leaf = name.rsplit("/", 1)[-1]
    return ("norm" in leaf or leaf.startswith("b")
            or leaf in ("A_log", "dt_bias", "D_skip", "conv_b"))


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to ``lr_min``."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def init(params: Dict[str, jax.Array]) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_shapes(param_shapes: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, Any]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, param_shapes),
        "m": jax.tree.map(f32, param_shapes),
        "v": jax.tree.map(f32, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _zero1_spec(spec, shape: tuple, rules: Rules):
    """Extend a param's PartitionSpec with the zero1 axis on the largest
    still-shardable dim (the ZeRO-1 bank assignment)."""
    z = rules._clean(rules.zero1)
    if z is None:
        return spec
    z_names = (z,) if isinstance(z, str) else tuple(z)
    z_size = rules.axis_size(z)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for n in ((e,) if isinstance(e, str) else (e or ())):
            used.add(n)
    if any(n in used for n in z_names):
        return spec
    best, best_len = -1, 0
    for d, e in enumerate(entries):
        here = rules.axis_size(e) if e else 1
        if shape[d] % (here * z_size) == 0:
            eff = shape[d] // here
            if eff > best_len:
                best, best_len = d, eff
    if best < 0:
        return spec
    e = entries[best]
    if e is None:
        entries[best] = z if isinstance(z, str) else z_names
    else:
        prev = (e,) if isinstance(e, str) else tuple(e)
        entries[best] = prev + z_names
    return jax.sharding.PartitionSpec(*entries)


def state_specs(param_specs: Dict[str, Any],
                param_shapes: Dict[str, jax.ShapeDtypeStruct],
                rules: Rules) -> Dict[str, Any]:
    """NamedShardings for the optimizer state: param spec + zero1 banking."""
    def one(ps, sds):
        spec = ps.spec if hasattr(ps, "spec") else ps
        return rules.mesh, _zero1_spec(spec, sds.shape, rules)

    banked = {k: jax.sharding.NamedSharding(*one(param_specs[k], param_shapes[k]))
              for k in param_shapes}
    return {"master": banked, "m": banked, "v": banked,
            "step": jax.sharding.NamedSharding(
                rules.mesh, jax.sharding.PartitionSpec())}


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads: Dict[str, jax.Array], max_norm: float
                        ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply(cfg: OptConfig, params: Dict[str, jax.Array],
          grads: Dict[str, jax.Array], state: Dict[str, Any],
          state_shardings: Optional[Dict[str, Any]] = None
          ) -> Tuple[Dict[str, jax.Array], Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(name, g, master, m, v):
        g = g.astype(jnp.float32)
        if state_shardings is not None:
            # ZeRO-1: push the gradient into the bank layout -> GSPMD emits
            # a reduce-scatter instead of a full all-reduce (paper C7).
            sh = state_shardings["m"][name]
            g = jax.lax.with_sharding_constraint(g, sh)
            master = jax.lax.with_sharding_constraint(master, sh)
            m = jax.lax.with_sharding_constraint(m, sh)
            v = jax.lax.with_sharding_constraint(v, sh)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and not no_decay(name):
            upd = upd + cfg.weight_decay * master
        master = master - lr * upd
        return master, m, v

    new_master, new_m, new_v = {}, {}, {}
    for name in params:
        new_master[name], new_m[name], new_v[name] = upd(
            name, grads[name], state["master"][name],
            state["m"][name], state["v"][name])
    new_params = {k: new_master[k].astype(params[k].dtype) for k in params}
    state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, state, {"grad_norm": gnorm, "lr": lr}
