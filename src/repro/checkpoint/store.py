"""Sharded checkpointing with credit-bounded async saves.

Layout (one directory per step):

    <root>/step_<N>/
        manifest.json            # tree structure, shapes, dtypes, hashes
        <leaf-name>.npy          # one file per pytree leaf (full array)

Production framing: each host writes only the shards it owns and the
manifest records the (host, shard) mapping; on this single-host container
every array is fully addressable, so a leaf is one ``.npy``.  What we keep
faithful to the multi-host design:

* **atomicity** — writes go to ``step_N.tmp/`` and the directory is
  renamed only after every leaf + manifest is fsync'd; a crashed save can
  never be mistaken for a complete one (restore scans for the newest
  *committed* step);
* **async with credits** (paper C3) — ``AsyncCheckpointer`` snapshots the
  arrays to host RAM, returns immediately, and a writer thread drains a
  bounded queue; the credit bound keeps at most ``credits`` snapshots in
  flight so checkpointing can never OOM the host.  ``fence()`` = wait for
  all credits to return (the paper's store barrier);
* **integrity** — every leaf carries a crc32; restore verifies before
  handing params to the trainer.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer",
           "verify_manifest"]

_SEP = "__"  # flat key separator: ("a", "b") -> "a__b"

# ml_dtypes types are stored as raw integer views (np.save can't round-trip
# them without pickle); the manifest records the true dtype.
_RAW_VIEW = {2: np.uint16, 1: np.uint8}


def _to_native(arr: np.ndarray):
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(_RAW_VIEW[arr.dtype.itemsize]), arr.dtype.name
    return arr, arr.dtype.name


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) != dtype_name:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(root: os.PathLike, step: int, tree: Any,
         extra: Optional[Dict] = None) -> Path:
    """Atomic synchronous save of ``tree`` at ``step``."""
    root = Path(root)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        for f in tmp.iterdir():
            f.unlink()
    tmp.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, arr in flat.items():
        fname = f"{key.replace('/', '@')}.npy"   # keys may contain "/"
        raw, dtype_name = _to_native(arr)
        np.save(tmp / fname, raw)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": dtype_name,
            "crc32": zlib.crc32(np.ascontiguousarray(raw).tobytes()) & 0xFFFFFFFF,
        }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():  # overwrite-retry after a partial failure
        import shutil
        shutil.rmtree(final)
    tmp.rename(final)   # the commit point
    return final


def latest_step(root: os.PathLike) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith("step_") and \
                not d.name.endswith(".tmp") and (d / "manifest.json").exists():
            steps.append(int(d.name[5:]))
    return max(steps) if steps else None


def verify_manifest(ckpt_dir: Path) -> Dict:
    with open(ckpt_dir / "manifest.json") as f:
        manifest = json.load(f)
    for key, meta in manifest["leaves"].items():
        raw = np.load(ckpt_dir / meta["file"])
        arr = _from_native(raw, meta["dtype"])
        if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
            raise IOError(f"checkpoint leaf {key}: shape/dtype mismatch")
        crc = zlib.crc32(np.ascontiguousarray(raw).tobytes()) & 0xFFFFFFFF
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint leaf {key}: crc mismatch "
                          f"(corrupt file {meta['file']})")
    return manifest


def restore(root: os.PathLike, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True
            ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``tree_like`` (a pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, step, extra)."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = verify_manifest(d) if verify else \
        json.load(open(d / "manifest.json"))
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    flat_sh = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(leaves))
    for (path, like), sh in zip(leaves, flat_sh):
        key = _SEP.join(_path_str(p) for p in path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint {d} is missing leaf {key}")
        meta = manifest["leaves"][key]
        arr = _from_native(np.load(d / meta["file"]), meta["dtype"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out), step,
            manifest["extra"])


class AsyncCheckpointer:
    """Credit-bounded async checkpoint writer (paper C3).

    ``submit`` snapshots device arrays to host RAM and enqueues; it blocks
    only when all ``credits`` are in flight (bounded memory — the endpoint
    FIFO rule).  ``fence`` drains outstanding writes (the store barrier:
    wait until the credit counter is back at max).
    """

    def __init__(self, root: os.PathLike, credits: int = 2):
        self.root = Path(root)
        self._q: queue.Queue = queue.Queue(maxsize=credits)
        self._errors: list = []
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, tree, extra = item
            try:
                save(self.root, step, tree, extra)
            except Exception as e:  # surfaced at next submit/fence
                self._errors.append(e)
            finally:
                self._q.task_done()

    def submit(self, step: int, tree: Any, extra: Optional[Dict] = None):
        if self._errors:
            raise self._errors.pop(0)
        # snapshot NOW: np.array(copy=True) so neither later device-buffer
        # donation nor host-side mutation can leak into the write
        host_tree = jax.tree.map(lambda x: np.array(x, copy=True), tree)
        self._q.put((step, host_tree, extra))

    def fence(self):
        self._q.join()
        if self._errors:
            raise self._errors.pop(0)

    def close(self):
        self.fence()
        self._q.put(None)
        self._thread.join()
