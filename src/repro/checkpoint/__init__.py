from .store import (AsyncCheckpointer, latest_step, restore, save,
                    verify_manifest)

__all__ = ["AsyncCheckpointer", "latest_step", "restore", "save",
           "verify_manifest"]
