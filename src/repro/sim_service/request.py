"""Request/response schema of the simulation service.

A request is either one phased measurement (:class:`SimRequest` — a mesh
configuration plus a traffic pattern/load or an explicit injection
program) or a full saturation curve (:class:`SweepRequest` — the
service-side equivalent of
:func:`repro.netsim_jax.measure.load_latency_sweep`, one *lane* per
offered load).  Both normalize to the same lane vocabulary the server
batches on: a :class:`~repro.netsim_jax.measure.SweepKey` (the compiled
identity), a ``check_every`` streaming cadence, and per-lane
(program, fifo_depth, max_credits) triples whose depth/credit knobs ride
the vmapped state *dynamically* — exactly the bucketing invariant of
:mod:`repro.dse.spec`, so one compilation serves every buffer sizing of
a shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.mesh.config import MeshConfig
from repro.mesh.traffic import make_traffic
from repro.netsim_jax.measure import (DEFAULT_SWEEP_RATES, PhaseStats,
                                      SweepKey, curve_is_monotone,
                                      saturation_point)
from repro.netsim_jax.sim import Program, load_program

__all__ = ["ServiceOverloaded", "LaneSpec", "SimRequest", "SweepRequest",
           "SimResponse", "SweepResponse"]


class ServiceOverloaded(RuntimeError):
    """The server's bounded queue is full; resubmit after ticks drain it
    (the backpressure contract — requests are never silently dropped)."""


@dataclasses.dataclass(frozen=True)
class LaneSpec:
    """One batch lane: an injection program plus the dynamic buffer knobs
    it simulates under.  ``label`` names the lane in streamed telemetry
    (the offered load for sweep lanes)."""
    program: Program
    fifo_depth: int
    max_credits: int
    label: str = ""


def _program_length(load: float, horizon: int) -> int:
    """Entries needed so ``load`` never exhausts its program inside the
    horizon (same sizing as ``stack_rate_programs``)."""
    return int(np.ceil(load * horizon)) + 1


@dataclasses.dataclass(frozen=True)
class _RequestBase:
    cfg: MeshConfig
    pattern: str = "uniform"
    seed: int = 0
    warmup: int = 200
    measure: int = 400
    drain: int = 400
    check_every: int = 100
    fifo_depth: Optional[int] = None
    max_credits: Optional[int] = None
    unroll: int = 1
    impl: str = "fused"
    cycles_per_call: int = 1

    def __post_init__(self):
        object.__setattr__(self, "cfg", MeshConfig.coerce(self.cfg))
        if self.check_every < 1:
            raise ValueError(
                f"check_every must be >= 1, got {self.check_every}")
        for knob, cap in (("fifo_depth", self.cfg.router_fifo),
                          ("max_credits", self.cfg.max_out_credits)):
            v = getattr(self, knob)
            if v is not None and not 1 <= v <= cap:
                raise ValueError(
                    f"{knob}={v} outside [1, {cap}] (the static config "
                    f"capacity it must ride under)")

    @property
    def horizon(self) -> int:
        return self.warmup + self.measure + self.drain

    def sweep_key(self) -> SweepKey:
        """The compiled-program identity this request buckets under."""
        return SweepKey(cfg=self.cfg.to_sim(), warmup=self.warmup,
                        measure=self.measure, drain=self.drain,
                        unroll=self.unroll, impl=self.impl,
                        cycles_per_call=self.cycles_per_call)

    def _knobs(self) -> Tuple[int, int]:
        d = self.cfg.router_fifo if self.fifo_depth is None \
            else self.fifo_depth
        c = self.cfg.max_out_credits if self.max_credits is None \
            else self.max_credits
        return int(d), int(c)

    def _pattern_program(self, load: float, length: int) -> Program:
        return load_program(make_traffic(
            self.pattern, self.cfg.nx, self.cfg.ny, length, rate=load,
            seed=self.seed, topology=self.cfg.topology))


@dataclasses.dataclass(frozen=True)
class SimRequest(_RequestBase):
    """One phased measurement: ``cfg`` + ``pattern``/``load`` (or an
    explicit injection-program ``entries`` mapping, same schema as
    ``MeshSim.load_program``).  ``fifo_depth`` / ``max_credits`` pick the
    *effective* buffer sizing (<= the config capacities) — dynamic, so
    they never cost a recompile."""
    load: float = 0.1
    entries: Optional[Mapping[str, np.ndarray]] = None

    def lanes(self) -> List[LaneSpec]:
        d, c = self._knobs()
        if self.entries is not None:
            prog = load_program(dict(self.entries))
        else:
            prog = self._pattern_program(
                self.load, _program_length(self.load, self.horizon))
        return [LaneSpec(prog, d, c, label=f"{self.pattern}@{self.load:g}")]

    def build_response(self, rid: int, stats: List[PhaseStats],
                       metrics: Dict) -> "SimResponse":
        assert len(stats) == 1
        return SimResponse(rid=rid, stats=stats[0], metrics=metrics)


@dataclasses.dataclass(frozen=True)
class SweepRequest(_RequestBase):
    """A full load–latency saturation curve: one lane per offered rate,
    every lane's program sized for the *fastest* rate (so the whole
    sweep shares one bucket and therefore one compile — the
    ``stack_rate_programs`` trick)."""
    rates: Tuple[float, ...] = DEFAULT_SWEEP_RATES

    def __post_init__(self):
        super().__post_init__()
        object.__setattr__(self, "rates",
                           tuple(sorted({float(r) for r in self.rates})))
        if not self.rates or min(self.rates) <= 0 or max(self.rates) > 1:
            raise ValueError(
                f"sweep rates must be in (0, 1], got {self.rates}")

    def lanes(self) -> List[LaneSpec]:
        d, c = self._knobs()
        length = _program_length(max(self.rates), self.horizon)
        return [LaneSpec(self._pattern_program(r, length), d, c,
                         label=f"{self.pattern}@{r:g}")
                for r in self.rates]

    def build_response(self, rid: int, stats: List[PhaseStats],
                       metrics: Dict) -> "SweepResponse":
        lat = np.asarray([float(s.lat_mean) for s in stats])
        sat = saturation_point(lat)
        curve = {k: [float(getattr(s, k)) for s in stats]
                 for k in PhaseStats._fields if k != "hist"}
        curve.update(
            rates=list(self.rates), pattern=self.pattern,
            mesh=f"{self.cfg.nx}x{self.cfg.ny}",
            zero_load_latency=float(lat[0]),
            saturation_index=sat,
            saturation_rate=None if sat is None else float(self.rates[sat]),
            saturation_throughput=float(max(curve["accepted"])),
            monotone=bool(curve_is_monotone(lat)))
        return SweepResponse(rid=rid, rates=self.rates, stats=stats,
                             curve=curve, metrics=metrics)


@dataclasses.dataclass
class SimResponse:
    """One measurement result: the :class:`PhaseStats` (numpy leaves,
    bit-identical to a direct :func:`phased_stats` run) plus per-request
    service metrics (queue wait, service/total wall seconds, bucket id,
    batch width, fresh compile counts)."""
    rid: int
    stats: PhaseStats
    metrics: Dict[str, object]


@dataclasses.dataclass
class SweepResponse:
    """One saturation curve: per-rate :class:`PhaseStats` plus the
    ``load_latency_sweep``-shaped ``curve`` record and service metrics."""
    rid: int
    rates: Tuple[float, ...]
    stats: List[PhaseStats]
    curve: Dict[str, object]
    metrics: Dict[str, object]
