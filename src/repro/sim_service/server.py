"""The async simulation server and its synchronous facade.

Structure mirrors :mod:`repro.launch.serve`'s continuous-batching decode
loop: a bounded admission queue (a ``deque``, popped from the head), a
per-tick scheduler, and one batched jitted call per compiled shape per
tick.  Differences are what the workload needs: slots here are *batch
lanes* grouped by :class:`~repro.sim_service.bucketing.BucketKey`
(compiled-shape identity) instead of a fixed slot pool, and a request
runs a statically-scheduled sequence of fence blocks rather than an
open-ended decode.

Scheduling contract (the amortization story):

* every tick, each bucket with work advances its in-flight
  :class:`~repro.sim_service.streaming.BatchRunner` by exactly one fence
  block — ONE vmapped call per bucket per tick;
* a new batch forms only when the bucket has no runner in flight, from
  every lane then waiting (up to ``max_batch``, padded to a power of
  two) — so late arrivals join the *next* batch, never an in-flight
  one, and never cost a fresh compile for a seen shape;
* admission is bounded (``queue_limit`` waiting lanes); beyond it
  ``submit`` raises :class:`~repro.sim_service.request.ServiceOverloaded`
  — backpressure, not silent dropping;
* telemetry streams per fence block: each lane's
  :class:`~repro.netsim_jax.measure.StreamChunk` lands on its request's
  :class:`Ticket` as soon as the block executes (async-iterate
  ``Ticket.stream()`` under a running ``serve()`` task, or consume the
  sync generator ``SimService.stream``).

With ``compile_cache_dir`` set, the server also arms JAX's persistent
on-disk compilation cache (keyed under :func:`repro.dse.cache
.config_hash`, shared with :func:`repro.dse.run_sweep`), so a *process*
restart on known shapes deserializes executables instead of re-running
XLA.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from typing import (AsyncIterator, Deque, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple, Union)

from repro.netsim_jax.measure import PhaseStats, StreamChunk

from .bucketing import BucketKey, bucket_key, next_pow2
from .metrics import ServiceMetrics
from .request import (LaneSpec, ServiceOverloaded, SimRequest, SimResponse,
                      SweepRequest, SweepResponse)
from .streaming import BatchRunner

__all__ = ["TelemetryChunk", "Ticket", "SimServer", "SimService"]

Request = Union[SimRequest, SweepRequest]
Response = Union[SimResponse, SweepResponse]


class TelemetryChunk(NamedTuple):
    """One streamed fence-block delta, addressed to a request: ``lane``
    indexes the request's lanes (always 0 for a :class:`SimRequest`; the
    rate index for sweeps) and ``label`` names it (e.g. ``uniform@0.3``)."""
    rid: int
    lane: int
    label: str
    chunk: StreamChunk


class Ticket:
    """A submitted request's handle: chunks accumulate on ``.chunks`` as
    ticks execute; ``.done``/``.response`` flip when every lane finished.
    Async consumption (``stream()`` / ``result()``) needs the server's
    ``serve()`` loop running somewhere; the sync paths
    (``SimService.run`` / ``SimService.stream``) drive ticks themselves."""

    def __init__(self, server: "SimServer", rid: int, request: Request,
                 n_lanes: int):
        self._server = server
        self.rid = rid
        self.request = request
        self.chunks: List[TelemetryChunk] = []
        self.stats: List[Optional[PhaseStats]] = [None] * n_lanes
        self.done = False
        self.response: Optional[Response] = None
        self.submitted_at = time.perf_counter()
        self.started_at: Optional[float] = None
        self._runner_meta: Dict[str, object] = {}

    async def stream(self) -> AsyncIterator[TelemetryChunk]:
        cursor = 0
        while True:
            while cursor < len(self.chunks):
                yield self.chunks[cursor]
                cursor += 1
            if self.done:
                return
            await self._server._wait_tick()

    async def result(self) -> Response:
        while not self.done:
            await self._server._wait_tick()
        assert self.response is not None
        return self.response


@dataclasses.dataclass
class _Waiter:
    ticket: Ticket
    lane_idx: int
    spec: LaneSpec


@dataclasses.dataclass
class _Bucket:
    waiting: Deque[_Waiter] = dataclasses.field(
        default_factory=collections.deque)
    inflight: Optional[BatchRunner] = None
    members: List[_Waiter] = dataclasses.field(default_factory=list)


class SimServer:
    """Continuous-batching phased-measurement server (see module doc)."""

    def __init__(self, *, max_batch: int = 8, queue_limit: int = 64,
                 compile_cache_dir=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        # batch widths are pow2-padded, so the cap must be a power of two
        # to stay a cap; round down (8 -> 8, 6 -> 4)
        self.max_batch = 1 << (int(max_batch).bit_length() - 1)
        self.queue_limit = int(queue_limit)
        self.metrics = ServiceMetrics()
        self._buckets: Dict[BucketKey, _Bucket] = {}
        self._pending = 0          # waiting lanes (the bounded queue)
        self._next_rid = 0
        self._tick_event: Optional[asyncio.Event] = None
        self._stop = False
        if compile_cache_dir is not None:
            from repro.compat import enable_persistent_compilation_cache
            from repro.dse.cache import config_hash
            enable_persistent_compilation_cache(compile_cache_dir,
                                                subkey=config_hash())

    # -- admission -----------------------------------------------------
    def submit(self, request: Request) -> Ticket:
        """Queue a request; raises :class:`ServiceOverloaded` when the
        bounded queue cannot take its lanes."""
        lanes = request.lanes()
        if self._pending + len(lanes) > self.queue_limit:
            self.metrics.rejected += 1
            raise ServiceOverloaded(
                f"queue holds {self._pending}/{self.queue_limit} lanes; "
                f"request needs {len(lanes)} more — retry after ticks "
                f"drain the backlog")
        rid = self._next_rid
        self._next_rid += 1
        ticket = Ticket(self, rid, request, len(lanes))
        key = request.sweep_key()
        for idx, lane in enumerate(lanes):
            bkey = bucket_key(key, lane.program, request.check_every)
            self._buckets.setdefault(bkey, _Bucket()).waiting.append(
                _Waiter(ticket, idx, lane))
        self._pending += len(lanes)
        self.metrics.submitted += 1
        self.metrics.lanes += len(lanes)
        self.metrics.peak_pending = max(self.metrics.peak_pending,
                                        self._pending)
        return ticket

    @property
    def pending_lanes(self) -> int:
        return self._pending

    @property
    def idle(self) -> bool:
        return not self._buckets

    # -- the scheduler tick ---------------------------------------------
    def tick(self) -> bool:
        """One scheduler step: form batches where buckets are free, then
        advance every in-flight batch by one fence block (one vmapped
        call per bucket).  Returns True when any work ran."""
        did = False
        for bkey, b in list(self._buckets.items()):
            if b.inflight is None and b.waiting:
                take = [b.waiting.popleft()
                        for _ in range(min(len(b.waiting), self.max_batch))]
                self._pending -= len(take)
                b.members = take
                b.inflight = BatchRunner(bkey, [w.spec for w in take],
                                         next_pow2(len(take)))
                now = time.perf_counter()
                for w in take:
                    if w.ticket.started_at is None:
                        w.ticket.started_at = now
                self.metrics.batches += 1
            if b.inflight is not None:
                for lane_i, chunk in b.inflight.advance():
                    w = b.members[lane_i]
                    w.ticket.chunks.append(TelemetryChunk(
                        w.ticket.rid, w.lane_idx, w.spec.label, chunk))
                    self.metrics.chunks += 1
                self.metrics.blocks += 1
                did = True
                if b.inflight.done:
                    self._finish(bkey, b)
            if b.inflight is None and not b.waiting:
                del self._buckets[bkey]
        self.metrics.ticks += 1
        self._notify()
        return did

    def _finish(self, bkey: BucketKey, b: _Bucket) -> None:
        runner = b.inflight
        assert runner is not None
        stats = runner.finalize()
        self.metrics.sim_compiles += runner.sim_compiles
        self.metrics.aux_compiles += runner.aux_compiles
        now = time.perf_counter()
        for w, st in zip(b.members, stats):
            t = w.ticket
            t.stats[w.lane_idx] = st
            t._runner_meta = {
                "bucket": f"{bkey.key.cfg.topology.spec}-"
                          f"{bkey.key.cfg.nx}x{bkey.key.cfg.ny}"
                          f"/L{bkey.prog_len}/ce{bkey.check_every}",
                "batch_width": runner.width,
                "batch_lanes": len(runner.lanes),
                "blocks": len(runner.schedule),
                "new_sim_compiles": runner.sim_compiles,
                "new_aux_compiles": runner.aux_compiles,
            }
            if all(s is not None for s in t.stats):
                meta = dict(t._runner_meta)
                meta.update(
                    queue_wait_s=round((t.started_at or now)
                                       - t.submitted_at, 6),
                    service_s=round(now - (t.started_at or now), 6),
                    total_s=round(now - t.submitted_at, 6),
                    chunks=len(t.chunks))
                t.response = t.request.build_response(t.rid, t.stats, meta)
                t.done = True
                self.metrics.completed += 1
        b.inflight = None
        b.members = []

    def run_until_idle(self, max_ticks: int = 1_000_000) -> int:
        """Drive ticks synchronously until every request finished."""
        n = 0
        while not self.idle:
            if n >= max_ticks:
                raise RuntimeError(
                    f"server not idle after {max_ticks} ticks")
            self.tick()
            n += 1
        return n

    # -- async surface ---------------------------------------------------
    def _notify(self) -> None:
        ev, self._tick_event = self._tick_event, None
        if ev is not None:
            ev.set()

    async def _wait_tick(self) -> None:
        if self._tick_event is None:
            self._tick_event = asyncio.Event()
        await self._tick_event.wait()

    async def serve(self, *, until_idle: bool = False,
                    idle_sleep: float = 0.001) -> None:
        """The server loop: tick until :meth:`stop` (or, with
        ``until_idle``, until the queue drains).  Run as a task next to
        async consumers of ``Ticket.stream()`` / ``Ticket.result()``."""
        self._stop = False
        while not self._stop:
            did = self.tick()
            if until_idle and self.idle:
                return
            await asyncio.sleep(0 if did else idle_sleep)

    def stop(self) -> None:
        self._stop = True


class SimService:
    """Synchronous facade over :class:`SimServer` — no event loop needed.
    ``run`` batches a list of requests through to completion; ``stream``
    is a generator of :class:`TelemetryChunk` whose ``StopIteration``
    value is the response."""

    def __init__(self, **kw):
        self.server = SimServer(**kw)

    @property
    def metrics(self) -> ServiceMetrics:
        return self.server.metrics

    def submit(self, request: Request) -> Ticket:
        return self.server.submit(request)

    def run(self, requests: Union[Request, Sequence[Request]]
            ) -> List[Response]:
        reqs = [requests] if isinstance(requests, (SimRequest, SweepRequest)) \
            else list(requests)
        tickets = [self.server.submit(r) for r in reqs]
        self.server.run_until_idle()
        return [t.response for t in tickets]

    def run_one(self, request: Request) -> Response:
        return self.run([request])[0]

    def stream(self, request: Request):
        ticket = self.server.submit(request)
        cursor = 0
        while not ticket.done:
            self.server.tick()
            while cursor < len(ticket.chunks):
                yield ticket.chunks[cursor]
                cursor += 1
        while cursor < len(ticket.chunks):
            yield ticket.chunks[cursor]
            cursor += 1
        return ticket.response
