"""The batched, block-streamed execution engine behind the sim service.

A :class:`BatchRunner` owns one formed batch: stacked injection programs,
a vmapped stack of simulator states, and the static fence-block schedule
(:func:`repro.netsim_jax.measure.phase_schedule`).  Each ``advance()``
executes ONE jitted vmapped ``simulate`` block for the whole batch —
that is the "one vmapped call per bucket per tick" contract — and emits
one :class:`~repro.netsim_jax.measure.StreamChunk` per lane from the
host-side counter deltas.  ``finalize()`` reduces the phase-boundary
snapshots through a jitted vmapped
:func:`~repro.netsim_jax.measure.reduce_window_stats`, which keeps every
:class:`PhaseStats` field bit-identical to the one-shot
:func:`~repro.netsim_jax.measure.phased_stats` program (the reduce must
run under ``jit`` — eager jnp arithmetic rounds division differently
than the XLA-optimized trace).

Compile accounting mirrors :mod:`repro.dse.runner`'s
``_EXECUTED_SHAPES`` registry: the module-level jit caches plus the
executed-shape sets distinguish a genuinely fresh XLA compilation from a
cache hit, so a *second* service instance in the same process reports 0
compiles, and the service's headline "N same-shape requests compile
once" claim is asserted rather than assumed.  ``sim_compiles`` counts
simulator-block executables (the expensive ones); ``aux_compiles``
counts the tiny state-init and stats-reduce programs.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim_jax.measure import (PhaseStats, StreamChunk, SweepKey,
                                      phase_schedule, reduce_window_stats)
from repro.netsim_jax.sim import init_state, simulate

from .bucketing import BucketKey, stack_lanes
from .request import LaneSpec

__all__ = ["BatchRunner", "clear_service_cache", "executed_shapes"]

# shapes (block/init/reduce executables) already executed by this
# process — the line between a fresh XLA compilation and a jit-cache hit
_EXECUTED: set = set()


@functools.lru_cache(maxsize=None)
def _block_jit(key: SweepKey, cycles: int):
    """One fence block for a whole batch: vmapped ``simulate`` over the
    lane axis, state donated (the FIFO buffers update in place across
    blocks instead of copying per block)."""
    cfg = key.cfg

    def block(progs, states):
        def one(p, st):
            st, _ = simulate(cfg, p, st, cycles, key.unroll, key.impl,
                             key.cycles_per_call)
            return st
        return jax.vmap(one)(progs, states)
    return jax.jit(block, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _init_jit(key: SweepKey):
    """Vmapped fresh-state builder with the measurement window armed —
    the same two lines :func:`phased_stats` opens with."""
    cfg = key.cfg

    def mk(depth, credits):
        st = init_state(cfg, depth, credits)
        return st._replace(
            measure_start=st.cycle + key.warmup,
            measure_stop=st.cycle + key.warmup + key.measure)
    return jax.jit(jax.vmap(mk))


@functools.lru_cache(maxsize=None)
def _reduce_jit(ntiles: int, measure: int):
    """Vmapped window reduce — jitted, because only the jitted trace of
    :func:`reduce_window_stats` matches :func:`phased_stats` bitwise."""
    def red(hist, d_inj, d_comp, d_util):
        return jax.vmap(
            lambda h, i, c, u: reduce_window_stats(
                ntiles, measure, h, i, c, u))(hist, d_inj, d_comp, d_util)
    return jax.jit(red)


def _note(shape_id) -> bool:
    """Record an executable shape; True when this process compiles it
    fresh (vs hitting the in-process jit cache)."""
    fresh = shape_id not in _EXECUTED
    _EXECUTED.add(shape_id)
    return fresh


def executed_shapes() -> int:
    """How many distinct executable shapes this process has run."""
    return len(_EXECUTED)


def clear_service_cache() -> None:
    """Drop the service's jitted programs AND the executed-shape
    registry — the cold-start reset the benchmarks use (pair with
    ``jax.clear_caches()`` for a fully cold in-process baseline)."""
    _block_jit.cache_clear()
    _init_jit.cache_clear()
    _reduce_jit.cache_clear()
    _EXECUTED.clear()


class BatchRunner:
    """One in-flight batch of a bucket: advance one fence block per call,
    stream per-lane chunk deltas, reduce to per-lane PhaseStats at the
    end.  ``width`` is the padded (pow2) lane count actually executed;
    ``lanes`` the real requests (padding replicates lane 0 and is
    dropped)."""

    def __init__(self, bkey: BucketKey, lanes: Sequence[LaneSpec],
                 width: int):
        self.bkey = bkey
        self.lanes = list(lanes)
        self.width = width
        key = bkey.key
        self.schedule = phase_schedule(key.warmup, key.measure, key.drain,
                                       bkey.check_every)
        self.idx = 0
        self.cycle = 0
        self.sim_compiles = 0
        self.aux_compiles = 0
        progs, depths, credits = stack_lanes(lanes, bkey.prog_len, width)
        self.progs = progs
        self.aux_compiles += _note(("init", key, width))
        self.states = _init_jit(key)(depths, credits)
        n = len(self.lanes)
        self._prev_inj = np.zeros(n, np.int64)
        self._prev_comp = np.zeros(n, np.int64)
        self._prev_deliv = np.zeros(n, np.int64)
        self._prev_hist = np.asarray(self.states.lat_hist)[:n].copy()
        # phase-boundary snapshots (a zero-length warmup's boundary is
        # the fresh state, exactly like phased_stats' 0-cycle scan)
        self._snap_w = self._snap_m = self._snapshot()

    # ------------------------------------------------------------------
    def _snapshot(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = len(self.lanes)
        st = self.states
        inj = np.asarray(st.prog_ptr, np.int64)[:n].reshape(n, -1).sum(1)
        comp = np.asarray(st.completed, np.int64)[:n].reshape(n, -1).sum(1)
        util = np.asarray(st.link_util, np.int64)[:n]
        return inj, comp, util

    @property
    def done(self) -> bool:
        return self.idx >= len(self.schedule)

    def advance(self) -> List[Tuple[int, StreamChunk]]:
        """Execute the next fence block (ONE vmapped call for the whole
        batch); returns ``(lane_index, chunk)`` telemetry deltas."""
        assert not self.done
        phase, cycles = self.schedule[self.idx]
        key = self.bkey.key
        self.sim_compiles += _note(
            ("block", key, cycles, self.width, self.bkey.prog_len))
        self.states = _block_jit(key, cycles)(self.progs, self.states)
        n = len(self.lanes)
        inj, comp, util = self._snapshot()
        hist = np.asarray(self.states.lat_hist)[:n]
        deliv = hist.sum(-1).astype(np.int64)
        out = [(i, StreamChunk(
            phase=phase, start=self.cycle, stop=self.cycle + cycles,
            injected=int(inj[i] - self._prev_inj[i]),
            completed=int(comp[i] - self._prev_comp[i]),
            delivered=int(deliv[i] - self._prev_deliv[i]),
            hist=hist[i] - self._prev_hist[i])) for i in range(n)]
        self._prev_inj, self._prev_comp = inj, comp
        self._prev_deliv, self._prev_hist = deliv, hist
        self.cycle += cycles
        self.idx += 1
        nxt = self.schedule[self.idx][0] if not self.done else None
        if phase == "warmup" and nxt != "warmup":
            self._snap_w = self._snap_m = (inj, comp, util)
        elif phase == "measure" and nxt != "measure":
            self._snap_m = (inj, comp, util)
        return out

    def finalize(self) -> List[PhaseStats]:
        """Per-lane PhaseStats, bit-identical to direct phased_stats."""
        assert self.done
        key, cfg = self.bkey.key, self.bkey.key.cfg
        n = len(self.lanes)
        d_inj = (self._snap_m[0] - self._snap_w[0]).astype(np.int32)
        d_comp = (self._snap_m[1] - self._snap_w[1]).astype(np.int32)
        d_util = (self._snap_m[2] - self._snap_w[2]).astype(np.int32)

        def grow(x):  # pad the reduce back to the executed batch width
            reps = [x[:1]] * (self.width - n)
            return jnp.asarray(np.concatenate([x] + reps)) if reps \
                else jnp.asarray(x)
        ntiles = cfg.nx * cfg.ny
        self.aux_compiles += _note(
            ("reduce", ntiles, key.measure, self.width))
        stats = _reduce_jit(ntiles, key.measure)(
            self.states.lat_hist, grow(d_inj), grow(d_comp), grow(d_util))
        host = PhaseStats(*(np.asarray(f) for f in stats))
        return [PhaseStats(*(f[i] for f in host)) for i in range(n)]
