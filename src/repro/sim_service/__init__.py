"""Simulation-as-a-service: the continuous-batched, compile-cached,
streaming front end over the JAX mesh simulator.

The ROADMAP's serving story, closed: concurrent phased-measurement
requests (:class:`SimRequest`) and saturation-curve sweeps
(:class:`SweepRequest`) are queued, bucketed by compiled shape
(:class:`~repro.netsim_jax.measure.SweepKey` + padded program length +
streaming cadence), executed as ONE vmapped call per bucket per tick,
and streamed back per fence block — with results **bit-identical** to
direct :func:`repro.netsim_jax.measure.phased_stats` runs (asserted in
``tests/test_sim_service.py``).

Entry points:

* :class:`SimService` — synchronous facade (``run`` / ``run_one`` /
  ``stream``);
* :class:`SimServer` — the async server (``submit`` + a ``serve()``
  task; consume ``Ticket.stream()`` / ``Ticket.result()``);
* ``compile_cache_dir=`` on either arms JAX's persistent on-disk
  compilation cache (shared with :func:`repro.dse.run_sweep` via
  :func:`repro.compat.enable_persistent_compilation_cache`), making
  process-cold starts on known shapes ~0 recompiles.
"""
from .bucketing import BucketKey, bucket_key, next_pow2  # noqa: F401
from .metrics import ServiceMetrics  # noqa: F401
from .request import (LaneSpec, ServiceOverloaded, SimRequest,  # noqa: F401
                      SimResponse, SweepRequest, SweepResponse)
from .server import (SimServer, SimService, TelemetryChunk,  # noqa: F401
                     Ticket)
from .streaming import (BatchRunner, clear_service_cache,  # noqa: F401
                        executed_shapes)

__all__ = ["SimRequest", "SweepRequest", "SimResponse", "SweepResponse",
           "LaneSpec", "ServiceOverloaded", "BucketKey", "bucket_key",
           "next_pow2", "ServiceMetrics", "SimServer", "SimService",
           "TelemetryChunk", "Ticket", "BatchRunner",
           "clear_service_cache", "executed_shapes"]
