"""Service-level accounting: what the server did, and what it cost.

One :class:`ServiceMetrics` per server instance.  Compile counts come
from the execution engine's honest executed-shape registry
(:mod:`repro.sim_service.streaming`) — ``sim_compiles`` is the headline
(simulator-block executables), ``aux_compiles`` the tiny state-init and
stats-reduce programs.  ``snapshot()`` folds in the persistent on-disk
compilation-cache counters from :func:`repro.compat
.compilation_cache_stats`, so a bench run can show both layers: 0
in-process compiles on a warm service, and disk hits instead of XLA
compiles on a warm *process*.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.compat import compilation_cache_stats

__all__ = ["ServiceMetrics"]


@dataclasses.dataclass
class ServiceMetrics:
    submitted: int = 0        # requests accepted into the queue
    rejected: int = 0         # requests refused by backpressure
    completed: int = 0        # requests finished (response built)
    lanes: int = 0            # batch lanes admitted (sweeps count per rate)
    ticks: int = 0            # scheduler ticks executed
    batches: int = 0          # batch runners formed
    blocks: int = 0           # vmapped fence-block calls executed
    chunks: int = 0           # telemetry chunks streamed
    sim_compiles: int = 0     # fresh simulator-block executables
    aux_compiles: int = 0     # fresh init/reduce executables
    peak_pending: int = 0     # max lanes waiting in the bounded queue

    def snapshot(self) -> Dict[str, object]:
        out = dataclasses.asdict(self)
        out["compilation_cache"] = compilation_cache_stats()
        return out
