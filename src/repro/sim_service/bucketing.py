"""Shape bucketing: which requests may share one compiled program.

A compiled batch program is determined by the
:class:`~repro.netsim_jax.measure.SweepKey` (config + phase lengths +
execution knobs), the padded injection-program length, the streaming
cadence (``check_every`` fixes the block schedule) and the padded batch
width.  The first three form the :class:`BucketKey` requests queue
under; the width is chosen at batch-formation time.

Both pads are power-of-two quantized so the set of distinct compiled
shapes stays small and *revisits* stay warm:

* **program length** pads with zero entries past each tile's ``length``
  counter — never injected, so dynamics are untouched (the same reason
  ``stack_rate_programs``' past-horizon tail entries are safe); nearby
  loads therefore share one bucket instead of compiling per length.
* **batch width** pads by replicating lane 0 (dropped on read-back), so
  2, 3 or 4 concurrent requests all execute the width-4 executable.
"""
from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.netsim_jax.measure import SweepKey
from repro.netsim_jax.sim import I32, Program

from .request import LaneSpec

__all__ = ["BucketKey", "bucket_key", "next_pow2", "pad_program_length",
           "stack_lanes"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(int(n) - 1, 0).bit_length()


class BucketKey(NamedTuple):
    """Everything two requests must agree on to ride one compiled batch
    program (up to batch width, padded at formation time)."""
    key: SweepKey       # config + warmup/measure/drain + execution knobs
    prog_len: int       # pow2-padded injection-program length
    check_every: int    # streaming cadence (fixes the block schedule)


def bucket_key(key: SweepKey, prog: Program, check_every: int) -> BucketKey:
    return BucketKey(key=key, prog_len=next_pow2(prog.buf.shape[-1]),
                     check_every=int(check_every))


def pad_program_length(prog: Program, length: int) -> Program:
    """Pad the program's entry axis with zero entries up to ``length``.
    Zero entries sit past every tile's ``length`` counter, so the
    injector never reads them — identical dynamics, one shared shape."""
    cur = prog.buf.shape[-1]
    if cur == length:
        return prog
    if cur > length:
        raise ValueError(
            f"program length {cur} exceeds bucket length {length}")
    buf = jnp.pad(prog.buf, ((0, 0), (0, 0), (0, 0), (0, length - cur)))
    return Program(buf=buf, length=prog.length)


def stack_lanes(lanes: Sequence[LaneSpec], prog_len: int,
                width: int) -> Tuple[Program, jax.Array, jax.Array]:
    """Stack lanes into the batch arrays of one vmapped call, padded to
    ``width`` rows by replicating lane 0 (its extra rows are dropped on
    read-back).  Returns (programs, fifo_depths, max_credits), each with
    a leading ``width`` axis."""
    if not 1 <= len(lanes) <= width:
        raise ValueError(
            f"batch of {len(lanes)} lanes cannot pad to width {width}")
    progs: List[Program] = [pad_program_length(ln.program, prog_len)
                            for ln in lanes]
    depths = [ln.fifo_depth for ln in lanes]
    credits = [ln.max_credits for ln in lanes]
    while len(progs) < width:
        progs.append(progs[0])
        depths.append(depths[0])
        credits.append(credits[0])
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *progs)
    return (stacked, jnp.asarray(np.asarray(depths, np.int32), I32),
            jnp.asarray(np.asarray(credits, np.int32), I32))
