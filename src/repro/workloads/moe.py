"""MoE expert-parallel dispatch lowered to mesh traffic.

Models the all-to-all of ``repro/models/moe.py``: every tile holds a
shard of the token batch, the router assigns each token ``top_k``
experts, and the dispatch scatters tokens to their experts' home tiles
(the combine is the mirror-image gather on the reverse path — which the
simulator's response network carries for free, since every remote store
returns a credit and every remote load returns data).

The router's *load imbalance* — the thing capacity factors and aux losses
exist to fight — is the workload's key knob: ``imbalance`` is the excess
probability mass concentrated on expert 0 (the "hot expert"), on top of
the uniform floor.  ``imbalance=0`` is a balanced router; ``0.5`` sends
half of all tokens to one tile, turning the all-to-all into a hotspot.
``meta`` reports the realized per-expert token loads plus the capacity /
overflow numbers (same provisioning rule as
:func:`repro.models.moe.capacity`: ``ceil(tokens * top_k * cf / E)``
rounded up to a multiple of 8), so a run shows both the traffic *and* the
drop statistics a capacity-factor choice implies.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.netsim import OP_STORE

from .base import Packet, Workload, program_from_packets
from .placement import Placement

__all__ = ["moe_all_to_all", "expert_capacity"]


def expert_capacity(assignments: int, n_experts: int,
                    capacity_factor: float = 1.25) -> int:
    """Slots provisioned per expert — the FIFO-provisioning rule of
    ``repro.models.moe.capacity`` (paper C2), kept dependency-light here:
    ``assignments`` is the total number of (token, expert) pairs."""
    raw = int(assignments * capacity_factor / n_experts) + 1
    return max(8, -(-raw // 8) * 8)


def moe_all_to_all(nx: int, ny: int, tokens_per_tile: int, *,
                   n_experts: Optional[int] = None, top_k: int = 1,
                   imbalance: float = 0.0, capacity_factor: float = 1.25,
                   placement: Optional[Placement] = None,
                   rate: float = 1.0, op: int = OP_STORE,
                   mem_words: int = 64, seed: int = 0,
                   start: int = 0) -> Workload:
    """Compile one MoE dispatch: every tile routes ``tokens_per_tile``
    tokens to ``top_k`` experts each.

    Experts live on the first ``n_experts`` tiles of ``placement``
    (default: row-major over the whole mesh, the paper's ``y*nx + x``
    homes).  With probability ``imbalance`` a token's first expert is the
    hot expert 0; otherwise experts are drawn uniformly (the extra
    ``top_k - 1`` choices are uniform over the remaining experts, like a
    balanced second choice).  ``rate`` paces injection exactly like the
    traffic library (token ``i`` not before ``floor(i / rate)``).
    """
    if not 0.0 <= imbalance < 1.0:
        raise ValueError(
            f"imbalance is the extra probability mass on the hot expert "
            f"and must be in [0, 1), got {imbalance}")
    if not 0.0 < rate <= 1.0:
        raise ValueError(
            f"injection rate must be in (0, 1], got {rate}")
    if tokens_per_tile < 1:
        raise ValueError(f"need at least one token per tile, "
                         f"got {tokens_per_tile}")
    pl = placement if placement is not None else Placement.grid(nx, ny)
    n_experts = pl.k if n_experts is None else int(n_experts)
    if not 1 <= n_experts <= pl.k:
        raise ValueError(
            f"n_experts={n_experts} experts do not fit the placement's "
            f"{pl.k} tiles")
    if not 1 <= top_k <= n_experts:
        raise ValueError(
            f"top_k={top_k} must be in [1, n_experts={n_experts}]")
    rng = np.random.default_rng(seed)
    n_tiles = nx * ny
    expert_load = np.zeros(n_experts, np.int64)
    packets = []
    for t in range(n_tiles):
        sy, sx = divmod(t, nx)
        for i in range(tokens_per_tile):
            if rng.random() < imbalance:
                first = 0
            else:
                first = int(rng.integers(n_experts))
            experts = [first]
            if top_k > 1:
                rest = [e for e in range(n_experts) if e != first]
                experts += list(rng.choice(rest, size=top_k - 1,
                                           replace=False))
            for j, e in enumerate(experts):
                ex, ey = pl.tile(e)
                expert_load[e] += 1
                packets.append(Packet(
                    src_x=sx, src_y=sy, dst_x=int(ex), dst_y=int(ey),
                    addr=(t * tokens_per_tile + i) % mem_words,
                    data=e, op=op,
                    not_before=start + math.floor((i * top_k + j) / rate)))
    assignments = n_tiles * tokens_per_tile * top_k
    cap = expert_capacity(assignments, n_experts, capacity_factor)
    overflow = int(np.maximum(expert_load - cap, 0).sum())
    return Workload(
        name=f"moe_a2a_e{n_experts}_t{tokens_per_tile}"
             f"_k{top_k}_i{imbalance:g}",
        family="moe", nx=nx, ny=ny,
        program=program_from_packets(nx, ny, packets),
        n_steps=1, n_packets=assignments, placement=pl,
        meta={"n_experts": n_experts, "tokens_per_tile": tokens_per_tile,
              "top_k": top_k, "imbalance": imbalance,
              "expert_load": expert_load.tolist(),
              "hot_expert_share": float(expert_load[0]) / assignments,
              "capacity": cap, "capacity_factor": capacity_factor,
              "overflow_tokens": overflow,
              "source": "models/moe.py router_topk dispatch "
                        "(EP homes over the model axis)"})
