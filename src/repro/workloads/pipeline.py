"""Pipeline-parallel point-to-point schedules lowered to mesh traffic.

Models ``repro/parallel/pipeline.py``'s rotating-buffer GPipe schedule:
``n_stages`` stages laid along a snake path (each forward hop is one mesh
hop), ``n_micro`` microbatches, one activation transfer of ``act_words``
words per (stage, microbatch) hop.  Microbatch ``m`` leaves stage ``s``
at tick ``m + s`` — the same diagonal wavefront as ``pipeline_apply``'s
``lax.scan``, where microbatch m is injected at tick m and surfaces at
tick ``m + S - 1``.  A tick is ``act_words`` cycles at the serialization
bound; backpressure stretches it to the measured value.

With ``backward=True`` the 1F1B-style reverse wave follows: after the
last forward tick, stage ``s`` sends gradient packets to stage ``s-1``
(the reverse path the paper's response network carries), mirroring what
``jax.grad`` of ``pipeline_apply`` produces.

``meta['bubble_fraction']`` is the GPipe bound ``(S-1)/(M+S-1)``
(:func:`repro.parallel.pipeline.bubble_fraction`).
"""
from __future__ import annotations

from typing import Optional

from repro.core.netsim import OP_STORE

from .base import Packet, Workload, program_from_packets
from .placement import Placement

__all__ = ["pipeline_p2p"]


def pipeline_p2p(nx: int, ny: int, *, n_stages: Optional[int] = None,
                 n_micro: int = 4, act_words: int = 8,
                 backward: bool = False,
                 placement: Optional[Placement] = None,
                 op: int = OP_STORE, mem_words: int = 64,
                 start: int = 0) -> Workload:
    """Compile the pipeline schedule's forward (and optionally backward)
    activation traffic.  ``n_steps`` is the tick count of the schedule:
    ``n_micro + n_stages - 1`` forward ticks (doubled with backward)."""
    pl = placement if placement is not None else \
        Placement.ring(nx, ny, n_stages)
    S = pl.k
    if S < 2:
        raise ValueError(f"a pipeline needs n_stages >= 2, got {S}")
    if n_micro < 1 or act_words < 1:
        raise ValueError(
            f"need n_micro >= 1 and act_words >= 1, got n_micro={n_micro}, "
            f"act_words={act_words}")
    ticks_fwd = n_micro + S - 1
    packets = []
    for m in range(n_micro):
        for s in range(S - 1):                       # stage s -> s + 1
            sx, sy = pl.tile(s)
            dx, dy = pl.tile(s + 1)
            t = m + s
            for w in range(act_words):
                packets.append(Packet(
                    src_x=sx, src_y=sy, dst_x=dx, dst_y=dy,
                    addr=(m * act_words + w) % mem_words,
                    data=m, op=op,
                    not_before=start + t * act_words))
    if backward:
        b0 = start + ticks_fwd * act_words
        for m in range(n_micro):
            for s in range(S - 1, 0, -1):            # stage s -> s - 1
                sx, sy = pl.tile(s)
                dx, dy = pl.tile(s - 1)
                t = m + (S - 1 - s)
                for w in range(act_words):
                    packets.append(Packet(
                        src_x=sx, src_y=sy, dst_x=dx, dst_y=dy,
                        addr=(m * act_words + w) % mem_words,
                        data=m, op=op,
                        not_before=b0 + t * act_words))
    n_steps = ticks_fwd * (2 if backward else 1)
    hops = n_micro * (S - 1) * (2 if backward else 1)
    return Workload(
        name=f"pipeline_s{S}_m{n_micro}_w{act_words}"
             f"{'_fwdbwd' if backward else ''}",
        family="pipeline", nx=nx, ny=ny,
        program=program_from_packets(nx, ny, packets),
        n_steps=n_steps, n_packets=hops * act_words, placement=pl,
        meta={"n_stages": S, "n_micro": n_micro, "act_words": act_words,
              "backward": backward,
              "bubble_fraction": (S - 1) / (n_micro + S - 1),
              "source": "parallel/pipeline.py pipeline_apply "
                        "(C6 token-queue channels)"})
