"""Workload representation: a named, steppable injection program.

Every workload compiler in this package lowers a communication pattern of
the repo's model stack into a :class:`Workload` — a plain injection
program (the ``make_traffic`` dict schema, consumable bit-identically by
both simulator backends through :class:`repro.mesh.Simulator`) plus the
bookkeeping the runner needs to report per-step numbers: how many logical
steps the program encodes, how many packets it injects, and how its ranks
are placed on the mesh.

The compilers emit *packet lists* — ``(src_x, src_y, dst_x, dst_y, addr,
data, cmp, op, not_before)`` tuples — and :func:`program_from_packets`
assembles them into the dense ``(ny, nx, L)`` program arrays, sorting each
tile's packets by ``not_before`` (the simulators inject strictly in slot
order, so an out-of-order slot would stall everything behind it).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.netsim import OP_LOAD, OP_STORE  # noqa: F401 (re-export)
from repro.mesh.traffic import empty_program

from .placement import Placement

__all__ = ["Packet", "Workload", "program_from_packets", "merge_workloads"]


@dataclasses.dataclass(frozen=True)
class Packet:
    """One forward-link packet of a compiled workload."""
    src_x: int
    src_y: int
    dst_x: int
    dst_y: int
    addr: int
    data: int = 0
    cmp: int = 0
    op: int = OP_STORE
    not_before: int = 0


def program_from_packets(nx: int, ny: int,
                         packets: Iterable[Packet]) -> Dict[str, np.ndarray]:
    """Assemble a packet list into a ``(ny, nx, L)`` injection program.

    Each tile's packets are stably sorted by ``not_before`` (compilers
    emit them in logical order, which breaks ties — so same-cycle packets
    keep their point-to-point program order)."""
    per_tile: Dict[Tuple[int, int], List[Packet]] = {}
    for p in packets:
        per_tile.setdefault((p.src_y, p.src_x), []).append(p)
    L = max([len(v) for v in per_tile.values()] + [1])
    prog = empty_program(nx, ny, L)
    for (y, x), items in per_tile.items():
        items = sorted(items, key=lambda p: p.not_before)
        for i, p in enumerate(items):
            prog["dst_x"][y, x, i] = p.dst_x
            prog["dst_y"][y, x, i] = p.dst_y
            prog["addr"][y, x, i] = p.addr
            prog["data"][y, x, i] = p.data
            prog["cmp"][y, x, i] = p.cmp
            prog["op"][y, x, i] = p.op
            prog["not_before"][y, x, i] = p.not_before
    return prog


@dataclasses.dataclass(frozen=True)
class Workload:
    """A compiled traffic workload, ready for ``Simulator.attach``.

    ``n_steps`` is the workload's own notion of a logical step (ring
    steps for all-reduce, microbatches for a pipeline, one dispatch for
    an all-to-all); ``WorkloadReport.cycles_per_step`` divides the drain
    cycle by it.  ``meta`` carries compiler-specific facts (payload
    sizes, expert loads, bubble fractions, ...), all JSON-ready.
    """

    name: str
    family: str              # "allreduce" | "broadcast" | "moe" | "pipeline" | "pgas"
    nx: int
    ny: int
    program: Dict[str, np.ndarray]
    n_steps: int
    n_packets: int
    placement: Optional[Placement] = None
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        counted = int((np.asarray(self.program["op"]) >= 0).sum())
        if counted != self.n_packets:
            raise ValueError(
                f"workload {self.name!r} claims {self.n_packets} packets "
                f"but its program holds {counted}")
        if self.n_steps < 1:
            raise ValueError(
                f"workload {self.name!r} needs n_steps >= 1, "
                f"got {self.n_steps}")

    @property
    def mesh(self) -> str:
        return f"{self.nx}x{self.ny}"

    def injected_per_tile(self) -> np.ndarray:
        """(ny, nx) packets each tile's program injects."""
        return (np.asarray(self.program["op"]) >= 0).sum(-1)


def merge_workloads(name: str, workloads: Sequence[Workload], *,
                    gap: int = 0) -> Workload:
    """Concatenate workloads in time: each successive workload's
    ``not_before`` schedule starts ``gap`` cycles after the previous
    one's last scheduled injection.  Families may differ (the merged
    family is "mixed" unless they all agree); steps add up."""
    if not workloads:
        raise ValueError("merge_workloads needs at least one workload")
    nx, ny = workloads[0].nx, workloads[0].ny
    if any(w.nx != nx or w.ny != ny for w in workloads):
        raise ValueError("cannot merge workloads compiled for different "
                         "mesh shapes")
    packets: List[Packet] = []
    offset = 0
    for w in workloads:
        op = np.asarray(w.program["op"])
        live = op >= 0
        for y, x, i in zip(*np.nonzero(live)):
            packets.append(Packet(
                src_x=int(x), src_y=int(y),
                dst_x=int(w.program["dst_x"][y, x, i]),
                dst_y=int(w.program["dst_y"][y, x, i]),
                addr=int(w.program["addr"][y, x, i]),
                data=int(w.program["data"][y, x, i]),
                cmp=int(w.program["cmp"][y, x, i]),
                op=int(op[y, x, i]),
                not_before=int(w.program["not_before"][y, x, i]) + offset))
        sched = np.asarray(w.program["not_before"])[live]
        offset += (int(sched.max()) if sched.size else 0) + 1 + gap
    fams = {w.family for w in workloads}
    return Workload(
        name=name, family=fams.pop() if len(fams) == 1 else "mixed",
        nx=nx, ny=ny, program=program_from_packets(nx, ny, packets),
        n_steps=sum(w.n_steps for w in workloads),
        n_packets=sum(w.n_packets for w in workloads),
        meta={"merged": [w.name for w in workloads]})
