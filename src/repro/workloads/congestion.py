"""Fit measured mesh congestion; price collectives with it.

The roofline's analytic collective term divides wire bytes by a nominal
link bandwidth — it knows nothing about contention, hotspots, or the
serialization the cycle-level simulator actually exhibits.
:class:`CongestionModel` closes that loop:

1. run calibration workloads (ring all-reduce, MoE all-to-all, pipeline
   p2p, broadcast) at a few payload sizes — :func:`calibrate` — or reuse
   any set of :class:`~repro.workloads.runner.WorkloadReport`\\ s;
2. fit, per workload family, the affine law

       ``drain_cycles = alpha * wire_words_per_rank + beta``

   by least squares, where ``wire_words_per_rank = injected / k`` is
   precisely the per-device word count crossing ring links (for a ring
   all-reduce each rank injects ``2 (k-1)/k`` of the payload — the same
   ``2 (k-1)/k`` the analytic ring model uses for wire bytes, so the two
   paths price the *same* byte count, one with measured cycles, one with
   nominal bandwidth);
3. convert an HLO collective's wire bytes into seconds:
   ``op_seconds = (alpha * wire_bytes / 4 + beta * count) / clock_hz``
   (the mesh moves one 32-bit word per link per cycle).

``launch/roofline.py``'s ``network="netsim"`` mode takes one of these
models and replaces its analytic collective term with
:meth:`collective_times`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["CongestionModel", "calibrate", "OP_FAMILY", "WORD_BYTES"]

# the mesh data lane is one 32-bit word per packet
WORD_BYTES = 4

# HLO collective op -> calibrated workload family
OP_FAMILY = {
    "all-reduce": "allreduce",
    "all-gather": "allreduce",        # one ring phase; wire bytes already
    "reduce-scatter": "allreduce",    # carry the (g-1)/g factor
    "all-to-all": "moe",
    "ragged-all-to-all": "moe",
    "collective-permute": "pipeline",
    "collective-broadcast": "broadcast",
}

# fallbacks when a family was not calibrated (e.g. a broadcast-free
# calibration run pricing a collective-broadcast)
_FAMILY_FALLBACK = {"broadcast": "allreduce", "pipeline": "allreduce",
                    "moe": "allreduce", "allreduce": "moe"}


@dataclasses.dataclass(frozen=True)
class CongestionModel:
    """Measured cycles-per-wire-word per workload family.

    ``coeffs[family] = (alpha, beta)``: simulated drain cycles of a
    family workload moving ``x`` wire words per rank is ``alpha*x +
    beta``.  ``clock_hz`` converts cycles to seconds (the mesh clock —
    1 GHz unless the caller models a specific fabric).
    """

    mesh: str
    coeffs: Dict[str, Tuple[float, float]]
    clock_hz: float = 1e9
    n_points: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.coeffs:
            raise ValueError("a CongestionModel needs at least one fitted "
                             "family")
        if self.clock_hz <= 0:
            raise ValueError(f"clock_hz must be positive, "
                             f"got {self.clock_hz}")

    # -- fitting --------------------------------------------------------
    @classmethod
    def fit(cls, reports: Iterable, *, mesh: str = "",
            clock_hz: float = 1e9) -> "CongestionModel":
        """Least-squares fit from :class:`WorkloadReport`\\ s (each must
        carry ``meta['k']`` or a placement-sized rank count; ``injected /
        k`` is the per-rank wire-word count).  One report per family fits
        a pure slope (beta = 0)."""
        pts: Dict[str, List[Tuple[float, float]]] = {}
        meshes = set()
        for r in reports:
            k = r.meta.get("k") or r.meta.get("n_experts") \
                or r.meta.get("n_stages")
            if not k:
                # fall back to every tile participating
                nx, ny = (int(v) for v in r.mesh.split("x"))
                k = nx * ny
            x = r.injected / float(k)
            pts.setdefault(r.family, []).append((x, float(r.cycles)))
            meshes.add(r.mesh)
        if not pts:
            raise ValueError("no reports to fit a congestion model from")
        coeffs: Dict[str, Tuple[float, float]] = {}
        npts: Dict[str, int] = {}
        for fam, xy in pts.items():
            xs = np.asarray([p[0] for p in xy], float)
            ys = np.asarray([p[1] for p in xy], float)
            if len(xy) == 1 or np.ptp(xs) == 0:
                alpha = float(ys.mean() / max(xs.mean(), 1e-9))
                beta = 0.0
            else:
                alpha, beta = (float(v) for v in np.polyfit(xs, ys, 1))
                # congestion can only add cycles; a tiny-sample fit can
                # go degenerate — clamp to the physical regime
                if alpha <= 0:
                    alpha = float(ys.mean() / max(xs.mean(), 1e-9))
                    beta = 0.0
                beta = max(beta, 0.0)
            coeffs[fam] = (alpha, beta)
            npts[fam] = len(xy)
        return cls(mesh=mesh or (meshes.pop() if len(meshes) == 1 else
                                 ",".join(sorted(meshes))),
                   coeffs=coeffs, clock_hz=clock_hz, n_points=npts)

    # -- pricing --------------------------------------------------------
    def family_for(self, op: str) -> str:
        fam = OP_FAMILY.get(op, "allreduce")
        while fam not in self.coeffs:
            nxt = _FAMILY_FALLBACK.get(fam)
            if nxt is None or nxt == fam or nxt in (None,):
                fam = next(iter(self.coeffs))
                break
            if nxt not in self.coeffs and \
                    _FAMILY_FALLBACK.get(nxt) == fam:
                fam = next(iter(self.coeffs))
                break
            fam = nxt
        return fam

    def op_cycles(self, op: str, wire_bytes: float,
                  count: float = 1.0) -> float:
        """Simulated cycles to move ``wire_bytes`` per device for ``op``
        (``count`` invocations pay the fitted fixed overhead each)."""
        alpha, beta = self.coeffs[self.family_for(op)]
        return alpha * (wire_bytes / WORD_BYTES) + beta * max(count, 0.0)

    def op_seconds(self, op: str, wire_bytes: float,
                   count: float = 1.0) -> float:
        return self.op_cycles(op, wire_bytes, count) / self.clock_hz

    def collective_times(self, colls: Dict[str, Dict[str, float]]
                         ) -> Dict[str, Dict[str, float]]:
        """Price a parsed-collectives dict (the
        :func:`repro.launch.roofline.parse_collectives` schema — per-op
        ``wire_bytes`` and ``count``); returns per-op ``{'sim_cycles',
        'sim_s', 'family'}``."""
        out: Dict[str, Dict[str, float]] = {}
        for op, d in colls.items():
            wb = float(d.get("wire_bytes", d.get("bytes", 0.0)))
            n = float(d.get("count", 1))
            cyc = self.op_cycles(op, wb, n)
            out[op] = {"sim_cycles": cyc, "sim_s": cyc / self.clock_hz,
                       "family": self.family_for(op)}
        return out

    # -- persistence ----------------------------------------------------
    def to_json(self) -> dict:
        return {"mesh": self.mesh, "clock_hz": self.clock_hz,
                "coeffs": {k: list(v) for k, v in self.coeffs.items()},
                "n_points": dict(self.n_points)}

    @classmethod
    def from_json(cls, d: dict) -> "CongestionModel":
        return cls(mesh=d["mesh"], clock_hz=float(d["clock_hz"]),
                   coeffs={k: (float(a), float(b))
                           for k, (a, b) in d["coeffs"].items()},
                   n_points={k: int(v)
                             for k, v in d.get("n_points", {}).items()})


def calibrate(nx: int, ny: int, *, backend: str = "numpy",
              payload_words: Sequence[int] = (32, 96),
              tokens_per_tile: Sequence[int] = (2, 6),
              clock_hz: float = 1e9, seed: int = 0,
              cfg=None) -> CongestionModel:
    """Run the calibration battery on an ``nx x ny`` mesh and fit.

    Two payload sizes per family (all-reduce, broadcast, MoE all-to-all,
    pipeline) — enough for the affine fit — on the requested backend.
    Returns the fitted :class:`CongestionModel` (its reports are not
    kept; use :meth:`CongestionModel.fit` directly to keep them).
    """
    from .collectives import parameter_broadcast, ring_all_reduce
    from .moe import moe_all_to_all
    from .pipeline import pipeline_p2p
    from .runner import run_workload

    reports = []
    for w in payload_words:
        reports.append(run_workload(
            ring_all_reduce(nx, ny, int(w)), cfg, backend=backend))
        reports.append(run_workload(
            parameter_broadcast(nx, ny, int(w)), cfg, backend=backend))
        reports.append(run_workload(
            pipeline_p2p(nx, ny, n_micro=4,
                         act_words=max(int(w) // 4, 1)),
            cfg, backend=backend))
    for t in tokens_per_tile:
        reports.append(run_workload(
            moe_all_to_all(nx, ny, int(t), seed=seed), cfg,
            backend=backend))
    return CongestionModel.fit(reports, mesh=f"{nx}x{ny}",
                               clock_hz=clock_hz)
