"""Gradient-sync collectives lowered to mesh traffic.

These compilers model the communication of ``repro/parallel/sharding.py``:
the data-parallel gradient all-reduce (rows of the device grid — the
mesh's Y dimension — reduce gradients every step) and the parameter
broadcast that re-distributes updated weights (ZeRO-1: each shard's owner
broadcasts its slice).  Both are lowered as *ring* schedules over a snake
placement (consecutive ranks are mesh neighbors, see
:mod:`repro.workloads.placement`), which is both the classic bandwidth-
optimal algorithm and the layout Celerity-style arrays actually use.

Ring all-reduce (k ranks, payload of ``words`` per rank):

* the payload splits into k chunks of ``ceil(words / k)`` words;
* **reduce-scatter** — k-1 steps, each rank sends one chunk to its ring
  successor (chunk ``(r - s) mod k`` at step ``s``);
* **all-gather** — k-1 more steps forwarding the reduced chunks around.

Every rank therefore injects exactly ``2 (k-1) * chunk`` packets — i.e.
``(k-1)/k`` of the (chunk-padded) payload crosses every ring hop in each
of the two phases, which is the conservation law the tests pin down and
the same ``2 (k-1)/k`` factor the roofline's analytic ring model uses
(:func:`repro.launch.roofline.parse_collectives` wire bytes).

The schedule is the serialization bound: step ``s`` injects at
``not_before = start + s * chunk`` (one word per cycle per rank).  The
simulator's backpressure then reveals the *congestion* on top — measured
``cycles_per_step >= chunk`` — which is exactly the signal
:class:`repro.workloads.CongestionModel` fits.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core.netsim import OP_STORE

from .base import Packet, Workload, program_from_packets
from .placement import Placement

__all__ = ["ring_all_reduce", "parameter_broadcast"]


def ring_all_reduce(nx: int, ny: int, words: int, *,
                    k: Optional[int] = None,
                    placement: Optional[Placement] = None,
                    op: int = OP_STORE, mem_words: int = 64,
                    start: int = 0) -> Workload:
    """Compile a k-rank ring all-reduce of ``words`` words per rank.

    ``placement`` defaults to the snake ring over the first ``k`` tiles
    (all tiles when ``k`` is None).  ``n_steps = 2 (k-1)`` ring steps.
    """
    pl = placement if placement is not None else Placement.ring(nx, ny, k)
    k = pl.k
    if k < 2:
        raise ValueError(f"ring all-reduce needs k >= 2 ranks, got k={k}")
    if words < 1:
        raise ValueError(f"payload must be at least one word, got {words}")
    chunk = math.ceil(words / k)
    packets = []
    for s in range(2 * (k - 1)):
        phase_step = s if s < k - 1 else s - (k - 1)
        for r in range(k):
            sx, sy = pl.tile(r)
            dx, dy = pl.tile((r + 1) % k)
            # reduce-scatter forwards chunk (r - s), all-gather re-forwards
            # the chunk reduced at rank r+1, i.e. (r + 1 - phase_step)
            cid = (r - phase_step) % k if s < k - 1 \
                else (r + 1 - phase_step) % k
            for w in range(chunk):
                packets.append(Packet(
                    src_x=sx, src_y=sy, dst_x=dx, dst_y=dy,
                    addr=(cid * chunk + w) % mem_words,
                    data=cid, op=op,
                    not_before=start + s * chunk))
    return Workload(
        name=f"allreduce_ring_k{k}_w{words}", family="allreduce",
        nx=nx, ny=ny, program=program_from_packets(nx, ny, packets),
        n_steps=2 * (k - 1), n_packets=2 * (k - 1) * chunk * k,
        placement=pl,
        meta={"k": k, "words": words, "chunk": chunk,
              "per_rank_injected": 2 * (k - 1) * chunk,
              "per_hop_words_per_phase": (k - 1) * chunk,
              "source": "parallel/sharding.py gradient all-reduce "
                        "(DP rows; ZeRO-1 zero1 axis)"})


def parameter_broadcast(nx: int, ny: int, words: int, *,
                        k: Optional[int] = None,
                        placement: Optional[Placement] = None,
                        op: int = OP_STORE, mem_words: int = 64,
                        start: int = 0) -> Workload:
    """Compile a ring-pipelined broadcast of ``words`` words from rank 0.

    Rank ``r`` (0..k-2) forwards the stream to rank ``r+1``; word ``w``
    leaves rank ``r`` at ``not_before = start + r + w`` — the broadcast
    wave one hop behind per rank, so the whole mesh carries the stream
    concurrently (the updated-parameter fan-out of ZeRO-1's shard owners
    in ``parallel/sharding.py``).
    """
    pl = placement if placement is not None else Placement.ring(nx, ny, k)
    k = pl.k
    if k < 2:
        raise ValueError(f"broadcast needs k >= 2 ranks, got k={k}")
    if words < 1:
        raise ValueError(f"payload must be at least one word, got {words}")
    packets = []
    for r in range(k - 1):
        sx, sy = pl.tile(r)
        dx, dy = pl.tile(r + 1)
        for w in range(words):
            packets.append(Packet(
                src_x=sx, src_y=sy, dst_x=dx, dst_y=dy,
                addr=w % mem_words, data=w, op=op,
                not_before=start + r + w))
    return Workload(
        name=f"param_broadcast_k{k}_w{words}", family="broadcast",
        nx=nx, ny=ny, program=program_from_packets(nx, ny, packets),
        n_steps=1, n_packets=(k - 1) * words, placement=pl,
        meta={"k": k, "words": words,
              "per_rank_injected": words,
              "source": "parallel/sharding.py ZeRO-1 parameter broadcast"})
