"""PGAS scatter/gather lowered to mesh traffic.

``repro/core/pgas.py`` expresses remote memory traffic as a
:class:`~repro.core.pgas.PacketBatch` — a destination-major ``(T, S)``
buffer per source tile (the paper's N^2 FIFO-provisioning rule as a
static shape), delivered by one SPMD ``xy_all_to_all``.  This compiler
takes the *global* view of those batches — ``(T_src, T_dst, S)`` arrays
of addr / data / mask — and lowers every valid slot into an individual
remote load/store packet, so the cycle-level simulator prices the exact
same scatter the SPMD collective executes in one shot.

Injection order is destination-major then slot order, matching the
batch's commit semantics: packets from one source to one destination
stay in slot order (the mesh preserves point-to-point ordering), while
cross-source interleavings are up to the routers — exactly the paper's
*Transaction ordering* rules that :func:`repro.core.pgas.remote_store`
reproduces on the SPMD side.

:func:`expected_memory` computes the post-scatter memory image (for
store batches with collision-free addresses), which
``examples/pgas_scatter_gather.py`` asserts against both the SPMD result
and the simulator's ``mem``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.netsim import OP_LOAD, OP_STORE

from .base import Packet, Workload, program_from_packets
from .placement import Placement

__all__ = ["pgas_from_batches", "pgas_scatter", "expected_memory"]


def _check_batches(addr, data, mask, k):
    addr = np.asarray(addr, np.int64)
    data = np.asarray(data, np.int64)
    mask = np.asarray(mask, bool)
    if not (addr.shape == data.shape == mask.shape) or addr.ndim != 3:
        raise ValueError(
            f"batch arrays must share one (T_src, T_dst, S) shape, got "
            f"addr {addr.shape}, data {data.shape}, mask {mask.shape}")
    if addr.shape[0] != k or addr.shape[1] != k:
        raise ValueError(
            f"batch arrays are {addr.shape[0]}x{addr.shape[1]} tiles but "
            f"the placement has {k} ranks")
    return addr, data, mask


def pgas_from_batches(addr, data, mask, nx: int, ny: int, *,
                      op: int = OP_STORE,
                      placement: Optional[Placement] = None,
                      rate: float = 1.0, mem_words: int = 64,
                      start: int = 0,
                      name: Optional[str] = None) -> Workload:
    """Compile global packet-batch arrays — ``(T_src, T_dst, S)``, one
    row of :class:`~repro.core.pgas.PacketBatch` fields per source tile —
    into a mesh workload.  ``data`` must be integral (the mesh data lane
    is an int32 word; scale floats before compiling)."""
    if not 0.0 < rate <= 1.0:
        raise ValueError(f"injection rate must be in (0, 1], got {rate}")
    pl = placement if placement is not None else Placement.grid(nx, ny)
    addr, data, mask = _check_batches(addr, data, mask, pl.k)
    if (addr[mask] < 0).any() or (addr[mask] >= mem_words).any():
        raise ValueError(
            f"batch addresses must lie in [0, mem_words={mem_words})")
    T, _, S = addr.shape
    packets = []
    for t in range(T):
        sx, sy = pl.tile(t)
        i = 0
        for d in range(T):
            dx, dy = pl.tile(d)
            for s in range(S):
                if not mask[t, d, s]:
                    continue
                packets.append(Packet(
                    src_x=sx, src_y=sy, dst_x=dx, dst_y=dy,
                    addr=int(addr[t, d, s]), data=int(data[t, d, s]),
                    op=op, not_before=start + int(i / rate)))
                i += 1
    opname = {OP_STORE: "scatter", OP_LOAD: "gather"}.get(op, f"op{op}")
    return Workload(
        name=name or f"pgas_{opname}_t{T}_s{S}",
        family="pgas", nx=nx, ny=ny,
        program=program_from_packets(nx, ny, packets),
        n_steps=1, n_packets=int(mask.sum()), placement=pl,
        meta={"slots": S, "op": opname,
              "valid_slots": int(mask.sum()),
              "source": "core/pgas.py PacketBatch "
                        "(remote_store / remote_load)"})


def pgas_scatter(nx: int, ny: int, slots: int, *, seed: int = 0,
                 mem_words: int = 64,
                 placement: Optional[Placement] = None,
                 start: int = 0) -> Workload:
    """A random scatter in the shape of the PGAS example: each tile
    stores ``slots`` words to ``slots`` distinct successor tiles (slot
    ``s`` goes to rank ``me + s + 1`` at address ``s``), data tagged
    ``me * slots + s`` — "the architecture is very good at random
    scatter"."""
    pl = placement if placement is not None else Placement.grid(nx, ny)
    T = pl.k
    if not 1 <= slots < T:
        raise ValueError(
            f"need 1 <= slots < num_tiles={T} for distinct destinations, "
            f"got slots={slots}")
    if slots > mem_words:
        raise ValueError(f"slots={slots} addresses do not fit "
                         f"mem_words={mem_words}")
    addr = np.zeros((T, T, slots), np.int64)
    data = np.zeros((T, T, slots), np.int64)
    mask = np.zeros((T, T, slots), bool)
    for t in range(T):
        for s in range(slots):
            d = (t + s + 1) % T
            addr[t, d, s] = s
            data[t, d, s] = t * slots + s
            mask[t, d, s] = True
    return pgas_from_batches(addr, data, mask, nx, ny, op=OP_STORE,
                             placement=pl, mem_words=mem_words, start=start,
                             name=f"pgas_scatter_t{T}_s{slots}")


def expected_memory(addr, data, mask, nx: int, ny: int, *,
                    mem_words: int = 64,
                    placement: Optional[Placement] = None) -> np.ndarray:
    """The (ny, nx, mem_words) memory image after committing a *store*
    batch, slot-major across sources (the deterministic commit order of
    :func:`repro.core.pgas.remote_store`).  Collisions — two sources
    writing one (tile, addr) in the same slot — are committed in source
    order here but are unordered on both the SPMD and the cycle-level
    paths, so callers wanting an exact three-way match should compile
    collision-free batches."""
    pl = placement if placement is not None else Placement.grid(nx, ny)
    addr, data, mask = _check_batches(addr, data, mask, pl.k)
    T, _, S = addr.shape
    mem = np.zeros((ny, nx, mem_words), np.int64)
    for s in range(S):
        for t in range(T):
            for d in range(T):
                if mask[t, d, s]:
                    dx, dy = pl.tile(d)
                    mem[dy, dx, addr[t, d, s] % mem_words] = data[t, d, s]
    return mem
