"""Workload traffic compiler: the model stack's communication patterns,
lowered to mesh injection programs.

The repo's model stack (``repro.parallel``, ``repro.models.moe``,
``repro.core.pgas``) *describes* communication — ring all-reduces over
sharded parameters, MoE token all-to-alls, pipeline activation hand-offs,
PGAS scatter/gathers — but until now the cycle-level mesh only ever saw
synthetic patterns.  This package compiles those real patterns into the
injection-program schema every backend consumes:

* :mod:`placement` — rank → tile maps (:class:`Placement`, snake rings);
* :mod:`collectives` — :func:`ring_all_reduce`, :func:`parameter_broadcast`
  (the traffic ``parallel/sharding.py`` implies);
* :mod:`moe` — :func:`moe_all_to_all` with a tunable hot-expert skew
  (the traffic ``models/moe.py`` implies);
* :mod:`pipeline` — :func:`pipeline_p2p` microbatch schedules (the
  traffic ``parallel/pipeline.py`` implies);
* :mod:`pgas` — :func:`pgas_from_batches` lowering
  :class:`repro.core.pgas.PacketBatch`-shaped arrays to programs, plus
  :func:`expected_memory` for end-state checks;
* :mod:`runner` — :func:`run_workload` through the
  :class:`repro.mesh.Simulator` facade on either backend (or ``"both"``
  with a bit-identical parity assert), producing
  :class:`WorkloadReport`\\ s;
* :mod:`congestion` — :class:`CongestionModel` fit from reports, feeding
  measured cycles back into ``launch/roofline.py``'s ``network="netsim"``
  mode.
"""
from .base import Packet, Workload, merge_workloads, program_from_packets
from .collectives import parameter_broadcast, ring_all_reduce
from .congestion import OP_FAMILY, WORD_BYTES, CongestionModel, calibrate
from .moe import expert_capacity, moe_all_to_all
from .pgas import expected_memory, pgas_from_batches, pgas_scatter
from .pipeline import pipeline_p2p
from .placement import Placement, row_major_order, snake_order
from .runner import WorkloadReport, default_workload_config, run_workload

__all__ = [
    "Packet", "Workload", "merge_workloads", "program_from_packets",
    "ring_all_reduce", "parameter_broadcast",
    "moe_all_to_all", "expert_capacity",
    "pipeline_p2p",
    "pgas_from_batches", "pgas_scatter", "expected_memory",
    "Placement", "snake_order", "row_major_order",
    "WorkloadReport", "run_workload", "default_workload_config",
    "CongestionModel", "calibrate", "OP_FAMILY", "WORD_BYTES",
]
