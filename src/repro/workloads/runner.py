"""Run compiled workloads through the mesh and report what happened.

The runner is a thin loop over the PR-4 :class:`repro.mesh.Simulator`
facade: attach the workload's injection program, run to the global drain
fence, and normalize the telemetry into a :class:`WorkloadReport` — the
JSON-ready record benchmarks persist and the
:class:`repro.workloads.CongestionModel` fits.

``backend="both"`` runs the numpy oracle *and* the JAX path and asserts
their telemetry bit-identical (the same cross-backend contract every
other traffic source honors), so a workload report doubles as a
differential test of the facade attach path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.mesh import MeshConfig, Simulator, Telemetry

from .base import Workload

__all__ = ["WorkloadReport", "run_workload", "default_workload_config"]


def default_workload_config(nx: int, ny: int) -> MeshConfig:
    """Mesh configuration for workload runs: the same deep-buffer setup
    the load–latency sweeps use (flow control, not storage, should be the
    limit), see :func:`repro.netsim_jax.measure.sweep_config`."""
    return MeshConfig(nx=nx, ny=ny, max_out_credits=128, router_fifo=16)


@dataclasses.dataclass(frozen=True)
class WorkloadReport:
    """What one workload did to the mesh (all fields JSON-ready)."""

    name: str
    family: str
    mesh: str
    backend: str                     # "numpy" | "jax" | "both" (parity-checked)
    cycles: int                      # drain cycle of the run
    n_steps: int
    cycles_per_step: float
    injected: int                    # packets injected (== workload size)
    delivered: int                   # responses completed (== injected at drain)
    accepted_throughput: float       # pkts/cycle/tile over the whole run
    mean_latency: float              # mean round-trip cycles
    peak_link_util: float            # busiest fwd mesh channel (W/E/N/S)
    hotspots: List[Tuple[float, int, int, str]]   # (util, x, y, port)
    link_heatmap: List               # (ny, nx, 5) fwd utilization, rounded
    meta: Dict[str, object]

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        hot = self.hotspots[0] if self.hotspots else (0.0, -1, -1, "?")
        return (f"{self.name:<28s} {self.mesh:<7s} "
                f"{self.cycles:6d} cyc  {self.cycles_per_step:8.1f} cyc/step  "
                f"acc {self.accepted_throughput:6.3f} pkt/cyc/tile  "
                f"lat {self.mean_latency:6.1f}  "
                f"hot ({hot[1]},{hot[2]}){hot[3]} {hot[0]:.3f}")


def _report(w: Workload, t: Telemetry, backend: str,
            drain_cycle: int) -> WorkloadReport:
    delivered = int(t.completed.sum())
    if delivered != w.n_packets:
        raise AssertionError(
            f"workload {w.name!r} leaked packets: injected {w.n_packets} "
            f"!= delivered {delivered} after the drain fence")
    ntiles = w.nx * w.ny
    # peak over the actual mesh channels (ports W/E/N/S; P is ejection)
    hm = t.link_heatmap("fwd")
    return WorkloadReport(
        name=w.name, family=w.family, mesh=w.mesh, backend=backend,
        cycles=int(drain_cycle), n_steps=w.n_steps,
        cycles_per_step=round(drain_cycle / w.n_steps, 2),
        injected=w.n_packets, delivered=delivered,
        accepted_throughput=round(delivered / max(drain_cycle, 1) / ntiles,
                                  4),
        mean_latency=round(t.mean_latency(), 2),
        peak_link_util=round(float(hm[..., 1:].max()), 4),
        hotspots=[(round(u, 4), x, y, p)
                  for (u, x, y, p) in t.hotspots("fwd", top=5)],
        link_heatmap=np.round(hm, 4).tolist(),
        meta=dict(w.meta))


def run_workload(w: Workload, cfg: Optional[MeshConfig] = None, *,
                 backend: str = "numpy", seed: int = 0,
                 max_cycles: int = 200_000) -> WorkloadReport:
    """Attach ``w`` to a fresh mesh, run to the drain fence, and report.

    ``backend="both"`` runs numpy and jax and asserts bit-identical
    telemetry (and equal drain cycles) before reporting.
    """
    cfg = default_workload_config(w.nx, w.ny) if cfg is None \
        else MeshConfig.coerce(cfg)
    if (cfg.nx, cfg.ny) != (w.nx, w.ny):
        raise ValueError(
            f"workload {w.name!r} was compiled for a {w.mesh} mesh but the "
            f"config describes {cfg.nx}x{cfg.ny}")
    if backend == "both":
        runs = {}
        for b in ("numpy", "jax"):
            sim = Simulator(cfg, backend=b, seed=seed)
            sim.attach({k: v.copy() for k, v in w.program.items()})
            runs[b] = (sim.run_until_drained(max_cycles), sim.telemetry())
        ca, ta = runs["numpy"]
        cb, tb = runs["jax"]
        assert ca == cb, (f"workload {w.name!r}: drain cycle diverged "
                          f"between backends: numpy {ca} != jax {cb}")
        ta.assert_bit_identical(tb)
        return _report(w, ta, "both", ca)
    sim = Simulator(cfg, backend=backend, seed=seed)
    sim.attach({k: v.copy() for k, v in w.program.items()})
    n = sim.run_until_drained(max_cycles)
    return _report(w, sim.telemetry(), backend, n)
