"""Mapping logical workload ranks onto mesh tiles.

A workload is written against *logical ranks* (ring position, pipeline
stage, expert id, PGAS tile id); a :class:`Placement` pins each rank to a
physical ``(x, y)`` tile of the mesh.  Two canonical embeddings:

* :meth:`Placement.ring` — boustrophedon ("snake") order, so consecutive
  ranks are mesh neighbors and a logical ring hop is one physical mesh
  hop everywhere except the single wrap-around link.  This is the natural
  embedding for ring all-reduce and pipeline chains (the same embedding
  Celerity used to lay collective chains over its 16x31 array).
* :meth:`Placement.grid` — row-major order, the paper's ``y * nx + x``
  tile id (:func:`repro.core.pgas.tile_linear_index`), used for PGAS and
  expert homes.

Placements are plain numpy and validate themselves: every rank must land
on a distinct tile inside the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["Placement", "snake_order", "row_major_order"]


def row_major_order(nx: int, ny: int) -> np.ndarray:
    """(nx*ny, 2) array of (x, y), rank r at tile (r % nx, r // nx)."""
    r = np.arange(nx * ny)
    return np.stack([r % nx, r // nx], axis=1)


def snake_order(nx: int, ny: int) -> np.ndarray:
    """(nx*ny, 2) array of (x, y) in boustrophedon order: row 0 left to
    right, row 1 right to left, ... — consecutive ranks are always mesh
    neighbors (Manhattan distance 1)."""
    coords = []
    for y in range(ny):
        xs = range(nx) if y % 2 == 0 else range(nx - 1, -1, -1)
        coords.extend((x, y) for x in xs)
    return np.asarray(coords, np.int64)


@dataclasses.dataclass(frozen=True)
class Placement:
    """``coords[r] = (x, y)`` — the tile of logical rank ``r``."""

    nx: int
    ny: int
    coords: np.ndarray

    def __post_init__(self):
        c = np.asarray(self.coords, np.int64).reshape(-1, 2)
        object.__setattr__(self, "coords", c)
        if len(c) == 0:
            raise ValueError("a placement needs at least one rank")
        if (c[:, 0] < 0).any() or (c[:, 0] >= self.nx).any() or \
                (c[:, 1] < 0).any() or (c[:, 1] >= self.ny).any():
            raise ValueError(
                f"placement has ranks outside the {self.nx}x{self.ny} mesh")
        if len({(int(x), int(y)) for x, y in c}) != len(c):
            raise ValueError("placement maps two ranks to the same tile")

    # -- constructors ---------------------------------------------------
    @classmethod
    def ring(cls, nx: int, ny: int, k: Optional[int] = None) -> "Placement":
        """First ``k`` ranks of the snake order (default: all tiles)."""
        order = snake_order(nx, ny)
        k = len(order) if k is None else int(k)
        if not 1 <= k <= len(order):
            raise ValueError(
                f"ring size k={k} does not fit a {nx}x{ny} mesh "
                f"({len(order)} tiles)")
        return cls(nx, ny, order[:k])

    @classmethod
    def grid(cls, nx: int, ny: int, k: Optional[int] = None) -> "Placement":
        """First ``k`` ranks of the row-major order (default: all tiles)."""
        order = row_major_order(nx, ny)
        k = len(order) if k is None else int(k)
        if not 1 <= k <= len(order):
            raise ValueError(
                f"k={k} ranks do not fit a {nx}x{ny} mesh "
                f"({len(order)} tiles)")
        return cls(nx, ny, order[:k])

    # -- queries --------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.coords)

    def tile(self, rank: int) -> Tuple[int, int]:
        x, y = self.coords[rank % self.k]
        return int(x), int(y)

    def ring_hop_length(self, rank: int, topology=None) -> int:
        """Routed distance of the ring link rank -> rank+1 (mod k):
        Manhattan on the mesh, ring distance on wrapped dimensions when a
        :class:`repro.mesh.topology.Topology` is given (on a torus the
        snake ring's long wrap-around link collapses to the wraparound
        hop)."""
        x0, y0 = self.tile(rank)
        x1, y1 = self.tile((rank + 1) % self.k)
        if topology is None:
            return abs(x1 - x0) + abs(y1 - y0)
        return int(topology.hops(x0, y0, x1, y1, self.nx, self.ny))
