"""PGAS remote memory operations over a device mesh (paper C1).

Each tile (device) owns a local ``memory region``; tiles issue
``remote_store`` / ``remote_load`` / ``remote_cas`` packets addressed by
``<X, Y, local>``.  On the SPMD side a "packet batch" is a dense,
destination-major buffer: every source tile provisions ``slots`` packet
slots toward every destination tile — exactly the paper's FIFO-provisioning
rule ("N^2 words of FIFO buffering per outstanding transaction") made
explicit as a static shape.  Delivery is a dimension-ordered all-to-all
(:func:`repro.core.routing.xy_all_to_all`), i.e. the X-then-Y route of the
hardware.

Ordering semantics reproduce the paper's *Transaction ordering* section:

* packets from one source to one destination commit in slot order
  (point-to-point ordering);
* packets from *different* sources have no ordering guarantee
  (different slots may interleave arbitrarily) — except for ``remote_cas``
  where we arbitrate deterministically in (source id, slot) order, the
  moral equivalent of the router's round-robin arbiter.

All functions run **inside** ``shard_map`` over the (y_axis, x_axis) mesh.
The reply path (credits for stores, data for loads) is the independent
reverse network: a second all-to-all phase that by construction can always
be absorbed (pre-allocated static output buffers — the "sink" property).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size as _axis_size
from repro.compat import pcast as _pcast
from .routing import xy_all_to_all

__all__ = ["PacketBatch", "make_packet_batch", "remote_store", "remote_load",
           "remote_cas", "tile_linear_index"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PacketBatch:
    """Outgoing packets from one tile, destination-major.

    Fields (``T`` = number of tiles, ``S`` = slots per destination):
      addr:  (T, S) int32   local word address at the destination
      data:  (T, S) payload (ignored for loads)
      mask:  (T, S) bool    slot valid ("out_v_li")
    """

    addr: jax.Array
    data: jax.Array
    mask: jax.Array

    @property
    def num_tiles(self) -> int:
        return self.addr.shape[0]

    @property
    def slots(self) -> int:
        return self.addr.shape[1]


def make_packet_batch(num_tiles: int, slots: int,
                      dtype=jnp.float32) -> PacketBatch:
    """An empty (all-invalid) packet batch — the idle endpoint."""
    return PacketBatch(
        addr=jnp.zeros((num_tiles, slots), jnp.int32),
        data=jnp.zeros((num_tiles, slots), dtype),
        mask=jnp.zeros((num_tiles, slots), bool),
    )


def tile_linear_index(x_axis: str, y_axis: str) -> jax.Array:
    """This tile's row-major id ``y * nx + x`` (paper Fig. 1 coordinates)."""
    nx = _axis_size(x_axis)
    return lax.axis_index(y_axis) * nx + lax.axis_index(x_axis)


def _deliver(pkts: PacketBatch, x_axis: str, y_axis: str) -> PacketBatch:
    """Route a packet batch: after this, row ``s`` of each field holds the
    packets *from* source tile ``s`` addressed to this tile."""
    return PacketBatch(
        addr=xy_all_to_all(pkts.addr, x_axis, y_axis, split_axis=0),
        data=xy_all_to_all(pkts.data, x_axis, y_axis, split_axis=0),
        mask=xy_all_to_all(pkts.mask, x_axis, y_axis, split_axis=0),
    )


def remote_store(mem: jax.Array, pkts: PacketBatch, x_axis: str,
                 y_axis: str) -> Tuple[jax.Array, jax.Array]:
    """Issue remote stores; returns ``(new_mem, credits_returned)``.

    ``credits_returned[t]`` counts this tile's stores acknowledged by tile
    ``t`` — the reverse-network credit packets.  Because delivery and commit
    happen inside one SPMD step, the credit is, as the paper requires, a
    *commit* acknowledgement, not a mere arrival receipt.
    """
    inbound = _deliver(pkts, x_axis, y_axis)
    # Commit in slot order => same-source writes are ordered; cross-source
    # writes within a slot land in one scatter (unordered, per the paper).
    for s in range(inbound.slots):
        mem = _masked_scatter(mem, inbound.addr[:, s], inbound.data[:, s],
                              inbound.mask[:, s])
    # Reverse network: one credit per committed packet, returned to sources.
    acks = inbound.mask.sum(axis=1).astype(jnp.int32)          # per-source
    credits = xy_all_to_all(acks[:, None], x_axis, y_axis, split_axis=0)
    return mem, credits[:, 0]


def remote_load(mem: jax.Array, pkts: PacketBatch, x_axis: str,
                y_axis: str) -> Tuple[jax.Array, jax.Array]:
    """Issue remote loads; returns ``(data, valid)`` both shaped (T, S),
    row ``t`` holding the responses from destination tile ``t`` in slot
    (request) order — the ``returned_data_r_o`` port of the endpoint.
    """
    inbound = _deliver(pkts, x_axis, y_axis)
    addr = jnp.clip(inbound.addr, 0, mem.shape[0] - 1)
    loaded = jnp.where(inbound.mask, mem[addr], jnp.zeros((), mem.dtype))
    # Reverse network: responses travel back along the independent path.
    data = xy_all_to_all(loaded, x_axis, y_axis, split_axis=0)
    valid = xy_all_to_all(inbound.mask, x_axis, y_axis, split_axis=0)
    return data, valid


def remote_cas(mem: jax.Array, pkts: PacketBatch, compare: jax.Array,
               x_axis: str, y_axis: str) -> Tuple[jax.Array, jax.Array]:
    """Remote compare-and-swap (``ePacketOp_remote_swap_*``).

    ``pkts.data`` carries the swap value, ``compare`` (T, S) the expected
    value.  Returns ``(new_mem, old_values)`` where ``old_values[t, s]`` is
    what the CAS at destination ``t`` slot ``s`` observed (the mutex
    winner sees the unlocked value).  Arbitration is deterministic in
    (source, slot) order — the round-robin arbiter's role.
    """
    inbound = _deliver(pkts, x_axis, y_axis)
    cmp_in = xy_all_to_all(compare, x_axis, y_axis, split_axis=0)

    T, S = inbound.addr.shape
    flat_addr = inbound.addr.reshape(-1)
    flat_data = inbound.data.reshape(-1)
    flat_cmp = cmp_in.reshape(-1)
    flat_mask = inbound.mask.reshape(-1)

    def body(i, carry):
        m, old = carry
        a = jnp.clip(flat_addr[i], 0, m.shape[0] - 1)
        cur = m[a]
        hit = flat_mask[i] & (cur == flat_cmp[i])
        m = m.at[a].set(jnp.where(hit, flat_data[i], cur))
        old = old.at[i].set(jnp.where(flat_mask[i], cur, jnp.zeros((), m.dtype)))
        return m, old

    old0 = _pcast(jnp.zeros(T * S, mem.dtype), (x_axis, y_axis), to="varying")
    mem, old = lax.fori_loop(0, T * S, body, (mem, old0))
    old = xy_all_to_all(old.reshape(T, S), x_axis, y_axis, split_axis=0)
    return mem, old


def _masked_scatter(mem: jax.Array, addr: jax.Array, data: jax.Array,
                    mask: jax.Array) -> jax.Array:
    """Scatter ``data`` into ``mem`` at ``addr`` where ``mask``; invalid
    slots are routed to a sacrificial out-of-range index and dropped."""
    sink = jnp.asarray(mem.shape[0], jnp.int32)
    idx = jnp.where(mask, jnp.clip(addr, 0, mem.shape[0] - 1), sink)
    return mem.at[idx].set(data.astype(mem.dtype), mode="drop")
