"""The standard endpoint (paper C5) — plug-and-play mesh integration.

``bsg_manycore_endpoint_standard`` hides the network's flow-control rules
behind a master/slave interface so that "the core can see the network as a
general master/slave module".  :class:`Endpoint` plays the same role for a
JAX compute function: it owns the credit counter, builds destination-major
packet batches, runs the PGAS delivery, and enforces the two protocol rules
that the paper says designers get wrong:

1. incoming requests are absorbed at line rate (the slave handler is a pure
   function applied to the whole inbound batch — it cannot block);
2. the reverse path is a sink (responses land in pre-allocated buffers).

It also implements the endpoint's special config registers (freeze /
arbiter-priority) as fields of the state, which the launcher uses for
start/stop semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import credits as cr
from . import pgas

__all__ = ["EndpointState", "make_endpoint", "master_store", "master_load",
           "fence", "freeze", "unfreeze", "CFG_FREEZE_ADDR", "CFG_ARB_ADDR"]

# Paper: "Special Local Address Map" — MSB set selects the config region.
CFG_FREEZE_ADDR = 0x0
CFG_ARB_ADDR = 0x4


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EndpointState:
    """Per-tile endpoint state (a pytree; lives inside shard_map)."""

    mem: jax.Array              # local memory region
    credits: cr.CreditCounter   # out_credits_o
    frozen: jax.Array           # freeze_r_o (freeze_init_p semantics)
    arb_priority: jax.Array     # reverse_arb_pr_o toggle

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def make_endpoint(mem_words: int, max_out_credits: int,
                  dtype=jnp.float32, freeze_init: bool = False) -> EndpointState:
    return EndpointState(
        mem=jnp.zeros((mem_words,), dtype),
        credits=cr.make_credits(max_out_credits),
        frozen=jnp.asarray(freeze_init, bool),
        arb_priority=jnp.asarray(False, bool),
    )


def master_store(state: EndpointState, pkts: pgas.PacketBatch,
                 x_axis: str, y_axis: str) -> Tuple[EndpointState, jax.Array]:
    """Issue a batch of remote stores, respecting credit flow control.

    Packets beyond the available credit are masked off (the core "should
    avoid sending when out of credit"); returns the per-destination count of
    packets actually sent, so callers can retry the remainder.
    """
    want = pkts.mask.sum().astype(jnp.int32)
    counter, granted = cr.issue(state.credits, want)
    # Grant in (dest, slot) order: a prefix of the flattened valid packets.
    order = jnp.cumsum(pkts.mask.reshape(-1).astype(jnp.int32))
    grant_mask = (order <= granted).reshape(pkts.mask.shape) & pkts.mask
    sendable = dataclasses.replace(pkts, mask=grant_mask & ~state.frozen)

    mem, credits_back = pgas.remote_store(state.mem, sendable, x_axis, y_axis)
    counter = cr.ack(counter, credits_back.sum())
    sent = sendable.mask.sum(axis=1).astype(jnp.int32)
    return state.replace(mem=mem, credits=counter), sent


def master_load(state: EndpointState, pkts: pgas.PacketBatch,
                x_axis: str, y_axis: str
                ) -> Tuple[EndpointState, jax.Array, jax.Array]:
    """Issue remote loads; returns ``(state, data, valid)``.

    The response path has NO handshake ("the core must accept the data") —
    ``data`` is a dense pre-allocated buffer, the sink property.
    """
    data, valid = pgas.remote_load(state.mem, pkts, x_axis, y_axis)
    return state, data, valid


def fence(state: EndpointState) -> jax.Array:
    """Transaction fence: true iff all outstanding stores have committed
    (credit counter back at ``max_out_credits_p``)."""
    return cr.fence_ok(state.credits)


def freeze(state: EndpointState) -> EndpointState:
    """Config-register write: Freeze Register := 1 (stop the tile)."""
    return state.replace(frozen=jnp.asarray(True, bool))


def unfreeze(state: EndpointState) -> EndpointState:
    """Config-register write: Freeze Register := 0 (start the tile)."""
    return state.replace(frozen=jnp.asarray(False, bool))
