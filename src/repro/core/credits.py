"""Credit-based flow control (paper C3).

The standard endpoint tracks outstanding transactions with a credit counter
initialized to ``max_out_credits_p``; a *fence* waits for the counter to
return to its initial value, which proves every prior store has **committed**
at its destination.

The same discipline governs three framework subsystems:

* the data pipeline's prefetch depth (``credits = BDP`` of host->device),
* the pipeline-parallel schedule (in-flight microbatches),
* async checkpoint writes (bounded dirty buffers).

:func:`bdp_credits` encodes the paper's sizing rule: *"set the number of
outstanding credits to the uncongested bandwidth-delay product of the longest
round-trip path"* (e.g. 1 word/cycle x 128-cycle RTT = 128 credits; or
20 hops x FIFO depth 4 = 80).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["CreditCounter", "make_credits", "issue", "ack", "fence_ok",
           "bdp_credits"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CreditCounter:
    """JAX-traceable credit counter (``out_credits_o``)."""

    available: jax.Array   # scalar int32 — credits currently available
    max_credits: jax.Array  # scalar int32 — max_out_credits_p

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def make_credits(max_out_credits: int) -> CreditCounter:
    m = jnp.asarray(max_out_credits, jnp.int32)
    return CreditCounter(available=m, max_credits=m)


def issue(c: CreditCounter, n) -> tuple:
    """Try to issue ``n`` transactions; returns ``(counter, granted)``.

    ``granted <= n`` — the endpoint must not send when out of credit
    ("Congestion Control: the core should avoid sending when out of
    credit"), so the grant is clamped, never negative.
    """
    n = jnp.asarray(n, jnp.int32)
    granted = jnp.minimum(n, c.available)
    return c.replace(available=c.available - granted), granted


def ack(c: CreditCounter, n) -> CreditCounter:
    """Return ``n`` credits (reverse-network acknowledgements)."""
    n = jnp.asarray(n, jnp.int32)
    return c.replace(available=jnp.minimum(c.available + n, c.max_credits))


def fence_ok(c: CreditCounter) -> jax.Array:
    """Transaction fence predicate: all outstanding transactions committed
    iff the counter is back to ``max_out_credits_p``."""
    return c.available == c.max_credits


def bdp_credits(round_trip_hops: int, fifo_depth: int = 4,
                issue_rate: float = 1.0) -> int:
    """Paper's sizing rule (Appendix A): ``hops x FIFO depth`` credits,
    i.e. the bandwidth-delay product at ``issue_rate`` words/cycle."""
    return max(1, int(round_trip_hops * fifo_depth * issue_rate))
