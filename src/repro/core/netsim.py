"""Cycle-level simulator of the BaseJump Manycore Accelerator Network.

This is the *faithful reproduction* layer: a vectorized (numpy) model of the
mesh exactly as the paper specifies it —

* 5-port routers (P/W/E/N/S, the ``bsg_noc_pkg`` order) with **input FIFOs
  and no output FIFOs**; every FIFO crossing costs one cycle (paper, Fig. 3);
* **round-robin arbitration** per output port (arbitration delay varies
  between 1 and 5 under contention) with head-of-line blocking;
* **XY dimension-ordered routing** with the reduced crossbar — the N→E and
  N→W turns are structurally forbidden (asserted);
* two independent physical networks: **forward** (requests) and **reverse**
  (responses/credits); the reverse network is a **sink** — delivered reverse
  packets are always absorbed immediately;
* **standard endpoints** with ``max_out_credits_p`` credit counters, an input
  FIFO of ``fifo_els_p``, line-rate servicing of remote load/store/CAS, and a
  registered response port (``returned_data_r_o``);
* the endpoint only services a request when the reverse channel has space,
  so it can always absorb its own response (the paper's masking rule).

Validated claims (see ``tests/test_netsim.py`` and
``benchmarks/bench_netsim.py``):

* unloaded 1-hop round trip = **7 cycles** (the ``mesh_master_example.v``
  log: "cycle 7, returned=00000000"), +2 cycles per extra Manhattan hop;
* bisection bound: 16 links across the median sustain ~32 remote ops/cycle
  on a 512-core array — 1 op per 16 cycles per core;
* store throughput vs credits has its knee at the round-trip BDP
  (credits = RTT × issue rate), and a fence completes exactly when the
  credit counter returns to ``max_out_credits_p``;
* point-to-point transaction ordering holds; the Fig. 5 cross-destination
  reordering is observable.

The simulator is deliberately numpy (not jit'd JAX): it is the *oracle* the
JAX layers are tested against, so clarity and exact cycle semantics win over
device execution. All state updates are start-of-cycle-read /
end-of-cycle-write, which makes each FIFO crossing exactly one cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["NetConfig", "MeshSim", "OP_LOAD", "OP_STORE", "OP_CAS",
           "P", "W", "E", "N", "S", "unloaded_rtt", "LAT_BINS", "NO_MEASURE"]

# bsg_noc_pkg: typedef enum {P=0, W, E, N, S}
P, W, E, N, S = 0, 1, 2, 3, 4
NUM_DIRS = 5

# Telemetry: per-packet round-trip latency histogram resolution.  The last
# bin is an overflow bucket (latency >= LAT_BINS - 1 cycles); both
# simulators share this constant so their histograms stay bit-identical.
LAT_BINS = 512
# Default measurement window = everything (any int32 tag qualifies).
NO_MEASURE = 2**31 - 1

OP_LOAD = 0   # ePacketOp_remote_load
OP_STORE = 1  # ePacketOp_remote_store
OP_CAS = 2    # ePacketOp_remote_swap_aq/_rl pair, modeled as one CAS

_PKT_FIELDS = ("dst_x", "dst_y", "src_x", "src_y", "addr", "data", "cmp",
               "op", "tag")


def unloaded_rtt(hops: int) -> int:
    """Analytic unloaded round-trip latency (cycles) at ``hops`` Manhattan
    distance: inject + hops + deliver-to-endpoint + yumi/service +
    response-inject + hops + deliver + registered output = ``2*hops + 5``.
    For 1 hop this is the paper's 7 cycles."""
    return 2 * hops + 5


@dataclasses.dataclass
class NetConfig:
    nx: int
    ny: int
    router_fifo: int = 4          # input FIFO depth per direction
    ep_fifo: int = 4              # fifo_els_p of the standard endpoint
    max_out_credits: int = 16     # max_out_credits_p
    mem_words: int = 64           # local memory region per tile
    resp_latency: int = 1         # >=1: "response at least one cycle later"
    record_log: bool = False      # keep a full per-response log
    # network topology (repro.mesh.topology.Topology); None -> plain mesh.
    # Typed loosely and imported lazily: repro.mesh imports this module at
    # package-init time, so a module-level topology import would cycle.
    topology: Optional[object] = None

    def __post_init__(self):
        from repro.mesh.topology import Topology
        if self.topology is None:
            self.topology = Topology.mesh()
        self.topology.validate_for(self.nx, self.ny)
        if (self.topology.wrap_x or self.topology.wrap_y) \
                and self.router_fifo < 2:
            raise ValueError(
                "wrapped (ring/torus) topologies need router_fifo >= 2: "
                "the ring bubble flow control reserves one slot for "
                f"entering packets, got router_fifo={self.router_fifo}")


class _Fifos:
    """Struct-of-arrays circular FIFOs, shape (ny, nx, ports, depth)."""

    def __init__(self, ny: int, nx: int, ports: int, depth: int):
        self.depth = depth
        self.f = {k: np.zeros((ny, nx, ports, depth), np.int64)
                  for k in _PKT_FIELDS}
        self.head = np.zeros((ny, nx, ports), np.int64)
        self.count = np.zeros((ny, nx, ports), np.int64)

    def peek(self) -> Dict[str, np.ndarray]:
        """Head packet of every FIFO, shape (ny, nx, ports) per field."""
        idx = (self.head % self.depth)[..., None]
        return {k: np.take_along_axis(v, idx, axis=-1)[..., 0]
                for k, v in self.f.items()}

    def pop_mask(self, mask: np.ndarray) -> None:
        """Dequeue head where ``mask`` (ny, nx, ports)."""
        m = mask.astype(np.int64)
        self.head = (self.head + m) % self.depth
        self.count = self.count - m

    def push_mask(self, mask: np.ndarray, pkt: Dict[str, np.ndarray]) -> None:
        """Enqueue ``pkt`` (fields shaped like mask) where ``mask``; caller
        must have verified space."""
        tail = ((self.head + self.count) % self.depth)
        for k in _PKT_FIELDS:
            v = self.f[k]
            np.put_along_axis(
                v, tail[..., None],
                np.where(mask, pkt[k], np.take_along_axis(
                    v, tail[..., None], axis=-1)[..., 0])[..., None],
                axis=-1)
        self.count = self.count + mask.astype(np.int64)

    def space(self) -> np.ndarray:
        return self.count < self.depth

    def push_one(self, y: int, x: int, port: int,
                 pkt: Dict[str, int]) -> None:
        """Enqueue a single packet at one (tile, port); caller must have
        verified space.  Scalar path for reactive endpoint injection."""
        tail = int((self.head[y, x, port] + self.count[y, x, port])
                   % self.depth)
        for k in _PKT_FIELDS:
            self.f[k][y, x, port, tail] = int(pkt[k])
        self.count[y, x, port] += 1


class MeshSim:
    """The full mesh: forward + reverse networks, endpoints, memories."""

    def __init__(self, cfg: NetConfig, seed: int = 0):
        self.cfg = cfg
        self.topo = cfg.topology
        ny, nx = cfg.ny, cfg.nx
        self.cycle = 0
        self.rng = np.random.default_rng(seed)
        self.fwd = _Fifos(ny, nx, NUM_DIRS, cfg.router_fifo)
        self.rev = _Fifos(ny, nx, NUM_DIRS, cfg.router_fifo)
        self.ep_in = _Fifos(ny, nx, 1, cfg.ep_fifo)      # endpoint request FIFO
        # response delay line: resp_latency slots of (valid + packet)
        L = cfg.resp_latency
        self.resp_valid = np.zeros((L, ny, nx), bool)
        self.resp_pkt = {k: np.zeros((L, ny, nx), np.int64) for k in _PKT_FIELDS}
        self.mem = np.zeros((ny, nx, cfg.mem_words), np.int64)
        self.credits = np.full((ny, nx), cfg.max_out_credits, np.int64)
        self.rr = np.zeros((ny, nx, NUM_DIRS), np.int64)  # fwd round-robin ptrs
        self.rr_rev = np.zeros((ny, nx, NUM_DIRS), np.int64)
        # injection program, appended via load_program()
        self.prog = {k: np.zeros((ny, nx, 0), np.int64) for k in
                     ("dst_x", "dst_y", "addr", "data", "cmp", "op", "not_before")}
        self.prog_len = np.zeros((ny, nx), np.int64)
        self.prog_ptr = np.zeros((ny, nx), np.int64)
        # registered response port (returned_*_r_o): becomes visible +1 cycle
        self.reg_valid = np.zeros((ny, nx), bool)
        self.reg_pkt = {k: np.zeros((ny, nx), np.int64) for k in _PKT_FIELDS}
        # stats
        self.completed = np.zeros((ny, nx), np.int64)
        self.lat_sum = np.zeros((ny, nx), np.int64)
        self.out_of_credit_cycles = np.zeros((ny, nx), np.int64)
        self.completed_per_cycle: List[int] = []
        # telemetry (see the JAX twin in repro.netsim_jax.sim.SimState):
        # packets leaving each router output port per network (P = ejection)
        self.link_util_fwd = np.zeros((ny, nx, NUM_DIRS), np.int64)
        self.link_util_rev = np.zeros((ny, nx, NUM_DIRS), np.int64)
        # input-FIFO occupancy high-water marks, sampled at cycle boundaries
        self.fifo_hwm_fwd = np.zeros((ny, nx, NUM_DIRS), np.int64)
        self.fifo_hwm_rev = np.zeros((ny, nx, NUM_DIRS), np.int64)
        self.ep_hwm = np.zeros((ny, nx), np.int64)
        # per-packet round-trip latency histogram (inject -> registered
        # response), counted only for packets whose injection cycle (tag)
        # falls in [measure_start, measure_stop) — the phased-measurement
        # window; defaults accept every packet
        self.lat_hist = np.zeros(LAT_BINS, np.int64)
        self.measure_start = 0
        self.measure_stop = NO_MEASURE
        self.log: List[Tuple[int, int, int, int, int, int]] = []  # (cycle, sy, sx, op, tag, data)
        # reactive endpoint injectors, (y, x) -> offer(cycle, credits)
        # callable returning a Request-shaped object or None; populated by
        # the repro.mesh.Simulator facade (empty => pure program dynamics)
        self._injectors: Dict[Tuple[int, int], object] = {}
        ys, xs = np.mgrid[0:ny, 0:nx]
        self._xs, self._ys = xs, ys

    # ------------------------------------------------------------------
    # program loading
    # ------------------------------------------------------------------
    def load_program(self, entries: Dict[str, np.ndarray]) -> None:
        """``entries`` fields shaped (ny, nx, L); ``op`` < 0 marks padding.

        ``not_before`` (optional) rate-limits injection to a given cycle.
        """
        ny, nx = self.cfg.ny, self.cfg.nx
        L = entries["op"].shape[-1]
        for k in self.prog:
            if k in entries:
                self.prog[k] = entries[k].astype(np.int64)
            else:
                self.prog[k] = np.zeros((ny, nx, L), np.int64)
        self.prog_len = (entries["op"] >= 0).sum(-1).astype(np.int64)
        self.prog_ptr = np.zeros((ny, nx), np.int64)

    # ------------------------------------------------------------------
    # per-cycle pieces
    # ------------------------------------------------------------------
    def _route(self, heads: Dict[str, np.ndarray]) -> np.ndarray:
        """Dimension-ordered output port for each head packet — the
        pluggable routing decision (:meth:`repro.mesh.topology.Topology.route`,
        shared verbatim with the JAX/Pallas backends)."""
        dx, dy = heads["dst_x"], heads["dst_y"]
        x, y = self._xs[..., None], self._ys[..., None]
        return self.topo.route(dx, dy, x, y, self.cfg.nx, self.cfg.ny, xp=np)

    def _router_step(self, net: _Fifos, rr: np.ndarray,
                     deliver_space: np.ndarray,
                     link_util: Optional[np.ndarray] = None,
                     ) -> Dict[str, np.ndarray]:
        """One cycle of every router in one network.

        ``deliver_space`` (ny, nx) — can the P output deliver this cycle.
        ``link_util`` (ny, nx, 5) — telemetry accumulator, incremented in
        place for every output port that fires (P counts ejections).
        Returns the packets delivered out of the P port (fields + 'valid').
        """
        cfg = self.cfg
        topo = self.topo
        heads = net.peek()
        valid = net.count > 0                       # (ny, nx, 5)
        want = self._route(heads)                   # desired output port

        # Structural turn restriction: N must never request E or W (holds
        # on every topology — routing is X-then-Y and the Y phase never
        # re-enters X).
        assert not (valid[..., N] & ((want[..., N] == E) | (want[..., N] == W))).any(), \
            "illegal N->E/W turn generated"

        # Destination space per output port (start-of-cycle, conservative);
        # wrapped dimensions connect the array edges into rings.
        space = net.space()                         # (ny, nx, 5) input FIFOs
        out_space = np.zeros((cfg.ny, cfg.nx, NUM_DIRS), bool)
        out_space[..., P] = deliver_space
        if topo.wrap_x:
            out_space[..., E] = np.roll(space[..., W], -1, axis=1)
            out_space[..., W] = np.roll(space[..., E], 1, axis=1)
        else:
            out_space[:, :-1, E] = space[:, 1:, W]  # east edge: no space
            out_space[:, 1:, W] = space[:, :-1, E]
        if topo.wrap_y:
            out_space[..., S] = np.roll(space[..., N], -1, axis=0)
            out_space[..., N] = np.roll(space[..., S], 1, axis=0)
        else:
            out_space[:-1, :, S] = space[1:, :, N]
            out_space[1:, :, N] = space[:-1, :, S]

        # Multi-chip boundary links accept one flit every boundary_period
        # cycles — the narrower off-chip channel (both networks share the
        # cycle counter, so both are gated identically).
        if topo.gated and (self.cycle % topo.boundary_period) != 0:
            for c in topo.boundary_cols(cfg.nx):
                out_space[:, c - 1, E] = False
                out_space[:, c, W] = False

        # Ring bubble flow control: a packet ENTERING a wrapped-dimension
        # ring needs TWO free slots in the target FIFO, a packet
        # CONTINUING around it the usual one — every ring keeps a bubble,
        # so dimension-ordered routing stays deadlock-free on rings (see
        # repro.mesh.topology).  bubble[o] is the continuing input port.
        bubble: Dict[int, int] = {}
        out_space2 = None
        if topo.wrap_x or topo.wrap_y:
            space2 = net.count <= net.depth - 2
            out_space2 = np.zeros((cfg.ny, cfg.nx, NUM_DIRS), bool)
            if topo.wrap_x:
                out_space2[..., E] = np.roll(space2[..., W], -1, axis=1)
                out_space2[..., W] = np.roll(space2[..., E], 1, axis=1)
                bubble[E], bubble[W] = W, E
            if topo.wrap_y:
                out_space2[..., S] = np.roll(space2[..., N], -1, axis=0)
                out_space2[..., N] = np.roll(space2[..., S], 1, axis=0)
                bubble[S], bubble[N] = N, S

        # Round-robin arbitration: for each output port o pick the valid
        # requester with minimal (in_port - rr[o]) mod 5.
        winners = np.full((cfg.ny, cfg.nx, NUM_DIRS), -1, np.int64)
        for o in range(NUM_DIRS):
            cand = valid & (want == o) & out_space[..., o:o + 1]
            if o in bubble:
                entering = np.arange(NUM_DIRS) != bubble[o]     # (5,) inputs
                cand = cand & (out_space2[..., o:o + 1] | ~entering)
            prio = (np.arange(NUM_DIRS)[None, None, :] - rr[..., o:o + 1]) % NUM_DIRS
            prio = np.where(cand, prio, NUM_DIRS + 1)
            best = prio.min(-1)
            win = np.where(best <= NUM_DIRS, prio.argmin(-1), -1)
            winners[..., o] = win
            # advance the round-robin pointer past the winner
            rr[..., o] = np.where(win >= 0, (win + 1) % NUM_DIRS, rr[..., o])

        # Gather winning packets per output port and move them.
        pop = np.zeros((cfg.ny, cfg.nx, NUM_DIRS), bool)
        delivered = {k: np.zeros((cfg.ny, cfg.nx), np.int64) for k in _PKT_FIELDS}
        delivered_valid = np.zeros((cfg.ny, cfg.nx), bool)
        moved = {}
        if link_util is not None:
            link_util += winners >= 0
        for o in range(NUM_DIRS):
            win = winners[..., o]
            has = win >= 0
            widx = np.clip(win, 0, NUM_DIRS - 1)
            pkt = {k: np.take_along_axis(heads[k], widx[..., None], -1)[..., 0]
                   for k in _PKT_FIELDS}
            np.put_along_axis(pop, widx[..., None],
                              np.take_along_axis(pop, widx[..., None], -1) | has[..., None],
                              -1)
            moved[o] = (has, pkt)

        net.pop_mask(pop)

        # Enqueue into neighbors (each destination FIFO has exactly one feeder).
        def _push_dir(o, dst_slice, src_slice, in_port):
            has, pkt = moved[o]
            mask = np.zeros((cfg.ny, cfg.nx), bool)
            mask[dst_slice] = has[src_slice]
            shifted = {k: np.zeros((cfg.ny, cfg.nx), np.int64) for k in _PKT_FIELDS}
            for k in _PKT_FIELDS:
                shifted[k][dst_slice] = pkt[k][src_slice]
            net.push_mask(mask[..., None].repeat(NUM_DIRS, -1) &
                          (np.arange(NUM_DIRS) == in_port),
                          {k: v[..., None].repeat(NUM_DIRS, -1) for k, v in shifted.items()})

        def _push_roll(o, shift, axis, in_port):
            # wrapped-dimension push: the edge output feeds the opposite edge
            has, pkt = moved[o]
            mask = np.roll(has, shift, axis=axis)
            shifted = {k: np.roll(pkt[k], shift, axis=axis)
                       for k in _PKT_FIELDS}
            net.push_mask(mask[..., None].repeat(NUM_DIRS, -1) &
                          (np.arange(NUM_DIRS) == in_port),
                          {k: v[..., None].repeat(NUM_DIRS, -1) for k, v in shifted.items()})

        if topo.wrap_x:
            _push_roll(E, 1, 1, W)
            _push_roll(W, -1, 1, E)
        else:
            _push_dir(E, np.s_[:, 1:], np.s_[:, :-1], W)
            _push_dir(W, np.s_[:, :-1], np.s_[:, 1:], E)
        if topo.wrap_y:
            _push_roll(S, 1, 0, N)
            _push_roll(N, -1, 0, S)
        else:
            _push_dir(S, np.s_[1:, :], np.s_[:-1, :], N)
            _push_dir(N, np.s_[:-1, :], np.s_[1:, :], S)

        has_p, pkt_p = moved[P]
        delivered_valid = has_p
        delivered = pkt_p
        delivered["valid"] = delivered_valid
        return delivered

    # ------------------------------------------------------------------
    def step(self) -> None:
        cfg = self.cfg
        ny, nx = cfg.ny, cfg.nx
        c = self.cycle

        # ---- registered response port becomes visible (stats record) ----
        rv = self.reg_valid
        if rv.any():
            self.completed += rv
            lat = c - self.reg_pkt["tag"]
            self.lat_sum += np.where(rv, lat, 0)
            # latency histogram, gated to the measurement window by the
            # packet's injection cycle (its tag)
            tag = self.reg_pkt["tag"]
            in_win = rv & (tag >= self.measure_start) & (tag < self.measure_stop)
            if in_win.any():
                np.add.at(self.lat_hist,
                          np.clip(lat[in_win], 0, LAT_BINS - 1), 1)
            if cfg.record_log:
                for (y, x) in zip(*np.nonzero(rv)):
                    self.log.append((c, int(y), int(x),
                                     int(self.reg_pkt["op"][y, x]),
                                     int(self.reg_pkt["tag"][y, x]),
                                     int(self.reg_pkt["data"][y, x])))
        self.completed_per_cycle.append(int(rv.sum()))
        self.reg_valid = np.zeros((ny, nx), bool)

        # ---- reverse network: route; P deliveries are ALWAYS absorbed ----
        rdel = self._router_step(self.rev, self.rr_rev,
                                 deliver_space=np.ones((ny, nx), bool),
                                 link_util=self.link_util_rev)
        absorbed = rdel["valid"]
        # credits return for every reverse packet (commit acknowledgement)
        self.credits += absorbed.astype(np.int64)
        # register the data for the core (returned_*_r_o)
        self.reg_valid = absorbed
        for k in _PKT_FIELDS:
            self.reg_pkt[k] = np.where(absorbed, rdel[k], 0)

        # ---- endpoint: inject pending responses into reverse P FIFO ----
        slot = c % cfg.resp_latency
        inj = self.resp_valid[slot]
        if inj.any():
            mask5 = inj[..., None] & (np.arange(NUM_DIRS) == P)
            self.rev.push_mask(mask5, {k: self.resp_pkt[k][slot][..., None]
                                       .repeat(NUM_DIRS, -1) for k in _PKT_FIELDS})
            self.resp_valid[slot] = False

        # ---- endpoint: service one request/cycle (line rate) ----------
        # Only service when the reverse channel is guaranteed to have space
        # at injection time (the paper's request-masking rule).
        resp_inflight = self.resp_valid.sum(0)
        rev_space = (self.rev.count[..., P] + resp_inflight) < self.rev.depth
        can = (self.ep_in.count[..., 0] > 0) & rev_space
        if can.any():
            req = {k: v[..., 0] for k, v in self.ep_in.peek().items()}
            addr = np.clip(req["addr"], 0, cfg.mem_words - 1)
            yidx, xidx = self._ys, self._xs
            cur = self.mem[yidx, xidx, addr]
            is_store = can & (req["op"] == OP_STORE)
            is_load = can & (req["op"] == OP_LOAD)
            is_cas = can & (req["op"] == OP_CAS)
            cas_hit = is_cas & (cur == req["cmp"])
            newval = np.where(is_store, req["data"],
                              np.where(cas_hit, req["data"], cur))
            self.mem[yidx, xidx, addr] = np.where(can, newval, cur)
            self.ep_in.pop_mask(can[..., None])
            # response: loads return data, stores return a credit packet,
            # CAS returns the observed (pre-swap) value.
            rdata = np.where(is_load, cur, np.where(is_cas, cur, 0))
            # delay-line slot: with resp_latency L the response is injected
            # into the reverse network exactly L cycles after service.
            wslot = c % cfg.resp_latency
            self.resp_valid[wslot] = np.where(can, True, self.resp_valid[wslot])
            for k in _PKT_FIELDS:
                self.resp_pkt[k][wslot] = np.where(can, req[k], self.resp_pkt[k][wslot])
            # swap src<->dst so the reverse packet routes home
            self.resp_pkt["dst_x"][wslot] = np.where(can, req["src_x"], self.resp_pkt["dst_x"][wslot])
            self.resp_pkt["dst_y"][wslot] = np.where(can, req["src_y"], self.resp_pkt["dst_y"][wslot])
            self.resp_pkt["src_x"][wslot] = np.where(can, self._xs, self.resp_pkt["src_x"][wslot])
            self.resp_pkt["src_y"][wslot] = np.where(can, self._ys, self.resp_pkt["src_y"][wslot])
            self.resp_pkt["data"][wslot] = np.where(can, rdata, self.resp_pkt["data"][wslot])

        # ---- forward network: route; P deliveries go to endpoint FIFO ----
        fdel = self._router_step(self.fwd, self.rr,
                                 deliver_space=self.ep_in.space()[..., 0],
                                 link_util=self.link_util_fwd)
        got = fdel["valid"]
        if got.any():
            self.ep_in.push_mask(got[..., None],
                                 {k: fdel[k][..., None] for k in _PKT_FIELDS})

        # ---- master injection from the per-tile program -----------------
        self.out_of_credit_cycles += ((self.prog_ptr < self.prog_len)
                                      & (self.credits <= 0)).astype(np.int64)
        can_inj = (self.prog_ptr < self.prog_len) & (self.credits > 0)
        if can_inj.any():
            pidx = np.clip(self.prog_ptr, 0, max(self.prog["op"].shape[-1] - 1, 0))
            entry = {k: np.take_along_axis(self.prog[k], pidx[..., None], -1)[..., 0]
                     for k in self.prog}
            can_inj &= entry["not_before"] <= c
            can_inj &= self.fwd.space()[..., P]
            if can_inj.any():
                pkt = {
                    "dst_x": entry["dst_x"], "dst_y": entry["dst_y"],
                    "src_x": self._xs.astype(np.int64), "src_y": self._ys.astype(np.int64),
                    "addr": entry["addr"], "data": entry["data"],
                    "cmp": entry["cmp"], "op": entry["op"],
                    "tag": np.full((ny, nx), c, np.int64),
                }
                mask5 = can_inj[..., None] & (np.arange(NUM_DIRS) == P)
                self.fwd.push_mask(mask5, {k: v[..., None].repeat(NUM_DIRS, -1)
                                           for k, v in pkt.items()})
                self.credits -= can_inj.astype(np.int64)
                self.prog_ptr += can_inj.astype(np.int64)

        # ---- reactive endpoint injection (the mesh-attach interface) ----
        # Same stage and same valid/ready rule as program injection: the
        # endpoint is offered the link only when a credit and port-P FIFO
        # space are available, so a returned packet always injects.
        # Endpoint tiles have no program entries, so the two paths never
        # contend for the same FIFO slot.
        if self._injectors:
            space_p = self.fwd.space()[..., P]
            for (y, x), offer in self._injectors.items():
                if self.credits[y, x] <= 0 or not space_p[y, x]:
                    continue
                req = offer(c, int(self.credits[y, x]))
                if req is None:
                    continue
                self.fwd.push_one(y, x, P, {
                    "dst_x": req.dst_x, "dst_y": req.dst_y,
                    "src_x": x, "src_y": y, "addr": req.addr,
                    "data": req.data, "cmp": req.cmp, "op": req.op,
                    "tag": c})
                self.credits[y, x] -= 1

        # ---- telemetry: FIFO occupancy high-water marks (cycle edge) ----
        np.maximum(self.fifo_hwm_fwd, self.fwd.count, out=self.fifo_hwm_fwd)
        np.maximum(self.fifo_hwm_rev, self.rev.count, out=self.fifo_hwm_rev)
        np.maximum(self.ep_hwm, self.ep_in.count[..., 0], out=self.ep_hwm)

        self.cycle += 1

    # ------------------------------------------------------------------
    def run(self, cycles: int) -> None:
        for _ in range(cycles):
            self.step()

    def run_until_drained(self, max_cycles: int = 100000) -> int:
        """Run until all programs issued and all credits returned (global
        fence); returns the cycle count."""
        for _ in range(max_cycles):
            if (self.prog_ptr >= self.prog_len).all() and \
               (self.credits == self.cfg.max_out_credits).all() and \
               not self.reg_valid.any():
                return self.cycle
            self.step()
        raise RuntimeError(f"network did not drain in {max_cycles} cycles")

    # ------------------------------------------------------------------
    def set_measure_window(self, start: int, stop: int) -> None:
        """Restrict the latency histogram to packets *injected* in cycle
        range [start, stop) — the phased warmup/measure/drain gate."""
        self.measure_start = int(start)
        self.measure_stop = int(stop)

    # ------------------------------------------------------------------
    def mean_latency(self) -> float:
        done = self.completed.sum()
        return float(self.lat_sum.sum()) / max(int(done), 1)

    def throughput(self, warmup: int = 0) -> float:
        """Completed remote operations per cycle (steady state)."""
        per = self.completed_per_cycle[warmup:]
        return float(np.sum(per)) / max(len(per), 1)
