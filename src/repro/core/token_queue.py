"""Token queues — credit-bounded virtual channels (paper C6, "Option 1").

A token queue virtualizes a producer->consumer channel over the load/store
network: the producer holds ``depth`` send tokens; each send consumes one,
each consumer dequeue returns one (via the reverse network).  The producer
therefore never overruns the consumer's buffer, and messages never back up
into the shared network — the congestion-avoidance property the paper builds
pipelines of filters on.

Here the queue state is a JAX ring buffer, and in the distributed setting the
channel endpoints are *mesh neighbors* connected by
:func:`repro.core.routing.shift` (one ``ppermute`` hop).  Pipeline
parallelism (`repro/parallel/pipeline.py`) is a chain of these channels with
``depth`` = the paper's Option-2 sizing: in-flight microbatches = consumer
FIFO capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .routing import shift

__all__ = ["TokenQueue", "tq_make", "tq_send", "tq_recv", "tq_can_send",
           "tq_can_recv", "channel_send", "channel_recv"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TokenQueue:
    """Ring-buffer token queue.

    buf:     (depth, *item_shape) payload storage
    head:    scalar int32 — next slot to dequeue
    count:   scalar int32 — occupied slots
    tokens:  scalar int32 — producer-side send tokens (credits)
    """

    buf: jax.Array
    head: jax.Array
    count: jax.Array
    tokens: jax.Array

    @property
    def depth(self) -> int:
        return self.buf.shape[0]

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def tq_make(depth: int, item_shape: Tuple[int, ...], dtype=jnp.float32) -> TokenQueue:
    return TokenQueue(
        buf=jnp.zeros((depth,) + tuple(item_shape), dtype),
        head=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
        tokens=jnp.asarray(depth, jnp.int32),
    )


def tq_can_send(q: TokenQueue) -> jax.Array:
    return q.tokens > 0


def tq_can_recv(q: TokenQueue) -> jax.Array:
    return q.count > 0


def tq_send(q: TokenQueue, item: jax.Array, do: jax.Array | bool = True
            ) -> TokenQueue:
    """Enqueue ``item`` if ``do`` and a token is available (masked no-op
    otherwise — SPMD-friendly).  Consumes one send token."""
    do = jnp.asarray(do) & tq_can_send(q)
    tail = (q.head + q.count) % q.depth
    buf = lax.cond(
        do,
        lambda b: lax.dynamic_update_index_in_dim(b, item.astype(b.dtype), tail, 0),
        lambda b: b,
        q.buf)
    inc = do.astype(jnp.int32)
    return q.replace(buf=buf, count=q.count + inc, tokens=q.tokens - inc)


def tq_recv(q: TokenQueue, do: jax.Array | bool = True
            ) -> Tuple[TokenQueue, jax.Array, jax.Array]:
    """Dequeue; returns ``(queue, item, valid)``.  The freed slot's token
    returns to the producer (instantly here; via the reverse network in the
    distributed channel)."""
    do = jnp.asarray(do) & tq_can_recv(q)
    item = lax.dynamic_index_in_dim(q.buf, q.head % q.depth, 0, keepdims=False)
    dec = do.astype(jnp.int32)
    q = q.replace(head=(q.head + dec) % q.depth, count=q.count - dec,
                  tokens=q.tokens + dec)
    return q, item, do


# ---------------------------------------------------------------------------
# Distributed channel: neighbor-to-neighbor token queue over one mesh axis.
# Every device runs both roles SPMD-style; the payload moves one hop down the
# ring, the token (credit) moves one hop up.
# ---------------------------------------------------------------------------

def channel_send(item: jax.Array, axis_name: str) -> jax.Array:
    """Forward path: push ``item`` to the next stage along ``axis_name``."""
    return shift(item, axis_name, +1)


def channel_recv(token: jax.Array, axis_name: str) -> jax.Array:
    """Reverse path: return a credit/token to the previous stage."""
    return shift(token, axis_name, -1)
