"""The paper's primary contribution as composable JAX modules.

Layers (see DESIGN.md §1 for the paper-mechanism mapping):

* :mod:`repro.core.coords`      — PGAS ``<X, Y, local>`` addressing (C1)
* :mod:`repro.core.routing`     — XY dimension-ordered collectives (C4)
* :mod:`repro.core.pgas`        — remote store / load / CAS over shard_map (C1)
* :mod:`repro.core.credits`     — credit flow control + fences (C3)
* :mod:`repro.core.token_queue` — credit-bounded channels (C6)
* :mod:`repro.core.endpoint`    — the standard endpoint (C5)
* :mod:`repro.core.sync`        — barrier / mutex on remote CAS (C8)
* :mod:`repro.core.netsim`      — cycle-level mesh simulator (C9 oracle)
* :mod:`repro.netsim_jax`       — JIT-compiled simulator + traffic library
  (C9 fast path; re-exported here for discoverability)
"""
from . import coords, credits, endpoint, netsim, pgas, routing, sync, token_queue  # noqa: F401

from .coords import GridSpec, encode_address, decode_address, manhattan_hops, xy_route  # noqa: F401
from .credits import CreditCounter, make_credits, bdp_credits  # noqa: F401
from .pgas import PacketBatch, make_packet_batch, remote_store, remote_load, remote_cas  # noqa: F401
from .routing import xy_all_to_all, xy_all_reduce, xy_reduce_scatter, xy_all_gather, shift  # noqa: F401
from .token_queue import TokenQueue, tq_make, tq_send, tq_recv  # noqa: F401

_NETSIM_JAX_NAMES = ("netsim_jax", "JaxMeshSim", "PATTERNS", "SimConfig",
                     "make_traffic")


def __getattr__(name):  # PEP 562
    # Lazy: repro.netsim_jax imports repro.core.netsim, so importing it
    # eagerly here would be circular whenever netsim_jax is imported first.
    if name in _NETSIM_JAX_NAMES:
        from .. import netsim_jax
        value = netsim_jax if name == "netsim_jax" else getattr(netsim_jax,
                                                                name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
