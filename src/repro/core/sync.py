"""Packet-based synchronization primitives (paper C8).

"Other high-level primitives like mutex, barrier and spin-lock can layer on
top of the built-in atomic compare-and-swap" — we build exactly those on top
of :func:`repro.core.pgas.remote_cas`, distributable to any tile at runtime.

In a single jitted SPMD step there are no data races, so these primitives
matter in two places: (1) the protocol layer / netsim, where they validate
the paper's design; (2) across steps in the launcher (multi-controller
coordination), where :func:`spmd_barrier` — a psum of tokens, the collective
analogue of the credit-drain barrier — is used for real.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import pgas

__all__ = ["mutex_try_acquire", "mutex_release", "barrier_arrive",
           "barrier_done", "spmd_barrier", "MUTEX_UNLOCKED"]

MUTEX_UNLOCKED = 0


def mutex_try_acquire(mem: jax.Array, owner_tile: jax.Array, lock_addr: int,
                      x_axis: str, y_axis: str,
                      num_tiles: int) -> Tuple[jax.Array, jax.Array]:
    """Every tile attempts ``CAS(lock, UNLOCKED -> my_id+1)`` on the lock
    word that lives at ``owner_tile``; returns ``(mem, acquired)`` where
    exactly one tile observes ``acquired``.
    """
    me = pgas.tile_linear_index(x_axis, y_axis)
    pkts = pgas.make_packet_batch(num_tiles, 1, mem.dtype)
    onehot = (jnp.arange(num_tiles) == owner_tile)[:, None]
    pkts = pgas.PacketBatch(
        addr=jnp.full((num_tiles, 1), lock_addr, jnp.int32),
        data=jnp.broadcast_to((me + 1).astype(mem.dtype), (num_tiles, 1)),
        mask=onehot,
    )
    compare = jnp.full((num_tiles, 1), MUTEX_UNLOCKED, mem.dtype)
    mem, old = pgas.remote_cas(mem, pkts, compare, x_axis, y_axis)
    # old[t, 0] is the pre-CAS value seen at destination t; we acquired iff
    # the CAS we sent observed UNLOCKED.
    saw = jnp.take_along_axis(old, owner_tile[None, None].astype(jnp.int32),
                              axis=0)[0, 0]
    return mem, saw == MUTEX_UNLOCKED


def mutex_release(mem: jax.Array, owner_tile: jax.Array, lock_addr: int,
                  holding: jax.Array, x_axis: str, y_axis: str,
                  num_tiles: int) -> jax.Array:
    """The holder stores UNLOCKED back to the lock word (a remote store)."""
    pkts = pgas.PacketBatch(
        addr=jnp.full((num_tiles, 1), lock_addr, jnp.int32),
        data=jnp.full((num_tiles, 1), MUTEX_UNLOCKED, mem.dtype),
        mask=((jnp.arange(num_tiles) == owner_tile)[:, None]) & holding,
    )
    mem, _ = pgas.remote_store(mem, pkts, x_axis, y_axis)
    return mem


def barrier_arrive(mem: jax.Array, root_tile: jax.Array, counter_addr: int,
                   x_axis: str, y_axis: str, num_tiles: int) -> jax.Array:
    """Multi-node barrier via remote stores: each tile stores a 1 into its
    own slot of the root tile's arrival vector (the paper's "multi-node
    barriers performed efficiently with remote stores")."""
    me = pgas.tile_linear_index(x_axis, y_axis)
    pkts = pgas.PacketBatch(
        addr=jnp.broadcast_to(counter_addr + me, (num_tiles, 1)).astype(jnp.int32),
        data=jnp.ones((num_tiles, 1), mem.dtype),
        mask=(jnp.arange(num_tiles) == root_tile)[:, None],
    )
    mem, _ = pgas.remote_store(mem, pkts, x_axis, y_axis)
    return mem


def barrier_done(mem: jax.Array, counter_addr: int, num_tiles: int) -> jax.Array:
    """Root-side check: all arrival slots set."""
    return (mem[counter_addr:counter_addr + num_tiles] != 0).all()


def spmd_barrier(x_axis: str, y_axis: str) -> jax.Array:
    """Collective barrier used across real steps: every device contributes a
    token; returns the tile count (equals nx*ny when everyone arrived —
    which SPMD guarantees, making this also a liveness probe)."""
    one = jnp.ones((), jnp.int32)
    return lax.psum(lax.psum(one, x_axis), y_axis)
