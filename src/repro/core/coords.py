"""PGAS coordinates and global addressing (paper C1).

The BaseJump network addresses the whole machine as
``<X cord, Y cord, local address>``.  We reproduce that addressing scheme
exactly: a :class:`GridSpec` defines the logical 2D grid (X grows east, Y
grows south, I/O attaches on the south edge per the paper's routing
constraints), and :func:`encode_address` / :func:`decode_address` pack the
three fields into a single integer word the way
``bsg_manycore_packet.vh`` does.

At the JAX level the same grid is laid over the device mesh: grid X maps to
the ``model`` mesh axis and grid Y to the ``data`` mesh axis (so that a row
of tiles shares weights — a TP group — and a column shares data shards).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

__all__ = [
    "GridSpec",
    "encode_address",
    "decode_address",
    "manhattan_hops",
    "xy_route",
]


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Logical 2D manycore grid.

    Mirrors the paper's parameters: ``x_cord_width_p`` / ``y_cord_width_p``
    define coordinate field widths, ``addr_width`` the per-tile local
    address space ("memory region", in words).
    """

    nx: int
    ny: int
    addr_width: int = 20  # paper default: 20-bit word addresses per tile
    data_width: int = 32  # paper default word size

    def __post_init__(self):
        if self.nx <= 0 or self.ny <= 0:
            raise ValueError(f"grid dims must be positive, got {self.nx}x{self.ny}")

    @property
    def x_cord_width(self) -> int:
        return max(1, int(np.ceil(np.log2(max(self.nx, 2)))))

    @property
    def y_cord_width(self) -> int:
        # Paper: extra Y coordinates multiply up space at the periphery
        # ("virtual mesh"), so the Y field must hold ny (south I/O row
        # included by the caller if needed).
        return max(1, int(np.ceil(np.log2(max(self.ny, 2)))))

    @property
    def num_tiles(self) -> int:
        return self.nx * self.ny

    @property
    def region_words(self) -> int:
        """Size of each tile's local memory region in words."""
        return 1 << self.addr_width

    def tile_id(self, x: int, y: int) -> int:
        """Row-major tile index (used to map tiles onto mesh devices)."""
        self._check(x, y)
        return y * self.nx + x

    def tile_xy(self, tid: int) -> Tuple[int, int]:
        return tid % self.nx, tid // self.nx

    def tiles(self) -> Iterator[Tuple[int, int]]:
        for y in range(self.ny):
            for x in range(self.nx):
                yield (x, y)

    def _check(self, x, y) -> None:
        if not (0 <= x < self.nx and 0 <= y < self.ny):
            raise ValueError(f"tile ({x},{y}) outside {self.nx}x{self.ny} grid")

    # --- bisection geometry (paper: "16 links crossing the bisection") ---
    def bisection_links(self, axis: str = "x") -> int:
        """Number of unidirectional links crossing the median cut.

        An ``nx x ny`` mesh cut across the X dimension has ``ny`` links in
        each direction; the paper counts both directions (8x8 mesh -> 16).
        """
        if axis == "x":
            return 2 * self.ny
        if axis == "y":
            return 2 * self.nx
        raise ValueError(axis)


def encode_address(spec: GridSpec, x: int, y: int, local: int) -> int:
    """Pack ``<X, Y, local>`` into one integer (paper C1 address format)."""
    spec._check(x, y)
    if not (0 <= local < spec.region_words):
        raise ValueError(f"local address {local:#x} exceeds region ({spec.addr_width} bits)")
    return (((y << spec.x_cord_width) | x) << spec.addr_width) | local


def decode_address(spec: GridSpec, addr: int) -> Tuple[int, int, int]:
    """Unpack a global address into ``(x, y, local)``."""
    local = addr & (spec.region_words - 1)
    rest = addr >> spec.addr_width
    x = rest & ((1 << spec.x_cord_width) - 1)
    y = rest >> spec.x_cord_width
    spec._check(x, y)
    return x, y, local


def manhattan_hops(src: Tuple[int, int], dst: Tuple[int, int]) -> int:
    """Hop count under XY dimension-ordered routing (== Manhattan distance)."""
    return abs(dst[0] - src[0]) + abs(dst[1] - src[1])


def xy_route(src: Tuple[int, int], dst: Tuple[int, int]) -> list:
    """The exact sequence of tiles an XY-routed packet traverses (paper C4).

    X first, then Y.  The N->E and N->W turns are structurally impossible in
    routes produced here, matching the router's reduced crossbar.
    """
    (sx, sy), (dx, dy) = src, dst
    path = [(sx, sy)]
    step = 1 if dx >= sx else -1
    for x in range(sx + step, dx + step, step) if dx != sx else []:
        path.append((x, sy))
    step = 1 if dy >= sy else -1
    for y in range(sy + step, dy + step, step) if dy != sy else []:
        path.append((dx, y))
    return path
