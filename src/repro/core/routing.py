"""Dimension-ordered (XY) routing expressed as JAX collectives (paper C4).

The BaseJump router moves a packet all the way along X, then along Y.  A TPU
pod's ICI fabric routes the same way, so we express every long-range
communication pattern in the framework as *per-axis phases*:

* ``xy_all_to_all``      — all-to-all over the combined (X, Y) group as an
                           X-phase ``all_to_all`` followed by a Y-phase
                           ``all_to_all``  (MoE dispatch / global shuffle).
* ``xy_all_reduce``      — hierarchical all-reduce: reduce along X rows, then
                           along Y columns (gradient reduction).
* ``xy_reduce_scatter`` / ``xy_all_gather`` — the matching two-phase forms.
* ``shift``              — single-hop neighbor ``ppermute`` (token queues,
                           pipeline channels).

All functions must be called **inside** a ``shard_map`` (they use named
axes).  They are pure jnp/lax — no Pallas — because the paper's contribution
here is the *schedule*, not the arithmetic.

The module also carries the hop-count cost model used by the roofline
collective term: on an ``nx x ny`` mesh, a dimension-ordered all-to-all of
``B`` bytes per device crosses the X bisection with ``B * nx/4`` bytes per
row and the Y bisection with ``B * ny/4`` per column (uniform traffic).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size as _axis_size

__all__ = [
    "shift",
    "ring_neighbors",
    "xy_all_to_all",
    "xy_all_reduce",
    "xy_reduce_scatter",
    "xy_all_gather",
    "axis_all_to_all",
    "a2a_phase_cost",
    "allreduce_cost",
]


# ---------------------------------------------------------------------------
# Single-hop primitives (the "link protocol"): neighbor shifts on one axis.
# ---------------------------------------------------------------------------

def ring_neighbors(axis_size: int, shift_by: int = 1) -> list:
    """Source->dest pairs for a ``ppermute`` ring shift along one axis."""
    return [(i, (i + shift_by) % axis_size) for i in range(axis_size)]


def shift(x: jax.Array, axis_name: str, shift_by: int = 1) -> jax.Array:
    """Move ``x`` to the ``shift_by``-hop neighbor along ``axis_name``.

    This is the forward-path link: one ``ppermute`` hop.  Token queues and
    the pipeline schedule are built from it.
    """
    size = _axis_size(axis_name)
    return lax.ppermute(x, axis_name, perm=ring_neighbors(size, shift_by))


# ---------------------------------------------------------------------------
# Phase collectives along a single axis.
# ---------------------------------------------------------------------------

def axis_all_to_all(x: jax.Array, axis_name: str, split_axis: int,
                    concat_axis: int, *, tiled: bool = True) -> jax.Array:
    """One routing phase: all-to-all along a single mesh axis."""
    return lax.all_to_all(x, axis_name, split_axis=split_axis,
                          concat_axis=concat_axis, tiled=tiled)


# ---------------------------------------------------------------------------
# XY (dimension-ordered) composite collectives.
# ---------------------------------------------------------------------------

def xy_all_to_all(x: jax.Array, x_axis: str, y_axis: str, *,
                  split_axis: int = 0, concat_axis: int = 0) -> jax.Array:
    """All-to-all over the combined (x_axis × y_axis) device group, routed
    dimension-ordered: X phase first, then Y phase.

    ``x``'s ``split_axis`` must be divisible by ``|X| * |Y|``.  Each device
    ends up with the blocks destined to it from every other device, exactly
    as a flat all-to-all over the product group would produce, but the HLO
    contains two smaller-group ``all-to-all`` ops whose traffic follows mesh
    rows then mesh columns (matching ICI hardware routing, so no link is
    traversed twice — the paper's argument for dimension-ordered routing).

    Layout contract: the ``split_axis`` dimension is ordered as
    ``(Y_dest, X_dest, ...)`` — i.e. destination = row-major
    ``(y, x)`` tile id, consistent with ``GridSpec.tile_id``.
    """
    nx = _axis_size(x_axis)
    ny = _axis_size(y_axis)
    n = x.shape[split_axis]
    if n % (nx * ny):
        raise ValueError(f"split dim {n} not divisible by mesh {nx}x{ny}")

    # Phase 1 (X): deliver to the correct column.  Blocks for destination
    # (y_d, x_d) travel to column x_d, staying in this row.
    # Reshape split dim (Y, X, rest) -> move X to front for the X-phase a2a.
    lead = list(range(x.ndim))
    xs = jnp.moveaxis(x, split_axis, 0)
    rest = xs.shape[1:]
    xs = xs.reshape((ny, nx, n // (nx * ny)) + rest)
    xs = jnp.swapaxes(xs, 0, 1)                       # (X, Y, blk, ...)
    xs = xs.reshape((nx, ny * (n // (nx * ny))) + rest)
    xs = lax.all_to_all(xs, x_axis, split_axis=0, concat_axis=0, tiled=True)

    # Phase 2 (Y): within each column, deliver to the correct row.
    xs = xs.reshape((nx, ny, n // (nx * ny)) + rest)
    xs = jnp.swapaxes(xs, 0, 1)                       # (Y, X, blk, ...)
    xs = xs.reshape((ny, nx * (n // (nx * ny))) + rest)
    xs = lax.all_to_all(xs, y_axis, split_axis=0, concat_axis=0, tiled=True)

    xs = xs.reshape((n,) + rest)
    return jnp.moveaxis(xs, 0, split_axis)


def xy_all_reduce(x: jax.Array, x_axis: str, y_axis: str) -> jax.Array:
    """Hierarchical all-reduce: reduce along mesh rows (X) then columns (Y).

    Semantically equal to ``psum(x, (x_axis, y_axis))`` but lowers to two
    smaller-group all-reduces whose traffic is dimension-ordered.
    """
    return lax.psum(lax.psum(x, x_axis), y_axis)


def xy_reduce_scatter(x: jax.Array, x_axis: str, y_axis: str,
                      scatter_dim: int = 0) -> jax.Array:
    """Two-phase reduce-scatter (X phase then Y phase) along ``scatter_dim``."""
    x = lax.psum_scatter(x, x_axis, scatter_dimension=scatter_dim, tiled=True)
    return lax.psum_scatter(x, y_axis, scatter_dimension=scatter_dim, tiled=True)


def xy_all_gather(x: jax.Array, x_axis: str, y_axis: str,
                  gather_dim: int = 0) -> jax.Array:
    """Two-phase all-gather: Y phase then X phase (reverse path order)."""
    x = lax.all_gather(x, y_axis, axis=gather_dim, tiled=True)
    return lax.all_gather(x, x_axis, axis=gather_dim, tiled=True)


# ---------------------------------------------------------------------------
# Cost model (used by launch/roofline.py).  Link bandwidth in bytes/s.
# ---------------------------------------------------------------------------

def a2a_phase_cost(bytes_per_device: float, axis_size: int,
                   link_bw: float, *, torus: bool = True) -> float:
    """Seconds for one all-to-all phase along a ring/torus axis.

    Uniform all-to-all on a ring of ``k`` devices moves ``B*(k-1)/k`` bytes
    off each device; the bisection-limited time on a (bidirectional) torus
    ring is ``B * k / (8 if torus else 4) / link_bw`` (paper's bisection
    argument: traffic crossing the median limits throughput).
    """
    k = axis_size
    if k <= 1:
        return 0.0
    cut = 4 * link_bw if torus else 2 * link_bw  # 2 links x 2 dirs (torus)
    # bytes crossing one bisection: each of k devices sends B*(k/2)/k ~ B/2
    # across the cut on average => k*B/4 each way.
    return (bytes_per_device * k / 4.0) / cut


def allreduce_cost(bytes_per_device: float, axis_size: int,
                   link_bw: float, *, torus: bool = True) -> float:
    """Seconds for a ring all-reduce along one axis (2(k-1)/k * B / links)."""
    k = axis_size
    if k <= 1:
        return 0.0
    lanes = 2 * link_bw if torus else link_bw  # both ring directions usable
    return 2.0 * (k - 1) / k * bytes_per_device / lanes
