"""Declarative design-space sweep specifications.

A :class:`SweepSpec` names the axes the BaseJump paper's sizing question
actually spans — router FIFO depth x credit allowance x traffic pattern
x offered load x topology on one mesh shape, plus (optionally) the real
workload families compiled by :mod:`repro.workloads` — and expands them
into the cross product of :class:`SweepPoint`\\ s the runner simulates.

The spec is *static metadata only*: expansion, feasibility pruning and
bucket grouping are pure Python, so a million-point spec costs nothing
until :func:`repro.dse.run_sweep` actually simulates its cache misses.

Bucketing invariant
-------------------
Every point maps to a :class:`~repro.netsim_jax.measure.SweepKey` whose
``cfg`` carries the *capacity* configuration of its topology bucket
(``router_fifo`` / ``max_out_credits`` = the max swept values), while
the point's own depth/credits ride as *dynamic* values inside the
vmapped state — so ONE compilation per (topology, program shape) bucket
covers every depth x credits x pattern x load combination, which is
what lets a 500+-point submission fan out with a handful of compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.mesh.config import MeshConfig
from repro.mesh.topology import Topology
from repro.mesh.traffic import PATTERNS
from repro.netsim_jax.measure import DEFAULT_SWEEP_RATES, SweepKey

__all__ = ["SweepPoint", "SweepSpec", "WORKLOAD_FAMILIES",
           "workload_entries"]

# The model-stack workload families the spec may sweep (lowered by
# repro.workloads); each builder returns the injection-program entries
# for one nx x ny array.  Sized modestly: the DSE compares *relative*
# throughput across buffer configurations, not absolute workload runtime.
WORKLOAD_FAMILIES = ("allreduce", "broadcast", "moe", "pipeline")

_WL_PREFIX = "wl:"


def workload_entries(family: str, nx: int, ny: int,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    """Injection-program entries for one workload family on an nx x ny
    array (the DSE's fixed, modestly-sized instances)."""
    from repro.workloads import (moe_all_to_all, parameter_broadcast,
                                 pipeline_p2p, ring_all_reduce)
    k = nx * ny
    if family == "allreduce":
        return ring_all_reduce(nx, ny, 2 * k).program
    if family == "broadcast":
        return parameter_broadcast(nx, ny, 2 * k).program
    if family == "moe":
        return moe_all_to_all(nx, ny, 4, imbalance=0.25, seed=seed).program
    if family == "pipeline":
        return pipeline_p2p(nx, ny, n_micro=4, act_words=8,
                            backward=True).program
    raise ValueError(
        f"unknown workload family {family!r}; known: {WORKLOAD_FAMILIES}")


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One simulated configuration: (shape, topology, buffer sizing,
    traffic).  ``traffic`` is a synthetic pattern name or ``"wl:family"``
    for a compiled workload; ``load`` is the offered injection rate
    (0 for workload points, whose programs carry their own schedule)."""
    nx: int
    ny: int
    topology: Topology
    fifo_depth: int
    credits: int
    traffic: str
    load: float = 0.0
    seed: int = 0

    @property
    def is_workload(self) -> bool:
        return self.traffic.startswith(_WL_PREFIX)

    @property
    def family(self) -> Optional[str]:
        """The workload family, or None for a synthetic pattern."""
        return self.traffic[len(_WL_PREFIX):] if self.is_workload else None

    def mesh_config(self) -> MeshConfig:
        """This point's *effective* configuration (depth/credits as the
        capacities) — the identity the result cache keys on, independent
        of whichever bucket capacity the point happened to batch under."""
        return MeshConfig(nx=self.nx, ny=self.ny,
                          router_fifo=self.fifo_depth,
                          max_out_credits=self.credits,
                          topology=self.topology)

    def label(self) -> str:
        load = "" if self.is_workload else f"@{self.load:g}"
        return (f"{self.topology.spec}/fifo{self.fifo_depth}"
                f"/cred{self.credits}/{self.traffic}{load}")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """The declarative sweep: every axis a tuple, every point their cross
    product.  Topologies accept :class:`Topology` objects or their
    string form (``"torus"``, ``"multi_chip:2:4"``); validation is eager
    and names the offending axis."""
    nx: int
    ny: int
    fifo_depths: Tuple[int, ...] = (2, 4, 8, 16)
    credits: Tuple[int, ...] = (8, 32, 128)
    patterns: Tuple[str, ...] = ("uniform",)
    loads: Tuple[float, ...] = DEFAULT_SWEEP_RATES
    topologies: Tuple[Topology, ...] = ("mesh",)
    workloads: Tuple[str, ...] = ()
    warmup: int = 200
    measure: int = 400
    drain: int = 400
    seed: int = 0
    unroll: int = 1
    impl: str = "fused"
    cycles_per_call: int = 1
    name: str = "sweep"

    def __post_init__(self):
        dedupe = lambda xs: tuple(dict.fromkeys(xs))  # noqa: E731
        object.__setattr__(self, "fifo_depths",
                           tuple(sorted({int(d) for d in self.fifo_depths})))
        object.__setattr__(self, "credits",
                           tuple(sorted({int(c) for c in self.credits})))
        object.__setattr__(self, "patterns", dedupe(self.patterns))
        object.__setattr__(self, "loads",
                           tuple(sorted({float(r) for r in self.loads})))
        object.__setattr__(
            self, "topologies",
            dedupe(Topology.parse(t) for t in self.topologies))
        object.__setattr__(self, "workloads", dedupe(self.workloads))
        if not self.fifo_depths or min(self.fifo_depths) < 1:
            raise ValueError(
                f"fifo_depths must be positive ints, got {self.fifo_depths}")
        if not self.credits or min(self.credits) < 1:
            raise ValueError(
                f"credits must be positive ints, got {self.credits}")
        for p in self.patterns:
            if p not in PATTERNS:
                raise ValueError(
                    f"unknown traffic pattern {p!r}; known: "
                    f"{sorted(PATTERNS)}")
        for r in self.loads:
            if not 0.0 < r <= 1.0:
                raise ValueError(
                    f"offered loads must be in (0, 1], got {r}")
        for w in self.workloads:
            if w not in WORKLOAD_FAMILIES:
                raise ValueError(
                    f"unknown workload family {w!r}; known: "
                    f"{WORKLOAD_FAMILIES}")
        if not self.patterns and not self.workloads:
            raise ValueError(
                "a sweep needs at least one traffic pattern or workload "
                "family")
        if not self.topologies:
            raise ValueError("a sweep needs at least one topology")
        for topo in self.topologies:
            # surfaces shape/topology mismatches (and the coord-field
            # limits) before any simulation happens
            MeshConfig(nx=self.nx, ny=self.ny,
                       router_fifo=max(max(self.fifo_depths),
                                       topo.min_router_fifo),
                       max_out_credits=max(self.credits), topology=topo)

    # -- expansion ------------------------------------------------------
    @property
    def horizon(self) -> int:
        return self.warmup + self.measure + self.drain

    def feasible_depths(self, topology: Topology) -> Tuple[int, ...]:
        return tuple(d for d in self.fifo_depths
                     if d >= topology.min_router_fifo)

    def points(self) -> List[SweepPoint]:
        """Every feasible point, in deterministic axis order."""
        out = []
        for topo in self.topologies:
            for depth in self.feasible_depths(topo):
                for cred in self.credits:
                    for pat in self.patterns:
                        for load in self.loads:
                            out.append(SweepPoint(
                                self.nx, self.ny, topo, depth, cred, pat,
                                load, self.seed))
                    for fam in self.workloads:
                        out.append(SweepPoint(
                            self.nx, self.ny, topo, depth, cred,
                            _WL_PREFIX + fam, 0.0, self.seed))
        return out

    def infeasible(self) -> List[Tuple[Topology, int, str]]:
        """(topology, fifo_depth, reason) for every pruned combination —
        reported by the runner so a sweep never silently shrinks."""
        out = []
        for topo in self.topologies:
            for depth in self.fifo_depths:
                if depth < topo.min_router_fifo:
                    out.append((topo, depth,
                                f"router_fifo {depth} < "
                                f"{topo.min_router_fifo} required by "
                                f"{topo.spec} bubble flow control"))
        return out

    # -- bucket / cache identities --------------------------------------
    def bucket_config(self, topology: Topology) -> MeshConfig:
        """The *capacity* configuration every point of ``topology``'s
        bucket batches under (max swept depth/credits; per-point values
        ride as dynamic state)."""
        depths = self.feasible_depths(topology)
        if not depths:
            raise ValueError(
                f"no feasible fifo depth for topology {topology.spec} "
                f"in {self.fifo_depths}")
        return MeshConfig(nx=self.nx, ny=self.ny, router_fifo=max(depths),
                          max_out_credits=max(self.credits),
                          topology=topology)

    def sweep_key(self, topology: Topology) -> SweepKey:
        """The compiled-program identity of ``topology``'s bucket —
        shared with :func:`repro.netsim_jax.measure` so the DSE rides
        the same jit cache as every other sweep in the repo."""
        return SweepKey(cfg=self.bucket_config(topology).to_sim(),
                        warmup=self.warmup, measure=self.measure,
                        drain=self.drain, unroll=self.unroll,
                        impl=self.impl,
                        cycles_per_call=self.cycles_per_call)

    def traffic_length(self) -> int:
        """Program length for synthetic-pattern points: sized for the
        fastest swept load so every program in a bucket shares one shape
        (slower loads schedule their tail entries past the horizon —
        never injected, exactly like ``stack_rate_programs``)."""
        if not self.loads:
            return 1
        return int(np.ceil(max(self.loads) * self.horizon)) + 1

    def point_key(self, point: SweepPoint) -> str:
        """The on-disk result-cache key: the point's *effective* config
        token plus the measurement recipe.  Deliberately excludes the
        bucket capacity and program-array length — neither changes the
        simulated dynamics, so cached results survive spec regrouping."""
        load = "wl" if point.is_workload else f"{point.load:.6g}"
        return (f"{point.mesh_config().cache_token()}|{point.traffic}"
                f"|load={load}|seed={point.seed}"
                f"|w{self.warmup}m{self.measure}d{self.drain}"
                f"|unroll{self.unroll}|{self.impl}x{self.cycles_per_call}")

    def describe(self) -> str:
        n = len(self.points())
        axes = (f"{len(self.topologies)} topologies x "
                f"{len(self.fifo_depths)} depths x "
                f"{len(self.credits)} credits x "
                f"({len(self.patterns)} patterns x {len(self.loads)} loads"
                f" + {len(self.workloads)} workloads)")
        return (f"sweep {self.name!r}: {self.nx}x{self.ny}, {axes} = "
                f"{n} feasible points, horizon {self.horizon} cycles")
