"""Pareto-frontier extraction over (buffer area, saturation throughput).

The DSE's deliverable: of all swept (fifo_depth, credits) configurations
of one topology, which are *undominated* — no cheaper configuration
delivers at least the same saturated throughput?  Minimizes the x key,
maximizes the y key; ties on x keep only the best y, so the frontier is
strictly increasing in throughput as area grows (asserted by
:func:`frontier_is_monotone`, which CI gates on).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np

__all__ = ["pareto_front", "frontier_is_monotone", "ascii_frontier"]


def _getter(key) -> Callable[[Dict], float]:
    return key if callable(key) else (lambda r: float(r[key]))


def pareto_front(records: Sequence[Dict], x_key="area_mm2",
                 y_key="throughput") -> List[Dict]:
    """The undominated subset of ``records`` (minimize ``x_key``,
    maximize ``y_key``), sorted by ascending x.  Keys may be dict keys
    or callables.  Records with missing/None metric values are excluded
    (a point that never saturated has no throughput to trade)."""
    gx, gy = _getter(x_key), _getter(y_key)

    def metrics(r):
        try:
            x, y = gx(r), gy(r)
        except (KeyError, TypeError):
            return None
        if x is None or y is None or not np.isfinite(x) or not np.isfinite(y):
            return None
        return x, y

    scored = [(m[0], m[1], r) for r in records
              if (m := metrics(r)) is not None]
    scored.sort(key=lambda t: (t[0], -t[1]))
    front: List[Dict] = []
    best = -np.inf
    for x, y, r in scored:
        if y > best:
            front.append(r)
            best = y
    return front


def frontier_is_monotone(front: Sequence[Dict], x_key="area_mm2",
                         y_key="throughput") -> bool:
    """Is ``front`` a well-formed Pareto frontier?  Nondecreasing in x
    AND strictly increasing in y (every extra mm² must buy throughput —
    anything else is a dominated point that should have been dropped).
    An empty frontier is NOT well formed: the sweep produced nothing."""
    if not front:
        return False
    gx, gy = _getter(x_key), _getter(y_key)
    xs = [gx(r) for r in front]
    ys = [gy(r) for r in front]
    return all(b >= a for a, b in zip(xs, xs[1:])) and \
        all(b > a for a, b in zip(ys, ys[1:]))


def ascii_frontier(records: Sequence[Dict], front: Sequence[Dict],
                   x_key="area_mm2", y_key="throughput",
                   width: int = 56, height: int = 14,
                   x_label: str = "buffer area [mm^2]",
                   y_label: str = "sat throughput") -> str:
    """Scatter figure of a sweep: ``*`` marks frontier points, ``.``
    dominated ones, with the axes annotated — the terminal twin of the
    JSON artifact, in the style of ``measure.ascii_curve``."""
    gx, gy = _getter(x_key), _getter(y_key)

    def xy(r):
        try:
            x, y = gx(r), gy(r)
        except (KeyError, TypeError):
            return None
        return None if x is None or y is None else (x, y)

    pts = [p for r in records if (p := xy(r)) is not None]
    if not pts:
        return "    (no points)"
    fset = {p for r in front if (p := xy(r)) is not None}
    xs, ys = zip(*pts)
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    sx = (width - 1) / max(x1 - x0, 1e-12)
    sy = (height - 1) / max(y1 - y0, 1e-12)
    grid = [[" "] * width for _ in range(height)]
    for x, y in pts:  # dominated first so frontier marks overwrite them
        if (x, y) not in fset:
            grid[int((y - y0) * sy)][int((x - x0) * sx)] = "."
    for x, y in fset:
        grid[int((y - y0) * sy)][int((x - x0) * sx)] = "*"
    rows = []
    for i in range(height - 1, -1, -1):
        edge = f"{y1:8.3f} +" if i == height - 1 else (
            f"{y0:8.3f} +" if i == 0 else "         |")
        rows.append(edge + "".join(grid[i]))
    rows.append("         +" + "-" * width)
    rows.append(f"          {x0:<12.4f}{x_label:^{max(width - 24, 8)}}"
                f"{x1:>12.4f}")
    rows.append(f"          ({len(front)} frontier / {len(pts)} points, "
                f"y = {y_label})")
    return "\n".join(rows)
