"""Fleet-scale design-space exploration over the mesh NoC simulator.

Declare a sweep (:class:`SweepSpec`), run it (:func:`run_sweep` —
bucketed by compiled shape, batched through the vmapped sweep kernels,
sharded across devices, resumable from the on-disk :class:`ResultCache`)
and extract Pareto frontiers of buffer area vs. saturation throughput
(:func:`frontier_artifact` / :func:`pareto_front`) priced by the
lumos-style :class:`CostModel`.

    from repro.dse import SweepSpec, run_sweep, frontier_artifact
    spec = SweepSpec(nx=16, ny=16, topologies=("mesh", "torus"))
    result = run_sweep(spec, cache_dir="experiments/dse_cache")
    artifact = frontier_artifact(result)
"""
from .cache import ResultCache, config_hash
from .cost import FLIT_BITS, CostModel
from .pareto import ascii_frontier, frontier_is_monotone, pareto_front
from .runner import (SweepResult, frontier_artifact, frontier_ascii,
                     run_sweep, write_frontier)
from .spec import WORKLOAD_FAMILIES, SweepPoint, SweepSpec, workload_entries

__all__ = [
    "SweepSpec", "SweepPoint", "WORKLOAD_FAMILIES", "workload_entries",
    "run_sweep", "SweepResult",
    "frontier_artifact", "frontier_ascii", "write_frontier",
    "CostModel", "FLIT_BITS",
    "pareto_front", "frontier_is_monotone", "ascii_frontier",
    "ResultCache", "config_hash",
]
