"""Resumable on-disk result cache for design-space sweeps.

A fleet-scale sweep is re-submitted constantly — widened axes, re-costed
frontiers, crashed runs resumed — and re-simulating half a million
cycles per already-known point would dwarf the new work.  The cache
stores one small JSON record per simulated point, keyed by

* the **point key** (:meth:`repro.dse.SweepSpec.point_key` — the
  point's effective mesh configuration plus the measurement recipe), and
* the **code hash** (:func:`config_hash`) — a digest of the git-tracked
  sources that determine simulated results (the simulator step, the
  measurement program, routing/topology, traffic generation, packet
  encoding).  Editing any of them moves the cache to a fresh directory,
  so stale results can never leak into a frontier; untouched code keeps
  the old directory hot.

Records hold raw telemetry only.  Costs (area/energy) are applied at
frontier-extraction time, so re-pricing a sweep under a different
:class:`~repro.dse.cost.CostModel` is free.
"""
from __future__ import annotations

import functools
import hashlib
import json
from pathlib import Path
from typing import Dict, Optional

__all__ = ["config_hash", "ResultCache"]

# the modules whose source determines simulated results; measured code
# only (cost/pareto/spec are applied after simulation and deliberately
# do NOT invalidate cached telemetry)
_HASHED_MODULES = (
    "repro.netsim_jax.sim",
    "repro.netsim_jax.measure",
    "repro.mesh.topology",
    "repro.mesh.traffic",
    "repro.mesh.encoding",
    "repro.kernels.router_step",
)


@functools.lru_cache(maxsize=1)
def config_hash() -> str:
    """Digest of the result-determining, git-tracked simulator sources."""
    import importlib
    h = hashlib.sha256()
    for name in _HASHED_MODULES:
        mod = importlib.import_module(name)
        h.update(name.encode())
        h.update(Path(mod.__file__).read_bytes())
    return h.hexdigest()[:16]


class ResultCache:
    """One JSON file per point under ``root/<config_hash>/``.

    ``root=None`` disables caching (every ``get`` misses, ``put`` is a
    no-op) so callers can thread one code path either way.  Filenames
    are a digest of the point key; the key itself is stored inside the
    record and verified on read, so a (vanishingly unlikely) digest
    collision degrades to a miss, never to a wrong result.
    """

    def __init__(self, root: Optional[Path]):
        self.root = None if root is None else Path(root)
        self.dir = None if self.root is None else self.root / config_hash()

    @staticmethod
    def _filename(key: str) -> str:
        return hashlib.sha256(key.encode()).hexdigest()[:24] + ".json"

    def path_for(self, key: str) -> Optional[Path]:
        return None if self.dir is None else self.dir / self._filename(key)

    def get(self, key: str) -> Optional[Dict]:
        path = self.path_for(key)
        if path is None:
            return None
        try:
            record = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return None
        if record.pop("key", None) != key:
            return None
        return record

    def put(self, key: str, record: Dict) -> None:
        path = self.path_for(key)
        if path is None:
            return
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps({**record, "key": key}, default=str))
        tmp.replace(path)  # atomic: concurrent sweeps never read half a file

    def __len__(self) -> int:
        if self.dir is None or not self.dir.is_dir():
            return 0
        return sum(1 for _ in self.dir.glob("*.json"))
