"""Lumos-style area/energy cost model for the router design space.

The BaseJump paper's sizing argument is that mesh routers must stay
*small* — the network is amortized across hundreds of tiles, so every
extra FIFO slot is paid ``nx * ny`` times.  This module prices exactly
the two quantities the DSE trades off:

* **Buffer area** — the router's input FIFOs dominate its storage; a
  tile holds ``networks x ports x fifo_depth`` router flit slots (the
  fwd/rev physical networks of the paper's two-network datapath, five
  ports each) plus the endpoint's ``ep_fifo`` slots, each
  :data:`FLIT_BITS` wide (the packed 5-lane int32 packet).  Area is
  flits x bits x an SRAM cell-area constant — the same
  budget-constrained accounting lumos's MPSoC model applies to core
  area (``SNIPPETS.md``), reduced to the network's share.
* **Link energy** — every W/E/N/S crossing moves one flit over one mesh
  channel; :class:`~repro.netsim_jax.measure.PhaseStats.hops` counts
  them during the measurement window, and energy is
  ``hops x flit_bits x pJ/bit/hop``.

The constants are deliberately plain dataclass knobs (45 nm-flavored
defaults in the lumos tech-node spirit), not a process model: the DSE
compares configurations under ONE consistent model, and the frontier
shape — not the absolute mm² — is the result.  Swap the knobs to re-cost
a sweep without re-simulating: the result cache stores raw telemetry and
the cost model is applied at frontier-extraction time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.netsim import NUM_DIRS
from repro.mesh.config import MeshConfig

__all__ = ["FLIT_BITS", "CostModel"]

# the packed packet: 5 int32 lanes (hdr/addr/data/cmp/tag)
FLIT_BITS = 5 * 32


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Area/energy knobs (hashable; JSON-ready via :meth:`to_json`).

    ``sram_um2_per_bit`` ~ a 6T SRAM cell at a 45 nm-class node;
    ``link_pj_per_bit_hop`` ~ on-chip wire + router traversal energy per
    bit per hop.  ``networks`` is the paper's fwd/rev physical-network
    pair; ``ports`` the 5-port (P/W/E/N/S) router.
    """
    flit_bits: int = FLIT_BITS
    sram_um2_per_bit: float = 0.525
    link_pj_per_bit_hop: float = 0.052
    networks: int = 2
    ports: int = NUM_DIRS

    # -- area -----------------------------------------------------------
    def tile_buffer_bits(self, fifo_depth: int, ep_fifo: int = 4) -> int:
        """Router + endpoint FIFO storage of one tile, in bits."""
        flits = self.networks * self.ports * int(fifo_depth) + int(ep_fifo)
        return flits * self.flit_bits

    def buffer_area_mm2(self, cfg: MeshConfig) -> float:
        """Total mesh buffer area (mm²) of a configuration — the x-axis
        of the Pareto frontier."""
        bits = self.tile_buffer_bits(cfg.router_fifo, cfg.ep_fifo)
        return cfg.nx * cfg.ny * bits * self.sram_um2_per_bit * 1e-6

    # -- energy ---------------------------------------------------------
    def hop_energy_pj(self, hops: float) -> float:
        """Energy (pJ) of ``hops`` flit link-crossings (both networks)."""
        return float(hops) * self.flit_bits * self.link_pj_per_bit_hop

    def energy_per_packet_pj(self, hops: float, packets: float) -> float:
        """Average network energy per delivered packet during a
        measurement window (0 when nothing was delivered)."""
        return self.hop_energy_pj(hops) / packets if packets > 0 else 0.0

    def to_json(self) -> Dict[str, float]:
        return dataclasses.asdict(self)
