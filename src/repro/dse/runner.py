"""The design-space-exploration runner: spec in, Pareto frontiers out.

One :func:`run_sweep` submission absorbs an arbitrarily large point
count by composing the repo's existing scaling machinery:

1. **Cache probe** — every expanded point is looked up in the
   :class:`~repro.dse.cache.ResultCache` first; re-runs simulate (and
   compile) nothing for known points.
2. **Bucketing** — cache misses group by
   (:class:`~repro.netsim_jax.measure.SweepKey`, program length): one
   static shape, ONE compilation per bucket, regardless of how many
   depth x credits x pattern x load points it holds.
3. **Batching** — each bucket stacks its injection programs and rides
   the vmapped :func:`~repro.netsim_jax.measure.batch_stats_fn` with
   per-point dynamic FIFO depths/credit allowances, chunked through
   ``lax.map`` so peak memory is one chunk of simulator states, not the
   whole bucket.
4. **Sharding** — with ``devices=N`` the chunked program is wrapped in
   the :func:`repro.compat.shard_map` adapter over a 1-D device mesh
   and each device simulates its slice of the bucket.  Requesting more
   devices than the host has degrades gracefully: one warning, then the
   single-device chunked-vmap path (so a spec written for a fleet still
   runs on a laptop).

Frontier extraction (:func:`frontier_artifact`) is a pure post-pass over
the cached telemetry: per topology, each (fifo_depth, credits)
configuration's load–latency curve is reduced to (saturation rate,
saturation throughput), priced with the
:class:`~repro.dse.cost.CostModel`, and the undominated
area-vs-throughput set is emitted as JSON + an ASCII figure.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
import warnings
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import device_mesh_1d, shard_map
from repro.mesh.traffic import make_traffic
from repro.netsim_jax.measure import (PhaseStats, SweepKey, batch_stats_fn,
                                      saturation_point)
from repro.netsim_jax.sim import I32, Program, load_program

from .cache import ResultCache, config_hash
from .cost import CostModel
from .pareto import ascii_frontier, frontier_is_monotone, pareto_front
from .spec import SweepPoint, SweepSpec, workload_entries

__all__ = ["SweepResult", "run_sweep", "frontier_artifact",
           "frontier_ascii", "write_frontier"]

# the PhaseStats scalars persisted per point (hist stays in-memory only:
# 512 bins x 500+ points of JSON would dwarf the numbers anyone reads)
STAT_FIELDS = ("offered", "accepted", "delivered", "lat_mean", "lat_p50",
               "lat_p95", "lat_p99", "lat_max", "peak_link_util", "hops")

# (SweepKey, ndev, chunk, padded batch, program length) shapes executed
# by this process — distinguishes a genuinely fresh XLA compilation from
# a jit-cache hit, so SweepResult.compiles reports honest numbers
_EXECUTED_SHAPES: set = set()


@dataclasses.dataclass
class SweepResult:
    """What one submission did: the per-point records (spec order) plus
    the service accounting the acceptance gates read."""
    spec: SweepSpec
    records: List[Dict]
    n_points: int
    simulated: int
    cache_hits: int
    infeasible: List[str]
    buckets: int
    compiles: int
    devices: int
    wall_s: float

    def by_point(self) -> Dict[SweepPoint, Dict]:
        return {_point_from_record(r): r for r in self.records}


def _resolve_devices(requested: Optional[int]) -> int:
    """The device-axis width actually used.  ``None`` means single-device
    chunked vmap; asking for more devices than the host has falls back
    to the same path with one warning instead of a shard_map crash."""
    if requested is None or requested <= 1:
        return 1
    avail = jax.device_count()
    if requested > avail:
        warnings.warn(
            f"sweep requested a {requested}-device axis but only {avail} "
            f"device(s) are visible; falling back to single-device "
            f"chunked vmap", stacklevel=3)
        return 1
    return int(requested)


@functools.lru_cache(maxsize=None)
def _bucket_jit(key: SweepKey, ndev: int, chunk: int):
    """The jitted bucket program: chunked (``lax.map`` over ``chunk``-row
    vmapped slices) and, for ``ndev > 1``, sharded over a 1-D device
    mesh.  Cached per (key, fan-out) like every other sweep program —
    :func:`repro.netsim_jax.measure.clear_sweep_cache` clears it too."""
    base = batch_stats_fn(key)

    def chunked(progs: Program, depths: jax.Array,
                credits: jax.Array) -> PhaseStats:
        def split(x):
            return x.reshape((-1, chunk) + x.shape[1:])

        def join(x):
            return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

        args = jax.tree_util.tree_map(split, (progs, depths, credits))
        out = jax.lax.map(lambda a: base(*a), args)
        return jax.tree_util.tree_map(join, out)

    if ndev == 1:
        return jax.jit(chunked)
    mesh = device_mesh_1d(ndev, "dse")
    spec = jax.sharding.PartitionSpec("dse")
    return jax.jit(shard_map(chunked, mesh=mesh,
                             in_specs=(spec, spec, spec), out_specs=spec))


def _pad_rows(n: int, ndev: int, chunk: int) -> Tuple[int, int]:
    """(padded batch, effective chunk): the batch must split evenly into
    ``ndev`` device rows of whole ``chunk``-row ``lax.map`` slices."""
    per_dev = math.ceil(n / ndev)
    eff = max(1, min(chunk, per_dev))
    per_dev = math.ceil(per_dev / eff) * eff
    return per_dev * ndev, eff


def _bucket_programs(spec: SweepSpec, pts: Sequence[SweepPoint],
                     length: int) -> Program:
    progs = []
    wl_cache: Dict[str, Dict[str, np.ndarray]] = {}
    for p in pts:
        if p.is_workload:
            ent = wl_cache.get(p.family)
            if ent is None:
                ent = wl_cache[p.family] = workload_entries(
                    p.family, p.nx, p.ny, p.seed)
            progs.append(load_program(ent))
        else:
            progs.append(load_program(make_traffic(
                p.traffic, p.nx, p.ny, length, rate=p.load, seed=p.seed,
                topology=p.topology)))
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *progs)


def _run_bucket(spec: SweepSpec, key: SweepKey, length: int,
                pts: Sequence[SweepPoint], ndev: int,
                chunk: int) -> Tuple[List[Dict], int]:
    """Simulate one bucket; returns (per-point stat dicts, new compiles)."""
    n = len(pts)
    padded, eff = _pad_rows(n, ndev, chunk)
    progs = _bucket_programs(spec, pts, length)
    depths = np.fromiter((p.fifo_depth for p in pts), np.int32, n)
    credits = np.fromiter((p.credits for p in pts), np.int32, n)
    if padded > n:  # repeat the first point; its rows are dropped below
        pad = padded - n

        def grow(x):
            return jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])
        progs = jax.tree_util.tree_map(grow, progs)
        depths = np.concatenate([depths, np.repeat(depths[:1], pad)])
        credits = np.concatenate([credits, np.repeat(credits[:1], pad)])
    shape_id = (key, ndev, eff, padded, length)
    fresh = shape_id not in _EXECUTED_SHAPES
    _EXECUTED_SHAPES.add(shape_id)
    stats = _bucket_jit(key, ndev, eff)(
        progs, jnp.asarray(depths, I32), jnp.asarray(credits, I32))
    host = {f: np.asarray(getattr(stats, f))[:n] for f in STAT_FIELDS}
    return [{f: float(host[f][i]) for f in STAT_FIELDS}
            for i in range(n)], int(fresh)


def _point_record(point: SweepPoint, stats: Dict[str, float]) -> Dict:
    return {
        "point": {"nx": point.nx, "ny": point.ny,
                  "topology": point.topology.spec,
                  "fifo_depth": point.fifo_depth, "credits": point.credits,
                  "traffic": point.traffic, "load": point.load,
                  "seed": point.seed},
        "stats": {k: round(v, 6) for k, v in stats.items()},
    }


def _point_from_record(record: Dict) -> SweepPoint:
    p = record["point"]
    from repro.mesh.topology import Topology
    return SweepPoint(nx=p["nx"], ny=p["ny"],
                      topology=Topology.parse(p["topology"]),
                      fifo_depth=p["fifo_depth"], credits=p["credits"],
                      traffic=p["traffic"], load=p["load"],
                      seed=p.get("seed", 0))


def run_sweep(spec: SweepSpec, *, cache_dir=None,
              devices: Optional[int] = None, chunk: int = 16,
              compile_cache_dir=None,
              progress: Optional[Callable[[str], None]] = None
              ) -> SweepResult:
    """Run (the uncached remainder of) a sweep spec; see the module
    docstring for the pipeline.  ``cache_dir`` may be a directory path
    or a :class:`ResultCache` (None disables caching); ``devices``
    requests the shard_map fan-out width; ``chunk`` bounds how many
    simulator states are live per device at once.  ``compile_cache_dir``
    additionally points JAX's persistent (on-disk) compilation cache at
    that directory, keyed under :func:`~repro.dse.cache.config_hash` —
    the same cache the simulation service (:mod:`repro.sim_service`)
    shares, so re-running a sweep in a fresh process deserializes its
    bucket executables instead of re-compiling them."""
    t0 = time.perf_counter()
    if compile_cache_dir is not None:
        from repro.compat import enable_persistent_compilation_cache
        enable_persistent_compilation_cache(compile_cache_dir,
                                            subkey=config_hash())
    log = progress if progress is not None else (lambda msg: None)
    cache = cache_dir if isinstance(cache_dir, ResultCache) \
        else ResultCache(cache_dir)
    points = spec.points()
    infeasible = [f"skipped {t.spec} fifo_depth={d}: {why}"
                  for t, d, why in spec.infeasible()]
    for line in infeasible:
        log(line)
    log(spec.describe())

    done: Dict[SweepPoint, Dict] = {}
    misses: List[SweepPoint] = []
    for p in points:
        rec = cache.get(spec.point_key(p))
        if rec is not None:
            done[p] = rec
        else:
            misses.append(p)
    ndev = _resolve_devices(devices)

    buckets: Dict[Tuple[SweepKey, int], List[SweepPoint]] = {}
    for p in misses:
        length = workload_entries(p.family, p.nx, p.ny, p.seed)[
            "op"].shape[-1] if p.is_workload else spec.traffic_length()
        buckets.setdefault((spec.sweep_key(p.topology), int(length)),
                           []).append(p)

    compiles = 0
    for (key, length), pts in buckets.items():
        log(f"bucket {key.cfg.topology.spec} L={length}: {len(pts)} points "
            f"({ndev} device(s), chunk {chunk})")
        stats, fresh = _run_bucket(spec, key, length, pts, ndev, chunk)
        compiles += fresh
        for p, s in zip(pts, stats):
            rec = _point_record(p, s)
            cache.put(spec.point_key(p), rec)
            done[p] = rec

    return SweepResult(
        spec=spec, records=[done[p] for p in points], n_points=len(points),
        simulated=len(misses), cache_hits=len(points) - len(misses),
        infeasible=infeasible, buckets=len(buckets), compiles=compiles,
        devices=ndev, wall_s=round(time.perf_counter() - t0, 2))


# -- frontier extraction -----------------------------------------------

def _config_points(spec: SweepSpec, records: Sequence[Dict], topology: str,
                   pattern: str, cost: CostModel) -> List[Dict]:
    """Reduce one topology's traffic records to per-(depth, credits)
    configuration points: saturation rate/throughput from the load
    curve, area/energy from the cost model."""
    groups: Dict[Tuple[int, int], List[Dict]] = {}
    for r in records:
        p = r["point"]
        if p["topology"] == topology and p["traffic"] == pattern:
            groups.setdefault((p["fifo_depth"], p["credits"]),
                              []).append(r)
    ntiles = spec.nx * spec.ny
    out = []
    for (depth, cred), recs in sorted(groups.items()):
        recs = sorted(recs, key=lambda r: r["point"]["load"])
        loads = [r["point"]["load"] for r in recs]
        lat = [r["stats"]["lat_mean"] for r in recs]
        acc = [r["stats"]["accepted"] for r in recs]
        sat = saturation_point(np.asarray(lat))
        peak = int(np.argmax(acc))
        packets = acc[peak] * ntiles * spec.measure
        cfg = dataclasses.replace(
            _point_from_record(recs[0]), fifo_depth=depth,
            credits=cred).mesh_config()
        out.append({
            "fifo_depth": depth, "credits": cred,
            "area_mm2": round(cost.buffer_area_mm2(cfg), 4),
            "throughput": round(float(max(acc)), 4),
            "saturation_rate": None if sat is None else float(loads[sat]),
            "zero_load_latency": round(float(lat[0]), 2),
            "energy_pj_per_packet": round(cost.energy_per_packet_pj(
                recs[peak]["stats"]["hops"], packets), 2),
            "loads": [round(float(x), 3) for x in loads],
        })
    return out


def frontier_artifact(result: SweepResult, cost: Optional[CostModel] = None,
                      pattern: Optional[str] = None) -> Dict:
    """The persisted JSON artifact: per-topology configuration points +
    Pareto frontier over (buffer area, saturation throughput).

    ``pattern`` picks the traffic pattern the frontier is computed from
    (default: ``"uniform"`` when swept, else the spec's first pattern —
    the standard saturation methodology)."""
    spec = result.spec
    cost = cost if cost is not None else CostModel()
    if pattern is None:
        pattern = "uniform" if "uniform" in spec.patterns else (
            spec.patterns[0] if spec.patterns else None)
    if pattern is None:
        raise ValueError(
            "frontier extraction needs a synthetic traffic pattern; this "
            "sweep spec only ran workload families")
    frontiers = {}
    for topo in spec.topologies:
        pts = _config_points(spec, result.records, topo.spec, pattern, cost)
        front = pareto_front(pts)
        frontiers[topo.spec] = {
            "points": pts,
            "frontier": front,
            "monotone": frontier_is_monotone(front),
        }
    return {
        "name": f"dse_frontier_{spec.name}",
        "mesh": f"{spec.nx}x{spec.ny}",
        "pattern": pattern,
        "config_hash": config_hash(),
        "cost_model": cost.to_json(),
        "spec": spec.describe(),
        "n_points": result.n_points,
        "frontiers": frontiers,
    }


def frontier_ascii(artifact: Dict) -> str:
    """Terminal rendering of every topology's frontier figure."""
    blocks = []
    for topo, f in artifact["frontiers"].items():
        blocks.append(f"  -- {topo} ({artifact['pattern']}, "
                      f"{artifact['mesh']}) --")
        blocks.append(ascii_frontier(f["points"], f["frontier"]))
    return "\n".join(blocks)


def write_frontier(path, artifact: Dict) -> Path:
    import json
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(artifact, indent=1, default=str))
    return path
