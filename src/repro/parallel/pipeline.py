"""Pipeline parallelism as a chain of token-queue channels (paper C6).

The paper's Option-2 congestion rule — *"the first node can have an
outstanding message counter that causes it to stall when the number of
outstanding messages equals the size of the second node's input FIFO"* —
is exactly a pipeline schedule: stages are mesh neighbors along one axis,
activations are the forward-path packets (one ``ppermute`` hop, the
``channel_send`` primitive), and the steady-state in-flight microbatch
count equals the channel depth (the BDP credit rule, C3).

:func:`pipeline_apply` is the SPMD rotating-buffer schedule: every device
executes its stage's layers each tick; activations rotate one hop along
``stage_axis``; microbatch ``m`` is injected at tick ``m`` and its output
surfaces at tick ``m + n_stages - 1``.  A full fwd+bwd through ``jax.grad``
yields the reverse (1B) wave automatically — the bwd ticks traverse the
reverse path like the paper's response network.

Bubble fraction = (S-1)/(T+S-1), the GPipe bound; the credit counter keeps
in-flight ≤ depth so no stage's input FIFO can overflow (deadlock-free by
C2's sink argument).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import pcast as _pcast
from repro.compat import shard_map

from repro.core.routing import shift

__all__ = ["pipeline_apply", "stage_params_spec", "bubble_fraction"]


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stage_params_spec(stage_axis: str):
    """Layer-stacked params (L, ...) are sharded over stages on dim 0."""
    return P(stage_axis)


def pipeline_apply(body: Callable, params_stacked, x_micro: jax.Array,
                   mesh, stage_axis: str = "model",
                   batch_axis=None) -> jax.Array:
    """Run ``body`` as a pipeline over ``stage_axis``.

    body:          (stage_layer_params, activation) -> activation; the
                   per-stage compute (its params carry a leading dim of
                   layers-per-stage and are scanned inside).
    params_stacked: pytree with leading dim L = n_stages * layers_per_stage,
                   sharded P(stage_axis) on dim 0.
    x_micro:       (n_micro, mb, S, D) microbatched input, replicated over
                   ``stage_axis`` (sharded over ``batch_axis`` on dim 1).

    Returns (n_micro, mb, S, D) outputs (what the LAST stage produced).
    """
    n_stages = mesh.shape[stage_axis]

    def island(params_l, xm):
        # (S, L/S, ...) sharded on dim 0 -> local (1, L/S, ...): drop it
        params_l = jax.tree.map(lambda p: p[0], params_l)
        # activations become stage-varying the moment stages diverge
        xm = _pcast(xm, (stage_axis,), to="varying")
        sid = lax.axis_index(stage_axis)
        n_micro = xm.shape[0]
        ticks = n_micro + n_stages - 1
        state = jnp.zeros_like(xm[0])                  # stage input buffer
        outs = jnp.zeros_like(xm)

        def tick(carry, t):
            state, outs = carry
            # stage 0 dequeues the next microbatch from the host queue
            mb_in = xm[jnp.minimum(t, n_micro - 1)]
            state = jnp.where(sid == 0, mb_in, state)
            y = body(params_l, state)
            # last stage commits its result for microbatch t-(S-1)
            slot = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (sid == n_stages - 1) & (t >= n_stages - 1)
            outs = lax.cond(
                emit,
                lambda o: lax.dynamic_update_index_in_dim(o, y, slot, 0),
                lambda o: o, outs)
            # forward-path hop: one ppermute to the next stage (C6 channel)
            state = shift(y, stage_axis, +1)
            return (state, outs), None

        (state, outs), _ = lax.scan(tick, (state, outs),
                                    jnp.arange(ticks))
        # broadcast the last stage's outputs to every stage (reverse path
        # is a sink: psum over the ring is always absorbable, C2)
        outs = lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(stage_axis), params_stacked),
                P(None, batch_axis))
    return shard_map(island, mesh=mesh,
                     in_specs=in_specs,
                     out_specs=P(None, batch_axis),
                     axis_names={stage_axis} | (
                         {batch_axis} if isinstance(batch_axis, str)
                         else set(batch_axis or ())))(params_stacked, x_micro)
