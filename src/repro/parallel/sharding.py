"""Logical-axis sharding rules (DP / TP / EP / SP / ZeRO-1).

The model code never names mesh axes directly; it asks :class:`Rules` to
constrain activations by *logical* axes.  One Rules object describes one
parallelism strategy; the dry-run and the perf hillclimb swap strategies by
swapping Rules (see launch/dryrun.py ``--strategy``).

Mapping to the paper: rows of the device grid (the ``data`` axis) are the
mesh's Y dimension, columns (``model``) the X dimension; the ``pod`` axis is
the off-chip link to the next pod ("the mesh extends over off-chip links",
BSG Ten).  Weight-stationary TP traffic flows along rows, gradient reduction
along columns then pods — dimension-ordered, exactly like the XY router.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

__all__ = ["Rules", "make_rules"]


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    batch: Axis = ("pod", "data")   # DP over pods and data rows
    seq: Axis = "model"             # SP: activation sequence sharding
    heads: Axis = "model"           # TP: attention heads
    ff: Axis = "model"              # TP: FFN hidden
    vocab: Axis = "model"           # "virtual mesh" embedding shard (C7)
    experts: Axis = "model"         # EP: MoE expert homes (sub-mesh, C7)
    kv_seq: Axis = "model"          # decode: KV cache sequence shard (C7)
    zero1: Axis = "data"            # optimizer-state shard axis (ZeRO-1)
    # how the MoE dispatch travels: "xy" = dimension-ordered two-phase
    # (paper C4), "flat" = single-axis all-to-all, "tp" = no dispatch
    # (experts tensor-parallel over ff)
    dispatch: str = "xy"
    # remat policy for the scanned layer: "full", "dots", "none"
    remat: str = "full"
    # attention implementation: "chunked" (memory-efficient jnp),
    # "ref" (materialized scores), "flash" (Pallas kernel),
    # "noattn" (cost-isolation stub, launch/costing.py only)
    attn_impl: str = "chunked"
    # SSD implementation: "chunked" | "kernel" | "skip" (cost isolation)
    ssd_impl: str = "chunked"
    # unroll lax.scan over layers (used by the dry-run cost variants:
    # cost_analysis counts a while-loop body once, so the roofline
    # extrapolates from unrolled 1- and 2-trip compiles)
    scan_unroll: bool = False
    # GQA: keep k/v at K heads inside attention (ablation; default repeats
    # KV to H heads so attention is fully head-parallel — see §Perf/qwen2)
    gqa_grouped: bool = False
    # Megatron TP as an explicit shard_map island (gather-once ->
    # local-heads -> reduce-scatter).  GSPMD's auto placement round-trips
    # q/k/v through per-tensor all-to-alls instead (§Perf/qwen2); manual
    # wins by ~8x on collective bytes.  Auto-falls-back per arch when
    # heads don't divide TP.
    manual_tp: bool = True
    # FSDP / ZeRO-3: additionally bank params+grads over the zero1 (data)
    # axis — weights all-gather per layer, grads reduce-scatter.  Required
    # to FIT the 72B train cells (TP-16 alone leaves params+grads
    # replicated over data: 8.5+8.5 GiB/chip; see EXPERIMENTS.md §Dry-run)
    fsdp: bool = False

    # ------------------------------------------------------------------
    def has_axis(self, name: str) -> bool:
        return name in self.mesh.axis_names

    def axis_size(self, axis: Axis) -> int:
        if axis is None:
            return 1
        names = (axis,) if isinstance(axis, str) else axis
        n = 1
        for a in names:
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n

    def _clean(self, axis: Axis) -> Axis:
        """Drop axes this mesh doesn't have (e.g. 'pod' on a single pod)."""
        if axis is None or isinstance(axis, str):
            return axis if (axis is None or self.has_axis(axis)) else None
        kept = tuple(a for a in axis if self.has_axis(a))
        return kept if kept else None

    def spec(self, *axes: Axis) -> P:
        return P(*[self._clean(a) for a in axes])

    def sharding(self, *axes: Axis) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*axes))

    def overlaps(self, a: Axis, b: Axis) -> bool:
        names = lambda ax: set((ax,) if isinstance(ax, str) else (ax or ()))
        return bool(names(self._clean(a)) & names(self._clean(b)))

    def cs(self, x: jax.Array, *axes: Axis) -> jax.Array:
        """with_sharding_constraint by logical axes (pads missing dims).

        Safety rails (both required by jax NamedSharding rules):
        * axes that do not evenly divide the dim are dropped (e.g. whisper's
          vocab=51866 cannot shard 16 ways -> logits replicated on vocab);
        * a mesh axis may appear once per spec — later duplicates drop.
        """
        axes = axes + (None,) * (x.ndim - len(axes))
        used: set = set()
        safe = []
        for d, a in enumerate(axes):
            a = self._clean(a)
            if a is not None:
                names = (a,) if isinstance(a, str) else tuple(a)
                if any(n in used for n in names) or \
                        x.shape[d] % max(self.axis_size(a), 1) != 0:
                    a = None
                else:
                    used.update(names)
            safe.append(a)
        return jax.lax.with_sharding_constraint(x, self.sharding(*safe))

    # common activation layouts ----------------------------------------
    def act_btd(self, x):   # (batch, seq, d_model)
        return self.cs(x, self.batch, self.seq, None)

    def act_bthd(self, x):  # (batch, seq, heads, head_dim)
        # inside attention, head (TP) sharding wins over SP on the same axis
        seq = None if self.overlaps(self.seq, self.heads) else self.seq
        return self.cs(x, self.batch, seq, self.heads, None)

    def act_btf(self, x):   # (batch, seq, ff)
        seq = None if self.overlaps(self.seq, self.ff) else self.seq
        return self.cs(x, self.batch, seq, self.ff)

    def logits(self, x):    # (batch, seq, vocab)
        seq = None if self.overlaps(self.seq, self.vocab) else self.seq
        return self.cs(x, self.batch, seq, self.vocab)


def make_rules(mesh: Mesh, strategy: str = "baseline", **overrides) -> Rules:
    """Named strategies used by the dry-run/hillclimb.

    baseline    — production defaults (TP rows, DP columns+pods, Megatron
                  SP for activations, xy MoE dispatch, full remat,
                  chunked attention)
    no_sp       — activations replicated over seq (ablation; higher temp)
    flat_a2a    — MoE dispatch as a single flat all-to-all (ablation)
    no_zero1    — optimizer states replicated (ablation)
    """
    base = dict()
    if strategy == "baseline":
        pass
    elif strategy == "fsdp":
        base["fsdp"] = True
    elif strategy == "no_sp":
        base["seq"] = None
    elif strategy == "flat_a2a":
        base["dispatch"] = "flat"
    elif strategy == "no_zero1":
        base["zero1"] = None
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    base.update(overrides)
    return Rules(mesh=mesh, **base)
