"""Version compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace in newer releases; the experimental module is slated for
removal.  Import it from here so the repo runs on both sides of the move:

    from repro.compat import shard_map
"""
from __future__ import annotations

import os

import jax
import numpy as np
from jax import lax

__all__ = ["shard_map", "axis_size", "pcast", "vma_of",
           "make_auto_mesh", "make_auto_device_mesh", "device_mesh_1d",
           "set_host_device_count"]


def set_host_device_count(n: int) -> None:
    """Give the process ``n`` CPU devices.  Must run before the first jax
    backend use.  ``jax_num_cpu_devices`` only exists on jax >= 0.5; older
    releases need the XLA flag."""
    try:
        jax.config.update("jax_num_cpu_devices", n)
    except AttributeError:
        flags = os.environ.get("XLA_FLAGS", "")
        flag = f"--xla_force_host_platform_device_count={n}"
        if flag not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()

try:  # jax >= 0.6: public API
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, **_kw):
        """Old-jax adapter.  ``axis_names`` (the new API's manual subset)
        maps onto the experimental API's complementary ``auto`` set;
        replication checking is off because the seed relies on
        ``lax.pcast`` (absent pre-0.6) to satisfy it."""
        extra = {}
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            if auto:
                extra["auto"] = auto
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False, **extra)

if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:
    def axis_size(axis_name):
        """Static size of a mapped axis: ``psum`` of a unit constant folds
        to a concrete int on pre-0.6 jax."""
        return lax.psum(1, axis_name)

if hasattr(lax, "pcast"):
    pcast = lax.pcast
else:
    def pcast(x, axis_name, *, to=None):  # noqa: ARG001
        """No varying-manual-axes type system before jax 0.6 — identity
        (the adapter above disables replication checking accordingly)."""
        return x


def vma_of(x):
    """Varying-manual-axes set of ``x`` (``jax.typeof(x).vma`` on jax >= 0.6,
    empty on older releases, which have no VMA tracking)."""
    if hasattr(jax, "typeof"):
        return tuple(getattr(jax.typeof(x), "vma", ()) or ())
    return ()


def make_auto_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with every axis in Auto (GSPMD) mode.

    ``axis_types`` / ``jax.sharding.AxisType`` only exist on jax >= 0.5;
    older releases have no explicit-sharding mode, so plain ``make_mesh``
    already means Auto there.
    """
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)


def make_auto_device_mesh(devices, axis_names):
    """``jax.sharding.Mesh`` over an explicit device array, all axes Auto
    (same version story as :func:`make_auto_mesh`)."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.sharding.Mesh(devices, axis_names, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.sharding.Mesh(devices, axis_names)


def device_mesh_1d(n: int, axis_name: str = "devices"):
    """A 1-D device mesh over the first ``n`` local devices — the
    fan-out axis :func:`shard_map` batch runners (``repro.dse``) shard
    over.  Raises ``ValueError`` when ``n`` exceeds the devices actually
    present; callers that want graceful degradation check
    ``jax.device_count()`` first."""
    devices = jax.devices()
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"device_mesh_1d needs 1 <= n <= {len(devices)} available "
            f"devices, got n={n}")
    return make_auto_device_mesh(np.asarray(devices[:n]), (axis_name,))
