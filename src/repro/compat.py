"""Version compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace in newer releases; the experimental module is slated for
removal.  Import it from here so the repo runs on both sides of the move:

    from repro.compat import shard_map
"""
from __future__ import annotations

import collections
import os
from pathlib import Path
from typing import Optional

import jax
import numpy as np
from jax import lax

__all__ = ["shard_map", "axis_size", "pcast", "vma_of",
           "make_auto_mesh", "make_auto_device_mesh", "device_mesh_1d",
           "set_host_device_count", "enable_persistent_compilation_cache",
           "disable_persistent_compilation_cache",
           "compilation_cache_stats", "reset_compilation_cache_stats"]


def set_host_device_count(n: int) -> None:
    """Give the process ``n`` CPU devices.  Must run before the first jax
    backend use.  ``jax_num_cpu_devices`` only exists on jax >= 0.5; older
    releases need the XLA flag."""
    try:
        jax.config.update("jax_num_cpu_devices", n)
    except AttributeError:
        flags = os.environ.get("XLA_FLAGS", "")
        flag = f"--xla_force_host_platform_device_count={n}"
        if flag not in flags:
            os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()

# ----------------------------------------------------------------------
# persistent (on-disk) XLA compilation cache
# ----------------------------------------------------------------------
# counters fed by jax.monitoring events; hits/misses are only recorded by
# jax while a cache dir is configured
_CACHE_EVENTS: collections.Counter = collections.Counter()
_CACHE_LISTENER_REGISTERED = False
_CACHE_DIR: Optional[Path] = None

_HIT_EVENT = "/jax/compilation_cache/cache_hits"
_MISS_EVENT = "/jax/compilation_cache/cache_misses"


def _cache_event_listener(event: str, **_kw) -> None:
    if "compilation_cache" in event:
        _CACHE_EVENTS[event] += 1


def _reset_jax_cache_state() -> None:
    """Force jax to re-resolve the cache directory.  The compilation
    cache initializes lazily at the first compile and then latches
    (``_cache_initialized``); without a reset, arming the cache after
    any jit call in the process is silently a no-op."""
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:  # private module moved: fresh-process arming still works
        pass


def enable_persistent_compilation_cache(cache_dir, *,
                                        subkey: Optional[str] = None) -> Path:
    """Point JAX's on-disk XLA compilation cache at ``cache_dir`` (created
    if missing) so a later process re-compiling an identical program
    deserializes the executable instead of re-running XLA — the cold-start
    story for the simulation service and the bench suites.

    ``subkey`` nests the cache one directory deeper (the sim service and
    :mod:`repro.dse` pass :func:`repro.dse.cache.config_hash`, keying the
    executables alongside the result cache: editing the simulator sources
    moves both to a fresh directory together).  The entry-size /
    compile-time floors are dropped so even the small CI programs cache.
    Returns the directory actually used; idempotent.
    """
    global _CACHE_LISTENER_REGISTERED, _CACHE_DIR
    path = Path(cache_dir)
    if subkey:
        path = path / subkey
    path.mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    for knob, value in (("jax_persistent_cache_min_entry_size_bytes", -1),
                        ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(knob, value)
        except AttributeError:  # knob not present on this jax release
            pass
    if not _CACHE_LISTENER_REGISTERED:
        try:
            jax.monitoring.register_event_listener(_cache_event_listener)
            # cache *hits* are reported as duration events, not plain ones
            jax.monitoring.register_event_duration_secs_listener(
                lambda event, _secs, **_kw: _cache_event_listener(event))
            _CACHE_LISTENER_REGISTERED = True
        except Exception:  # monitoring API moved/absent: stats degrade to 0
            pass
    _reset_jax_cache_state()
    _CACHE_DIR = path
    return path


def disable_persistent_compilation_cache() -> None:
    """Detach the on-disk compilation cache (fresh compiles pay full XLA
    cost again).  Used by benchmarks that need an honest no-cache
    baseline leg; re-enable with
    :func:`enable_persistent_compilation_cache`."""
    global _CACHE_DIR
    jax.config.update("jax_compilation_cache_dir", None)
    _reset_jax_cache_state()
    _CACHE_DIR = None


def compilation_cache_stats() -> dict:
    """Hit/miss/entry accounting of the persistent compilation cache (all
    zero until :func:`enable_persistent_compilation_cache` ran and a jit
    compile exercised it)."""
    entries = 0
    if _CACHE_DIR is not None and _CACHE_DIR.is_dir():
        entries = sum(1 for p in _CACHE_DIR.iterdir() if p.is_file())
    return {
        "enabled": _CACHE_DIR is not None,
        "dir": None if _CACHE_DIR is None else str(_CACHE_DIR),
        "hits": int(_CACHE_EVENTS[_HIT_EVENT]),
        "misses": int(_CACHE_EVENTS[_MISS_EVENT]),
        "entries": entries,
    }


def reset_compilation_cache_stats() -> None:
    """Zero the hit/miss counters (the on-disk entries stay)."""
    _CACHE_EVENTS.clear()


try:  # jax >= 0.6: public API
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, **_kw):
        """Old-jax adapter.  ``axis_names`` (the new API's manual subset)
        maps onto the experimental API's complementary ``auto`` set;
        replication checking is off because the seed relies on
        ``lax.pcast`` (absent pre-0.6) to satisfy it."""
        extra = {}
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            if auto:
                extra["auto"] = auto
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False, **extra)

if hasattr(lax, "axis_size"):
    axis_size = lax.axis_size
else:
    def axis_size(axis_name):
        """Static size of a mapped axis: ``psum`` of a unit constant folds
        to a concrete int on pre-0.6 jax."""
        return lax.psum(1, axis_name)

if hasattr(lax, "pcast"):
    pcast = lax.pcast
else:
    def pcast(x, axis_name, *, to=None):  # noqa: ARG001
        """No varying-manual-axes type system before jax 0.6 — identity
        (the adapter above disables replication checking accordingly)."""
        return x


def vma_of(x):
    """Varying-manual-axes set of ``x`` (``jax.typeof(x).vma`` on jax >= 0.6,
    empty on older releases, which have no VMA tracking)."""
    if hasattr(jax, "typeof"):
        return tuple(getattr(jax.typeof(x), "vma", ()) or ())
    return ()


def make_auto_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with every axis in Auto (GSPMD) mode.

    ``axis_types`` / ``jax.sharding.AxisType`` only exist on jax >= 0.5;
    older releases have no explicit-sharding mode, so plain ``make_mesh``
    already means Auto there.
    """
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)


def make_auto_device_mesh(devices, axis_names):
    """``jax.sharding.Mesh`` over an explicit device array, all axes Auto
    (same version story as :func:`make_auto_mesh`)."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.sharding.Mesh(devices, axis_names, axis_types=axis_types)
    except (AttributeError, TypeError):
        return jax.sharding.Mesh(devices, axis_names)


def device_mesh_1d(n: int, axis_name: str = "devices"):
    """A 1-D device mesh over the first ``n`` local devices — the
    fan-out axis :func:`shard_map` batch runners (``repro.dse``) shard
    over.  Raises ``ValueError`` when ``n`` exceeds the devices actually
    present; callers that want graceful degradation check
    ``jax.device_count()`` first."""
    devices = jax.devices()
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"device_mesh_1d needs 1 <= n <= {len(devices)} available "
            f"devices, got n={n}")
    return make_auto_device_mesh(np.asarray(devices[:n]), (axis_name,))
