"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].  One attention layer per 8; MoE on every second
layer (e=16, k=2); the rest dense SwiGLU MLPs."""
from .base import ModelConfig, MoEConfig, SSMConfig, register

JAMBA_52B = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_period=8,                       # 1 attn : 7 mamba
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  every_n_layers=2),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, num_groups=1,
                  conv_width=4, chunk=256),
    rope_theta=1e4,
    source="arXiv:2403.19887; hf",
))
