"""qwen2-vl-72b [vlm] — qwen2-72b backbone with M-RoPE; the vision frontend
is a STUB (input_specs() provides patch embeddings + 3D position ids)
[arXiv:2409.12191; hf]."""
from .base import ModelConfig, register

QWEN2_VL_72B = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),         # t/h/w sections of head_dim/2
    rope_theta=1e6,
    source="arXiv:2409.12191; hf",
))
