"""stablelm-3b [dense] — MHA [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from .base import ModelConfig, register

STABLELM_3B = register(ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    qkv_bias=False,
    rope_theta=1e4,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
))
