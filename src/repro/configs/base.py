"""Architecture + shape configuration system.

Every assigned architecture is a :class:`ModelConfig`; every workload cell is
a (:class:`ModelConfig`, :class:`ShapeConfig`) pair.  ``REGISTRY`` maps
``--arch`` ids to configs; ``SHAPES`` holds the four assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

__all__ = ["MoEConfig", "SSMConfig", "EncDecConfig", "ModelConfig",
           "ShapeConfig", "SHAPES", "REGISTRY", "register", "get_config",
           "list_archs", "reduced_config"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every_n_layers: int = 1        # 1 = every layer is MoE (mixtral/moonshot)
    capacity_factor: float = 1.25  # FIFO provisioning rule (paper C2/C6)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128           # N
    head_dim: int = 64             # P
    expand: int = 2                # d_inner = expand * d_model
    num_groups: int = 1            # G (B/C groups)
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length Q

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int
    encoder_seq: int = 1500        # whisper: 30 s of audio -> 1500 frames


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    sliding_window: Optional[int] = None                   # mixtral SWA
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_period: Optional[int] = None  # jamba: one attn layer per this many
    encdec: Optional[EncDecConfig] = None
    dtype: str = "bfloat16"
    source: str = ""               # citation tag from the assignment

    # ------------------------------------------------------------------
    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """May run the 500k-token long-context shape (DESIGN.md §6)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def param_count(self) -> int:
        """Total parameters (used for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k experts only)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    D, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    total = cfg.vocab_size * D * (1 if cfg.tie_embeddings else 2)

    def attn_params() -> int:
        p = D * H * hd + 2 * D * K * hd + H * hd * D
        if cfg.qkv_bias:
            p += H * hd + 2 * K * hd
        return p

    def dense_mlp(ff: int) -> int:
        return 3 * D * ff  # SwiGLU: gate, up, down

    def moe_mlp() -> int:
        m = cfg.moe
        experts = m.top_k if active_only else m.num_experts
        return D * m.num_experts + experts * 3 * D * m.d_ff_expert

    def ssm_params() -> int:
        s = cfg.ssm
        di = s.d_inner(D)
        nh = s.num_heads(D)
        # in_proj (x, z, B, C, dt) + conv + out_proj + A/D/dt_bias
        inp = D * (2 * di + 2 * s.num_groups * s.state_dim + nh)
        conv = s.conv_width * (di + 2 * s.num_groups * s.state_dim)
        return inp + conv + di * D + 3 * nh

    per_layer = []
    for layer in range(cfg.num_layers):
        is_attn = True
        if cfg.family == "ssm":
            is_attn = False
        elif cfg.family == "hybrid":
            is_attn = (layer % cfg.attn_period) == (cfg.attn_period - 1)
        mixer = attn_params() if is_attn else ssm_params()
        if cfg.moe is not None and (layer % cfg.moe.every_n_layers
                                    == cfg.moe.every_n_layers - 1):
            mlp = moe_mlp()
        elif cfg.d_ff > 0:
            mlp = dense_mlp(cfg.d_ff)
        else:
            mlp = 0
        per_layer.append(mixer + mlp + 2 * D)
    total += sum(per_layer) + D
    if cfg.encdec is not None:
        # encoder self-attn + mlp, and decoder cross-attention blocks
        enc = cfg.encdec.encoder_layers * (attn_params() + dense_mlp(cfg.d_ff) + 2 * D)
        cross = cfg.num_layers * (attn_params() + D)
        total += enc + cross
    return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode
    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # lazy: populate the registry
    _load_all()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> Sequence[str]:
    from . import _load_all
    _load_all()
    return sorted(REGISTRY)


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family == "hybrid" else 2),
        d_model=128,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        dtype="float32",
    )
    if cfg.mrope_sections is not None:
        # keep the 2:3:3 t/h/w split but sum to the reduced head_dim/2
        half = small["head_dim"] // 2
        s1 = half // 4
        small["mrope_sections"] = (s1, (half - s1) // 2,
                                   half - s1 - (half - s1) // 2)
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64)
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk=16)
    if cfg.attn_period is not None:
        small["attn_period"] = 2
    if cfg.encdec is not None:
        small["encdec"] = EncDecConfig(encoder_layers=2, encoder_seq=16)
    if cfg.sliding_window is not None:
        small["sliding_window"] = 16
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)
