"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].  Every layer is MoE; the assigned
d_ff=1408 is the per-expert FFN width."""
from .base import ModelConfig, MoEConfig, register

MOONSHOT_16B = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                              # no dense MLP; MoE every layer
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  every_n_layers=1),
    rope_theta=5e4,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
))
