"""Assigned architecture configs (one module per ``--arch`` id)."""
import importlib

from .base import (REGISTRY, SHAPES, EncDecConfig, ModelConfig, MoEConfig,
                   ShapeConfig, SSMConfig, get_config, list_archs,
                   reduced_config, register)

_MODULES = [
    "qwen2_72b", "yi_34b", "qwen15_32b", "stablelm_3b", "jamba_v01_52b",
    "moonshot_v1_16b_a3b", "mixtral_8x7b", "whisper_large_v3", "mamba2_370m",
    "qwen2_vl_72b",
]

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
