"""whisper-large-v3 [audio] — encoder-decoder; the conv/audio frontend is a
STUB: input_specs() provides precomputed 1500-frame embeddings
[arXiv:2212.04356; unverified]."""
from .base import EncDecConfig, ModelConfig, register

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                       # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    encdec=EncDecConfig(encoder_layers=32, encoder_seq=1500),
    rope_theta=1e4,                      # (whisper uses learned pos; rope as stand-in)
    source="arXiv:2212.04356; unverified",
))
