"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]."""
from .base import ModelConfig, SSMConfig, register

MAMBA2_370M = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, num_groups=1,
                  conv_width=4, chunk=256),
    source="arXiv:2405.21060; unverified",
))
