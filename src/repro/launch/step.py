"""Step builders: one jit-able function + shardings per (arch × shape) cell.

Three step kinds, matching the assigned shapes:

* ``train``   — fwd + bwd + AdamW/ZeRO-1 update (``train_4k``);
* ``prefill`` — forward, last-token logits only (``prefill_32k``);
* ``decode``  — one-token ``decode_step`` against a seq_len KV cache
                (``decode_32k`` / ``long_500k``).

:func:`build_cell` returns everything the dry-run, the trainer and the
server need: the function, its in/out shardings, donate_argnums, and
ShapeDtypeStruct stand-ins for every input (no allocation — the shannon/
kernels pattern).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.models.api import get_model
from repro.parallel.sharding import Rules, make_rules

__all__ = ["Cell", "cell_rules", "input_specs", "batch_shardings",
           "build_cell", "cell_applicable", "all_cells"]


# ---------------------------------------------------------------------------
# cell applicability (DESIGN.md §6)
# ---------------------------------------------------------------------------

def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k-token KV does not fit "
                       "any chip; skipped per DESIGN.md §6")
    return True, ""


def all_cells():
    from repro.configs import list_archs
    from repro.configs.base import get_config
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out


# ---------------------------------------------------------------------------
# per-cell rules: adapt the strategy to the cell's divisibility constraints
# ---------------------------------------------------------------------------

def cell_rules(mesh, cfg: ModelConfig, shape: ShapeConfig,
               strategy: str = "baseline", **overrides) -> Rules:
    rules = make_rules(mesh, strategy, **overrides)
    dp = rules.axis_size(rules.batch)
    if shape.global_batch % max(dp, 1) != 0:
        # e.g. long_500k's B=1: no batch sharding; spread the KV slab over
        # every axis instead ("virtual mesh" uses the whole edge).
        kv = tuple(a for a in ("data", "model") if rules.has_axis(a))
        rules = dataclasses.replace(rules, batch=None, zero1=None,
                                    kv_seq=kv if shape.kind == "decode"
                                    else rules.kv_seq)
    if shape.kind != "train":
        # inference: no remat (nothing to re-materialize)
        rules = dataclasses.replace(rules, remat="none")
    return rules


# ---------------------------------------------------------------------------
# input ShapeDtypeStructs + shardings
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the data inputs of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
        }
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            batch["mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return {"batch": batch}
    # decode: KV cache of seq_len, one new token per sequence
    model = get_model(cfg)
    cache = jax.eval_shape(
        functools.partial(model.init_cache, cfg, B, S))
    return {"cache": cache, "tokens": jax.ShapeDtypeStruct((B,), i32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, rules: Rules
                    ) -> Dict[str, Any]:
    b = rules.batch
    out = {"tokens": rules.sharding(b, None)}
    if shape.kind == "train":
        out["labels"] = rules.sharding(b, None)
        out["mask"] = rules.sharding(b, None)
    if cfg.family == "audio":
        out["frames"] = rules.sharding(b, None, None)
    if cfg.family == "vlm":
        out["positions"] = rules.sharding(None, b, None)
    return out


# ---------------------------------------------------------------------------
# the three step kinds
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, rules: Rules, opt_cfg: optim.OptConfig,
                    state_shardings: Optional[Dict] = None):
    model = get_model(cfg)

    def train_step(params, opt_state, batch):
        def loss(p):
            return model.loss_fn(p, batch, cfg, rules)

        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params, opt_state, om = optim.apply(
            opt_cfg, params, grads, opt_state, state_shardings)
        return params, opt_state, {"loss": l, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: Rules):
    model = get_model(cfg)

    def prefill_step(params, batch):
        kwargs = {}
        if cfg.family == "audio":
            kwargs["frames"] = batch["frames"]
        logits, _aux = model.forward(params, batch["tokens"], cfg, rules,
                                     positions=batch.get("positions"),
                                     last_only=True, **kwargs)
        return logits[:, 0]            # (B, V): next-token distribution

    return prefill_step


def make_serve_step(cfg: ModelConfig, rules: Rules):
    model = get_model(cfg)

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens, cfg, rules)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


# ---------------------------------------------------------------------------
# the full cell bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    rules: Rules
    fn: Any                      # the step callable
    args: tuple                  # ShapeDtypeStruct args, in order
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               strategy: str = "baseline",
               opt_cfg: Optional[optim.OptConfig] = None,
               **rule_overrides) -> Cell:
    rules = cell_rules(mesh, cfg, shape, strategy, **rule_overrides)
    model = get_model(cfg)
    p_shapes = model.param_shapes(cfg)
    p_specs = model.param_specs(cfg, rules)
    if rules.fsdp and shape.kind == "train":
        # ZeRO-3: bank params (and thus grads) over the zero1 axis too —
        # GSPMD all-gathers weights per layer and reduce-scatters grads
        from repro.optim.adamw import _zero1_spec
        from jax.sharding import NamedSharding
        p_specs = {k: NamedSharding(
            rules.mesh, _zero1_spec(p_specs[k].spec, p_shapes[k].shape,
                                    rules))
            for k in p_specs}
    specs = input_specs(cfg, shape)
    repl = rules.sharding()

    if shape.kind == "train":
        opt_cfg = opt_cfg or optim.OptConfig()
        s_shapes = optim.state_shapes(p_shapes)
        s_specs = optim.state_specs(p_specs, p_shapes, rules)
        fn = make_train_step(cfg, rules, opt_cfg, s_specs)
        b_spec = batch_shardings(cfg, shape, rules)
        return Cell(cfg, shape, rules, fn,
                    args=(p_shapes, s_shapes, specs["batch"]),
                    in_shardings=(p_specs, s_specs, b_spec),
                    out_shardings=(p_specs, s_specs, repl),  # repl: prefix
                    donate_argnums=(0, 1))

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, rules)
        b_spec = batch_shardings(cfg, shape, rules)
        out = rules.sharding(rules.batch, rules.vocab if
                             cfg.vocab_size % max(
                                 rules.axis_size(rules.vocab), 1) == 0
                             else None)
        return Cell(cfg, shape, rules, fn,
                    args=(p_shapes, specs["batch"]),
                    in_shardings=(p_specs, b_spec),
                    out_shardings=out,
                    donate_argnums=())

    # decode
    fn = make_serve_step(cfg, rules)
    c_specs = model.cache_specs(cfg, rules)
    tok_spec = rules.sharding(rules.batch)
    return Cell(cfg, shape, rules, fn,
                args=(p_shapes, specs["cache"], specs["tokens"]),
                in_shardings=(p_specs, c_specs, tok_spec),
                out_shardings=(tok_spec, c_specs),
                donate_argnums=(1,))
