"""Production meshes (single-pod and multi-pod).

``make_production_mesh`` is a FUNCTION, not a module constant, so importing
this module never touches jax device state (the dry-run sets the 512-device
XLA flag before its first jax call; tests run with 8 devices).

Axis semantics (DESIGN.md §5): ``model`` is the mesh X dimension (TP /
expert columns), ``data`` the Y dimension (DP rows), ``pod`` the off-chip
link between pods ("the mesh extends over off-chip links", BSG Ten).
"""
from __future__ import annotations

import jax  # noqa: F401  (re-exported for callers building custom meshes)

from repro.compat import make_auto_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "HW"]


class HW:
    """TPU v5e hardware constants used by the roofline (per chip)."""
    PEAK_FLOPS_BF16 = 197e12     # FLOP/s
    HBM_BW = 819e9               # bytes/s
    ICI_BW = 50e9                # bytes/s per link
    HBM_BYTES = 16 * 2**30       # capacity
    VMEM_BYTES = 128 * 2**20
    DCN_BW = 25e9                # cross-pod (the "off-chip link")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh over however many devices the test process has."""
    return make_auto_mesh(shape, axes)
