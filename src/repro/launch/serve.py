"""Serving launcher: continuous-batched decode against a KV cache.

The server loop is the paper's endpoint discipline applied to request
handling: a bounded slot pool (slots = credits), per-slot sequence state,
and one batched decode step per tick that services every slot at line
rate.  Admission is **inline prefill** (Orca-style token-level continuous
batching): a newly admitted request spends its first ticks feeding prompt
tokens through the same decode step (outputs discarded), so no slot ever
stalls another — the "absorb at line rate" rule (paper C2).  Finished
sequences free their slot (the credit returns on the reverse path).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduced \
      --requests 16 --slots 4 --max-new 32
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Deque, List, Optional

import numpy as np

__all__ = ["Request", "Server"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: "np.ndarray"
    max_new: int
    out: Optional[List[int]] = None
    submitted_at: float = 0.0
    done_at: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: Request
    fed: int = 0          # prompt tokens already fed

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.req.prompt)


class Server:
    """Continuous-batching decode server over the framework's serve step."""

    def __init__(self, cfg, mesh, slots: int, max_seq: int,
                 strategy: str = "baseline", eos_id: int = -1):
        import jax
        import jax.numpy as jnp
        from repro.configs.base import ShapeConfig
        from repro.launch import step as step_mod
        from repro.models.api import get_model

        self.jax, self.jnp = jax, jnp
        self.cfg, self.mesh = cfg, mesh
        self.model = get_model(cfg)
        self.slots, self.max_seq, self.eos_id = slots, max_seq, eos_id
        shape = ShapeConfig("serve", max_seq, slots, "decode")
        self.rules = step_mod.cell_rules(mesh, cfg, shape, strategy)
        self.serve_step = jax.jit(
            step_mod.make_serve_step(cfg, self.rules), donate_argnums=(1,))
        with mesh:
            self.params = jax.jit(
                self.model.init_params, static_argnums=0,
                out_shardings=self.model.param_specs(cfg, self.rules)
            )(cfg, jax.random.key(0))
            self.cache = self.model.init_cache(cfg, slots, max_seq)
        self.active: List[Optional[_Slot]] = [None] * slots
        self.feed = np.zeros((slots,), np.int32)   # token each slot eats next
        # deque: admission pops from the head every tick, and a deep
        # backlog with List.pop(0) is O(queue) per admit
        self.queue: Deque[Request] = collections.deque()
        self.completed: List[Request] = []
        self.ticks = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        req.out = []
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                slot = _Slot(req=req)
                self.active[s] = slot
                self.cache = self._reset_slot(self.cache, s)
                self.feed[s] = int(req.prompt[0])
                slot.fed = 1

    def _reset_slot(self, cache, s):
        """Zero slot ``s``'s lane: length always; recurrent state for
        SSM/hybrid families (stale KV needs no wipe — attention masks by
        length, and new appends overwrite)."""
        out = dict(cache)
        out["len"] = cache["len"].at[s].set(0)
        if "state" in cache:   # mamba2: (L,B,...) / jamba: (NP,nm,B,...)
            bdim = 1 if self.cfg.family == "ssm" else 2
            idx = (slice(None),) * bdim + (s,)
            out["state"] = cache["state"].at[idx].set(0)
            out["conv"] = cache["conv"].at[idx].set(0)
        return out

    # ------------------------------------------------------------------
    def tick(self):
        """One decode step for every slot (idle slots eat a pad token)."""
        self._admit()
        with self.mesh:
            nxt, self.cache = self.serve_step(
                self.params, self.cache, self.jnp.asarray(self.feed))
        nxt = np.asarray(nxt)
        self.ticks += 1
        for s, slot in enumerate(self.active):
            if slot is None:
                continue
            req = slot.req
            if slot.prefilling:
                self.feed[s] = int(req.prompt[slot.fed])   # ignore output
                slot.fed += 1
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self.feed[s] = tok
            if tok == self.eos_id or len(req.out) >= req.max_new:
                req.done_at = time.perf_counter()
                self.completed.append(req)
                self.active[s] = None   # credit returns; slot freed

    def run(self, tick_limit: int = 10_000) -> int:
        while (self.queue or any(sl is not None for sl in self.active)) \
                and self.ticks < tick_limit:
            self.tick()
        return self.ticks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh-shape", default="2,4")
    args = ap.parse_args()

    from repro.compat import set_host_device_count
    set_host_device_count(args.devices)
    from repro.configs import get_config, reduced_config
    from repro.launch.mesh import make_test_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh_shape = tuple(int(x) for x in args.mesh_shape.split(","))
    mesh = make_test_mesh(mesh_shape, ("data", "model"))
    rng = np.random.default_rng(0)
    server = Server(cfg, mesh, slots=args.slots, max_seq=args.max_seq)
    for r in range(args.requests):
        server.submit(Request(
            rid=r, prompt=rng.integers(0, cfg.vocab_size,
                                       size=args.prompt_len).astype(np.int32),
            max_new=args.max_new))
    t0 = time.perf_counter()
    ticks = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in server.completed)
    lat = [r.done_at - r.submitted_at for r in server.completed]
    print(f"served {len(server.completed)}/{args.requests} requests, "
          f"{toks} tokens in {ticks} ticks / {dt:.1f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s), "
          f"mean latency {np.mean(lat):.2f}s")


if __name__ == "__main__":
    main()
