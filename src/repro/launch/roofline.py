"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds (deliverable g):

* compute    = HLO_FLOPs_total / (chips × peak_FLOP/s)
* memory     = HLO_bytes_total / (chips × HBM_bw)
* collective = collective_bytes_total / (chips × link_bw)

``cost_analysis()`` on the SPMD-partitioned executable reports per-device
FLOPs/bytes, so totals are per-device × chips (the two cancel in the term).
Collective bytes are NOT in cost_analysis: :func:`parse_collectives` scans
the post-optimization HLO text and sums *operand* bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
deriving operand size from the result shape and the replica-group size.

Hardware constants: TPU v5e (mesh.HW).  MODEL_FLOPS uses 6·N·D for train
(2·N·D forward-only for prefill/decode), N = active params for MoE; the
reported ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import re
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .mesh import HW

__all__ = ["parse_collectives", "roofline", "RooflineReport", "model_flops",
           "DTYPE_BYTES", "NETWORK_MODES", "default_congestion_model"]

# collective-term pricing: "analytic" divides wire bytes by the nominal
# link bandwidth; "netsim" prices them with cycles measured on the
# cycle-level mesh simulator (a fitted repro.workloads.CongestionModel)
NETWORK_MODES = ("analytic", "netsim")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "ragged-all-to-all", "collective-broadcast")

# `%name = TYPE op-name(...)` where TYPE is one shape or a tuple of shapes
_OP_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*"
    r"(?P<op>" + "|".join(_COLL_OPS) + r")(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:  # replica_groups=[G,S]<=[N]: G groups of S
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-type {'bytes': operand bytes per device, 'count': n}.

    Result-shape -> operand-size conversion:
      all-gather: operand = result / group;  reduce-scatter: = result × group;
      others: operand = result.  ``-done`` ops are skipped (their ``-start``
      was counted); ``-start`` tuple results take the first element (the
      operand alias).
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        rtype = m.group("rtype")
        shapes = _SHAPE_RE.findall(rtype)
        if not shapes:
            continue
        if m.group("start") and rtype.startswith("("):
            shapes = shapes[:1]
        rbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        if op == "all-gather":
            obytes = rbytes / max(g, 1)
        elif op == "reduce-scatter":
            obytes = rbytes * max(g, 1)
        else:
            obytes = rbytes
        d = out.setdefault(op, {"bytes": 0.0, "count": 0, "wire_bytes": 0.0})
        d["bytes"] += obytes
        d["count"] += 1
        # ring-schedule wire estimate (bytes crossing links per device)
        if op == "all-reduce":
            wire = 2.0 * (g - 1) / max(g, 1) * obytes
        elif op in ("all-gather", "reduce-scatter"):
            wire = (g - 1) / max(g, 1) * (rbytes if op == "all-gather"
                                          else obytes)
        elif op == "all-to-all":
            wire = (g - 1) / max(g, 1) * obytes
        else:  # collective-permute: one hop
            wire = obytes
        d["wire_bytes"] += wire
    return out


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (inference), N = active params for MoE."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per sequence


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float          # raw XLA 'bytes accessed'
    bytes_adj_per_device: float      # mixer HLO traffic -> Pallas model
    coll_bytes_per_device: float
    coll_wire_bytes_per_device: float
    coll_detail: Dict[str, Dict[str, float]]
    compute_s: float
    memory_s: float                  # raw
    memory_adj_s: float              # TPU-target (kernels in VMEM)
    collective_s: float
    bottleneck: str                  # argmax(compute, memory_adj, collective)
    model_flops: float
    useful_ratio: float
    peak_step_s: float
    roofline_frac: float             # compute_s / peak_step_s
    network: str = "analytic"        # how collective_s was priced

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:>22s} {self.shape:<12s} {self.mesh:<9s} "
                f"compute {self.compute_s:9.3e}s  mem(adj) {self.memory_adj_s:9.3e}s  "
                f"coll {self.collective_s:9.3e}s  -> {self.bottleneck:<10s} "
                f"roofline {self.roofline_frac:5.1%} useful {self.useful_ratio:6.1%}")


@functools.lru_cache(maxsize=None)
def default_congestion_model(nx: int = 8, ny: int = 8):
    """The congestion model ``network="netsim"`` falls back to when the
    caller does not supply one: the standard calibration battery on an
    ``nx x ny`` mesh, numpy oracle, cached per mesh size (calibration
    runs the simulator, so it costs seconds the first time)."""
    from repro.workloads import calibrate
    return calibrate(nx, ny, backend="numpy")


def roofline(cfg, shape, mesh_name: str, chips: int,
             cost: Dict[str, float], colls, *,
             network: str = "analytic",
             congestion=None) -> RooflineReport:
    """``colls``: pre-parsed collectives dict, or raw HLO text.

    ``network="netsim"`` replaces the analytic collective term
    (bytes / nominal link bandwidth) with cycles from a
    :class:`repro.workloads.CongestionModel` — pass one as ``congestion``
    or the cached :func:`default_congestion_model` is fit on first use.
    Each ``coll_detail`` entry then also carries ``sim_cycles`` /
    ``sim_s`` / ``family``.
    """
    if network not in NETWORK_MODES:
        raise ValueError(f"network must be one of {NETWORK_MODES}, "
                         f"got {network!r}")
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    bytes_adj = float(cost.get("bytes adjusted", bytes_dev))
    if isinstance(colls, str):
        colls = parse_collectives(colls)
    coll_dev = sum(d["bytes"] for d in colls.values())
    wire_dev = sum(d["wire_bytes"] for d in colls.values())

    compute_s = flops_dev / HW.PEAK_FLOPS_BF16
    memory_s = bytes_dev / HW.HBM_BW
    memory_adj_s = bytes_adj / HW.HBM_BW
    if network == "netsim":
        if congestion is None:
            congestion = default_congestion_model()
        sim = congestion.collective_times(colls)
        colls = {op: {**d, **sim.get(op, {})} for op, d in colls.items()}
        collective_s = sum(d["sim_s"] for d in sim.values())
    else:
        collective_s = coll_dev / HW.ICI_BW
    terms = {"compute": compute_s, "memory": memory_adj_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_flops = flops_dev * chips
    peak = max(terms.values())
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        bytes_adj_per_device=bytes_adj,
        coll_bytes_per_device=coll_dev, coll_wire_bytes_per_device=wire_dev,
        coll_detail=colls,
        compute_s=compute_s, memory_s=memory_s, memory_adj_s=memory_adj_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=mf,
        useful_ratio=(mf / total_flops) if total_flops else 0.0,
        peak_step_s=peak,
        roofline_frac=(compute_s / peak) if peak else 0.0,
        network=network,
    )


# ---------------------------------------------------------------------------
# markdown table from the dry-run JSON directory
# ---------------------------------------------------------------------------

def _advice(rec: dict) -> str:
    """One sentence: what would move the dominant term down."""
    rl = rec["roofline"]
    b = rl["bottleneck"]
    colls = rl.get("coll_detail", {})
    ag = colls.get("all-gather", {}).get("bytes", 0)
    ar = colls.get("all-reduce", {}).get("bytes", 0)
    a2a = colls.get("all-to-all", {}).get("bytes", 0)
    kind = rec["shape"].split("_")[0]
    if b == "collective":
        if a2a > max(ag, ar):
            return ("shrink a2a payload: bf16 wire format + lower MoE "
                    "capacity factor")
        if ag >= ar:
            return ("TP act all-gathers dominate: overlap with matmuls, "
                    "bf16 boundaries, or trade TP for more DP/EP")
        return ("all-reduce bound: 2D-shard params (ZeRO-2/3 style) so "
                "grads reduce-scatter instead of all-reduce")
    if b == "memory":
        if kind in ("decode", "long"):
            return ("decode reads weights+KV once per token: quantize KV "
                    "to int8 / batch more sequences per step")
        return ("HBM-bound: fuse elementwise chains (Pallas), keep "
                "activations bf16, selective remat instead of full")
    return "compute-bound: already at the roof; raise per-chip batch"


def markdown_table(json_dir: Path) -> str:
    rows = []
    for f in sorted(json_dir.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok" and "roofline" in rec:
            rl = rec["roofline"]
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rl['compute_s']:.3e} "
                f"| {rl['memory_adj_s']:.3e} | {rl['memory_s']:.3e} "
                f"| {rl['collective_s']:.3e} | **{rl['bottleneck']}** "
                f"| {rl['model_flops']:.2e} | {rl['useful_ratio']:.1%} "
                f"| {rl['roofline_frac']:.1%} | {_advice(rec)} |")
        elif rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — "
                        f"| skipped | — | — | — | {rec['reason'][:60]} |")
    head = ("| arch | shape | compute (s) | memory adj (s) | memory raw (s) "
            "| collective (s) | bottleneck | MODEL_FLOPS | useful | roofline "
            "| what would move the dominant term |\n"
            "|---|---|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/pod")
    args = ap.parse_args()
    print(markdown_table(Path(args.dir)))


if __name__ == "__main__":
    main()
