"""Training launcher.

On the CPU container this runs REDUCED configs end-to-end (the full configs
are exercised by the dry-run); on a real pod the same entry point runs the
full config — only ``--devices``/``--reduced`` change.

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
      --reduced --steps 50 --devices 8 --mesh-shape 2,4
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh-shape", default="2,4")
    ap.add_argument("--strategy", default="baseline")
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.compat import set_host_device_count
    set_host_device_count(args.devices)

    from repro import optim
    from repro.configs import SHAPES, get_config, reduced_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import Prefetcher, batch_iterator
    from repro.launch.mesh import make_test_mesh
    from repro.runtime import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
        shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    else:
        shape = SHAPES[args.shape]

    mesh_shape = tuple(int(x) for x in args.mesh_shape.split(","))
    mesh = make_test_mesh(mesh_shape, ("data", "model")[-len(mesh_shape):]
                          if len(mesh_shape) == 2 else ("pod", "data", "model"))
    opt_cfg = optim.OptConfig(lr_peak=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, shape, mesh, opt_cfg, tcfg,
                      strategy=args.strategy)
    if args.resume:
        trainer.resume_or_init()
    else:
        trainer.init()
    data = Prefetcher(batch_iterator(cfg, shape, start_step=trainer.step))
    try:
        final = trainer.run(iter(data))
        print("final metrics:", final)
        for ev in trainer.events:
            print("event:", ev)
    finally:
        data.close()
        trainer.close()


if __name__ == "__main__":
    main()
