import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) cell on the production meshes, prove it fits,
and extract the roofline terms.

The two lines above MUST run before any other import: jax locks the device
count on first backend initialization, and the dry-run needs 512 host
placeholder devices to build the 16×16 and 2×16×16 meshes.  Tests and
benchmarks never import this module (they see 1–8 devices).

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh pod        # 40-cell baseline
  python -m repro.launch.dryrun --all --mesh multipod   # 2-pod pass
Outputs one JSON per cell under experiments/dryrun/<mesh>/.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config, list_archs
from repro.launch import costing
from repro.launch import roofline as rl
from repro.launch import step as step_mod
from repro.launch.mesh import make_production_mesh

OUT_ROOT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_name: str,
             strategy: str = "baseline", verbose: bool = True,
             with_cost: bool = True, **rule_overrides) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = step_mod.cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.devices.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "strategy": strategy, "chips": chips}
    try:
        with mesh:
            cell = step_mod.build_cell(cfg, shape, mesh, strategy,
                                       **rule_overrides)
            lowered = cell.lower()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            memory=_mem_dict(mem),
            memory_analytic=costing.analytic_memory(cfg, shape, cell.rules,
                                                    chips),
        )
        if with_cost:
            # exact per-device cost terms via unrolled 1/2-trip variants
            # (cost_analysis counts a scanned body once — see costing.py)
            cost, colls = costing.measure_cell_cost(
                cfg, shape, mesh, strategy, **rule_overrides)
            rep = rl.roofline(cfg, shape, mesh_name, chips, cost, colls)
            rec.update(cost=cost, roofline=rep.to_json())
            if verbose:
                print(rep.summary(), flush=True)
        elif verbose:
            print(f"{arch:>22s} {shape_name:<12s} {mesh_name:<9s} "
                  f"compiled OK in {t2 - t1:.0f}s", flush=True)
        if verbose and mem is not None:
            print(f"  per-device bytes: args={getattr(mem, 'argument_size_in_bytes', 0):.3e} "
                  f"out={getattr(mem, 'output_size_in_bytes', 0):.3e} "
                  f"temp={getattr(mem, 'temp_size_in_bytes', 0):.3e}",
                  flush=True)
    except Exception as e:  # a failure here is a bug in our sharding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"{arch:>22s} {shape_name:<12s} {mesh_name:<9s} "
                  f"FAILED: {type(e).__name__}: {str(e)[:300]}", flush=True)
    return rec


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list_archs(), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod"), default="pod")
    ap.add_argument("--strategy", default="baseline")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="compile + memory proof only (multipod pass: the "
                         "roofline table is single-pod per EXPERIMENTS.md)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output dir (hillclimb variants)")
    args = ap.parse_args()

    outdir = OUT_ROOT / (args.mesh + (f"-{args.tag}" if args.tag else ""))
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for arch, shape in cells:
        path = outdir / f"{arch}__{shape}.json"
        if args.skip_existing and path.exists():
            rec = json.loads(path.read_text())
            if rec.get("status") == "ok":
                n_ok += 1
                continue
        rec = run_cell(arch, shape, args.mesh, args.strategy,
                       with_cost=not args.no_cost)
        path.write_text(json.dumps(rec, indent=1))
        n_ok += rec["status"] == "ok"
        n_skip += rec["status"] == "skipped"
        n_err += rec["status"] == "error"
    print(f"\ndry-run [{args.mesh}]: {n_ok} ok, {n_skip} skipped, "
          f"{n_err} failed", flush=True)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
