"""Exact roofline cost terms via unrolled reduced-depth compiles.

``cost_analysis()`` on a compiled module counts a ``while``-loop body ONCE,
so a layer-scanned LM under-reports FLOPs/bytes/collectives by ~the layer
count.  Instead of unrolling the full 80-layer production graph (minutes of
compile per cell), we compile two UNROLLED variants of the same cell at 1
and 2 scan-trips and extrapolate linearly — valid because scan requires the
body to be identical across trips:

    total(T) = cost(1 trip) + (T - 1) × [cost(2 trips) - cost(1 trip)]

The 1-trip base correctly contains the non-scanned prologue/epilogue
(embedding, head, loss, optimizer update of the non-layer params); the trip
delta contains one layer body (fwd + remat'd bwd + its optimizer slice) and
its collectives.

The variants keep the production ``chunked`` attention; its inner KV-block
scan honours the same ``scan_unroll`` flag, so attention FLOPs/bytes are
counted per block rather than once.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax

from repro.configs.base import ModelConfig, ShapeConfig

from . import roofline as rl
from . import step as step_mod

__all__ = ["reduced_depth_cfg", "scan_trips", "measure_cell_cost",
           "netsim_collectives"]


def netsim_collectives(colls: Dict[str, Dict[str, float]],
                       congestion) -> Dict[str, Dict[str, float]]:
    """Annotate a parsed-collectives dict with simulated network timing.

    ``congestion`` is a fitted :class:`repro.workloads.CongestionModel`;
    every op entry gains ``sim_cycles`` / ``sim_s`` / ``family`` next to
    the analytic ``bytes`` / ``wire_bytes`` — the numbers
    ``roofline(..., network="netsim")`` prices the collective term with.
    """
    sim = congestion.collective_times(colls)
    return {op: {**d, **sim.get(op, {})} for op, d in colls.items()}


def reduced_depth_cfg(cfg: ModelConfig, n_units: int) -> ModelConfig:
    """Same arch at ``n_units`` scan trips of depth (full width)."""
    unit = cfg.attn_period if cfg.family == "hybrid" else 1
    kw = dict(num_layers=unit * n_units)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec,
                                           encoder_layers=n_units)
    return dataclasses.replace(cfg, **kw)


def scan_trips(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_period
    return cfg.num_layers


def _variant_trips(cfg: ModelConfig) -> int:
    """Second variant's trip count: deeper for small bodies, where a 1-trip
    delta would drown in constant-folding noise."""
    return min(scan_trips(cfg), 4 if cfg.d_model <= 2048 else 2)


def _measure_variants(cfg, shape, mesh, strategy, **rule_overrides):
    stats = []
    n2 = _variant_trips(cfg)
    for n in (1, n2):
        vcfg = reduced_depth_cfg(cfg, n)
        cell = step_mod.build_cell(vcfg, shape, mesh, strategy,
                                   scan_unroll=True, **rule_overrides)
        with mesh:
            compiled = cell.lower().compile()
        cost = compiled.cost_analysis() or {}
        colls = rl.parse_collectives(compiled.as_text())
        stats.append((float(cost.get("flops", 0.0)),
                      float(cost.get("bytes accessed", 0.0)), colls))
    return stats, n2


def kernel_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> float:
    """Per-device HBM traffic of the Pallas mixer kernels on TPU.

    flash_attention reads Q,K,V and writes O once per pass (scores stay in
    VMEM); ssd_scan reads x,dt,B,C and writes y (+ fp32 chunk states).
    Training ~4 passes (fwd, remat recompute, bwd read + grad write);
    prefill ~1.5.
    """
    T = shape.global_batch * shape.seq_len
    rw = 4.0 if shape.kind == "train" else 1.5
    hd, H, K = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    attn_l = (2 * H * hd + 2 * K * hd) * 2.0 * T          # q+o, k+v bf16
    total = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        total = cfg.num_layers * attn_l
    elif cfg.family == "ssm":
        s = cfg.ssm
        di, nh = s.d_inner(cfg.d_model), s.num_heads(cfg.d_model)
        ssd_l = (2 * di + 2 * s.num_groups * s.state_dim + nh) * 2.0 * T \
            + (T // s.chunk) * nh * s.state_dim * s.head_dim * 4.0
        total = cfg.num_layers * ssd_l
    elif cfg.family == "hybrid":
        s = cfg.ssm
        di, nh = s.d_inner(cfg.d_model), s.num_heads(cfg.d_model)
        ssd_l = (2 * di + 2 * s.num_groups * s.state_dim + nh) * 2.0 * T \
            + (T // s.chunk) * nh * s.state_dim * s.head_dim * 4.0
        n_attn = cfg.num_layers // cfg.attn_period
        total = n_attn * attn_l + (cfg.num_layers - n_attn) * ssd_l
    elif cfg.family == "audio":
        Te = shape.global_batch * cfg.encdec.encoder_seq
        enc = cfg.encdec.encoder_layers * (2 * H * hd + 2 * K * hd) * 2.0 * Te
        dec = cfg.num_layers * (attn_l                       # self
                                + (2 * H * hd) * 2.0 * T     # cross q+o
                                + (2 * K * hd) * 2.0 * Te)   # cross k,v
        total = enc + dec
    return rw * total / chips


def _shard_bytes(shapes, shardings) -> int:
    import numpy as np
    total = 0
    for k, sds in shapes.items():
        sh = shardings[k]
        local = sh.shard_shape(sds.shape) if sds.shape else ()
        total += int(np.prod(local, dtype=np.int64)) * sds.dtype.itemsize \
            if local else sds.dtype.itemsize
    return total


def analytic_memory(cfg: ModelConfig, shape: ShapeConfig, rules,
                    chips: int) -> Dict[str, float]:
    """TPU-expected per-device HBM residency (the CPU artifact's
    ``temp_size`` over-reports: its buffer assignment neither aliases
    donated inputs nor reuses while-loop carries).

    Components: params (bf16) + optimizer state (3×fp32, ZeRO-1-sharded) +
    gradients (transient, params-sized) + remat carries (one activation
    slab per scan trip) + logits (+fp32 softmax copy) + KV cache.
    """
    from repro.models.api import get_model
    model = get_model(cfg)
    p_shapes = model.param_shapes(cfg)
    p_specs = model.param_specs(cfg, rules)
    if rules.fsdp and shape.kind == "train":
        from jax.sharding import NamedSharding
        from repro.optim.adamw import _zero1_spec
        p_specs = {k: NamedSharding(
            rules.mesh, _zero1_spec(p_specs[k].spec, p_shapes[k].shape,
                                    rules)) for k in p_specs}
    params_b = _shard_bytes(p_shapes, p_specs)
    out: Dict[str, float] = {"params": params_b}
    dp = rules.axis_size(rules.batch)
    sp = rules.axis_size(rules.seq)
    B_loc = shape.global_batch / max(dp, 1)
    if shape.kind == "train":
        from repro import optim
        s_shapes = optim.state_shapes(p_shapes)
        s_specs = optim.state_specs(p_specs, p_shapes, rules)
        out["opt_state"] = sum(
            _shard_bytes(s_shapes[k], s_specs[k])
            for k in ("master", "m", "v"))
        out["grads"] = params_b
        trips = scan_trips(cfg)
        carry = B_loc * (shape.seq_len / max(sp, 1)) * cfg.d_model * 2.0
        out["remat_carries"] = trips * carry
        v_loc = cfg.vocab_size / (rules.axis_size(rules.vocab)
                                  if cfg.vocab_size %
                                  max(rules.axis_size(rules.vocab), 1) == 0
                                  else 1)
        out["logits"] = B_loc * (shape.seq_len / max(sp, 1)) * v_loc * 6.0
    elif shape.kind == "decode":
        cache = jax.eval_shape(functools.partial(
            model.init_cache, cfg, shape.global_batch, shape.seq_len))
        c_specs = model.cache_specs(cfg, rules)
        out["kv_cache"] = _shard_bytes(cache, c_specs)
    out["total"] = float(sum(out.values()))
    return out


def measure_cell_cost(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      strategy: str = "baseline", *, congestion=None,
                      **rule_overrides
                      ) -> Tuple[Dict[str, float], Dict[str, Dict[str, float]]]:
    """Returns (cost, collectives), per-device, extrapolated to full depth.

    cost keys: ``flops``, ``bytes accessed`` (raw XLA), ``bytes adjusted``
    (mixer-core HLO traffic replaced by the Pallas-kernel traffic — the
    TPU-target number; decode cells need no adjustment: their dominant
    traffic, the KV-cache read, is real HBM traffic on TPU too).

    Pass a fitted ``congestion`` model to get the collectives dict back
    already annotated with simulated timing (:func:`netsim_collectives`).
    """
    ((f1, b1, c1), (f2, b2, c2)), n2 = _measure_variants(
        cfg, shape, mesh, strategy, **rule_overrides)
    t = scan_trips(cfg)

    def extra(v1, v2):
        """v(T) = v1 + (T-1) * per-trip delta, clamped non-negative (a tiny
        body's delta can go slightly negative from constant folding)."""
        delta = max((v2 - v1) / max(n2 - 1, 1), 0.0)
        return v1 + (t - 1) * delta

    flops = extra(f1, f2)
    bytes_raw = extra(b1, b2)
    cost = {"flops": flops, "bytes accessed": bytes_raw}

    if shape.kind == "decode":
        cost["bytes adjusted"] = bytes_raw
    else:
        ((_, nb1, _), (_, nb2, _)), _n = _measure_variants(
            cfg, shape, mesh, strategy, attn_impl="noattn", ssd_impl="skip",
            **{k: v for k, v in rule_overrides.items()
               if k not in ("attn_impl", "ssd_impl")})
        nomix = extra(nb1, nb2)
        chips = mesh.devices.size
        cost["bytes adjusted"] = min(
            nomix + kernel_hbm_bytes(cfg, shape, chips), bytes_raw)
        cost["bytes mixer hlo"] = max(bytes_raw - nomix, 0.0)

    colls: Dict[str, Dict[str, float]] = {}
    for op in set(c1) | set(c2):
        d1 = c1.get(op, {"bytes": 0.0, "count": 0, "wire_bytes": 0.0})
        d2 = c2.get(op, {"bytes": 0.0, "count": 0, "wire_bytes": 0.0})
        colls[op] = {k: extra(d1[k], d2[k]) for k in d1}
    if congestion is not None:
        colls = netsim_collectives(colls, congestion)
    return cost, colls
