"""Deterministic, sharded synthetic data pipeline with credit-bounded
prefetch.

Production framing: each host process feeds the devices it owns; the global
batch is partitioned by (pod, data-row), matching the ``batch`` sharding
rule.  Prefetch depth follows the paper's credit rule (C3): in-flight
batches = bandwidth-delay product of the host->device path — we default to
2 credits (the classic double-buffer), configurable.

The generator is counter-based (stateless): batch ``i`` is a pure function
of (seed, i), so restart-after-failure resumes mid-epoch exactly (the
checkpoint stores only the step counter).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["DataConfig", "synthetic_batch", "batch_iterator", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    prefetch_credits: int = 2   # paper C3: BDP of host->device transfer
    pack_docs: bool = True      # pack multiple docs per row (mask at joins)
    mean_doc_len: int = 512


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
                    data_cfg: DataConfig = DataConfig(),
                    batch_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Batch ``step`` of the infinite deterministic stream.

    Emits a Zipf-distributed token stream with document packing: a weak
    long-range structure so losses move during the example runs (pure
    uniform tokens give flat loss immediately).
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    rng = np.random.default_rng(
        np.random.SeedSequence([data_cfg.seed, step]))
    V = cfg.vocab_size
    # Zipf-ish unigram with per-document offset (documents are "topics")
    ranks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
    if data_cfg.pack_docs:
        n_docs = max(1, (S + 1) // data_cfg.mean_doc_len)
        starts = np.sort(rng.integers(1, S + 1, size=(B, n_docs)), axis=1)
        doc_id = np.zeros((B, S + 1), np.int64)
        for j in range(n_docs):
            doc_id += (np.arange(S + 1)[None] >= starts[:, j:j + 1])
        offset = rng.integers(0, V, size=(B, 1)) * 31 + doc_id * 977
    else:
        offset = rng.integers(0, V, size=(B, 1))
    tokens = ((ranks + offset) % V).astype(np.int32)
    batch = {
        "tokens": tokens[:, :-1],
        "labels": tokens[:, 1:],
        "mask": np.ones((B, S), np.float32),
    }
    if cfg.family == "audio":
        fr = rng.standard_normal(
            (B, cfg.encdec.encoder_seq, cfg.d_model)).astype(np.float32) * 0.1
        batch["frames"] = fr
    if cfg.family == "vlm":
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
        batch["positions"] = np.broadcast_to(pos[None], (3, B, S)).copy()
    return batch


def batch_iterator(cfg: ModelConfig, shape: ShapeConfig,
                   start_step: int = 0,
                   data_cfg: DataConfig = DataConfig(),
                   batch_override: Optional[int] = None
                   ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, shape, step, data_cfg, batch_override)
        step += 1


class Prefetcher:
    """Credit-bounded background prefetch (paper C3 as a host-side queue).

    The producer thread holds ``credits`` tokens; each produced batch
    consumes one, each consumed batch returns one — the queue can never
    grow beyond the credit count (no unbounded host memory), and a fence
    (``close``) drains it.
    """

    def __init__(self, it: Iterator, credits: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=credits)
        self._it = it
        self._done = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._done.is_set():
                    return
                self._q.put(item)   # blocks when out of credits
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._done.set()
        while not self._q.empty():
            self._q.get_nowait()
