"""Mamba-2 (SSD — state-space duality) blocks and the attention-free LM.

The chunked SSD algorithm is implemented twice, deliberately:

* :func:`ssd_chunked` — pure jnp (differentiable, XLA-fused); used inside
  train/prefill graphs.  The inter-chunk recurrence is a ``lax.scan`` over
  S/chunk steps, everything intra-chunk is batched matmuls.
* ``repro.kernels.ssd_scan_op`` — the Pallas TPU kernel (same math, VMEM
  state carried across the sequential chunk grid); used for serving
  benchmarks and validated against the same oracle.

Decode carries (state (N,P) per head + conv tail) — O(1) per token, which
is what makes the 500k-token shape runnable (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Rules
from .layers import cross_entropy, embed_lookup, init_dense, init_norm, rms_norm

__all__ = ["param_table", "init_params", "param_shapes", "param_specs",
           "forward", "loss_fn", "init_cache", "cache_specs", "decode_step",
           "ssd_chunked"]


# ---------------------------------------------------------------------------
# chunked SSD in pure jnp
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, B, C, A, chunk: int = 256):
    """x: (b, S, H, P); dt: (b, S, H); B/C: (b, S, G, N); A: (H,) -> y like x.

    Matches the naive recurrence (kernels/ref.py) to fp32 tolerance.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))       # dt=0 pad is exact
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk
    q = chunk

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = jnp.repeat(B.astype(jnp.float32), hg, axis=2).reshape(b, nc, q, h, n)
    Cf = jnp.repeat(C.astype(jnp.float32), hg, axis=2).reshape(b, nc, q, h, n)
    Af = A.astype(jnp.float32)

    dA = dtf * Af                                   # (b, nc, q, h)
    cum = jnp.cumsum(dA, axis=2)                    # inclusive within chunk
    # li[b,c,i,j,h] = cum_i - cum_j  (broadcast: i on axis 2, j on axis 3)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask BEFORE exp: the masked upper triangle has positive exponents that
    # overflow, and grad-of-where would turn that inf into NaN.
    L = jnp.exp(jnp.where(tri, li, -1e30))

    xdt = xf * dtf[..., None]                       # (b, nc, q, h, p)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", Cf, Bf)   # (b,nc,q,q,h)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", cb * L, xdt)

    total = cum[:, :, -1]                           # (b, nc, h)
    decay_out = jnp.exp(total[:, :, None] - cum)    # (b, nc, q, h)
    states = jnp.einsum("bcqhn,bcqhp->bchnp", Bf * decay_out[..., None], xdt)

    def scan_fn(state, inp):
        st_c, tot_c = inp                           # (b,h,n,p), (b,h)
        new = jnp.exp(tot_c)[..., None, None] * state + st_c
        return new, state                           # emit state ENTERING chunk

    st0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, state_in = lax.scan(scan_fn, st0,
                           (states.transpose(1, 0, 2, 3, 4),
                            total.transpose(1, 0, 2)))
    state_in = state_in.transpose(1, 0, 2, 3, 4)    # (b, nc, h, n, p)

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp",
                         Cf * jnp.exp(cum)[..., None], state_in)
    y = (y_intra + y_inter).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# the Mamba-2 mixer block
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    conv_dim = di + 2 * s.num_groups * s.state_dim
    proj_out = 2 * di + 2 * s.num_groups * s.state_dim + nh
    return s, di, nh, conv_dim, proj_out


def mixer_table(cfg: ModelConfig, L: int) -> Dict[str, Tuple[tuple, tuple]]:
    s, di, nh, conv_dim, proj_out = _dims(cfg)
    D = cfg.d_model
    return {
        "norm": ((L, D), (None, None)),
        "in_proj": ((L, D, proj_out), (None, None, "heads")),
        "conv_w": ((L, s.conv_width, conv_dim), (None, None, "heads")),
        "conv_b": ((L, conv_dim), (None, "heads")),
        "A_log": ((L, nh), (None, "heads")),
        "D_skip": ((L, nh), (None, "heads")),
        "dt_bias": ((L, nh), (None, "heads")),
        "gate_norm": ((L, di), (None, "heads")),
        "out_proj": ((L, di, D), (None, "heads", None)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, S, Cd); w: (W, Cd); b: (Cd,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out + b


def mixer_apply(lp: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
                rules: Optional[Rules]) -> jax.Array:
    """One Mamba-2 mixer (pre-norm + residual handled by caller)."""
    s, di, nh, conv_dim, _ = _dims(cfg)
    Bsz, S, D = x.shape
    G, N, P = s.num_groups, s.state_dim, s.head_dim

    zxbcdt = x @ lp["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, lp["conv_w"], lp["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)
    xs, Bmat, Cmat = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(Bsz, S, nh, P)
    Bmat = Bmat.reshape(Bsz, S, G, N)
    Cmat = Cmat.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    if rules is not None:
        # inside the mixer, head (TP) sharding wins over SP on the same axis
        seq = None if rules.overlaps(rules.seq, rules.heads) else rules.seq
        xs = rules.cs(xs, rules.batch, seq, rules.heads, None)
    ssd_impl = rules.ssd_impl if rules is not None else "chunked"
    if ssd_impl == "skip":      # cost-isolation stub (launch/costing.py)
        y = xs
    elif ssd_impl == "kernel":
        from repro.kernels import ssd_scan_op
        y = ssd_scan_op(xs, dt.astype(x.dtype), Bmat, Cmat, A, chunk=s.chunk)
    else:
        y = ssd_chunked(xs, dt.astype(x.dtype), Bmat, Cmat, A, chunk=s.chunk)
    y = y + xs * lp["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 lp["gate_norm"], cfg.norm_eps)
    out = y @ lp["out_proj"]
    if rules is not None:
        out = rules.act_btd(out)
    return out


# ---------------------------------------------------------------------------
# single-token recurrence (decode)
# ---------------------------------------------------------------------------

def mixer_decode(lp, x, state, conv_tail, cfg: ModelConfig):
    """x: (B, D); state: (B, H, N, P); conv_tail: (B, W-1, conv_dim)."""
    s, di, nh, conv_dim, _ = _dims(cfg)
    Bsz = x.shape[0]
    G, N, P = s.num_groups, s.state_dim, s.head_dim

    zxbcdt = x @ lp["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    window = jnp.concatenate([conv_tail, xbc[:, None]], axis=1)  # (B,W,Cd)
    conv_out = (window * lp["conv_w"][None]).sum(1) + lp["conv_b"]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_tail = window[:, 1:]
    xs, Bmat, Cmat = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(Bsz, nh, P)
    Bmat = jnp.repeat(Bmat.reshape(Bsz, G, N), nh // G, axis=1)
    Cmat = jnp.repeat(Cmat.reshape(Bsz, G, N), nh // G, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # (B, H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)[..., None, None]                      # (B,H,1,1)
    upd = jnp.einsum("bhn,bhp->bhnp", Bmat.astype(jnp.float32),
                     xs.astype(jnp.float32) * dt[..., None])
    state = decay * state + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cmat.astype(jnp.float32), state)
    y = y.astype(x.dtype) + xs * lp["D_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(Bsz, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 lp["gate_norm"], cfg.norm_eps)
    return y @ lp["out_proj"], state, new_tail


# ---------------------------------------------------------------------------
# the attention-free LM (mamba2-370m)
# ---------------------------------------------------------------------------

def param_table(cfg: ModelConfig) -> Dict[str, Tuple[tuple, tuple]]:
    t = {
        "embed": ((cfg.vocab_size, cfg.d_model), ("vocab", None)),
        "final_norm": ((cfg.d_model,), (None,)),
        "lm_head": ((cfg.d_model, cfg.vocab_size), (None, "vocab")),
    }
    for k, v in mixer_table(cfg, cfg.num_layers).items():
        t[f"layers/{k}"] = v
    return t


def _resolve(cfg, rules: Optional[Rules], axes, shape):
    """vocab/heads labels -> mesh axes, with flat-dim divisibility checks."""
    if rules is None:
        return tuple(None for _ in axes)
    out = []
    for a, size in zip(axes, shape):
        if a in ("vocab", "heads"):
            axis = getattr(rules, a)
            out.append(axis if size % max(rules.axis_size(axis), 1) == 0
                       else None)
        else:
            out.append(None)
    return tuple(out)


def param_shapes(cfg):
    return {k: jax.ShapeDtypeStruct(s, jnp.float32
                                    if k.endswith(("A_log", "dt_bias")) else cfg.param_dtype)
            for k, (s, _a) in param_table(cfg).items()}


def param_specs(cfg, rules: Rules):
    return {k: rules.sharding(*_resolve(cfg, rules, a, s))
            for k, (s, a) in param_table(cfg).items()}


def init_params(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    table = param_table(cfg)
    keys = jax.random.split(key, len(table))
    out = {}
    for (name, (shape, _a)), k in zip(sorted(table.items()), keys):
        if "norm" in name:
            out[name] = init_norm(shape, cfg.param_dtype)
        elif name.endswith("A_log"):
            out[name] = jnp.log(jnp.linspace(1.0, 16.0, shape[-1]))[None] \
                .repeat(shape[0], 0).astype(jnp.float32)
        elif name.endswith(("D_skip", "conv_b")):
            out[name] = jnp.zeros(shape, cfg.param_dtype) if "conv" in name \
                else jnp.ones(shape, cfg.param_dtype)
        elif name.endswith("dt_bias"):
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            out[name] = init_dense(k, shape, cfg.param_dtype)
    return out


def _split(params):
    glob = {k: v for k, v in params.items() if not k.startswith("layers/")}
    layers = {k.split("/", 1)[1]: v for k, v in params.items()
              if k.startswith("layers/")}
    return glob, layers


def forward(params, tokens, cfg: ModelConfig, rules: Optional[Rules] = None,
            positions=None, embeds=None, last_only: bool = False):
    glob, layers = _split(params)
    x = embeds if embeds is not None else embed_lookup(glob["embed"], tokens, rules)
    x = x.astype(cfg.param_dtype)
    if rules is not None:
        x = rules.act_btd(x)

    def block(x, lp):
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        return x + mixer_apply(lp, h, cfg, rules)

    if rules is not None and rules.remat == "full":
        block = jax.checkpoint(block)
    elif rules is not None and rules.remat == "dots":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    x, _ = lax.scan(lambda c, lp: (block(c, lp), None), x, layers,
                    unroll=(rules.scan_unroll if rules else False))
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    logits = x @ glob["lm_head"]
    if rules is not None:
        logits = rules.cs(logits, rules.batch, None, rules.vocab) \
            if last_only else rules.logits(logits)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, rules=None):
    logits, _ = forward(params, batch["tokens"], cfg, rules)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0,
               filled: Optional[int] = None):
    s, di, nh, conv_dim, _ = _dims(cfg)
    L = cfg.num_layers
    return {
        "state": jnp.zeros((L, batch, nh, s.state_dim, s.head_dim), jnp.float32),
        "conv": jnp.zeros((L, batch, s.conv_width - 1, conv_dim), cfg.param_dtype),
        "len": jnp.full((batch,), filled or 0, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, rules: Rules):
    return {
        "state": rules.sharding(None, rules.batch, rules.heads, None, None),
        "conv": rules.sharding(None, rules.batch, None, rules.heads),
        "len": rules.sharding(rules.batch),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig,
                rules: Optional[Rules] = None, positions=None):
    glob, layers = _split(params)
    x = embed_lookup(glob["embed"], tokens[:, None], rules)[:, 0]
    x = x.astype(cfg.param_dtype)

    def layer(carry, xs):
        x = carry
        lp, st, ct = xs
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        out, st, ct = mixer_decode(lp, h, st, ct, cfg)
        return x + out, (st, ct)

    x, (st_all, ct_all) = lax.scan(layer, x,
                                   (layers, cache["state"], cache["conv"]),
                                   unroll=(rules.scan_unroll if rules else False))
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    logits = x @ glob["lm_head"]
    if rules is not None:
        logits = rules.cs(logits, rules.batch, rules.vocab)
    return logits, {"state": st_all, "conv": ct_all, "len": cache["len"] + 1}
