"""Decoder-only transformer (dense / MoE / VLM backbones).

Covers qwen2-72b, yi-34b, qwen1.5-32b, stablelm-3b, mixtral-8x7b,
moonshot-v1-16b-a3b and qwen2-vl-72b.  Layers are stacked along a leading
``L`` dimension and executed with ``lax.scan`` (+ configurable remat), so
the compiled HLO contains each layer body exactly once regardless of depth.

Params / shapes / shardings all derive from one table (:func:`param_table`),
which keeps init, the dry-run's ShapeDtypeStructs, and the NamedShardings
structurally in sync.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Rules
from . import moe as moe_mod
from .attention import attention, decode_attention, repeat_kv
from .layers import (cross_entropy, embed_lookup, init_dense, init_norm,
                     mrope, rms_norm, rope, swiglu)

__all__ = ["param_table", "init_params", "param_shapes", "param_specs",
           "forward", "loss_fn", "init_cache", "cache_specs", "decode_step"]

AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Parameter table: name -> (shape, logical sharding axes, init scale or None)
# Logical axes: "vocab" | "heads" | "ff" | "experts" | "layers"(=None) | None
# ---------------------------------------------------------------------------

def param_table(cfg: ModelConfig) -> Dict[str, Tuple[tuple, tuple]]:
    D, hd = cfg.d_model, cfg.head_dim
    H, K, F, V, L = (cfg.num_heads, cfg.num_kv_heads, cfg.d_ff,
                     cfg.vocab_size, cfg.num_layers)
    t: Dict[str, Tuple[tuple, tuple]] = {
        "embed": ((V, D), ("vocab", None)),
        "final_norm": ((D,), (None,)),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ((D, V), (None, "vocab"))
    lt: Dict[str, Tuple[tuple, tuple]] = {
        "attn_norm": ((L, D), (None, None)),
        "wq": ((L, D, H * hd), (None, None, "heads")),
        "wk": ((L, D, K * hd), (None, None, "kv_heads")),  # replicated if K<TP
        "wv": ((L, D, K * hd), (None, None, "kv_heads")),
        "wo": ((L, H * hd, D), (None, "heads", None)),
        "mlp_norm": ((L, D), (None, None)),
    }
    if cfg.qkv_bias:
        lt["bq"] = ((L, H * hd), (None, "heads"))
        lt["bk"] = ((L, K * hd), (None, "kv_heads"))
        lt["bv"] = ((L, K * hd), (None, "kv_heads"))
    if F > 0:
        lt["w_gate"] = ((L, D, F), (None, None, "ff"))
        lt["w_up"] = ((L, D, F), (None, None, "ff"))
        lt["w_down"] = ((L, F, D), (None, "ff", None))
    if cfg.moe is not None:
        m = cfg.moe
        E, Fe = m.num_experts, m.d_ff_expert
        # EP when E divides the model axis; otherwise expert-TP over ff.
        exp_axes = ("experts", None, "ff_expert")
        lt["router"] = ((L, D, E), (None, None, None))
        lt["moe_gate"] = ((L, E, D, Fe), (None,) + exp_axes)
        lt["moe_up"] = ((L, E, D, Fe), (None,) + exp_axes)
        lt["moe_down"] = ((L, E, Fe, D), (None, "experts", "ff_expert", None))
    for k, v in lt.items():
        t[f"layers/{k}"] = v
    return t


def _resolve_axis(cfg: ModelConfig, rules: Optional[Rules], name,
                  dim_size: Optional[int] = None):
    """Map table axis labels to Rules attributes, handling the EP/TP choice
    for MoE expert weights.

    Divisibility is checked on the FLAT weight dimension (``dim_size``), not
    the head count: e.g. yi-34b's 56 heads do not divide TP=16, but its flat
    H*hd = 7168 projection dim does, so the *weights* stay sharded (memory
    is what matters) while the attention activations fall back to GSPMD
    propagation (``heads_even`` in :func:`_attn_block`)."""
    if rules is None or name is None:
        return None

    def fits(axis, size):
        return axis if (size is None
                        or size % max(rules.axis_size(axis), 1) == 0) else None

    if name in ("heads", "kv_heads"):
        return fits(rules.heads, dim_size)
    if name in ("vocab", "ff"):
        return fits(getattr(rules, name), dim_size)
    if name in ("experts", "ff_expert"):
        ep = rules.axis_size(rules.experts)
        use_ep = cfg.moe is not None and ep > 1 and \
            cfg.moe.num_experts % ep == 0 and rules.dispatch != "tp"
        if name == "experts":
            return rules.experts if use_ep else None
        return None if use_ep else fits(rules.ff, dim_size)
    raise KeyError(name)


def param_shapes(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = cfg.param_dtype
    out = {}
    for name, (shape, _axes) in param_table(cfg).items():
        d = jnp.float32 if name.endswith("router") else dt
        out[name] = jax.ShapeDtypeStruct(shape, d)
    return out


def param_specs(cfg: ModelConfig, rules: Rules) -> Dict[str, Any]:
    out = {}
    for name, (shape, axes) in param_table(cfg).items():
        resolved = [_resolve_axis(cfg, rules, a, shape[i])
                    for i, a in enumerate(axes)]
        out[name] = rules.sharding(*resolved)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jax.Array]:
    table = param_table(cfg)
    keys = jax.random.split(key, len(table))
    out = {}
    for (name, (shape, _axes)), k in zip(sorted(table.items()), keys):
        if "norm" in name:
            out[name] = init_norm(shape, cfg.param_dtype)
        elif name.startswith("layers/b"):
            out[name] = jnp.zeros(shape, cfg.param_dtype)
        elif name.endswith("router"):
            out[name] = init_dense(k, shape, jnp.float32)
        else:
            out[name] = init_dense(k, shape, cfg.param_dtype)
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _split_layers(params: Dict[str, jax.Array]):
    glob = {k: v for k, v in params.items() if not k.startswith("layers/")}
    layers = {k.split("/", 1)[1]: v for k, v in params.items()
              if k.startswith("layers/")}
    return glob, layers


def _manual_tp_ok(cfg: ModelConfig, rules: Optional[Rules]) -> bool:
    """Explicit-island Megatron TP applies when whole q heads land on each
    column and the per-column heads align with GQA groups."""
    if rules is None or not rules.manual_tp or rules.heads != "model" \
            or not rules.has_axis("model"):
        return False
    tp = rules.axis_size("model")
    H, K = cfg.num_heads, cfg.num_kv_heads
    if tp <= 1 or H % tp:
        return False
    hq, g = H // tp, H // K
    return hq % g == 0 or g % hq == 0


def _attn_manual(x, lp, cfg: ModelConfig, rules: Rules, positions):
    """Megatron TP attention as an explicit shard_map island over `model`.

    Per column: all-gather the normed block input ONCE, project into the
    column's own q heads (and its GQA kv slice), attend locally, and
    reduce-scatter the wo product straight back to the seq-sharded layout.
    Forward collectives per layer: 1 AG(h) + 2 AG(k,v) + 1 RS(out) — vs
    GSPMD's per-tensor q/k/v all-to-all round-trips (EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    tp = rules.axis_size("model")
    hq, g = H // tp, H // K
    kv_w = max(hq // g, 1)                       # kv heads per column
    seq_sharded = rules.overlaps(rules.seq, "model") and S % tp == 0

    def island(x_l, positions, attn_norm, wq, wk, wv, wo, bq, bk, bv):
        col = lax.axis_index("model")
        bl = x_l.shape[0]
        h = rms_norm(x_l, attn_norm, cfg.norm_eps)
        if seq_sharded:
            h = lax.all_gather(h, "model", axis=1, tiled=True)
        sl = h.shape[1]                           # full seq after gather
        q = h @ wq                                # (b, S, hq*hd) local heads
        k = h @ wk                                # (b, S, K*hd/tp) partial
        v = h @ wv
        if cfg.qkv_bias:
            q, k, v = q + bq, k + bk, v + bv
        # k/v columns hold K*hd/tp lanes; gather to whole kv heads and take
        # this column's GQA slice
        k = lax.all_gather(k, "model", axis=2, tiled=True) \
            .reshape(bl, sl, K, hd)
        v = lax.all_gather(v, "model", axis=2, tiled=True) \
            .reshape(bl, sl, K, hd)
        kv0 = (col * hq) // g
        k = lax.dynamic_slice_in_dim(k, kv0, kv_w, axis=2)
        v = lax.dynamic_slice_in_dim(v, kv0, kv_w, axis=2)
        q = q.reshape(bl, sl, hq, hd)
        if cfg.mrope_sections is not None:       # (3, b, S) position streams
            q = mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
            pos1d = positions[0]
        else:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            pos1d = positions
        out = attention(q, k, v, impl=rules.attn_impl, causal=True,
                        window=cfg.sliding_window, q_positions=pos1d,
                        k_positions=pos1d,
                        unroll=rules.scan_unroll)
        out = (out.reshape(bl, sl, hq * hd) @ wo).astype(x_l.dtype)
        if seq_sharded:                           # back to seq-sharded
            out = lax.psum_scatter(out, "model", scatter_dimension=1,
                                   tiled=True)
        else:
            out = lax.psum(out, "model")
        return x_l + out

    # fully-manual island: partial-manual (auto batch axes) trips an XLA
    # CPU crash ("Invalid binary instruction opcode copy"); and decode/MoE
    # islands are fully manual anyway — keep one convention.
    bspec = rules._clean(rules.batch)
    names = {"model"} | ({bspec} if isinstance(bspec, str)
                         else set(bspec or ()))
    zero = jnp.zeros((), x.dtype)
    args = (x, positions, lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"],
            lp["wo"],
            lp.get("bq", zero), lp.get("bk", zero), lp.get("bv", zero))
    specs = (P(bspec, "model" if seq_sharded else None, None),  # x
             P(None, bspec, None) if cfg.mrope_sections is not None
             else P(bspec, None),                               # positions
             P(None),                                           # norm
             P(None, "model"), P(None, "model"), P(None, "model"),
             P("model", None),
             P("model") if cfg.qkv_bias else P(),
             P("model") if cfg.qkv_bias else P(),
             P("model") if cfg.qkv_bias else P())
    sm = shard_map(island, mesh=rules.mesh, in_specs=specs,
                   out_specs=P(bspec, "model" if seq_sharded else None, None),
                   axis_names=names)
    return sm(*args)


def _mlp_manual(x, lp, cfg: ModelConfig, rules: Rules):
    """Megatron TP SwiGLU island: AG(h) -> local F/tp -> RS(out)."""
    S = x.shape[1]
    tp = rules.axis_size("model")
    seq_sharded = rules.overlaps(rules.seq, "model") and S % tp == 0

    def island(x_l, norm, wg, wu, wd):
        h = rms_norm(x_l, norm, cfg.norm_eps)
        if seq_sharded:
            h = lax.all_gather(h, "model", axis=1, tiled=True)
        gte = h @ wg
        u = h @ wu
        act = jax.nn.silu(gte.astype(jnp.float32)).astype(h.dtype) * u
        out = (act @ wd).astype(x_l.dtype)
        if seq_sharded:
            out = lax.psum_scatter(out, "model", scatter_dimension=1,
                                   tiled=True)
        else:
            out = lax.psum(out, "model")
        return x_l + out

    bspec = rules._clean(rules.batch)
    names = {"model"} | ({bspec} if isinstance(bspec, str)
                         else set(bspec or ()))
    xspec = P(bspec, "model" if seq_sharded else None, None)
    sm = shard_map(island, mesh=rules.mesh,
                   in_specs=(xspec, P(None), P(None, "model"),
                             P(None, "model"), P("model", None)),
                   out_specs=xspec, axis_names=names)
    return sm(x, lp["mlp_norm"], lp["w_gate"], lp["w_up"], lp["w_down"])


def _attn_block(x, lp, cfg: ModelConfig, rules: Optional[Rules],
                positions, layer_pos_bias=None):
    if _manual_tp_ok(cfg, rules):
        # explicit Megatron island (8x less collective traffic than the
        # GSPMD auto placement — EXPERIMENTS.md §Perf/qwen2)
        return _attn_manual(x, lp, cfg, rules, positions)
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    # (NOTE: a `cs(h, batch, None, None)` "Megatron-SP hint" here was
    # measured 0% on qwen2 and a 2.6x REGRESSION on yi/qwen1.5 — GSPMD
    # re-reshards around advisory constraints; see EXPERIMENTS.md §Perf)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    heads_even = rules is not None and \
        H % max(rules.axis_size(rules.heads), 1) == 0
    if heads_even:
        q = rules.act_bthd(q)
    if cfg.mrope_sections is not None:
        q = mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        pos1d = positions[0]
    else:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        pos1d = positions
    # GQA layout choice (EXPERIMENTS.md §Perf/qwen2): grouped attention
    # (k/v at K heads) avoids materializing repeated KV but forces seq
    # all-gathers of k/v when K < TP; with Megatron-SP the repeated,
    # head-sharded layout needs NO attention-side collectives at all —
    # measured better, so repeat is the default and grouped is the
    # ablation (rules.gqa_grouped).
    if rules is None or not rules.gqa_grouped:
        k = repeat_kv(k, H // K)
        v = repeat_kv(v, H // K)
    if rules is not None and k.shape[2] % max(
            rules.axis_size(rules.heads), 1) == 0:
        k, v = rules.act_bthd(k), rules.act_bthd(v)
    impl = rules.attn_impl if rules is not None else "ref"
    out = attention(q, k, v, impl=impl, causal=True,
                    window=cfg.sliding_window,
                    q_positions=pos1d, k_positions=pos1d,
                    unroll=(rules.scan_unroll if rules else False))
    out = out.reshape(B, S, H * hd) @ lp["wo"]
    if rules is not None:
        out = rules.act_btd(out)
    return x + out


def _mlp_block(x, lp, cfg: ModelConfig, rules: Optional[Rules]):
    if cfg.moe is None and cfg.d_ff > 0 and rules is not None \
            and rules.manual_tp and rules.ff == "model" \
            and rules.has_axis("model") \
            and cfg.d_ff % rules.axis_size("model") == 0:
        return _mlp_manual(x, lp, cfg, rules), jnp.zeros((), jnp.float32)
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        mp = {"router": lp["router"], "w_gate": lp["moe_gate"],
              "w_up": lp["moe_up"], "w_down": lp["moe_down"]}
        out, aux = moe_mod.moe_block(h, mp, cfg, rules)
        if cfg.d_ff > 0:  # dense + MoE both present (not used by our cfgs)
            out = out + swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"], rules)
    else:
        out = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"], rules)
    return x + out, aux


def _remat(fn, rules: Optional[Rules]):
    policy = rules.remat if rules is not None else "none"
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(policy)


def forward(params: Dict[str, jax.Array], tokens: jax.Array,
            cfg: ModelConfig, rules: Optional[Rules] = None,
            positions: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            last_only: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V), moe_aux scalar).

    ``positions``: (B, S) int32, or (3, B, S) for M-RoPE.  ``embeds``
    optionally replaces the token embedding lookup (modality stubs).
    ``last_only`` computes logits for the final position only (serving
    prefill: avoids materializing the (B, S, V) tensor).
    """
    glob, layers = _split_layers(params)
    B, S = tokens.shape
    if positions is None:
        base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        positions = (jnp.broadcast_to(base, (3, B, S))
                     if cfg.mrope_sections is not None else base)
    x = embeds if embeds is not None else embed_lookup(glob["embed"], tokens, rules)
    x = x.astype(cfg.param_dtype)
    if rules is not None:
        x = rules.act_btd(x)

    block = _remat(functools.partial(_layer_body, cfg=cfg, rules=rules),
                   rules)

    def scan_fn(carry, lp):
        y, aux = block(carry, lp, positions)
        return y, aux

    x, auxs = lax.scan(scan_fn, x, layers,
                       unroll=(rules.scan_unroll if rules else False))
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    head = glob["embed"].T if cfg.tie_embeddings else glob["lm_head"]
    logits = x @ head
    if rules is not None:
        logits = rules.cs(logits, rules.batch, None, rules.vocab) \
            if last_only else rules.logits(logits)
    return logits, auxs.sum()


def _layer_body(x, lp, positions, cfg: ModelConfig, rules: Optional[Rules]):
    x = _attn_block(x, lp, cfg, rules, positions)
    x, aux = _mlp_block(x, lp, cfg, rules)
    return x, aux


def loss_fn(params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            rules: Optional[Rules] = None) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg, rules,
                          positions=batch.get("positions"),
                          embeds=batch.get("embeds"))
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + AUX_COEF * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode (serve_step): sequence-sharded KV cache (paper C7 "virtual mesh")
# ---------------------------------------------------------------------------

def _cache_len_dim(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               filled: Optional[int] = None) -> Dict[str, jax.Array]:
    S = _cache_len_dim(cfg, max_seq)
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    filled = 0 if filled is None else filled
    pos = jnp.where(jnp.arange(S) < filled, jnp.arange(S), -1)
    return {
        "k": jnp.zeros((L, batch, S, K, hd), cfg.param_dtype),
        "v": jnp.zeros((L, batch, S, K, hd), cfg.param_dtype),
        "pos": jnp.broadcast_to(pos[None], (batch, S)).astype(jnp.int32),
        "len": jnp.full((batch,), filled, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, rules: Rules) -> Dict[str, Any]:
    kv = rules.sharding(None, rules.batch, rules.kv_seq, None, None)
    return {"k": kv, "v": kv,
            "pos": rules.sharding(rules.batch, rules.kv_seq),
            "len": rules.sharding(rules.batch)}


def decode_step(params, cache, tokens: jax.Array, cfg: ModelConfig,
                rules: Optional[Rules] = None,
                positions: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One serve step: append ``tokens`` (B,) to the cache, return logits.

    The KV cache stays sequence-sharded; the new token's K/V are broadcast
    (a "remote store" to the owning shard) and attention partials return via
    psum (the reverse network).
    """
    glob, layers = _split_layers(params)
    B = tokens.shape[0]
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    S_cache = cache["k"].shape[2]
    cur_len = cache["len"]                                   # (B,)
    if positions is None:
        positions = cur_len.astype(jnp.int32)
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[None], (3, B))

    x = embed_lookup(glob["embed"], tokens[:, None], rules)  # (B,1,D)
    x = x.astype(cfg.param_dtype)
    slot = (cur_len % S_cache).astype(jnp.int32)             # SWA wraps

    def layer(carry, xs):
        x = carry
        lp, k_c, v_c = xs
        h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q = h @ lp["wq"]
        k_new = h @ lp["wk"]
        v_new = h @ lp["wv"]
        if cfg.qkv_bias:
            q, k_new, v_new = q + lp["bq"], k_new + lp["bk"], v_new + lp["bv"]
        q = q.reshape(B, H, hd)
        k_new = k_new.reshape(B, K, hd)
        v_new = v_new.reshape(B, K, hd)
        if cfg.mrope_sections is not None:
            q = mrope(q[:, None], positions[:, :, None],
                      cfg.mrope_sections, cfg.rope_theta)[:, 0]
            k_new = mrope(k_new[:, None], positions[:, :, None],
                          cfg.mrope_sections, cfg.rope_theta)[:, 0]
        else:
            q = rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
            k_new = rope(k_new[:, None], positions[:, None],
                         cfg.rope_theta)[:, 0]
        # remote store of the new KV into the owning sequence shard
        k_c = _scatter_kv(k_c, k_new[:, None], slot)
        v_c = _scatter_kv(v_c, v_new[:, None], slot)
        if rules is not None:
            q = rules.cs(q, rules.batch, None, None)
        # NOTE: no window mask here — a sliding-window cache is sized to the
        # window and wraps, so residency IS the window (DESIGN.md §6).
        out = decode_attention(rules if rules is not None else _NORULES,
                               q, k_c, v_c, cur_len + 1, window=None)
        out = out.reshape(B, 1, H * hd) @ lp["wo"]
        x = x + out
        h2 = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            mp = {"router": lp["router"], "w_gate": lp["moe_gate"],
                  "w_up": lp["moe_up"], "w_down": lp["moe_down"]}
            # decode uses the dense-layout path (tokens replicated)
            mo, _ = moe_mod.moe_block(h2, mp, cfg,
                                      _decode_rules(rules))
            x = x + mo
        else:
            x = x + swiglu(h2, lp["w_gate"], lp["w_up"], lp["w_down"], None)
        return x, (k_c, v_c)

    x, (k_all, v_all) = lax.scan(layer, x, (layers, cache["k"], cache["v"]),
                                 unroll=(rules.scan_unroll if rules else False))
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    head = glob["embed"].T if cfg.tie_embeddings else glob["lm_head"]
    logits = (x[:, 0] @ head)
    if rules is not None:
        logits = rules.cs(logits, rules.batch, rules.vocab)
    new_pos = _scatter_pos(cache["pos"], cur_len, slot)
    cache = {"k": k_all, "v": v_all, "pos": new_pos, "len": cur_len + 1}
    return logits, cache


class _NoRules:
    kv_seq = None

    @staticmethod
    def has_axis(_):
        return False


_NORULES = _NoRules()


def _decode_rules(rules):
    if rules is None:
        return None
    return dataclasses.replace(rules, seq=None,
                               dispatch="auto" if rules.dispatch == "xy"
                               else rules.dispatch)


def _scatter_kv(cache, new, slot):
    """cache: (B, S, K, hd); new: (B, 1, K, hd); slot: (B,)."""
    return jax.vmap(
        lambda c, n, s: lax.dynamic_update_slice(c, n.astype(c.dtype),
                                                 (s, 0, 0)))(cache, new, slot)


def _scatter_pos(pos, cur_len, slot):
    return jax.vmap(
        lambda p, l, s: lax.dynamic_update_slice(p, l[None].astype(p.dtype),
                                                 (s,)))(pos, cur_len, slot)
