"""Shared model layers: norms, RoPE/M-RoPE, embeddings, MLPs.

Everything is functional: params are plain dict pytrees, and each layer is a
pure function ``f(params, x, ...)``.  Sharding is applied by the caller via
:class:`repro.parallel.sharding.Rules`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "mrope", "swiglu", "init_dense",
           "init_norm", "embed_lookup", "cross_entropy"]


def init_dense(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM pretraining setups)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * s).astype(dtype)


def init_norm(shape, dtype):
    return jnp.ones(shape, dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32 accumulation regardless of activation dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim/2), fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S)."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)     # (B, S, hd/2)
    cos, sin = cos[..., None, :], sin[..., None, :]   # broadcast over heads
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def mrope(x: jax.Array, positions: jax.Array,
          sections: Tuple[int, int, int], theta: float = 1e6) -> jax.Array:
    """Multi-dimensional RoPE (qwen2-vl): ``positions`` is (3, B, S) — the
    temporal/height/width position streams; ``sections`` partitions the
    head_dim/2 frequency bands among them."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    cos_parts, sin_parts = [], []
    start = 0
    for i, sec in enumerate(sections):
        freqs = theta ** (-jnp.arange(start, start + sec, dtype=jnp.float32) / half)
        ang = positions[i].astype(jnp.float32)[..., None] * freqs  # (B,S,sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    cos = jnp.concatenate(cos_parts, -1)[..., None, :]
    sin = jnp.concatenate(sin_parts, -1)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, rules=None) -> jax.Array:
    """SwiGLU MLP with column-parallel in / row-parallel out (Megatron)."""
    g = x @ w_gate
    u = x @ w_up
    if rules is not None:
        g, u = rules.act_btf(g), rules.act_btf(u)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = h @ w_down
    if rules is not None:
        out = rules.act_btd(out)
    return out


def embed_lookup(table: jax.Array, tokens: jax.Array, rules=None) -> jax.Array:
    """Vocab-sharded embedding ("virtual mesh" table on the mesh edge).

    With the table sharded over vocab, GSPMD lowers the gather to a
    one-hot-mask + psum over the vocab axis — the remote-load gather of C1.
    """
    out = jnp.take(table, tokens, axis=0)
    if rules is not None:
        out = rules.act_btd(out)
    return out


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy in fp32 (stable log-softmax)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
