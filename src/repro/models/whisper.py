"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings ``frames: (B, enc_seq, d_model)``.
The encoder is bidirectional self-attention; the decoder adds causal
self-attention (with the sequence-sharded KV cache at decode) and
cross-attention into the encoder output (cross-KV precomputed once).

RoPE stands in for Whisper's learned positions (noted in the config file) —
irrelevant for systems behaviour, keeps the layer uniform with the LM stack.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Rules
from . import transformer as tfm
from .attention import attention, decode_attention, repeat_kv
from .layers import (cross_entropy, embed_lookup, init_dense, init_norm,
                     rms_norm, rope, swiglu)

__all__ = ["param_table", "init_params", "param_shapes", "param_specs",
           "forward", "loss_fn", "init_cache", "cache_specs", "decode_step",
           "encode"]


def _attn_fields(prefix, L, D, H, K, hd):
    return {
        f"{prefix}_norm": ((L, D), (None, None)),
        f"{prefix}_wq": ((L, D, H * hd), (None, None, "heads")),
        f"{prefix}_wk": ((L, D, K * hd), (None, None, "kv_heads")),
        f"{prefix}_wv": ((L, D, K * hd), (None, None, "kv_heads")),
        f"{prefix}_wo": ((L, H * hd, D), (None, "heads", None)),
    }


def _mlp_fields(prefix, L, D, F):
    return {
        f"{prefix}_mlp_norm": ((L, D), (None, None)),
        f"{prefix}_w_gate": ((L, D, F), (None, None, "ff")),
        f"{prefix}_w_up": ((L, D, F), (None, None, "ff")),
        f"{prefix}_w_down": ((L, F, D), (None, "ff", None)),
    }


def param_table(cfg: ModelConfig) -> Dict[str, Tuple[tuple, tuple]]:
    D, hd = cfg.d_model, cfg.head_dim
    H, K, F = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    Le, Ld = cfg.encdec.encoder_layers, cfg.num_layers
    t = {
        "embed": ((cfg.vocab_size, D), ("vocab", None)),
        "enc_final_norm": ((D,), (None,)),
        "final_norm": ((D,), (None,)),
        "lm_head": ((D, cfg.vocab_size), (None, "vocab")),
    }
    for k, v in {**_attn_fields("enc", Le, D, H, K, hd),
                 **_mlp_fields("enc", Le, D, F)}.items():
        t[f"enc/{k}"] = v
    for k, v in {**_attn_fields("self", Ld, D, H, K, hd),
                 **_attn_fields("cross", Ld, D, H, K, hd),
                 **_mlp_fields("dec", Ld, D, F)}.items():
        t[f"dec/{k}"] = v
    return t


def param_shapes(cfg):
    return {k: jax.ShapeDtypeStruct(s, cfg.param_dtype)
            for k, (s, _a) in param_table(cfg).items()}


def param_specs(cfg, rules: Rules):
    out = {}
    for k, (s, axes) in param_table(cfg).items():
        resolved = [tfm._resolve_axis(cfg, rules, a, s[i]) if a else None
                    for i, a in enumerate(axes)]
        out[k] = rules.sharding(*resolved)
    return out


def init_params(cfg: ModelConfig, key):
    table = param_table(cfg)
    keys = jax.random.split(key, len(table))
    out = {}
    for (name, (shape, _a)), k in zip(sorted(table.items()), keys):
        out[name] = init_norm(shape, cfg.param_dtype) if "norm" in name \
            else init_dense(k, shape, cfg.param_dtype)
    return out


def _split(params):
    glob = {k: v for k, v in params.items() if "/" not in k}
    enc = {k.split("/", 1)[1]: v for k, v in params.items()
           if k.startswith("enc/")}
    dec = {k.split("/", 1)[1]: v for k, v in params.items()
           if k.startswith("dec/")}
    return glob, enc, dec


def _sa(x, lp, prefix, cfg, rules, positions, causal, kv_x=None,
        kv_positions=None):
    """Self- or cross-attention block with residual."""
    B, S, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rms_norm(x, lp[f"{prefix}_norm"], cfg.norm_eps)
    src = h if kv_x is None else kv_x
    q = (h @ lp[f"{prefix}_wq"]).reshape(B, S, H, hd)
    k = (src @ lp[f"{prefix}_wk"]).reshape(B, src.shape[1], K, hd)
    v = (src @ lp[f"{prefix}_wv"]).reshape(B, src.shape[1], K, hd)
    kp = positions if kv_positions is None else kv_positions
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, kp, cfg.rope_theta)
    if rules is None or not rules.gqa_grouped:
        k = repeat_kv(k, H // K)
        v = repeat_kv(v, H // K)
    impl = rules.attn_impl if rules is not None else "ref"
    out = attention(q, k, v, impl=impl, causal=causal,
                    q_positions=positions, k_positions=kp,
                    unroll=(rules.scan_unroll if rules else False))
    out = out.reshape(B, S, H * hd) @ lp[f"{prefix}_wo"]
    if rules is not None:
        out = rules.act_btd(out)
    return x + out


def _mlp(x, lp, prefix, cfg, rules):
    h = rms_norm(x, lp[f"{prefix}_mlp_norm"], cfg.norm_eps)
    return x + swiglu(h, lp[f"{prefix}_w_gate"], lp[f"{prefix}_w_up"],
                      lp[f"{prefix}_w_down"], rules)


def encode(params, frames, cfg: ModelConfig, rules: Optional[Rules] = None):
    """frames: (B, enc_seq, D) stubbed frontend embeddings -> encoder out."""
    glob, enc, _dec = _split(params)
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = frames.astype(cfg.param_dtype)
    if rules is not None:
        x = rules.act_btd(x)

    def body(x, lp):
        x = _sa(x, lp, "enc", cfg, rules, positions, causal=False)
        return _mlp(x, lp, "enc", cfg, rules)

    if rules is not None and rules.remat in ("full", "dots"):
        body = jax.checkpoint(body)
    x, _ = lax.scan(lambda c, lp: (body(c, lp), None), x, enc,
                    unroll=(rules.scan_unroll if rules else False))
    return rms_norm(x, glob["enc_final_norm"], cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, rules: Optional[Rules] = None,
            positions=None, embeds=None, frames=None, last_only: bool = False):
    """Teacher-forced decoder pass.  ``frames`` (B, enc_seq, D) required
    (or pass ``embeds`` to stand in for encoder output directly)."""
    glob, _enc, dec = _split(params)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc_out = embeds if embeds is not None else \
        encode(params, frames, cfg, rules)
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_out.shape[1], dtype=jnp.int32), (B, enc_out.shape[1]))
    x = embed_lookup(glob["embed"], tokens, rules).astype(cfg.param_dtype)
    if rules is not None:
        x = rules.act_btd(x)

    def body(x, lp):
        x = _sa(x, lp, "self", cfg, rules, positions, causal=True)
        x = _sa(x, lp, "cross", cfg, rules, positions, causal=False,
                kv_x=enc_out, kv_positions=enc_pos)
        return _mlp(x, lp, "dec", cfg, rules)

    if rules is not None and rules.remat in ("full", "dots"):
        body = jax.checkpoint(body)
    x, _ = lax.scan(lambda c, lp: (body(c, lp), None), x, dec,
                    unroll=(rules.scan_unroll if rules else False))
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    logits = x @ glob["lm_head"]
    if rules is not None:
        logits = rules.cs(logits, rules.batch, None, rules.vocab) \
            if last_only else rules.logits(logits)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, rules=None):
    logits, _ = forward(params, batch["tokens"], cfg, rules,
                        frames=batch["frames"])
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               filled: Optional[int] = None, enc_out=None,
               params=None, rules=None):
    """Self-attention KV cache + precomputed cross-attention KV."""
    L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    # pad the cross-KV sequence so it can shard over the model axis
    Se = -(-cfg.encdec.encoder_seq // 16) * 16
    filled = filled or 0
    cache = {
        "k": jnp.zeros((L, batch, max_seq, K, hd), cfg.param_dtype),
        "v": jnp.zeros((L, batch, max_seq, K, hd), cfg.param_dtype),
        "xk": jnp.zeros((L, batch, Se, K, hd), cfg.param_dtype),
        "xv": jnp.zeros((L, batch, Se, K, hd), cfg.param_dtype),
        "len": jnp.full((batch,), filled, jnp.int32),
    }
    if enc_out is not None and params is not None:
        _g, _e, dec = _split(params)
        B, Se_, _ = enc_out.shape
        ep = jnp.broadcast_to(jnp.arange(Se_, dtype=jnp.int32), (B, Se_))

        def one(lp):
            xk = rope((enc_out @ lp["cross_wk"]).reshape(B, Se_, K, hd), ep,
                      cfg.rope_theta)
            xv = (enc_out @ lp["cross_wv"]).reshape(B, Se_, K, hd)
            return xk, xv

        xk, xv = jax.vmap(one)(dec)
        pad = Se - Se_
        cache["xk"] = jnp.pad(xk, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["xv"] = jnp.pad(xv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return cache


def cache_specs(cfg: ModelConfig, rules: Rules):
    kv = rules.sharding(None, rules.batch, rules.kv_seq, None, None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv,
            "len": rules.sharding(rules.batch)}


def decode_step(params, cache, tokens, cfg: ModelConfig,
                rules: Optional[Rules] = None, positions=None):
    glob, _enc, dec = _split(params)
    B = tokens.shape[0]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cur_len = cache["len"]
    pos = cur_len.astype(jnp.int32)
    S_cache = cache["k"].shape[2]
    slot = (cur_len % S_cache).astype(jnp.int32)
    Se = cache["xk"].shape[2]
    x = embed_lookup(glob["embed"], tokens[:, None], rules)[:, 0]
    x = x.astype(cfg.param_dtype)

    def layer(carry, xs):
        x = carry
        lp, k_c, v_c, xk, xv = xs
        # --- causal self-attention against the sharded cache -------------
        h = rms_norm(x, lp["self_norm"], cfg.norm_eps)
        q = rope(((h @ lp["self_wq"]).reshape(B, H, hd))[:, None],
                 pos[:, None], cfg.rope_theta)[:, 0]
        k_new = rope(((h @ lp["self_wk"]).reshape(B, K, hd))[:, None],
                     pos[:, None], cfg.rope_theta)[:, 0]
        v_new = (h @ lp["self_wv"]).reshape(B, K, hd)
        k_c = tfm._scatter_kv(k_c, k_new[:, None], slot)
        v_c = tfm._scatter_kv(v_c, v_new[:, None], slot)
        att = decode_attention(rules if rules is not None else tfm._NORULES,
                               q, k_c, v_c, cur_len + 1, window=None)
        x = x + att.reshape(B, H * hd) @ lp["self_wo"]
        # --- cross-attention against precomputed encoder KV --------------
        h = rms_norm(x, lp["cross_norm"], cfg.norm_eps)
        qx = rope(((h @ lp["cross_wq"]).reshape(B, H, hd))[:, None],
                  pos[:, None], cfg.rope_theta)[:, 0]
        # mask to the true encoder length (the cross-KV tail is padding)
        full = jnp.full((B,), cfg.encdec.encoder_seq, jnp.int32)
        attx = decode_attention(rules if rules is not None else tfm._NORULES,
                                qx, xk, xv, full, window=None)
        x = x + attx.reshape(B, H * hd) @ lp["cross_wo"]
        # --- MLP ----------------------------------------------------------
        h = rms_norm(x, lp["dec_mlp_norm"], cfg.norm_eps)
        x = x + swiglu(h[:, None], lp["dec_w_gate"], lp["dec_w_up"],
                       lp["dec_w_down"], None)[:, 0]
        return x, (k_c, v_c)

    x, (k_all, v_all) = lax.scan(
        layer, x, (dec, cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=(rules.scan_unroll if rules else False))
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    logits = x @ glob["lm_head"]
    if rules is not None:
        logits = rules.cs(logits, rules.batch, rules.vocab)
    return logits, {"k": k_all, "v": v_all, "xk": cache["xk"],
                    "xv": cache["xv"], "len": cur_len + 1}
