"""Mixture-of-Experts with capacity-provisioned, dimension-ordered dispatch.

The paper's network mechanics map one-to-one onto MoE routing:

* expert **capacity buffers** are the endpoint input FIFOs: a destination
  must absorb everything the network can deliver, so buffers are provisioned
  by the capacity factor and overflow tokens are *dropped* (the paper's
  Option 3 trade-off, measured by the aux/drop stats we return);
* the **dispatch** is a remote-store scatter, the **combine** the
  reverse-path gather;
* ``dispatch="xy"`` routes tokens in two phases — first along the ``data``
  axis (rebalance across rows), then along the ``model`` axis (deliver to
  the expert's home column) — the XY dimension-ordered route of C4 and the
  hierarchical all-to-all used across pods in production MoE systems.

Three dispatch modes (auto-selected by divisibility, overridable):

* ``tp``  — experts replicated, FFN width sharded (tensor-parallel experts;
            no token movement).  Required when E < |model|.
* ``ep``  — experts sharded over ``model``; activations replicated over
            ``model``, so dispatch is a local SELECT and combine is the
            row-parallel psum (GSPMD inserts it).
* ``xy``  — sequence-sharded activations with explicit two-phase all-to-all
            (shard_map island).  The paper-faithful mode.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import pcast as _pcast
from repro.compat import vma_of as _vma_of
from repro.compat import shard_map

from repro.configs.base import ModelConfig, MoEConfig
from repro.kernels import grouped_matmul
from .layers import init_dense

__all__ = ["init_moe", "moe_block", "router_topk", "capacity"]


def _pmean_all(x, names):
    """pmean over every manual axis, pcasting to varying only where the
    value is not already varying (VMA-safe)."""
    ax = tuple(sorted(names))
    missing = tuple(a for a in ax if a not in _vma_of(x))
    if missing:
        x = _pcast(x, missing, to="varying")
    return lax.pmean(x, ax)


def init_moe(key, cfg: ModelConfig, m: MoEConfig, dtype) -> Dict:
    D, E, F = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (D, E), jnp.float32),  # router in fp32
        "w_gate": init_dense(ks[1], (E, D, F), dtype),
        "w_up": init_dense(ks[2], (E, D, F), dtype),
        "w_down": init_dense(ks[3], (E, F, D), dtype),
    }


def capacity(tokens: int, m: MoEConfig, over: float = 1.0) -> int:
    """FIFO provisioning (paper C2): slots per expert for ``tokens``
    assignments = ceil(tokens * top_k / E * capacity_factor * over),
    rounded up to a multiple of 8 for TPU-friendly layouts."""
    raw = int(tokens * m.top_k * m.capacity_factor * over / m.num_experts) + 1
    return max(8, -(-raw // 8) * 8)


def router_topk(x2d: jax.Array, w_router: jax.Array, k: int,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. x2d: (T, D).  Returns (idx (T,k), weights (T,k) fp32
    renormalized, aux_loss scalar)."""
    logits = x2d.astype(jnp.float32) @ w_router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    weights, idx = lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = w_router.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return idx, weights, aux


def _fifo_slots(assign: jax.Array, num_experts: int, cap: int):
    """Slot allocation in arrival order (the FIFO): returns (slot, keep)."""
    onehot = jax.nn.one_hot(assign, num_experts, dtype=jnp.int32)  # (A, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(ranks, assign[:, None], axis=1)[:, 0]
    keep = slot < cap
    return slot, keep


def _dispatch(x2d, assign, slot, keep, num_experts, cap):
    """Scatter tokens into (E, cap, D) capacity buffers; dropped tokens
    (FIFO overflow) simply never land."""
    T_k = assign.shape[0]
    token_of = jnp.arange(T_k) // (T_k // x2d.shape[0])
    e_idx = jnp.where(keep, assign, num_experts)        # sink row for drops
    buf = jnp.zeros((num_experts + 1, cap, x2d.shape[1]), x2d.dtype)
    buf = buf.at[e_idx, jnp.minimum(slot, cap - 1)].add(x2d[token_of])
    return buf[:num_experts]


def _combine(buf_out, assign, slot, keep, weights2d, T):
    """Gather expert outputs back to token order with routing weights."""
    gathered = buf_out[jnp.where(keep, assign, 0),
                       jnp.minimum(slot, buf_out.shape[1] - 1)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    k = assign.shape[0] // T
    gathered = gathered.reshape(T, k, -1)
    return (gathered.astype(jnp.float32)
            * weights2d[..., None]).sum(1)


def _expert_ffn(buf, w_gate, w_up, w_down):
    """(E, cap, D) -> (E, cap, D) SwiGLU via grouped matmul."""
    g = grouped_matmul(buf, w_gate)
    u = grouped_matmul(buf, w_up)
    h = (jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(buf.dtype)
    return grouped_matmul(h, w_down)


# ---------------------------------------------------------------------------
# modes
# ---------------------------------------------------------------------------

def _moe_dense_layout(x2d, params, m: MoEConfig, rules, expert_axis,
                      ff_axis) -> Tuple[jax.Array, jax.Array]:
    """Shared path for ``tp`` (ff_axis sharded) and ``ep`` (expert_axis
    sharded): GSPMD places the collectives implied by the constraints."""
    T = x2d.shape[0]
    idx, weights, aux = router_topk(x2d, params["router"], m.top_k)
    assign = idx.reshape(-1)
    cap = capacity(T, m)
    slot, keep = _fifo_slots(assign, m.num_experts, cap)
    buf = _dispatch(x2d, assign, slot, keep, m.num_experts, cap)
    if rules is not None:
        buf = rules.cs(buf, expert_axis, None, None)
        wg = rules.cs(params["w_gate"], expert_axis, None, ff_axis)
        wu = rules.cs(params["w_up"], expert_axis, None, ff_axis)
        wd = rules.cs(params["w_down"], expert_axis, ff_axis, None)
    else:
        wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    out_buf = _expert_ffn(buf, wg, wu, wd)
    if rules is not None:
        out_buf = rules.cs(out_buf, expert_axis, None, None)
    out = _combine(out_buf, assign, slot, keep, weights, T)
    return out, aux


def _moe_local(x_btd, params, m: MoEConfig, rules
               ) -> Tuple[jax.Array, jax.Array]:
    """Local-dispatch MoE for replicated/ff-sharded experts (E < columns).

    When experts are NOT placed on specific chips (weights replicated over
    the mesh, FFN width TP-sharded), tokens never need to move: each device
    routes only its LOCAL tokens into local capacity buffers and runs the
    grouped matmul on them.  The only collective is the row-parallel
    reduction of the combined output (after w_down) — same as a dense FFN —
    done on the bf16 wire format and reduce-SCATTERED straight back to the
    outer seq-sharded layout.

    This is the paper's "locality of remote accesses" submode: prefer
    keeping traffic on-tile over provisioning N^2 FIFO space.  Without it
    (the naive GSPMD lowering of the dense layout), the *global* capacity
    buffers become partial sums that XLA all-reduces across every shard —
    10+ TB/device/step on mixtral (EXPERIMENTS.md §Perf).

    NOTE: activations enter replicated along the ff axis (seq sharding
    dropped) — every column must hold the SAME tokens or the ff reduction
    after w_down would sum different tokens\' outputs.
    """
    mesh = rules.mesh
    batch_spec = rules._clean(rules.batch)
    ff_axis = rules._clean(rules.ff)
    names = set()
    for a in (batch_spec, ff_axis):
        names |= {a} if isinstance(a, str) else set(a or ())
    ff_names = ((ff_axis,) if isinstance(ff_axis, str)
                else tuple(ff_axis or ()))
    # seq long enough to scatter back over ff? (decode has S == 1)
    scatter = bool(ff_names) and all(
        x_btd.shape[1] % rules.axis_size(a) == 0 for a in ff_names) \
        and x_btd.shape[1] > 1

    def island(x_l, router, wg, wu, wd):
        b_l, s_l, D = x_l.shape
        x2d = x_l.reshape(-1, D)
        T_l = x2d.shape[0]
        idx, weights, aux = router_topk(x2d, router, m.top_k)
        assign = idx.reshape(-1)
        cap = capacity(T_l, m)                 # per-DEVICE FIFO provisioning
        slot, keep = _fifo_slots(assign, m.num_experts, cap)
        buf = _dispatch(x2d, assign, slot, keep, m.num_experts, cap)
        out_buf = _expert_ffn(buf, wg, wu, wd)  # partial over ff shards
        out = _combine(out_buf, assign, slot, keep, weights, T_l)
        out = out.astype(x_l.dtype).reshape(b_l, s_l, D)
        if scatter:
            # bf16 wire + ring reduce-scatter back to seq-sharded layout:
            # 2x less operand bytes than f32, 2x less wire than all-reduce
            for a in ff_names:
                out = lax.psum_scatter(out, a, scatter_dimension=1,
                                       tiled=True)
        elif ff_names:
            out = lax.psum(out, ff_names)
        aux = _pmean_all(aux, names)
        return out, aux

    sm = shard_map(
        island, mesh=mesh,
        in_specs=(P(batch_spec, None, None), P(None, None),
                  P(None, None, ff_axis), P(None, None, ff_axis),
                  P(None, ff_axis, None)),
        out_specs=(P(batch_spec, ff_axis if scatter else None, None), P()),
        axis_names=names)
    return sm(x_btd, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])


def _moe_xy(x_btd, params, m: MoEConfig, rules) -> Tuple[jax.Array, jax.Array]:
    """Paper-faithful two-phase dispatch (shard_map island).

    Expects activations sequence-sharded over ``model`` and batch-sharded
    over ``data``.  Phase Y (data axis): round-robin token rebalance across
    rows.  Phase X (model axis): deliver to the expert's home column.
    Combine runs the two phases in reverse (the response network).
    """
    mesh = rules.mesh
    d_axis, m_axis = "data", "model"
    R = mesh.shape[d_axis]
    C = mesh.shape[m_axis]
    E = m.num_experts
    assert E % C == 0, f"xy dispatch needs experts {E} divisible by cols {C}"
    e_loc = E // C
    batch_spec = rules._clean(rules.batch)

    use_y = getattr(rules, "dispatch", "xy") != "x"

    def island(x_l, router, wg, wu, wd):
        b_l, s_l, D = x_l.shape
        x2d = x_l.reshape(-1, D)
        T = x2d.shape[0]
        idx, weights, aux = router_topk(x2d, router, m.top_k)
        assign = idx.reshape(-1)                       # (T*k,)
        A = assign.shape[0]
        token_of = jnp.arange(A) // m.top_k

        if use_y:
            # ---- Phase Y (rows): round-robin rebalance along `data` ------
            row_of = jnp.arange(A) % R                 # deterministic RR
            cap1 = max(8, -(-int(A / R * m.capacity_factor) // 8) * 8)
            slot1, keep1 = _fifo_slots(row_of, R, cap1)
            r_idx = jnp.where(keep1, row_of, R)
            buf1 = jnp.zeros((R + 1, cap1, D), x_l.dtype) \
                .at[r_idx, jnp.minimum(slot1, cap1 - 1)].add(x2d[token_of])[:R]
            meta1 = jnp.zeros((R + 1, cap1), jnp.int32) \
                .at[r_idx, jnp.minimum(slot1, cap1 - 1)].add(assign + 1)[:R]
            buf1 = lax.all_to_all(buf1, d_axis, split_axis=0, concat_axis=0,
                                  tiled=True)          # rows merged here
            meta1 = lax.all_to_all(meta1, d_axis, split_axis=0,
                                   concat_axis=0, tiled=True)
            buf1 = buf1.reshape(R * cap1, D)
            e_in = meta1.reshape(R * cap1) - 1         # -1 = empty slot
        else:
            # ---- "x" dispatch: straight to the expert's home column ------
            # (beyond-paper variant: skips the row rebalance — half the
            # wire when rows are already balanced; §Perf/moonshot)
            buf1 = x2d[token_of]                       # (A, D)
            e_in = assign

        # ---- Phase X (columns): deliver to expert home column ------------
        col_of = jnp.where(e_in >= 0, e_in // e_loc, C)
        rows1 = R * cap1 if use_y else A
        cap2 = max(8, -(-int(rows1 * (1 if use_y else m.capacity_factor)
                             / C) // 8) * 8)
        slot2, keep2 = _fifo_slots(col_of, C + 1, cap2)
        keep2 &= e_in >= 0
        c_idx = jnp.where(keep2, col_of, C)
        buf2 = jnp.zeros((C + 1, cap2, D), x_l.dtype) \
            .at[c_idx, jnp.minimum(slot2, cap2 - 1)].add(buf1)[:C]
        meta2 = jnp.zeros((C + 1, cap2), jnp.int32) \
            .at[c_idx, jnp.minimum(slot2, cap2 - 1)].add(e_in + 1)[:C]
        buf2 = lax.all_to_all(buf2, m_axis, split_axis=0, concat_axis=0,
                              tiled=True)
        meta2 = lax.all_to_all(meta2, m_axis, split_axis=0, concat_axis=0,
                               tiled=True)
        toks = buf2.reshape(C * cap2, D)
        e_here = meta2.reshape(C * cap2) - 1           # global expert id
        col = lax.axis_index(m_axis)
        e_local = jnp.where(e_here >= 0, e_here - col * e_loc, e_loc)

        # ---- local expert FFN over capacity buffers ----------------------
        cap3 = max(8, -(-int(C * cap2 / e_loc) // 8) * 8)
        slot3, keep3 = _fifo_slots(jnp.clip(e_local, 0, e_loc), e_loc + 1, cap3)
        keep3 &= e_here >= 0
        el_idx = jnp.where(keep3, e_local, e_loc)
        ebuf = jnp.zeros((e_loc + 1, cap3, D), x_l.dtype) \
            .at[el_idx, jnp.minimum(slot3, cap3 - 1)].add(toks)[:e_loc]
        eout = _expert_ffn(ebuf, wg, wu, wd)
        # un-scatter expert outputs back into the phase-X receive layout
        back = eout[jnp.where(keep3, e_local, 0),
                    jnp.minimum(slot3, cap3 - 1)]
        back = jnp.where(keep3[:, None], back, 0).astype(x_l.dtype)

        # ---- reverse path (the response network): X phase, then Y --------
        rbuf2 = lax.all_to_all(back.reshape(C, cap2, D), m_axis,
                               split_axis=0, concat_axis=0, tiled=True)
        # rows I sent to column c sit at (c, slot2) of the returned buffer
        got = rbuf2[jnp.where(keep2, col_of, 0),
                    jnp.minimum(slot2, cap2 - 1)]
        got = jnp.where(keep2[:, None], got, 0)
        if use_y:
            rbuf1 = lax.all_to_all(got.reshape(R, cap1, D), d_axis,
                                   split_axis=0, concat_axis=0, tiled=True)
            # un-scatter phase Y back to (token, k) assignments
            out_a = rbuf1[jnp.where(keep1, row_of, 0),
                          jnp.minimum(slot1, cap1 - 1)]
            out_a = jnp.where(keep1[:, None], out_a, 0)
        else:
            out_a = got                                # already (A, D)
        out = (out_a.reshape(T, m.top_k, D).astype(jnp.float32)
               * weights[..., None]).sum(1)
        # aux must come out replicated over EVERY manual axis (pod included
        # on the multi-pod mesh), or the P() out_spec is unprovable
        aux = _pmean_all(aux, names)
        return out.reshape(b_l, s_l, D).astype(x_l.dtype), aux

    names = {d_axis, m_axis} | ({batch_spec} if isinstance(batch_spec, str)
                                else set(batch_spec or ()))
    pspecs = (P(None, None), P(m_axis, None, None), P(m_axis, None, None),
              P(m_axis, None, None))
    sm = shard_map(
        island, mesh=mesh,
        in_specs=(P(batch_spec, m_axis, None),) + pspecs,
        out_specs=(P(batch_spec, m_axis, None), P()),
        axis_names=names)
    return sm(x_btd, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])


def moe_block(x: jax.Array, params: Dict, cfg: ModelConfig, rules=None
              ) -> Tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN to (B, S, D) activations; returns (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    mode = rules.dispatch if rules is not None else "tp"
    ep = rules.axis_size(rules.experts) if rules is not None else 1
    if mode in ("auto", "xy", "x", "flat", "local"):
        # xy needs sequence sharding + divisibility; fall back otherwise
        if mode in ("xy", "x") and rules is not None \
                and rules.seq is not None \
                and m.num_experts % rules.axis_size("model") == 0:
            out, aux = _moe_xy(x, params, m, rules)
            return out.astype(x.dtype), aux
        if m.num_experts % max(ep, 1) == 0 and ep > 1 and mode != "local":
            mode = "ep"
        elif rules is not None and rules.axis_size(rules.ff) > 1:
            # experts not placeable (E % columns != 0): keep tokens LOCAL,
            # shard expert FFN width instead (EXPERIMENTS.md §Perf/mixtral)
            out, aux = _moe_local(x, params, m, rules)
            return out.astype(x.dtype), aux
        else:
            mode = "tp"
    x2d = x.reshape(-1, D)
    if mode == "ep":
        out, aux = _moe_dense_layout(x2d, params, m, rules,
                                     expert_axis=rules.experts if rules else None,
                                     ff_axis=None)
    elif mode == "tp":
        out, aux = _moe_dense_layout(x2d, params, m, rules,
                                     expert_axis=None,
                                     ff_axis=rules.ff if rules else None)
    else:
        raise ValueError(f"unknown dispatch mode {mode!r}")
    return out.reshape(B, S, D).astype(x.dtype), aux
