"""Model substrate: layers, attention, and the assigned architectures."""
from .api import get_model  # noqa: F401
