"""Uniform model API: family dispatch for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from types import ModuleType

from repro.configs.base import ModelConfig
from . import jamba, mamba2, transformer, whisper

_FAMILY_MODULE = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": jamba,
    "ssm": mamba2,
    "audio": whisper,
}


def get_model(cfg: ModelConfig) -> ModuleType:
    """The module implementing this config's family.  Every module exposes:
    ``init_params, param_shapes, param_specs, forward, loss_fn, init_cache,
    cache_specs, decode_step``."""
    return _FAMILY_MODULE[cfg.family]
