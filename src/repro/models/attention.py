"""Attention: reference, memory-efficient chunked, and distributed decode.

Three implementations, selected by ``Rules.attn_impl``:

* ``ref``     — materialized (S, S) scores; the small-shape oracle.
* ``chunked`` — online-softmax over KV blocks (lax.scan), O(S·chunk) memory;
                the structural twin of the Pallas kernel and the default for
                32k prefill / 4k train graphs.
* ``flash``   — the Pallas TPU kernel (kernels/flash_attention.py);
                interpret-mode on CPU.

Decode uses :func:`decode_attention` — the paper-C7 "virtual mesh" layout:
the KV cache is sequence-sharded across the ``model`` axis (each chip owns a
contiguous slab of the context, like a bank of the distributed DRAM), every
chip computes partial attention for ALL heads over its slab, and the partials
are combined with a numerically exact log-sum-exp ``psum`` on the reverse
path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import axis_size as _axis_size
from repro.compat import pcast as _pcast
from repro.compat import vma_of as _vma_of
from repro.compat import shard_map

__all__ = ["repeat_kv", "attention", "reference_attention",
           "chunked_attention", "decode_attention"]

_NEG_INF = -1e30


def repeat_kv(k: jax.Array, n: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, K*n, hd): GQA KV-head replication for TP>K."""
    if n == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n, hd)) \
              .reshape(b, s, kh * n, hd)


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    """(…, Sq, Sk) additive bias from causal + sliding-window masking."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        ok &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return jnp.where(ok, 0.0, _NEG_INF)


def reference_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_positions=None, k_positions=None) -> jax.Array:
    """Oracle: full softmax over materialized scores. q:(B,Sq,H,hd),
    k/v:(B,Sk,K,hd) with K | H (GQA handled natively — KV never repeated)."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = scores + _mask_bias(q_positions, k_positions, causal,
                                 window)[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(q.dtype), v)
    return out.reshape(b, sq, h, hd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, chunk: int = 1024,
                      q_positions=None, k_positions=None,
                      unroll: bool = False) -> jax.Array:
    """Online-softmax attention, scanning KV in blocks of ``chunk``.

    Peak memory O(B·H·Sq·chunk) instead of O(B·H·Sq·Sk).  Matches
    reference_attention to fp32 accumulation error.  GQA is handled
    natively: k/v stay at K heads (K | H), queries grouped g = H/K — the
    repeated-KV tensor (and its cross-shard reshard) never materializes.
    """
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    chunk = min(chunk, sk)
    nblk = (sk + chunk - 1) // chunk
    pad = nblk * chunk - sk
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=jnp.iinfo(jnp.int32).max // 2)
    kb = k.reshape(b, nblk, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    pb = k_positions.reshape(b, nblk, chunk).transpose(1, 0, 2)

    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, hd) * (hd ** -0.5)

    def body(carry, blk):
        acc, m, den = carry          # (B,Sq,H,hd), (B,H,Sq), (B,H,Sq)
        kc, vc, pc = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32))
        s = s.reshape(b, h, sq, chunk)
        s = s + _mask_bias(q_positions, pc, causal, window)[:, None]
        m_new = jnp.maximum(m, s.max(-1))
        scale = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        den = den * scale + p.sum(-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd",
                        p.reshape(b, kh, g, sq, chunk),
                        vc.astype(jnp.float32)).reshape(b, sq, h, hd)
        acc = acc * scale.transpose(0, 2, 1)[..., None] + pv
        return (acc, m_new, den), None

    acc0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    m0 = jnp.full((b, h, sq), _NEG_INF, jnp.float32)
    den0 = jnp.zeros((b, h, sq), jnp.float32)
    # inside a shard_map island the carries must match the body's
    # varying-manual-axes type
    vma = tuple(_vma_of(q))
    if vma:
        acc0, m0, den0 = (_pcast(t, vma, to="varying")
                          for t in (acc0, m0, den0))
    (acc, m, den), _ = lax.scan(body, (acc0, m0, den0), (kb, vb, pb),
                                unroll=unroll)
    den = jnp.maximum(den, 1e-30)
    out = acc / den.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attention(q, k, v, *, impl: str = "chunked", causal: bool = True,
              window: Optional[int] = None, chunk: int = 1024,
              q_positions=None, k_positions=None,
              unroll: bool = False) -> jax.Array:
    if impl == "ref":
        return reference_attention(q, k, v, causal=causal, window=window,
                                   q_positions=q_positions,
                                   k_positions=k_positions)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 chunk=chunk, q_positions=q_positions,
                                 k_positions=k_positions, unroll=unroll)
    if impl == "flash":
        from repro.kernels import flash_attention_op
        return flash_attention_op(q, k, v, causal=causal, window=window)
    if impl == "noattn":
        # cost-isolation stub (launch/costing.py): same shapes/dtypes, no
        # score computation — used to measure the attention core's share of
        # HLO traffic, which the Pallas flash kernel keeps in VMEM on TPU.
        return q
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Distributed decode: sequence-sharded KV cache ("virtual mesh", paper C7).
# ---------------------------------------------------------------------------

def decode_attention(rules, q, k_cache, v_cache, cache_len,
                     window: Optional[int] = None) -> jax.Array:
    """One-token attention against a sequence-sharded KV cache.

    q:        (B, H, hd)        — replicated over the model axis
    k_cache:  (B, S, K, hd)     — sharded over ``rules.kv_seq`` on dim 1
    v_cache:  (B, S, K, hd)
    cache_len:(B,) int32        — valid prefix length (global positions)

    Every model shard holds all KV *heads* for a slab of the sequence;
    partial softmax statistics combine via psum — the reverse-network
    "response" of the remote-load gather.
    """
    kv_axis = rules.kv_seq if not hasattr(rules, "_clean") else \
        rules._clean(rules.kv_seq)
    if kv_axis is None:
        # single-shard fallback: plain local decode
        return _local_decode(q, k_cache, v_cache, cache_len, 0, window)[0]
    kv_axes = (kv_axis,) if isinstance(kv_axis, str) else tuple(kv_axis)

    batch_spec = rules._clean(rules.batch)

    def island(q_l, k_l, v_l, len_l):
        # linear shard index over the (possibly multi-axis) kv_seq group
        idx = jnp.zeros((), jnp.int32)
        for a in kv_axes:
            idx = idx * _axis_size(a) + lax.axis_index(a)
        s_local = k_l.shape[1]
        out, num_den = _local_decode(q_l, k_l, v_l, len_l,
                                     idx * s_local, window)
        num, m, den = num_den
        m_all = lax.pmax(m, kv_axes)
        corr = jnp.exp(m - m_all)
        num = lax.psum(num * corr[..., None], kv_axes)
        den = lax.psum(den * corr, kv_axes)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(q_l.dtype)

    names = set(kv_axes) | ({batch_spec} if isinstance(batch_spec, str)
                            else set(batch_spec or ()))
    sm = shard_map(
        island, mesh=rules.mesh,
        in_specs=(P(batch_spec, None, None),
                  P(batch_spec, kv_axis, None, None),
                  P(batch_spec, kv_axis, None, None),
                  P(batch_spec)),
        out_specs=P(batch_spec, None, None),
        axis_names=names)
    return sm(q, k_cache, v_cache, cache_len)


def _local_decode(q, k, v, cache_len, pos_offset, window):
    """Partial decode attention over a local KV slab.

    q: (B, H, hd); k/v: (B, S_local, K, hd); returns (out, (num, m, den))
    with fp32 partial statistics for cross-shard combination.
    """
    b, h, hd = q.shape
    s_local, kh = k.shape[1], k.shape[2]
    g = h // kh                                   # q heads per kv head
    qf = q.astype(jnp.float32).reshape(b, kh, g, hd) * (hd ** -0.5)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kf)     # (B, K, g, S_local)
    pos = pos_offset + jnp.arange(s_local)
    ok = pos[None, :] < cache_len[:, None]        # only the valid prefix
    if window is not None:
        ok &= pos[None, :] > (cache_len[:, None] - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, _NEG_INF)
    m = s.max(-1)                                 # (B, K, g)
    p = jnp.exp(s - m[..., None])
    # guard all-masked shards: exp(-inf - -inf) -> make contribution zero
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    den = p.sum(-1)
    num = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    num = num.reshape(b, h, hd)
    m = m.reshape(b, h)
    den = den.reshape(b, h)
    out = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
    return out, (num, m, den)
