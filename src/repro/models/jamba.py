"""Jamba: hybrid Mamba + attention (1:7) with interleaved MoE.

Layer pattern per period of ``attn_period`` (=8) layers:
mixer  = [mamba x 7, attention]  (attention closes each period)
mlp    = [dense, MoE, dense, MoE, ...]  (MoE every ``moe.every_n_layers``=2)

Params are stacked over *periods* and scanned, with the period body unrolled
(heterogeneous layers can't share one scan body) — the HLO contains one
period, not 32 layers.

This is the showcase arch for the paper's heterogeneity story (C7): mamba
mixers are "sub-nodes" (small, many per tile), the MoE is a "sub-mesh"
(experts spread over the model axis), attention KV at decode is the
"virtual mesh" sequence-sharded cache.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Rules
from . import mamba2, moe as moe_mod, transformer as tfm
from .attention import attention, decode_attention, repeat_kv
from .layers import (cross_entropy, embed_lookup, init_dense, init_norm,
                     rms_norm, rope, swiglu)

__all__ = ["param_table", "init_params", "param_shapes", "param_specs",
           "forward", "loss_fn", "init_cache", "cache_specs", "decode_step"]

AUX_COEF = 0.01


def _layout(cfg: ModelConfig):
    P = cfg.attn_period
    NP = cfg.num_layers // P
    n_moe = sum(1 for i in range(P)
                if (i % cfg.moe.every_n_layers) == cfg.moe.every_n_layers - 1)
    return P, NP, P - 1, n_moe, P - n_moe


def param_table(cfg: ModelConfig) -> Dict[str, Tuple[tuple, tuple]]:
    D, hd = cfg.d_model, cfg.head_dim
    H, K, F = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    P, NP, n_mamba, n_moe, n_dense = _layout(cfg)
    E, Fe = cfg.moe.num_experts, cfg.moe.d_ff_expert
    t = {
        "embed": ((cfg.vocab_size, D), ("vocab", None)),
        "final_norm": ((D,), (None,)),
        "lm_head": ((D, cfg.vocab_size), (None, "vocab")),
    }
    # attention: one per period
    t["periods/attn_norm"] = ((NP, D), (None, None))
    t["periods/wq"] = ((NP, D, H * hd), (None, None, "heads"))
    t["periods/wk"] = ((NP, D, K * hd), (None, None, "kv_heads"))
    t["periods/wv"] = ((NP, D, K * hd), (None, None, "kv_heads"))
    t["periods/wo"] = ((NP, H * hd, D), (None, "heads", None))
    # mamba mixers: (NP, n_mamba, ...)
    for k, (shape, axes) in mamba2.mixer_table(cfg, n_mamba).items():
        t[f"periods/mamba_{k}"] = ((NP,) + shape, (None,) + axes)
    # dense MLPs
    t["periods/mlp_norm"] = ((NP, n_dense, D), (None, None, None))
    t["periods/w_gate"] = ((NP, n_dense, D, F), (None, None, None, "ff"))
    t["periods/w_up"] = ((NP, n_dense, D, F), (None, None, None, "ff"))
    t["periods/w_down"] = ((NP, n_dense, F, D), (None, None, "ff", None))
    # MoE MLPs
    t["periods/moe_norm"] = ((NP, n_moe, D), (None, None, None))
    t["periods/router"] = ((NP, n_moe, D, E), (None, None, None, None))
    t["periods/moe_gate"] = ((NP, n_moe, E, D, Fe), (None, None, "experts", None, "ff_expert"))
    t["periods/moe_up"] = ((NP, n_moe, E, D, Fe), (None, None, "experts", None, "ff_expert"))
    t["periods/moe_down"] = ((NP, n_moe, E, Fe, D), (None, None, "experts", "ff_expert", None))
    return t


def param_shapes(cfg):
    return {k: jax.ShapeDtypeStruct(
        s, jnp.float32 if k.endswith(("A_log", "dt_bias", "router")) else cfg.param_dtype)
        for k, (s, _a) in param_table(cfg).items()}


def param_specs(cfg, rules: Rules):
    out = {}
    for k, (s, axes) in param_table(cfg).items():
        resolved = [tfm._resolve_axis(cfg, rules, a, s[i]) if a in
                    ("vocab", "heads", "kv_heads", "ff", "experts", "ff_expert")
                    else None for i, a in enumerate(axes)]
        out[k] = rules.sharding(*resolved)
    return out


def init_params(cfg: ModelConfig, key):
    table = param_table(cfg)
    keys = jax.random.split(key, len(table))
    out = {}
    for (name, (shape, _a)), k in zip(sorted(table.items()), keys):
        if "norm" in name:
            out[name] = init_norm(shape, cfg.param_dtype)
        elif name.endswith("A_log"):
            nh = shape[-1]
            out[name] = jnp.broadcast_to(
                jnp.log(jnp.linspace(1.0, 16.0, nh)), shape).astype(jnp.float32)
        elif name.endswith("D_skip"):
            out[name] = jnp.ones(shape, cfg.param_dtype)
        elif name.endswith(("dt_bias",)):
            out[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith("conv_b"):
            out[name] = jnp.zeros(shape, cfg.param_dtype)
        elif name.endswith("router"):
            out[name] = init_dense(k, shape, jnp.float32)
        else:
            out[name] = init_dense(k, shape, cfg.param_dtype)
    return out


def _split(params):
    glob = {k: v for k, v in params.items() if not k.startswith("periods/")}
    per = {k.split("/", 1)[1]: v for k, v in params.items()
           if k.startswith("periods/")}
    return glob, per


def _is_moe_layer(cfg, i):
    n = cfg.moe.every_n_layers
    return (i % n) == n - 1


def _mlp(x, pp, cfg, rules, i, counters):
    di, mi = counters  # running indices into dense / moe stacks
    if _is_moe_layer(cfg, i):
        lp = {"router": pp["router"][mi], "w_gate": pp["moe_gate"][mi],
              "w_up": pp["moe_up"][mi], "w_down": pp["moe_down"][mi]}
        h = rms_norm(x, pp["moe_norm"][mi], cfg.norm_eps)
        out, aux = moe_mod.moe_block(h, lp, cfg, rules)
        return x + out, aux, (di, mi + 1)
    h = rms_norm(x, pp["mlp_norm"][di], cfg.norm_eps)
    out = swiglu(h, pp["w_gate"][di], pp["w_up"][di], pp["w_down"][di], rules)
    return x + out, jnp.zeros((), jnp.float32), (di + 1, mi)


def _period_body(x, pp, positions, cfg: ModelConfig, rules: Optional[Rules]):
    P = cfg.attn_period
    aux_tot = jnp.zeros((), jnp.float32)
    counters = (0, 0)
    for i in range(P):
        if i == P - 1:  # attention layer
            lp = {"attn_norm": pp["attn_norm"], "wq": pp["wq"],
                  "wk": pp["wk"], "wv": pp["wv"], "wo": pp["wo"]}
            x = tfm._attn_block(x, lp, cfg, rules, positions)
        else:           # mamba mixer
            lp = {k[len("mamba_"):]: v[i] for k, v in pp.items()
                  if k.startswith("mamba_")}
            h = rms_norm(x, lp["norm"], cfg.norm_eps)
            x = x + mamba2.mixer_apply(lp, h, cfg, rules)
        x, aux, counters = _mlp(x, pp, cfg, rules, i, counters)
        aux_tot = aux_tot + aux
    return x, aux_tot


def forward(params, tokens, cfg: ModelConfig, rules: Optional[Rules] = None,
            positions=None, embeds=None, last_only: bool = False):
    glob, per = _split(params)
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embeds if embeds is not None else embed_lookup(glob["embed"], tokens, rules)
    x = x.astype(cfg.param_dtype)
    if rules is not None:
        x = rules.act_btd(x)

    body = functools.partial(_period_body, cfg=cfg, rules=rules)
    if rules is not None and rules.remat == "full":
        body = jax.checkpoint(body)
    elif rules is not None and rules.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_fn(carry, pp):
        y, aux = body(carry, pp, positions)
        return y, aux

    x, auxs = lax.scan(scan_fn, x, per,
                       unroll=(rules.scan_unroll if rules else False))
    if last_only:
        x = x[:, -1:]
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    logits = x @ glob["lm_head"]
    if rules is not None:
        logits = rules.cs(logits, rules.batch, None, rules.vocab) \
            if last_only else rules.logits(logits)
    return logits, auxs.sum()


def loss_fn(params, batch, cfg, rules=None):
    logits, aux = forward(params, batch["tokens"], cfg, rules)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + AUX_COEF * aux, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               filled: Optional[int] = None):
    P, NP, n_mamba, _nm, _nd = _layout(cfg)
    K, hd = cfg.num_kv_heads, cfg.head_dim
    s, di, nh, conv_dim, _ = mamba2._dims(cfg)
    filled = filled or 0
    return {
        "k": jnp.zeros((NP, batch, max_seq, K, hd), cfg.param_dtype),
        "v": jnp.zeros((NP, batch, max_seq, K, hd), cfg.param_dtype),
        "state": jnp.zeros((NP, n_mamba, batch, nh, s.state_dim, s.head_dim),
                           jnp.float32),
        "conv": jnp.zeros((NP, n_mamba, batch, s.conv_width - 1, conv_dim),
                          cfg.param_dtype),
        "len": jnp.full((batch,), filled, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, rules: Rules):
    kv = rules.sharding(None, rules.batch, rules.kv_seq, None, None)
    return {"k": kv, "v": kv,
            "state": rules.sharding(None, None, rules.batch, rules.heads, None, None),
            "conv": rules.sharding(None, None, rules.batch, None, rules.heads),
            "len": rules.sharding(rules.batch)}


def decode_step(params, cache, tokens, cfg: ModelConfig,
                rules: Optional[Rules] = None, positions=None):
    glob, per = _split(params)
    B = tokens.shape[0]
    P = cfg.attn_period
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cur_len = cache["len"]
    pos = cur_len.astype(jnp.int32)
    x = embed_lookup(glob["embed"], tokens[:, None], rules)[:, 0]
    x = x.astype(cfg.param_dtype)
    S_cache = cache["k"].shape[2]
    slot = (cur_len % S_cache).astype(jnp.int32)
    moe_rules = tfm._decode_rules(rules)

    def period(carry, xs):
        x = carry
        pp, k_c, v_c, st, ct = xs
        aux_counters = (0, 0)
        sts, cts = [], []
        for i in range(P):
            if i == P - 1:
                h = rms_norm(x, pp["attn_norm"], cfg.norm_eps)
                q = (h @ pp["wq"]).reshape(B, H, hd)
                k_new = (h @ pp["wk"]).reshape(B, K, hd)
                v_new = (h @ pp["wv"]).reshape(B, K, hd)
                q = rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
                k_new = rope(k_new[:, None], pos[:, None], cfg.rope_theta)[:, 0]
                k_c = tfm._scatter_kv(k_c, k_new[:, None], slot)
                v_c = tfm._scatter_kv(v_c, v_new[:, None], slot)
                if rules is not None:
                    q = rules.cs(q, rules.batch, None, None)
                att = decode_attention(
                    rules if rules is not None else tfm._NORULES,
                    q, k_c, v_c, cur_len + 1, window=None)
                x = x + att.reshape(B, H * hd) @ pp["wo"]
            else:
                lp = {k[len("mamba_"):]: v[i] for k, v in pp.items()
                      if k.startswith("mamba_")}
                h = rms_norm(x, lp["norm"], cfg.norm_eps)
                out, st_i, ct_i = mamba2.mixer_decode(lp, h, st[i], ct[i], cfg)
                x = x + out
                sts.append(st_i)
                cts.append(ct_i)
            x2 = x[:, None]
            x2, _aux, aux_counters = _mlp(x2, pp, cfg, moe_rules, i, aux_counters)
            x = x2[:, 0]
        return x, (k_c, v_c, jnp.stack(sts), jnp.stack(cts))

    x, (k_all, v_all, st_all, ct_all) = lax.scan(
        period, x, (per, cache["k"], cache["v"], cache["state"], cache["conv"]),
        unroll=(rules.scan_unroll if rules else False))
    x = rms_norm(x, glob["final_norm"], cfg.norm_eps)
    logits = x @ glob["lm_head"]
    if rules is not None:
        logits = rules.cs(logits, rules.batch, rules.vocab)
    return logits, {"k": k_all, "v": v_all, "state": st_all, "conv": ct_all,
                    "len": cur_len + 1}
