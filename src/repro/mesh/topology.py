"""Pluggable network topologies for the mesh simulators.

The paper's network is a 2-D mesh, but the datapath generalizes: BSG Ten
extends the same mesh over off-chip links to an FPGA (two sub-meshes
joined by narrower, higher-latency boundary links), and the related-work
Ring-Mesh (Mazumdar & Scionti 2019) and torus variants differ from the
mesh only in *where each output port leads* and *which way a packet
turns*.  :class:`Topology` captures exactly those two degrees of freedom:

* **Connectivity** — whether the X and/or Y dimension wraps around
  (``wrap_x`` / ``wrap_y``), and, for the multi-chip topology, which
  column-to-column links are chip-boundary links and how much narrower
  they are (``chips_x`` / ``boundary_period``).
* **Routing** — :meth:`route`, the per-packet output-port decision.  It
  is written against a caller-supplied array namespace (``xp=numpy`` for
  the oracle, ``xp=jax.numpy`` for the fused/Pallas paths) using only
  arithmetic and ``where`` so ONE function serves all three backends and
  stays jit- and Pallas-kernel-safe (no data-dependent indexing, no
  captured array constants).

Routing stays dimension-ordered (X then Y) on every topology, which
preserves the paper's reduced-crossbar invariant — the N input never
requests E or W — because the Y phase never re-enters X.  On wrapped
dimensions the router takes the minimal ring direction; the exact-half
tie on even rings is broken by coordinate parity so the two ring
directions stay load-balanced (a fixed tie-break would cost ~11% of
uniform throughput).

**Deadlock freedom.** A wrapped dimension is a ring, and rings deadlock
under plain dimension-ordered routing.  The simulators avoid this with
*local bubble flow control* (Carara/Bubble ring rule): a packet may
ENTER a ring (from the P port or from the orthogonal dimension) only if
the target FIFO has **two** free slots, while a packet CONTINUING around
the ring needs the usual one.  Ring occupancy only grows via entering
packets, each of which leaves a free slot behind, so every ring always
keeps at least one bubble and the continuing traffic can always make
progress.  On non-wrapped topologies the rule is compiled out and the
datapath is bit-identical to the original mesh.

**Multi-chip boundary links.**  ``Topology.multi_chip(chips_x=C,
boundary_period=S)`` splits the mesh into C equal-width sub-meshes along
X.  The E/W links crossing a chip boundary model BSG Ten's off-chip hop
as an S× narrower channel: they accept a flit only on cycles where
``cycle % S == 0`` — 1/S throughput and 0..S-1 cycles of added latency,
with no extra state (so all three backends stay trivially bit-identical).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.netsim import E, N, P, S, W

__all__ = ["Topology", "KINDS"]

KINDS = ("mesh", "torus", "ring_mesh", "multi_chip")


@dataclasses.dataclass(frozen=True)
class Topology:
    """Network topology: connectivity + routing function.  Frozen and
    hashable, so it rides inside the static (jit) simulator configs.

    Use the constructors — :meth:`mesh`, :meth:`torus`, :meth:`ring_mesh`,
    :meth:`multi_chip` — rather than spelling the fields out.
    """
    kind: str = "mesh"
    wrap_x: bool = False         # X dimension is a ring
    wrap_y: bool = False         # Y dimension is a ring
    chips_x: int = 1             # sub-meshes along X (multi_chip only)
    boundary_period: int = 1     # boundary link accepts 1 flit / S cycles

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown topology kind {self.kind!r}; known: {KINDS}")
        if self.chips_x < 1 or self.boundary_period < 1:
            raise ValueError(
                f"chips_x and boundary_period must be >= 1, got "
                f"chips_x={self.chips_x}, boundary_period={self.boundary_period}")
        want = {"mesh": (False, False, 1), "torus": (True, True, 1),
                "ring_mesh": (True, False, 1),
                "multi_chip": (False, False, self.chips_x)}[self.kind]
        if (self.wrap_x, self.wrap_y) != want[:2] or self.chips_x != want[2] \
                or (self.kind != "multi_chip" and
                    (self.chips_x != 1 or self.boundary_period != 1)):
            raise ValueError(
                f"inconsistent topology fields for kind {self.kind!r}: "
                f"wrap_x={self.wrap_x}, wrap_y={self.wrap_y}, "
                f"chips_x={self.chips_x}, "
                f"boundary_period={self.boundary_period}; use the "
                f"Topology.{self.kind}() constructor")
        if self.kind == "multi_chip" and self.chips_x < 2:
            raise ValueError(
                f"multi_chip needs chips_x >= 2, got {self.chips_x}")

    # -- constructors ---------------------------------------------------
    @classmethod
    def mesh(cls) -> "Topology":
        """The paper's plain 2-D mesh (the default everywhere)."""
        return cls("mesh")

    @classmethod
    def torus(cls) -> "Topology":
        """Both dimensions wrap around (2-D torus)."""
        return cls("torus", wrap_x=True, wrap_y=True)

    @classmethod
    def ring_mesh(cls) -> "Topology":
        """Ring-Mesh hybrid (Mazumdar & Scionti): rows are rings
        (X wraps), columns remain a plain mesh."""
        return cls("ring_mesh", wrap_x=True)

    @classmethod
    def multi_chip(cls, chips_x: int = 2,
                   boundary_period: int = 4) -> "Topology":
        """``chips_x`` equal-width sub-meshes joined along X by boundary
        links that accept one flit every ``boundary_period`` cycles
        (BSG Ten's narrower, higher-latency off-chip hop)."""
        return cls("multi_chip", chips_x=int(chips_x),
                   boundary_period=int(boundary_period))

    # -- string form (sweep specs, cache keys, JSON artifacts) ----------
    @classmethod
    def parse(cls, spec) -> "Topology":
        """A :class:`Topology` from its string form: one of the plain
        kinds (``"mesh"``, ``"torus"``, ``"ring_mesh"``) or
        ``"multi_chip[:chips_x[:boundary_period]]"``.  A ``Topology`` is
        passed through, so declarative sweep specs can mix both forms."""
        if isinstance(spec, cls):
            return spec
        if not isinstance(spec, str):
            raise TypeError(
                f"cannot interpret {spec!r} as a topology; pass a "
                f"Topology or one of {KINDS} (multi_chip optionally as "
                f"'multi_chip:chips_x:boundary_period')")
        kind, _, rest = spec.partition(":")
        if kind != "multi_chip":
            if rest:
                raise ValueError(
                    f"topology {kind!r} takes no ':' parameters, "
                    f"got {spec!r}")
            if kind not in KINDS:
                raise ValueError(
                    f"unknown topology kind {kind!r}; known: {KINDS}")
            return {"mesh": cls.mesh, "torus": cls.torus,
                    "ring_mesh": cls.ring_mesh}[kind]()
        try:
            params = [int(p) for p in rest.split(":")] if rest else []
        except ValueError:
            raise ValueError(
                f"multi_chip parameters must be ints, got {spec!r}") from None
        if len(params) > 2:
            raise ValueError(
                f"multi_chip takes at most chips_x:boundary_period, "
                f"got {spec!r}")
        return cls.multi_chip(*params)

    @property
    def spec(self) -> str:
        """The string :meth:`parse` round-trips (``"torus"``,
        ``"multi_chip:2:4"``, ...)."""
        if self.kind == "multi_chip":
            return f"multi_chip:{self.chips_x}:{self.boundary_period}"
        return self.kind

    @property
    def min_router_fifo(self) -> int:
        """Smallest valid router FIFO depth: wrapped (ring) dimensions
        need 2 slots for the bubble flow control, plain meshes 1."""
        return 2 if (self.wrap_x or self.wrap_y) else 1

    # -- validation -----------------------------------------------------
    def validate_for(self, nx: int, ny: int) -> None:
        """Raise ``ValueError`` when this topology cannot be laid onto an
        ``nx`` x ``ny`` array (multi-chip needs equal-width chips)."""
        if self.chips_x > 1 and (nx % self.chips_x != 0 or
                                 nx // self.chips_x < 1):
            raise ValueError(
                f"multi_chip topology with chips_x={self.chips_x} needs nx "
                f"divisible into equal-width chips, got nx={nx}")

    # -- routing --------------------------------------------------------
    def route(self, dst_x, dst_y, x, y, nx: int, ny: int, xp=np):
        """Output port (P/W/E/N/S) for a packet at ``(x, y)`` heading to
        ``(dst_x, dst_y)``.  Pure elementwise arithmetic in the array
        namespace ``xp`` (numpy or jax.numpy) — shared verbatim by the
        oracle, the fused XLA step and the Pallas kernel trace.

        Dimension-ordered X-then-Y on every topology.  Wrapped dimensions
        take the minimal ring direction; the even-ring half-way tie is
        broken by the parity of ``x + y + dst_x + dst_y`` (stable along a
        route: the tie can only occur at one position, after which the
        minimal direction is strict).
        """
        if not self.wrap_x and not self.wrap_y:
            # the original mesh expression, byte-for-byte (both the oracle
            # and the fused step must keep their seed traces on the mesh)
            return xp.where(dst_x > x, E, xp.where(dst_x < x, W,
                   xp.where(dst_y > y, S, xp.where(dst_y < y, N, P))))
        tie = ((x + y + dst_x + dst_y) % 2) == 0
        if self.wrap_x:
            dx = (dst_x - x) % nx
            go_e = (2 * dx < nx) | ((2 * dx == nx) & tie)
            xstep = xp.where(go_e, E, W)
            x_need = dx != 0
        else:
            xstep = xp.where(dst_x > x, E, W)
            x_need = dst_x != x
        if self.wrap_y:
            dy = (dst_y - y) % ny
            go_s = (2 * dy < ny) | ((2 * dy == ny) & tie)
            ystep = xp.where(go_s, S, N)
            y_need = dy != 0
        else:
            ystep = xp.where(dst_y > y, S, N)
            y_need = dst_y != y
        return xp.where(x_need, xstep, xp.where(y_need, ystep, P))

    # -- distances ------------------------------------------------------
    def hops(self, src_x, src_y, dst_x, dst_y, nx: int, ny: int):
        """Routed hop count (Manhattan on mesh dims, ring distance on
        wrapped dims).  Elementwise; works on scalars or arrays."""
        ax = np.abs(np.asarray(dst_x) - np.asarray(src_x))
        ay = np.abs(np.asarray(dst_y) - np.asarray(src_y))
        hx = np.minimum(ax, nx - ax) if self.wrap_x else ax
        hy = np.minimum(ay, ny - ay) if self.wrap_y else ay
        return hx + hy

    def diameter(self, nx: int, ny: int) -> int:
        """Longest minimal route on an ``nx`` x ``ny`` array."""
        return int((nx // 2 if self.wrap_x else nx - 1)
                   + (ny // 2 if self.wrap_y else ny - 1))

    # -- multi-chip boundary --------------------------------------------
    @property
    def gated(self) -> bool:
        """True when some links are cycle-gated boundary links."""
        return self.chips_x > 1 and self.boundary_period > 1

    def chip_width(self, nx: int) -> int:
        self.validate_for(nx, 1)
        return nx // self.chips_x

    def boundary_cols(self, nx: int) -> Tuple[int, ...]:
        """Column indices ``c`` such that the link between columns
        ``c - 1`` and ``c`` crosses a chip boundary (E output gated at
        column ``c - 1``, W output gated at column ``c``)."""
        if self.chips_x <= 1:
            return ()
        w = self.chip_width(nx)
        return tuple(b * w for b in range(1, self.chips_x))

    # -- analytic capacity ----------------------------------------------
    def uniform_saturation_bound(self, nx: int, ny: int) -> float:
        """Analytic per-tile injection-rate bound under uniform-random
        traffic (the bisection/channel-load bound the saturation
        benchmarks compare against).

        Walks the *actual* :meth:`route` for every (src, dst) pair and
        accumulates per-channel crossing counts C(l); a channel of
        capacity ``cap`` (1 flit/cycle, or 1/boundary_period on a
        chip-boundary link) then bounds the rate at
        ``cap * (N - 1) / C(l)``.  Because the walk uses the real routing
        function, the bound accounts for the ring tie-break exactly.  On
        the plain mesh this recovers the classic 4/k bisection bound
        (2k/N with XY routing); the torus doubles it to 8/k.
        """
        self.validate_for(nx, ny)
        n = nx * ny
        if n < 2:
            return 1.0
        ys, xs = np.mgrid[0:ny, 0:nx]
        fx, fy = xs.reshape(-1), ys.reshape(-1)
        sx, sy = np.repeat(fx, n), np.repeat(fy, n)
        dx, dy = np.tile(fx, n), np.tile(fy, n)
        sel = (sx != dx) | (sy != dy)
        px, py, dx, dy = sx[sel].copy(), sy[sel].copy(), dx[sel], dy[sel]
        cross = np.zeros((ny, nx, 5), np.int64)
        for _ in range(self.diameter(nx, ny) + 1):
            d = self.route(dx, dy, px, py, nx, ny, xp=np)
            alive = d != P
            if not alive.any():
                break
            np.add.at(cross, (py[alive], px[alive], d[alive]), 1)
            px = np.where(d == E, (px + 1) % nx,
                          np.where(d == W, (px - 1) % nx, px))
            py = np.where(d == S, (py + 1) % ny,
                          np.where(d == N, (py - 1) % ny, py))
        cap = np.ones((ny, nx, 5))
        for c in self.boundary_cols(nx):
            cap[:, c - 1, E] = 1.0 / self.boundary_period
            cap[:, c, W] = 1.0 / self.boundary_period
        used = cross > 0
        rate = float((cap[used] * (n - 1) / cross[used]).min()) \
            if used.any() else 1.0
        return min(rate, 1.0)
