"""The backend-agnostic mesh simulator facade.

One front door for every way of driving the mesh::

    from repro.mesh import MeshConfig, Simulator, make_traffic

    sim = Simulator(MeshConfig(nx=8, ny=8), backend="jax")
    sim.attach(make_traffic("uniform", 8, 8, 64, rate=0.5))   # a program
    sim.run_until_drained()
    t = sim.telemetry()          # Telemetry — bit-identical across backends

or, attaching a *reactive user design* (the paper's integration story)::

    from repro.mesh import DmaEndpoint, Simulator

    sim = Simulator(cfg)                      # numpy oracle backend
    sim.attach(DmaEndpoint(dst_x=3, dst_y=2, data=range(16)), at=(0, 0))
    sim.run_until_drained()

Backends:

* ``backend="numpy"`` — :class:`repro.core.netsim.MeshSim`, the oracle.
  Reactive endpoints run *natively*: each cycle the facade delivers any
  registered response to its endpoint, then the router step asks every
  ready endpoint for an offer.
* ``backend="jax"`` — :class:`repro.netsim_jax.JaxMeshSim` (imported
  lazily so the oracle works without the JAX stack warmed up).  Injection
  programs run directly under ``jit``.  Reactive endpoints run through
  the **trace-to-program bridge**: the scenario executes once on an
  internal oracle (recording the exact injection cycle of every packet),
  and the resulting ``not_before``-pinned program replays bit-identically
  on the JAX path — so endpoint-driven scenarios still compile, and their
  exported programs (:meth:`injection_trace_program`) ``vmap`` into
  sweeps.

Everything not defined here (``mem``, ``credits``, ``lat_hist``,
``throughput()``, ...) transparently delegates to the backend object, so
the facade satisfies the same oracle-shaped contract the differential
suites check.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.netsim import MeshSim, _PKT_FIELDS

from .config import MeshConfig
from .encoding import validate_program
from .endpoint import Endpoint, Request, Response, trace_to_program
from .telemetry import Telemetry

__all__ = ["Simulator", "BACKENDS"]

BACKENDS = ("numpy", "jax")


class Simulator:
    """Backend-agnostic facade over the two cycle-level simulators."""

    def __init__(self, cfg, *, backend: str = "numpy", seed: int = 0,
                 fifo_depth: Optional[int] = None,
                 max_credits: Optional[int] = None,
                 unroll: int = 1, check_every: int = 1,
                 impl: str = "fused", cycles_per_call: int = 1):
        """``cfg`` may be a MeshConfig, NetConfig or SimConfig.

        ``fifo_depth`` / ``max_credits`` set the *effective* router-FIFO
        depth and credit allowance below the config's capacities — on the
        JAX backend they stay dynamic state (so sweeps vmap without
        recompiling); the numpy oracle folds them into its config, which
        is dynamics-identical.

        ``unroll`` / ``check_every`` / ``impl`` / ``cycles_per_call`` are
        JAX-backend jit tuning knobs (scan-unroll factor of ``run``;
        drain-fence check cadence of ``run_until_drained``; the
        fused-XLA vs Pallas-kernel cycle step and the kernel's
        cycles-per-launch — see :func:`repro.netsim_jax.simulate` /
        :func:`repro.netsim_jax.run_until_drained`).  They affect speed
        only, never results; the numpy oracle ignores them.
        """
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; known: {BACKENDS}")
        if unroll < 1 or check_every < 1:
            raise ValueError(
                f"unroll and check_every must be >= 1, got unroll={unroll}, "
                f"check_every={check_every}")
        if impl not in ("fused", "pallas"):
            raise ValueError(
                f"unknown step impl {impl!r}: expected 'fused' or 'pallas'")
        if cycles_per_call < 1:
            raise ValueError(
                f"cycles_per_call must be >= 1, got {cycles_per_call}")
        self.cfg = MeshConfig.coerce(cfg)
        self.backend = backend
        self._seed = seed
        self._fifo_depth = fifo_depth
        self._max_credits = max_credits
        self._unroll = int(unroll)
        self._check_every = int(check_every)
        self._impl = impl
        self._cycles_per_call = int(cycles_per_call)
        self._endpoints: Dict[Tuple[int, int], Endpoint] = {}  # (y, x) -> ep
        self._trace: List[Tuple[int, int, int, Request]] = []
        self._program: Optional[Dict[str, np.ndarray]] = None
        self._mem0: Optional[np.ndarray] = None
        self._window: Optional[Tuple[int, int]] = None
        self._oracle: Optional["Simulator"] = None   # jax+endpoints bridge
        self._sim = self._make_backend()

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    def _effective_cfg(self) -> MeshConfig:
        cfg = self.cfg
        if self._fifo_depth is not None:
            cfg = cfg.replace(router_fifo=int(self._fifo_depth))
        if self._max_credits is not None:
            cfg = cfg.replace(max_out_credits=int(self._max_credits))
        return cfg

    def _make_backend(self):
        if self.backend == "numpy":
            # effective values fold into the oracle's config (identical
            # dynamics; the capacity/effective split is a JAX vmap affordance)
            return MeshSim(self._effective_cfg().to_net(), seed=self._seed)
        from repro.netsim_jax.sim import JaxMeshSim
        return JaxMeshSim(self.cfg.to_sim(), fifo_depth=self._fifo_depth,
                          max_credits=self._max_credits,
                          unroll=self._unroll,
                          check_every=self._check_every,
                          impl=self._impl,
                          cycles_per_call=self._cycles_per_call)

    def _bridge(self) -> "Simulator":
        """The internal oracle that natively executes reactive endpoints
        for the JAX backend (created on first endpoint attach)."""
        if self._oracle is None:
            self._oracle = Simulator(self._effective_cfg(),
                                     backend="numpy", seed=self._seed)
            if self._mem0 is not None:
                self._oracle.set_mem(self._mem0)
            if self._window is not None:
                self._oracle.set_measure_window(*self._window)
            if self._program is not None:
                self._oracle.attach(self._program)
        return self._oracle

    # ------------------------------------------------------------------
    # attachment
    # ------------------------------------------------------------------
    def attach(self, item, at: Optional[Tuple[int, int]] = None) -> "Simulator":
        """Attach a master to the mesh and return ``self`` (chainable).

        * a dict injection program (the ``make_traffic`` schema) loads on
          every tile at once;
        * an :class:`Endpoint` attaches to the single tile ``at=(x, y)``.
        """
        if isinstance(item, dict):
            if at is not None:
                raise ValueError(
                    "a program drives every tile; 'at' only applies to "
                    "endpoint attachment")
            self._attach_program(item)
            return self
        if not isinstance(item, Endpoint):
            raise TypeError(
                f"cannot attach {type(item).__name__}: expected an injection"
                " program dict or an object with offer/deliver/done")
        if at is None:
            raise ValueError(
                "attaching an endpoint needs its tile: attach(ep, at=(x, y))")
        x, y = at
        if not (0 <= x < self.cfg.nx and 0 <= y < self.cfg.ny):
            raise ValueError(
                f"endpoint tile (x={x}, y={y}) is outside the "
                f"{self.cfg.nx}x{self.cfg.ny} mesh")
        if (y, x) in self._endpoints:
            raise ValueError(
                f"tile (x={x}, y={y}) already has an endpoint attached; "
                "a tile has one master")
        if self.backend == "jax" and self._cycles_run() > 0:
            raise ValueError(
                "cannot attach an endpoint to a jax-backend Simulator that "
                "has already run: the trace-to-program bridge replays the "
                "scenario from cycle 0, which would drop the pre-attach "
                "history; attach endpoints before running (the numpy "
                "backend supports mid-run attachment natively)")
        if self._program is not None and \
                (np.asarray(self._program["op"])[y, x] >= 0).any():
            raise ValueError(
                f"tile (x={x}, y={y}) already has injection-program "
                "entries; a tile has one master")
        self._endpoints[(y, x)] = item
        if self.backend == "numpy":
            self._sim._injectors[(y, x)] = self._traced_offer(y, x, item)
        else:
            self._bridge().attach(item, at=at)
        return self

    def _attach_program(self, entries: Dict[str, np.ndarray]) -> None:
        # one packet-domain contract for BOTH backends: coordinates and
        # opcode must fit the packed header widths (and the mesh), payload
        # lanes must fit int32 — the error names the offending field
        validate_program(entries, nx=self.cfg.nx, ny=self.cfg.ny,
                         topology=self.cfg.topology)
        op = np.asarray(entries["op"])
        for (y, x) in self._endpoints:
            if (op[y, x] >= 0).any():
                raise ValueError(
                    f"tile (x={x}, y={y}) is driven by an endpoint but the "
                    "program has entries there; a tile has one master")
        self._program = {k: np.asarray(v).copy() for k, v in entries.items()}
        if self.backend == "jax" and self._endpoints:
            # bridge mode replays from cycle 0, so a program arriving after
            # cycles have run would be scheduled earlier than it was seen
            if self._cycles_run() > 0:
                raise ValueError(
                    "cannot attach a program to an endpoint-driven "
                    "jax-backend Simulator that has already run: the "
                    "trace-to-program bridge replays from cycle 0; attach "
                    "everything before running")
            self._bridge().attach(self._program)
        else:
            self._sim.load_program(
                {k: v.copy() for k, v in self._program.items()})

    # program-compatibility alias (load_program(prog) == attach(prog))
    def load_program(self, entries: Dict[str, np.ndarray]) -> None:
        self.attach(entries)

    def _traced_offer(self, y: int, x: int, ep: Endpoint):
        def offer(cycle: int, credits: int) -> Optional[Request]:
            req = ep.offer(cycle, credits)
            if req is not None:
                self._trace.append((y, x, cycle, req))
            return req
        return offer

    # ------------------------------------------------------------------
    # state seeding
    # ------------------------------------------------------------------
    def set_mem(self, mem: np.ndarray) -> None:
        """Initialize every tile's local memory, shape (ny, nx, mem_words)
        — e.g. to seed the pointer chains a memory-controller endpoint
        chases."""
        cfg = self.cfg
        mem = np.asarray(mem)
        if mem.shape != (cfg.ny, cfg.nx, cfg.mem_words):
            raise ValueError(
                f"memory image must be shaped (ny={cfg.ny}, nx={cfg.nx}, "
                f"mem_words={cfg.mem_words}), got {mem.shape}")
        self._mem0 = mem.astype(np.int64)
        if self.backend == "numpy":
            self._sim.mem[:] = self._mem0
        else:
            import jax.numpy as jnp
            self._sim.state = self._sim.state._replace(
                mem=jnp.asarray(self._mem0, jnp.int32))
            if self._oracle is not None:
                self._oracle.set_mem(self._mem0)

    def set_measure_window(self, start: int, stop: int) -> None:
        """Restrict the latency histogram to packets *injected* in cycle
        range [start, stop) — same contract on every backend."""
        self._window = (int(start), int(stop))
        self._sim.set_measure_window(start, stop)
        if self._oracle is not None:
            self._oracle.set_measure_window(start, stop)

    def _cycles_run(self) -> int:
        """Total scenario cycles executed so far (the bridge oracle's view
        when endpoints run on the jax backend)."""
        src = self._oracle if self._oracle is not None else self
        return int(src._sim.cycle)

    # ------------------------------------------------------------------
    # running
    # ------------------------------------------------------------------
    def step(self) -> None:
        """Advance one cycle (numpy backend only — the jit path dispatches
        whole runs).  With endpoints attached this is the reactive step:
        responses are delivered before the router cycle, exactly as in
        :meth:`run`, so manual stepping never starves a request/reply
        endpoint."""
        if self.backend != "numpy":
            raise NotImplementedError(
                "cycle-by-cycle stepping is a numpy-backend feature; the "
                "jax backend dispatches whole runs — use run(cycles)")
        if self._endpoints:
            self._step_reactive()
        else:
            self._sim.step()

    def run(self, cycles: int) -> None:
        """Advance ``cycles`` cycles."""
        if not self._endpoints:
            self._sim.run(cycles)
            return
        if self.backend == "numpy":
            for _ in range(cycles):
                self._step_reactive()
            return
        self._bridge().run(cycles)
        self._replay(drained=False)

    def run_until_drained(self, max_cycles: int = 100_000) -> int:
        """Run until the global fence closes — programs fully issued,
        every endpoint ``done()``, all credits home and the registered
        response port idle; returns the drain cycle."""
        if not self._endpoints:
            return self._sim.run_until_drained(max_cycles)
        if self.backend == "numpy":
            for _ in range(max_cycles):
                if self._reactive_drained():
                    return int(self._sim.cycle)
                self._step_reactive()
            raise RuntimeError(
                f"network did not drain in {max_cycles} cycles")
        n = self._bridge().run_until_drained(max_cycles)
        self._replay(drained=True, max_cycles=max_cycles)
        return n

    def _step_reactive(self) -> None:
        """One oracle cycle with the reverse link serviced: deliver any
        registered response to its endpoint (the sink rule — the endpoint
        cannot refuse), then step; offers happen inside the step at the
        injection stage, exactly where program injection lives."""
        sim = self._sim
        rv = sim.reg_valid
        if rv.any():
            c = int(sim.cycle)
            for (y, x), ep in self._endpoints.items():
                if rv[y, x]:
                    p = sim.reg_pkt
                    ep.deliver(Response(
                        op=int(p["op"][y, x]), addr=int(p["addr"][y, x]),
                        data=int(p["data"][y, x]),
                        src_x=int(p["src_x"][y, x]),
                        src_y=int(p["src_y"][y, x]),
                        tag=int(p["tag"][y, x]), cycle=c))
        sim.step()

    def _reactive_drained(self) -> bool:
        sim = self._sim
        return (all(ep.done() for ep in self._endpoints.values())
                and bool((sim.prog_ptr >= sim.prog_len).all())
                and bool((sim.credits == sim.cfg.max_out_credits).all())
                and not bool(sim.reg_valid.any()))

    # ------------------------------------------------------------------
    # the trace -> program bridge (jax backend with endpoints)
    # ------------------------------------------------------------------
    def injection_trace_program(self) -> Dict[str, np.ndarray]:
        """The injection program equivalent to everything injected so far
        (endpoint offers pinned to their recorded cycles, merged with any
        attached base program).  Replayable on either backend — and
        stackable for ``vmap`` sweeps."""
        oracle = self._oracle if self._oracle is not None else self
        return trace_to_program(oracle._trace, self.cfg.nx, self.cfg.ny,
                                base=self._program)

    def _replay(self, drained: bool, max_cycles: int = 100_000) -> None:
        """Re-run the oracle-traced scenario on a fresh JAX state."""
        prog = self.injection_trace_program()
        self._sim = self._make_backend()
        if self._mem0 is not None:
            import jax.numpy as jnp
            self._sim.state = self._sim.state._replace(
                mem=jnp.asarray(self._mem0, jnp.int32))
        if self._window is not None:
            self._sim.set_measure_window(*self._window)
        self._sim.load_program(prog)
        if drained:
            self._sim.run_until_drained(max_cycles)
        else:
            self._sim.run(int(self._oracle.cycle))

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------
    def telemetry(self) -> Telemetry:
        """The unified, backend-bit-identical telemetry record."""
        return Telemetry.of(self._sim)

    @property
    def endpoints(self) -> Dict[Tuple[int, int], Endpoint]:
        """Attached endpoints, keyed (x, y)."""
        return {(x, y): ep for (y, x), ep in self._endpoints.items()}

    def __getattr__(self, name):
        # oracle-shaped passthrough (mem, credits, lat_hist, throughput,
        # mean_latency, cycle, ...) — keeps the facade drop-in for the
        # differential suites' state assertions
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._sim, name)

    def __repr__(self) -> str:
        return (f"Simulator({self.cfg.nx}x{self.cfg.ny}, "
                f"backend={self.backend!r}, "
                f"endpoints={len(self._endpoints)}, "
                f"program={'yes' if self._program is not None else 'no'})")


# re-exported for facade users who want the raw packet fields
PKT_FIELDS = _PKT_FIELDS
