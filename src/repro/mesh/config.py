"""One mesh configuration for every backend.

``MeshConfig`` subsumes the two configuration types that grew up with the
two simulators — :class:`repro.core.netsim.NetConfig` (the numpy oracle)
and :class:`repro.netsim_jax.sim.SimConfig` (the JIT path) — so user code
describes a mesh exactly once and hands it to the backend-agnostic
:class:`repro.mesh.Simulator`.

Conversions are lossless in both directions with one documented
exception: ``SimConfig`` has no ``record_log`` field (a per-response
Python log cannot live inside a jitted state), so
``MeshConfig -> SimConfig -> MeshConfig`` resets ``record_log`` to
``False``.  The round-trip property is asserted in
``tests/test_mesh_api.py``.

The module deliberately does NOT import :mod:`repro.netsim_jax` at import
time — the JAX stack itself imports :mod:`repro.mesh`, and the facade must
stay importable on a machine that only wants the numpy oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.netsim import NetConfig
from repro.mesh.topology import Topology

__all__ = ["MeshConfig"]


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Backend-agnostic mesh description (hashable, usable as a jit static).

    Field names follow the paper's parameters: ``router_fifo`` is the
    per-direction input-FIFO depth, ``ep_fifo`` is the standard endpoint's
    ``fifo_els_p``, ``max_out_credits`` is ``max_out_credits_p``.
    """
    nx: int
    ny: int
    router_fifo: int = 4
    ep_fifo: int = 4
    max_out_credits: int = 16
    mem_words: int = 64
    resp_latency: int = 1
    record_log: bool = False      # numpy oracle only; dropped by to_sim()
    # network topology (mesh / torus / ring_mesh / multi_chip); None is
    # normalized to the plain mesh so existing call sites are unchanged
    topology: Optional[Topology] = None

    def __post_init__(self):
        if self.nx < 1 or self.ny < 1:
            raise ValueError(
                f"mesh dimensions must be positive, got nx={self.nx}, "
                f"ny={self.ny}")
        if self.topology is None:
            object.__setattr__(self, "topology", Topology.mesh())
        self.topology.validate_for(self.nx, self.ny)

    # -- NetConfig (numpy oracle) --------------------------------------
    @classmethod
    def from_net(cls, cfg: NetConfig) -> "MeshConfig":
        return cls(nx=cfg.nx, ny=cfg.ny, router_fifo=cfg.router_fifo,
                   ep_fifo=cfg.ep_fifo, max_out_credits=cfg.max_out_credits,
                   mem_words=cfg.mem_words, resp_latency=cfg.resp_latency,
                   record_log=cfg.record_log, topology=cfg.topology)

    def to_net(self) -> NetConfig:
        return NetConfig(nx=self.nx, ny=self.ny, router_fifo=self.router_fifo,
                         ep_fifo=self.ep_fifo,
                         max_out_credits=self.max_out_credits,
                         mem_words=self.mem_words,
                         resp_latency=self.resp_latency,
                         record_log=self.record_log, topology=self.topology)

    # -- SimConfig (JAX backend) ---------------------------------------
    @classmethod
    def from_sim(cls, cfg) -> "MeshConfig":
        """From :class:`repro.netsim_jax.sim.SimConfig` (duck-typed so the
        JAX stack is not imported just to read a dataclass)."""
        return cls(nx=cfg.nx, ny=cfg.ny, router_fifo=cfg.router_fifo,
                   ep_fifo=cfg.ep_fifo, max_out_credits=cfg.max_out_credits,
                   mem_words=cfg.mem_words, resp_latency=cfg.resp_latency,
                   topology=getattr(cfg, "topology", None))

    def to_sim(self):
        """To :class:`repro.netsim_jax.sim.SimConfig` (drops ``record_log``,
        which has no jit-compatible equivalent)."""
        from repro.netsim_jax.sim import SimConfig
        return SimConfig(nx=self.nx, ny=self.ny, router_fifo=self.router_fifo,
                         ep_fifo=self.ep_fifo,
                         max_out_credits=self.max_out_credits,
                         mem_words=self.mem_words,
                         resp_latency=self.resp_latency,
                         topology=self.topology)

    # -- normalization -------------------------------------------------
    @classmethod
    def coerce(cls, cfg) -> "MeshConfig":
        """Accept a :class:`MeshConfig`, :class:`NetConfig` or ``SimConfig``
        and return the equivalent :class:`MeshConfig`."""
        if isinstance(cfg, cls):
            return cfg
        if isinstance(cfg, NetConfig):
            return cls.from_net(cfg)
        if all(hasattr(cfg, f) for f in
               ("nx", "ny", "router_fifo", "ep_fifo", "max_out_credits",
                "mem_words", "resp_latency")):
            return cls.from_sim(cfg)
        raise TypeError(
            f"cannot interpret {type(cfg).__name__} as a mesh configuration; "
            "pass a MeshConfig, NetConfig or SimConfig")

    def replace(self, **kw) -> "MeshConfig":
        return dataclasses.replace(self, **kw)

    # -- stable identity (DSE result cache, JSON artifacts) ------------
    def cache_token(self) -> str:
        """A stable, human-readable string identifying this
        configuration — the mesh half of :mod:`repro.dse`'s on-disk
        result-cache keys (``record_log`` is excluded: it changes what is
        *logged*, never what is simulated)."""
        return (f"{self.nx}x{self.ny}/{self.topology.spec}"
                f"/fifo{self.router_fifo}/ep{self.ep_fifo}"
                f"/cred{self.max_out_credits}/mem{self.mem_words}"
                f"/lat{self.resp_latency}")
