"""The mesh-attach interface: how a user design plugs into the network.

The paper's headline deliverable is the standardized
``bsg_manycore_link`` endpoint interface — a valid/ready forward
(request) link plus a credit-counted reverse (response) link — that lets
arbitrary designs (accelerators, memory controllers, off-chip bridges)
attach to any tile of the mesh.  :class:`Endpoint` is that interface for
the simulators:

* ``offer(cycle, credits)`` — the forward link.  The simulator calls it
  once per cycle **only when the link is ready** (the tile has a credit
  and the injection FIFO has space); returning a :class:`Request` asserts
  *valid* and the packet is guaranteed to inject THIS cycle (so the
  endpoint may commit its state immediately); returning ``None`` leaves
  the link idle.  ``credits`` is the tile's remaining credit count, for
  endpoints that pace themselves below the hardware window.
* ``deliver(response)`` — the reverse link.  Called when a response lands
  in the tile's registered output port.  Per the paper's sink rule the
  endpoint **cannot back-pressure** this call; it must absorb the
  response at line rate.
* ``done()`` — drain fence: ``True`` once the endpoint will never offer
  another packet.  ``Simulator.run_until_drained`` waits for every
  endpoint's ``done()`` plus the credit fence.

Built-ins:

* :class:`ProgramEndpoint` — one tile's slice of a precomputed injection
  program, re-expressed through the reactive interface.  Driving a whole
  program through ProgramEndpoints is cycle-identical to the simulators'
  native program path (asserted in ``tests/test_mesh_api.py``).
* :class:`DmaEndpoint` — a remote-store DMA engine: streams a buffer into
  a remote tile's memory with a configurable outstanding-request window.
* :class:`MemoryControllerEndpoint` — the request/reply client of the
  paper's source-code integration example: each reply's data selects the
  next request (pointer chase), so the traffic is *reactive* — it cannot
  be expressed as a precomputed program without running the mesh.

Endpoints run natively on the numpy oracle.  On the JAX backend the
facade replays them through :func:`trace_to_program`: the oracle records
the exact cycle each packet injected, and an injection program with
``not_before`` pinned to those cycles reproduces the run bit-identically
(the injection condition is a pure function of simulator state, which
matches by induction).  That keeps endpoint-driven scenarios ``vmap``-able
into saturation sweeps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from repro.core.netsim import OP_LOAD, OP_STORE

from .traffic import empty_program

__all__ = ["Request", "Response", "Endpoint", "ProgramEndpoint",
           "DmaEndpoint", "MemoryControllerEndpoint", "trace_to_program"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One forward-link packet (the master side of the link).

    ``src_x/src_y`` and the injection-cycle tag are filled in by the
    simulator; the endpoint only names the remote operation."""
    dst_x: int
    dst_y: int
    addr: int
    data: int = 0
    cmp: int = 0
    op: int = OP_STORE


@dataclasses.dataclass(frozen=True)
class Response:
    """One reverse-link packet as seen at the registered output port."""
    op: int
    addr: int
    data: int          # load/CAS return value; 0 for store acks
    src_x: int         # the tile that serviced the request
    src_y: int
    tag: int           # the request's injection cycle
    cycle: int         # the cycle this response became visible

    @property
    def latency(self) -> int:
        """Round-trip cycles, injection -> registered response."""
        return self.cycle - self.tag


@runtime_checkable
class Endpoint(Protocol):
    """The mesh-attach protocol (see module docstring for the contract)."""

    def offer(self, cycle: int, credits: int) -> Optional[Request]:
        """Forward link: return a packet to inject this cycle, or None.
        Called only when the link is ready; a returned packet is
        guaranteed accepted."""
        ...

    def deliver(self, response: Response) -> None:
        """Reverse link: absorb one response (cannot back-pressure)."""
        ...

    def done(self) -> bool:
        """True once no further packet will ever be offered."""
        ...


# ----------------------------------------------------------------------
# built-in endpoints
# ----------------------------------------------------------------------
class ProgramEndpoint:
    """One tile's slice of an injection program behind the reactive
    interface — the compatibility bridge from precomputed dict-of-arrays
    programs to the :class:`Endpoint` world.

    Use :meth:`grid` to wrap a whole ``(ny, nx, L)`` program as one
    endpoint per tile.
    """

    def __init__(self, entries: Dict[str, np.ndarray], x: int, y: int):
        tile = {k: np.asarray(v)[y, x] for k, v in entries.items()}
        n = int((tile["op"] >= 0).sum())
        self._fields = {k: tile.get(k, np.zeros(len(tile["op"]), np.int64))
                        for k in ("dst_x", "dst_y", "addr", "data", "cmp",
                                  "op", "not_before")}
        self._n = n
        self._ptr = 0

    @classmethod
    def grid(cls, entries: Dict[str, np.ndarray]
             ) -> Dict[Tuple[int, int], "ProgramEndpoint"]:
        """One endpoint per tile, keyed ``(x, y)`` — ready for
        ``Simulator.attach(ep, at=(x, y))``."""
        ny, nx = np.asarray(entries["op"]).shape[:2]
        return {(x, y): cls(entries, x, y)
                for y in range(ny) for x in range(nx)}

    def offer(self, cycle: int, credits: int) -> Optional[Request]:
        if self._ptr >= self._n:
            return None
        f, i = self._fields, self._ptr
        if int(f["not_before"][i]) > cycle:
            return None
        self._ptr += 1
        return Request(dst_x=int(f["dst_x"][i]), dst_y=int(f["dst_y"][i]),
                       addr=int(f["addr"][i]), data=int(f["data"][i]),
                       cmp=int(f["cmp"][i]), op=int(f["op"][i]))

    def deliver(self, response: Response) -> None:
        pass                      # fire-and-forget, like the program path

    def done(self) -> bool:
        return self._ptr >= self._n


class DmaEndpoint:
    """Remote-store DMA engine: streams ``data`` into the memory of tile
    ``(dst_x, dst_y)`` starting at ``addr``, at most ``max_inflight``
    stores outstanding (its own window on top of the hardware credits).

    After the drain fence, ``acked`` equals ``len(data)`` and the
    destination tile's memory holds the buffer.
    """

    def __init__(self, dst_x: int, dst_y: int, data: Sequence[int],
                 addr: int = 0, max_inflight: Optional[int] = None):
        self.dst_x, self.dst_y, self.addr = dst_x, dst_y, addr
        self.data = [int(v) for v in data]
        self.max_inflight = len(self.data) if max_inflight is None \
            else max_inflight
        if self.max_inflight < 1:
            raise ValueError(
                f"DMA window must allow at least one outstanding store, "
                f"got max_inflight={max_inflight}")
        self.sent = 0
        self.acked = 0
        self.peak_inflight = 0

    def offer(self, cycle: int, credits: int) -> Optional[Request]:
        inflight = self.sent - self.acked
        if self.sent >= len(self.data) or inflight >= self.max_inflight:
            return None
        req = Request(dst_x=self.dst_x, dst_y=self.dst_y,
                      addr=self.addr + self.sent,
                      data=self.data[self.sent], op=OP_STORE)
        self.sent += 1
        self.peak_inflight = max(self.peak_inflight, inflight + 1)
        return req

    def deliver(self, response: Response) -> None:
        self.acked += 1

    def done(self) -> bool:
        return self.sent >= len(self.data)


class MemoryControllerEndpoint:
    """Request/reply memory-controller client — the paper's integration
    example expressed as an endpoint.

    Issues a remote load to the controller tile ``(dst_x, dst_y)``; each
    reply's *data* is the address of the next load (pointer chase), for
    ``n_requests`` links of the chain.  Because every request depends on
    the previous response, this traffic is genuinely reactive: it cannot
    be precomputed without simulating the mesh.

    ``visited`` records the chased addresses; ``latencies`` the per-link
    round-trip cycles.
    """

    def __init__(self, dst_x: int, dst_y: int, start_addr: int,
                 n_requests: int, mem_words: int = 64):
        self.dst_x, self.dst_y = dst_x, dst_y
        self.mem_words = mem_words
        self._addr = start_addr % mem_words
        self.n_requests = n_requests
        self.issued = 0
        self._outstanding = False
        self.visited: List[int] = []
        self.latencies: List[int] = []

    def offer(self, cycle: int, credits: int) -> Optional[Request]:
        if self._outstanding or self.issued >= self.n_requests:
            return None
        self.issued += 1
        self._outstanding = True
        self.visited.append(self._addr)
        return Request(dst_x=self.dst_x, dst_y=self.dst_y,
                       addr=self._addr, op=OP_LOAD)

    def deliver(self, response: Response) -> None:
        self._outstanding = False
        self._addr = int(response.data) % self.mem_words
        self.latencies.append(response.latency)

    def done(self) -> bool:
        return self.issued >= self.n_requests


# ----------------------------------------------------------------------
# the trace -> program bridge (endpoints on the JAX backend)
# ----------------------------------------------------------------------
def trace_to_program(trace: Sequence[Tuple[int, int, int, Request]],
                     nx: int, ny: int,
                     base: Optional[Dict[str, np.ndarray]] = None,
                     ) -> Dict[str, np.ndarray]:
    """Convert an injection trace — ``(y, x, cycle, request)`` tuples in
    injection order — into an injection program whose ``not_before``
    fields pin every packet to its recorded cycle.

    Replaying the program reproduces the traced run bit-identically on
    either simulator: at each recorded cycle the injection conditions
    (credit available, FIFO space) held in the traced run, and the state
    evolution matches by induction, so they hold in the replay too.

    ``base`` merges a static injection program (for tiles driven by a
    program rather than an endpoint) into the same schedule; traced tiles
    must not also have base entries.
    """
    per_tile: Dict[Tuple[int, int], List[Tuple[int, Request]]] = {}
    for (y, x, cycle, req) in trace:
        per_tile.setdefault((y, x), []).append((cycle, req))

    base_len = 0
    if base is not None:
        base_len = int(np.asarray(base["op"]).shape[-1])
        for (y, x) in per_tile:
            if (np.asarray(base["op"])[y, x] >= 0).any():
                raise ValueError(
                    f"tile (x={x}, y={y}) is driven by an endpoint but the "
                    "base program also has entries there; a tile has one "
                    "master")

    L = max([len(v) for v in per_tile.values()] + [base_len, 1])
    prog = empty_program(nx, ny, L)
    if base is not None:
        for k in prog:
            if k in base:
                prog[k][..., :base_len] = np.asarray(base[k])
    for (y, x), items in per_tile.items():
        for i, (cycle, req) in enumerate(items):
            prog["op"][y, x, i] = req.op
            prog["dst_x"][y, x, i] = req.dst_x
            prog["dst_y"][y, x, i] = req.dst_y
            prog["addr"][y, x, i] = req.addr
            prog["data"][y, x, i] = req.data
            prog["cmp"][y, x, i] = req.cmp
            prog["not_before"][y, x, i] = cycle
    return prog
