"""The unified telemetry record of a mesh simulation.

Both backends accumulate the same cycle-exact counters (numpy attributes
on :class:`repro.core.netsim.MeshSim`, ``SimState`` fields on the JAX
path); :class:`Telemetry` is the one normalized view —
``Simulator.telemetry()`` returns it with every field as int64 numpy, so
results are **bit-identical across backends** and directly comparable
(the parity suites assert exactly that).

``out_of_credit_cycles`` is deliberately NOT part of the record: it
counts cycles where a tile had *pending but unissuable* work, which is
well defined for injection programs but not observable for reactive
endpoints (the simulator cannot know whether an endpoint "would have"
offered), so it cannot be made backend-identical for bridged endpoint
runs.  It stays available on the program path via the backend object.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["Telemetry", "TELEMETRY_ARRAY_FIELDS", "PORT_NAMES",
           "render_heatmap"]

TELEMETRY_ARRAY_FIELDS = ("completed", "lat_sum", "completed_per_cycle",
                          "link_util_fwd", "link_util_rev",
                          "fifo_hwm_fwd", "fifo_hwm_rev", "ep_hwm",
                          "lat_hist")

# bsg_noc_pkg port order (P = ejection to the endpoint)
PORT_NAMES = ("P", "W", "E", "N", "S")

_SHADES = " .:-=+*#%@"


def render_heatmap(util: np.ndarray, *, title: str = "",
                   per_port: bool = False) -> str:
    """ASCII rendering of a ``(ny, nx, ports)`` utilization array
    (fractions of cycles, as returned by :meth:`Telemetry.link_heatmap`).

    The default view shades each tile by its *busiest* output port
    (`` .:-=+*#%@`` over utilization 0..max) and annotates the peak link;
    ``per_port=True`` adds one grid per port.  Rows print north to south,
    so the figure matches the paper's Fig. 1 orientation.
    """
    util = np.asarray(util, float)
    if util.ndim != 3:
        raise ValueError(
            f"expected a (ny, nx, ports) utilization array, "
            f"got shape {util.shape}")
    peak = float(util.max())
    scale = peak if peak > 0 else 1.0

    def grid(u2d: np.ndarray) -> str:
        rows = []
        for y in range(u2d.shape[0]):
            cells = [_SHADES[min(int(u2d[y, x] / scale * (len(_SHADES) - 1)),
                                 len(_SHADES) - 1)]
                     for x in range(u2d.shape[1])]
            rows.append("    " + " ".join(cells))
        return "\n".join(rows)

    ny, nx, _ = util.shape
    hy, hx, hp = np.unravel_index(int(util.argmax()), util.shape)
    lines = []
    if title:
        lines.append(title)
    lines.append(grid(util.max(axis=-1)))
    lines.append(f"    scale: ' '=0 .. '@'={peak:.3f} pkts/cycle; "
                 f"peak link ({hx},{hy}) port "
                 f"{PORT_NAMES[hp % len(PORT_NAMES)]}")
    if per_port:
        for p in range(util.shape[-1]):
            lines.append(f"  port {PORT_NAMES[p % len(PORT_NAMES)]}:")
            lines.append(grid(util[..., p]))
    return "\n".join(lines)


@dataclasses.dataclass(frozen=True, eq=False)
class Telemetry:
    """Normalized simulation telemetry (all arrays int64 numpy)."""

    cycles: int                        # total cycles simulated
    completed: np.ndarray              # (ny, nx) responses received per tile
    lat_sum: np.ndarray                # (ny, nx) summed round-trip latency
    completed_per_cycle: np.ndarray    # (cycles,) completion trace
    link_util_fwd: np.ndarray          # (ny, nx, 5) pkts out of each port
    link_util_rev: np.ndarray          # (ny, nx, 5)
    fifo_hwm_fwd: np.ndarray           # (ny, nx, 5) FIFO occupancy HWMs
    fifo_hwm_rev: np.ndarray           # (ny, nx, 5)
    ep_hwm: np.ndarray                 # (ny, nx) endpoint-FIFO HWM
    lat_hist: np.ndarray               # (LAT_BINS,) RTT histogram (windowed)

    @classmethod
    def of(cls, sim) -> "Telemetry":
        """Build from anything oracle-shaped (``MeshSim``, ``JaxMeshSim``
        or the :class:`repro.mesh.Simulator` facade).

        Every array is **copied** (``np.array(copy=True)``, not
        ``asarray``): the sources are live simulator counters — the numpy
        oracle's int64 accumulators, or JAX buffers that the donating
        jitted drivers (``donate_argnums`` / the Pallas kernel's
        ``input_output_aliases``) may overwrite in place on the next
        ``run``.  ``asarray`` into the same dtype is a zero-copy view of
        exactly those buffers, so a snapshot taken at the facade boundary
        would silently mutate later; an explicit copy makes the record a
        true point-in-time snapshot."""
        return cls(cycles=int(sim.cycle),
                   **{f: np.array(getattr(sim, f), dtype=np.int64, copy=True)
                      for f in TELEMETRY_ARRAY_FIELDS})

    def assert_bit_identical(self, other: "Telemetry") -> None:
        """Assert exact equality of every field (the cross-backend
        contract); raises AssertionError naming the first mismatch."""
        assert self.cycles == other.cycles, \
            f"telemetry mismatch: cycles {self.cycles} != {other.cycles}"
        for f in TELEMETRY_ARRAY_FIELDS:
            np.testing.assert_array_equal(getattr(self, f),
                                          getattr(other, f),
                                          err_msg=f"telemetry mismatch: {f}")

    def __eq__(self, other) -> bool:
        if not isinstance(other, Telemetry):
            return NotImplemented
        return self.cycles == other.cycles and all(
            np.array_equal(getattr(self, f), getattr(other, f))
            for f in TELEMETRY_ARRAY_FIELDS)

    # quick derived views -----------------------------------------------
    def link_heatmap(self, network: str = "fwd",
                     cycles: Optional[int] = None) -> np.ndarray:
        """Per-link utilization as a float ``(ny, nx, 5)`` array: packets
        sent out of each router output port per cycle (``P`` = ejection
        to the endpoint; bsg_noc_pkg port order, see :data:`PORT_NAMES`).

        ``network`` picks the physical network (``"fwd"`` requests /
        ``"rev"`` responses); ``cycles`` overrides the normalization
        window (e.g. a measurement-window length) — default is the full
        run."""
        if network not in ("fwd", "rev"):
            raise ValueError(
                f"network must be 'fwd' or 'rev', got {network!r}")
        util = self.link_util_fwd if network == "fwd" else self.link_util_rev
        denom = self.cycles if cycles is None else int(cycles)
        return np.asarray(util, np.float64) / max(denom, 1)

    def hotspots(self, network: str = "fwd", top: int = 5
                 ) -> List[Tuple[float, int, int, str]]:
        """The ``top`` busiest links as ``(utilization, x, y, port)``,
        most loaded first — the congestion culprits a workload report
        names."""
        hm = self.link_heatmap(network)
        flat = hm.reshape(-1)
        order = np.argsort(flat)[::-1][:max(top, 0)]
        out = []
        for idx in order:
            y, x, p = np.unravel_index(int(idx), hm.shape)
            out.append((float(flat[idx]), int(x), int(y), PORT_NAMES[p]))
        return out

    def heatmap_str(self, network: str = "fwd", *, title: str = "",
                    per_port: bool = False) -> str:
        """:func:`render_heatmap` of :meth:`link_heatmap`."""
        return render_heatmap(self.link_heatmap(network), title=title,
                              per_port=per_port)

    def mean_latency(self) -> float:
        done = int(self.completed.sum())
        return float(self.lat_sum.sum()) / max(done, 1)

    def throughput(self, warmup: int = 0) -> float:
        per = self.completed_per_cycle[warmup:]
        return float(per.sum()) / max(len(per), 1)
