"""The unified telemetry record of a mesh simulation.

Both backends accumulate the same cycle-exact counters (numpy attributes
on :class:`repro.core.netsim.MeshSim`, ``SimState`` fields on the JAX
path); :class:`Telemetry` is the one normalized view —
``Simulator.telemetry()`` returns it with every field as int64 numpy, so
results are **bit-identical across backends** and directly comparable
(the parity suites assert exactly that).

``out_of_credit_cycles`` is deliberately NOT part of the record: it
counts cycles where a tile had *pending but unissuable* work, which is
well defined for injection programs but not observable for reactive
endpoints (the simulator cannot know whether an endpoint "would have"
offered), so it cannot be made backend-identical for bridged endpoint
runs.  It stays available on the program path via the backend object.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Telemetry", "TELEMETRY_ARRAY_FIELDS"]

TELEMETRY_ARRAY_FIELDS = ("completed", "lat_sum", "completed_per_cycle",
                          "link_util_fwd", "link_util_rev",
                          "fifo_hwm_fwd", "fifo_hwm_rev", "ep_hwm",
                          "lat_hist")


@dataclasses.dataclass(frozen=True, eq=False)
class Telemetry:
    """Normalized simulation telemetry (all arrays int64 numpy)."""

    cycles: int                        # total cycles simulated
    completed: np.ndarray              # (ny, nx) responses received per tile
    lat_sum: np.ndarray                # (ny, nx) summed round-trip latency
    completed_per_cycle: np.ndarray    # (cycles,) completion trace
    link_util_fwd: np.ndarray          # (ny, nx, 5) pkts out of each port
    link_util_rev: np.ndarray          # (ny, nx, 5)
    fifo_hwm_fwd: np.ndarray           # (ny, nx, 5) FIFO occupancy HWMs
    fifo_hwm_rev: np.ndarray           # (ny, nx, 5)
    ep_hwm: np.ndarray                 # (ny, nx) endpoint-FIFO HWM
    lat_hist: np.ndarray               # (LAT_BINS,) RTT histogram (windowed)

    @classmethod
    def of(cls, sim) -> "Telemetry":
        """Build from anything oracle-shaped (``MeshSim``, ``JaxMeshSim``
        or the :class:`repro.mesh.Simulator` facade)."""
        return cls(cycles=int(sim.cycle),
                   **{f: np.asarray(getattr(sim, f), dtype=np.int64)
                      for f in TELEMETRY_ARRAY_FIELDS})

    def assert_bit_identical(self, other: "Telemetry") -> None:
        """Assert exact equality of every field (the cross-backend
        contract); raises AssertionError naming the first mismatch."""
        assert self.cycles == other.cycles, \
            f"telemetry mismatch: cycles {self.cycles} != {other.cycles}"
        for f in TELEMETRY_ARRAY_FIELDS:
            np.testing.assert_array_equal(getattr(self, f),
                                          getattr(other, f),
                                          err_msg=f"telemetry mismatch: {f}")

    def __eq__(self, other) -> bool:
        if not isinstance(other, Telemetry):
            return NotImplemented
        return self.cycles == other.cycles and all(
            np.array_equal(getattr(self, f), getattr(other, f))
            for f in TELEMETRY_ARRAY_FIELDS)

    # quick derived views -----------------------------------------------
    def mean_latency(self) -> float:
        done = int(self.completed.sum())
        return float(self.lat_sum.sum()) / max(done, 1)

    def throughput(self, warmup: int = 0) -> float:
        per = self.completed_per_cycle[warmup:]
        return float(per.sum()) / max(len(per), 1)
