"""Packed-header packet encoding shared by the whole netsim stack.

The paper's mesh moves *narrow* packets: two destination coordinates,
two source coordinates and a 2-bit opcode, next to the address/data
payload.  The JAX simulator exploits that by bit-packing all five
routing/control fields into a single int32 **header word**, so a packet
occupies 5 int32 lanes (``hdr, addr, data, cmp, tag``) instead of 9 —
nearly halving the per-cycle router-FIFO traffic and the emitted HLO.

Header layout (LSB first)::

    bits  0..6   dst_x      (COORD_BITS = 7 -> meshes up to 128x128)
    bits  7..13  dst_y
    bits 14..20  src_x
    bits 21..27  src_y
    bits 28..29  op         (OP_BITS = 2 -> the 3 remote ops + 1 spare)

The packed value is < 2**30, so it is always a *non-negative* int32 and
never wraps.  ``addr``/``data``/``cmp``/``tag`` stay full int32 lanes —
negative payload data passes through unchanged (asserted in
``tests/test_encoding.py``).

Everything here is plain integer arithmetic (``&``, ``|``, shifts), so
the same helpers work on Python ints, numpy arrays and jax arrays; the
module deliberately imports neither backend's array library beyond numpy
(the facade must stay importable without the JAX stack warmed up).

Shared by:

* :func:`repro.netsim_jax.sim.load_program` — packs injection programs
  and validates the packet domain (:func:`validate_program`);
* :class:`repro.mesh.Simulator` — validates programs on ``attach`` for
  *both* backends (same limits, one error message);
* :mod:`repro.netsim_jax.testing` — decodes packed in-flight state to
  compare field-for-field against the numpy oracle.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

__all__ = ["COORD_BITS", "COORD_LIMIT", "COORD_MASK", "OP_BITS", "OP_LIMIT",
           "OP_MASK", "DST_X_SHIFT", "DST_Y_SHIFT", "SRC_X_SHIFT",
           "SRC_Y_SHIFT", "OP_SHIFT", "HEADER_FIELDS", "pack_header",
           "pack_dst_op", "with_src", "swap_for_response", "hdr_dst_x",
           "hdr_dst_y", "hdr_src_x", "hdr_src_y", "hdr_op", "decode_header",
           "chip_split", "chip_join", "validate_program"]

COORD_BITS = 7
COORD_LIMIT = 1 << COORD_BITS          # 128: max mesh extent per dimension
COORD_MASK = COORD_LIMIT - 1
OP_BITS = 2
OP_LIMIT = 1 << OP_BITS                # 4 opcodes
OP_MASK = OP_LIMIT - 1

DST_X_SHIFT = 0
DST_Y_SHIFT = COORD_BITS
SRC_X_SHIFT = 2 * COORD_BITS
SRC_Y_SHIFT = 3 * COORD_BITS
OP_SHIFT = 4 * COORD_BITS

# mask covering the (dst_x, dst_y) pair — also the width of the (src_x,
# src_y) pair, which sits exactly SRC_X_SHIFT bits higher
_PAIR_MASK = (1 << (2 * COORD_BITS)) - 1

HEADER_FIELDS = ("dst_x", "dst_y", "src_x", "src_y", "op")


def pack_header(dst_x, dst_y, src_x, src_y, op):
    """Pack the five header fields into one word.  Inputs are masked to
    their field widths, so packing is total; use :func:`validate_program`
    to *reject* out-of-range values instead of silently wrapping them."""
    return ((dst_x & COORD_MASK)
            | ((dst_y & COORD_MASK) << DST_Y_SHIFT)
            | ((src_x & COORD_MASK) << SRC_X_SHIFT)
            | ((src_y & COORD_MASK) << SRC_Y_SHIFT)
            | ((op & OP_MASK) << OP_SHIFT))


def pack_dst_op(dst_x, dst_y, op):
    """Header with the source pair left zero — the form injection
    *programs* are stored in (the source is the injecting tile, ORed in
    by :func:`with_src` at injection time)."""
    return ((dst_x & COORD_MASK)
            | ((dst_y & COORD_MASK) << DST_Y_SHIFT)
            | ((op & OP_MASK) << OP_SHIFT))


def with_src(hdr, src_x, src_y):
    """OR the source pair into a header whose src field is zero."""
    return hdr | (((src_x & COORD_MASK)
                   | ((src_y & COORD_MASK) << COORD_BITS)) << SRC_X_SHIFT)


def swap_for_response(hdr, src_x, src_y):
    """The endpoint's reply header: the request's source pair becomes the
    destination (so the packet routes home), ``(src_x, src_y)`` — the
    servicing tile — becomes the source, and the opcode is preserved."""
    return (((hdr >> SRC_X_SHIFT) & _PAIR_MASK)
            | (((src_x & COORD_MASK)
                | ((src_y & COORD_MASK) << COORD_BITS)) << SRC_X_SHIFT)
            | (hdr & (OP_MASK << OP_SHIFT)))


def hdr_dst_x(hdr):
    return (hdr >> DST_X_SHIFT) & COORD_MASK


def hdr_dst_y(hdr):
    return (hdr >> DST_Y_SHIFT) & COORD_MASK


def hdr_src_x(hdr):
    return (hdr >> SRC_X_SHIFT) & COORD_MASK


def hdr_src_y(hdr):
    return (hdr >> SRC_Y_SHIFT) & COORD_MASK


def hdr_op(hdr):
    return (hdr >> OP_SHIFT) & OP_MASK


def decode_header(hdr) -> Dict[str, np.ndarray]:
    """The five header fields of ``hdr`` (scalar or array), as a dict."""
    return {"dst_x": hdr_dst_x(hdr), "dst_y": hdr_dst_y(hdr),
            "src_x": hdr_src_x(hdr), "src_y": hdr_src_y(hdr),
            "op": hdr_op(hdr)}


_I32 = np.iinfo(np.int32)


# ----------------------------------------------------------------------
# multi-chip coordinates
# ----------------------------------------------------------------------
# On a multi-chip topology (repro.mesh.topology.Topology.multi_chip) the
# global x coordinate *contains* the chip id: chip boundaries fall on
# multiples of the chip width, so x = chip * width + local_x.  The packed
# header needs no extra bits — BSG Ten's scheme, where the off-chip hop
# is address-transparent.  These helpers make the containment explicit
# (and testable): split/join are exact inverses for every representable
# coordinate, so the chip-id "bits" round-trip through the header.

def chip_split(x, topology, nx: int):
    """``(chip_id, local_x)`` of a global x coordinate under a multi-chip
    ``topology`` on an ``nx``-wide array.  Elementwise (ints or arrays)."""
    w = topology.chip_width(nx)
    return x // w, x % w


def chip_join(chip, local_x, topology, nx: int):
    """Global x coordinate of ``(chip_id, local_x)`` — the exact inverse
    of :func:`chip_split`."""
    return chip * topology.chip_width(nx) + local_x


def validate_program(entries: Dict[str, np.ndarray],
                     nx: Optional[int] = None,
                     ny: Optional[int] = None,
                     topology=None) -> None:
    """Reject injection programs whose packets cannot be represented.

    For every non-padding entry (``op >= 0``):

    * ``dst_x`` / ``dst_y`` must fit the packed ``COORD_BITS``-bit
      coordinate fields — and lie inside the mesh when ``nx``/``ny`` are
      given (the facade attach path passes them; a packet aimed off-mesh
      can never be delivered and wedges the router it reaches);
    * ``op`` must fit the ``OP_BITS``-bit opcode field;
    * ``addr`` / ``data`` / ``cmp`` / ``not_before`` must fit int32 (the
      JAX simulator's lane width; the numpy oracle is int64 but the
      facade applies one limit so programs stay portable).

    When ``topology`` is given it is checked against the array shape
    (:meth:`repro.mesh.topology.Topology.validate_for` — e.g. multi-chip
    needs ``nx`` divisible into equal-width chips).  Destination
    coordinates themselves are topology-independent: they are global
    (the chip id is contained in the x coordinate, see
    :func:`chip_split`), so the same bounds apply on every topology.

    Raises ``ValueError`` naming the offending field and its bound.
    """
    if topology is not None and nx is not None and ny is not None:
        topology.validate_for(nx, ny)
    op = np.asarray(entries["op"])
    live = op >= 0
    bounds = {
        "dst_x": COORD_LIMIT if nx is None else min(nx, COORD_LIMIT),
        "dst_y": COORD_LIMIT if ny is None else min(ny, COORD_LIMIT),
        "op": OP_LIMIT,
    }
    for field, limit in bounds.items():
        v = np.asarray(entries.get(field, op * 0))[live]
        if v.size and (v.min() < 0 or v.max() >= limit):
            what = (f"the {COORD_BITS}-bit packed header coordinate"
                    if field != "op" else f"the {OP_BITS}-bit opcode field")
            where = "" if (nx is None or field == "op") \
                else f" and the {nx}x{ny} mesh"
            raise ValueError(
                f"program field {field!r} must be in [0, {limit}) to fit "
                f"{what}{where}; got values in "
                f"[{int(v.min())}, {int(v.max())}]")
    for field in ("addr", "data", "cmp", "not_before"):
        if field not in entries:
            continue
        v = np.asarray(entries[field])
        if v.size and (v.min(initial=0) < _I32.min
                       or v.max(initial=0) > _I32.max):
            raise ValueError(
                f"program field {field!r} exceeds the int32 packet lane "
                f"domain [{_I32.min}, {_I32.max}]; got values in "
                f"[{int(v.min())}, {int(v.max())}]")
