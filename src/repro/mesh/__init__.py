"""The unified mesh-attach API — one front door for the whole netsim stack.

This package is the reproduction of the paper's *interface* contribution:
the standardized ``bsg_manycore_link`` attachment point that lets any
user design plug into the mesh.  It provides

* :class:`MeshConfig` — one configuration subsuming the oracle's
  ``NetConfig`` and the JAX path's ``SimConfig`` (lossless round-trip
  converters);
* :class:`Endpoint` — the per-tile attach protocol (valid/ready forward
  link via ``offer``, credit-counted reverse link via ``deliver``), with
  built-ins :class:`ProgramEndpoint`, :class:`DmaEndpoint` and
  :class:`MemoryControllerEndpoint`;
* :class:`Simulator` — the backend-agnostic facade (``backend="numpy"``
  oracle / ``backend="jax"`` jit path) with ``attach`` / ``run`` /
  ``run_until_drained`` / ``telemetry()``;
* :class:`Telemetry` — the normalized, backend-bit-identical telemetry
  record;
* the traffic-pattern library (``make_traffic`` and friends) emitting
  injection programs consumable everywhere.
"""
from . import encoding  # noqa: F401
from .config import MeshConfig  # noqa: F401
from .topology import Topology  # noqa: F401
from .encoding import validate_program  # noqa: F401
from .endpoint import (DmaEndpoint, Endpoint,  # noqa: F401
                       MemoryControllerEndpoint, ProgramEndpoint, Request,
                       Response, trace_to_program)
from .simulator import BACKENDS, Simulator  # noqa: F401
from .telemetry import (PORT_NAMES, TELEMETRY_ARRAY_FIELDS,  # noqa: F401
                        Telemetry, render_heatmap)
from .traffic import (PATTERNS, PROG_KEYS, bit_complement,  # noqa: F401
                      empty_program, hotspot, make_traffic,
                      nearest_neighbor, tornado, transpose, uniform_random)

__all__ = ["MeshConfig", "Topology", "Simulator", "BACKENDS", "Telemetry",
           "encoding", "validate_program", "PORT_NAMES", "render_heatmap",
           "TELEMETRY_ARRAY_FIELDS", "Endpoint", "Request", "Response",
           "ProgramEndpoint", "DmaEndpoint", "MemoryControllerEndpoint",
           "trace_to_program", "PATTERNS", "PROG_KEYS", "empty_program",
           "make_traffic", "uniform_random", "transpose", "bit_complement",
           "tornado", "hotspot", "nearest_neighbor"]
