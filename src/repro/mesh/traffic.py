"""Synthetic traffic-pattern workload library for the mesh simulators.

Standard NoC evaluation battery (the patterns used by the Epiphany-V and
Ring-Mesh evaluations, and by Dally & Towles): uniform random, transpose,
bit-complement, tornado, hotspot, nearest-neighbor.  Every generator
returns an *injection program* — a dict of ``(ny, nx, length)`` int64
arrays with the exact schema of ``MeshSim.load_program`` — so one program
drives the numpy oracle, the JAX simulator, and the
:class:`repro.mesh.Simulator` facade bit-identically.

The injection *rate* r (packets/cycle/tile, 0 < r <= 1) is enforced with
the ``not_before`` field: entry ``i`` may not inject before cycle
``floor(i / r)``.  Offered load is open-loop up to the credit limit; the
endpoints' credit flow control then back-pressures naturally, exactly as
in hardware.

(This module is the canonical home of the library; the old
``repro.netsim_jax.traffic`` path re-exports it for compatibility.)
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.netsim import OP_LOAD, OP_STORE  # noqa: F401 (re-export)

__all__ = ["PATTERNS", "PROG_KEYS", "empty_program", "make_traffic",
           "uniform_random", "transpose", "bit_complement", "tornado",
           "hotspot", "nearest_neighbor"]

PROG_KEYS = ("dst_x", "dst_y", "addr", "data", "cmp", "op", "not_before")


def empty_program(nx: int, ny: int, length: int = 1) -> Dict[str, np.ndarray]:
    """All-padding program (``op`` = -1 everywhere); arrays are
    (ny, nx, length) as the simulators expect.

    This is the one canonical empty-program helper — the deprecated
    ``repro.netsim_jax.traffic.empty_program`` and
    ``repro.netsim_jax.sim.empty_program_for`` both delegate here.
    """
    prog = {k: np.zeros((ny, nx, length), np.int64) for k in PROG_KEYS}
    prog["op"][:] = -1
    return prog


def _base(nx: int, ny: int, length: int, rate: float, op: int,
          mem_words: int, seed: int) -> Tuple[Dict[str, np.ndarray],
                                              np.random.Generator]:
    if not 0.0 < rate <= 1.0:
        raise ValueError(
            f"injection rate must be in (0, 1] packets/cycle/tile, "
            f"got {rate}")
    prog = empty_program(nx, ny, length)
    i = np.arange(length)
    prog["op"][:] = op
    prog["addr"][:] = i % mem_words
    prog["data"][:] = np.arange(ny * nx * length).reshape(ny, nx, length)
    prog["not_before"][:] = np.floor(i / rate).astype(np.int64)
    return prog, np.random.default_rng(seed)


# ----------------------------------------------------------------------
# the patterns: each fills dst_x / dst_y of a base program
# ----------------------------------------------------------------------
def uniform_random(nx: int, ny: int, length: int, *, rate: float = 1.0,
                   op: int = OP_STORE, mem_words: int = 64,
                   seed: int = 0, topology=None) -> Dict[str, np.ndarray]:
    """Every packet targets a uniformly random *other* tile (the pattern
    itself is topology-independent; ``topology`` is accepted so every
    generator has a uniform signature)."""
    prog, rng = _base(nx, ny, length, rate, op, mem_words, seed)
    n = ny * nx
    src = np.arange(n).reshape(ny, nx, 1)
    # uniform over the n-1 other tiles: src + U[1, n) mod n is never self
    dst = (src + rng.integers(1, n, (ny, nx, length))) % n
    prog["dst_y"], prog["dst_x"] = np.divmod(dst, nx)
    return prog


def transpose(nx: int, ny: int, length: int, *, rate: float = 1.0,
              op: int = OP_STORE, mem_words: int = 64,
              seed: int = 0, topology=None) -> Dict[str, np.ndarray]:
    """(x, y) -> (y, x).  Only defined on square arrays — on a non-square
    array the transposed coordinate falls off the edge (wraparound does
    not help: the transpose of a valid coordinate must itself be a valid
    coordinate, so the constraint is the same on every topology)."""
    if nx != ny:
        raise ValueError(
            f"transpose traffic is undefined on a non-square mesh "
            f"(got nx={nx}, ny={ny}); use a square mesh or another pattern")
    prog, _ = _base(nx, ny, length, rate, op, mem_words, seed)
    ys, xs = np.mgrid[0:ny, 0:nx]
    prog["dst_x"][:] = ys[..., None]
    prog["dst_y"][:] = xs[..., None]
    return prog


def bit_complement(nx: int, ny: int, length: int, *, rate: float = 1.0,
                   op: int = OP_STORE, mem_words: int = 64,
                   seed: int = 0, topology=None) -> Dict[str, np.ndarray]:
    """(x, y) -> (nx-1-x, ny-1-y): every packet crosses both bisections."""
    prog, _ = _base(nx, ny, length, rate, op, mem_words, seed)
    ys, xs = np.mgrid[0:ny, 0:nx]
    prog["dst_x"][:] = (nx - 1 - xs)[..., None]
    prog["dst_y"][:] = (ny - 1 - ys)[..., None]
    return prog


def _tornado_shift(k: int, wrap: bool) -> int:
    """Tornado offset along one dimension of extent ``k``.

    The classic tornado pattern is torus-relative: shift ``floor(k/2)``
    with wraparound, so minimal routes all march the same way around the
    ring and saturate it.  On a non-wrapped dimension that offset cannot
    wrap, so the adversarial offset is the near-half-way
    ``ceil(k/2) - 1`` (Dally & Towles §3.2) — which is also what keeps
    the mesh tornado baselines bit-identical to the pre-topology code.
    """
    return (k // 2) if wrap else max(math.ceil(k / 2) - 1, 0)


def tornado(nx: int, ny: int, length: int, *, rate: float = 1.0,
            op: int = OP_STORE, mem_words: int = 64,
            seed: int = 0, topology=None) -> Dict[str, np.ndarray]:
    """Each dimension shifts by the tornado offset (see
    :func:`_tornado_shift`): ``floor(k/2)`` with wraparound on wrapped
    (ring/torus) dimensions, ``ceil(k/2) - 1`` on mesh dimensions."""
    prog, _ = _base(nx, ny, length, rate, op, mem_words, seed)
    wrap_x = topology is not None and topology.wrap_x
    wrap_y = topology is not None and topology.wrap_y
    ys, xs = np.mgrid[0:ny, 0:nx]
    prog["dst_x"][:] = ((xs + _tornado_shift(nx, wrap_x)) % nx)[..., None]
    prog["dst_y"][:] = ((ys + _tornado_shift(ny, wrap_y)) % ny)[..., None]
    return prog


def hotspot(nx: int, ny: int, length: int, *, rate: float = 1.0,
            op: int = OP_STORE, mem_words: int = 64, seed: int = 0,
            spot: Optional[Tuple[int, int]] = None,
            fraction: float = 0.5, topology=None) -> Dict[str, np.ndarray]:
    """A ``fraction`` of packets hammer one hot tile (default: the center);
    the rest are uniform random over the other tiles."""
    if not 0.0 < fraction <= 1.0:
        raise ValueError(
            f"hotspot fraction must be in (0, 1] (the share of packets "
            f"aimed at the hot tile), got {fraction}")
    hx, hy = spot if spot is not None else (nx // 2, ny // 2)
    if not (0 <= hx < nx and 0 <= hy < ny):
        raise ValueError(
            f"hotspot coordinate must lie inside the {nx}x{ny} mesh, "
            f"got spot=({hx}, {hy})")
    prog, rng = _base(nx, ny, length, rate, op, mem_words, seed)
    uni = uniform_random(nx, ny, length, rate=rate, op=op,
                         mem_words=mem_words, seed=seed + 1)
    hot = rng.random((ny, nx, length)) < fraction
    prog["dst_x"] = np.where(hot, hx, uni["dst_x"])
    prog["dst_y"] = np.where(hot, hy, uni["dst_y"])
    return prog


def nearest_neighbor(nx: int, ny: int, length: int, *, rate: float = 1.0,
                     op: int = OP_STORE, mem_words: int = 64,
                     seed: int = 0, topology=None) -> Dict[str, np.ndarray]:
    """Each tile streams to its east neighbour (wrapping at the edge) — the
    paper's line-rate one-to-one pattern at array scale."""
    prog, _ = _base(nx, ny, length, rate, op, mem_words, seed)
    ys, xs = np.mgrid[0:ny, 0:nx]
    prog["dst_x"][:] = ((xs + 1) % nx)[..., None]
    prog["dst_y"][:] = ys[..., None]
    return prog


PATTERNS: Dict[str, Callable[..., Dict[str, np.ndarray]]] = {
    "uniform": uniform_random,
    "transpose": transpose,
    "bit_complement": bit_complement,
    "tornado": tornado,
    "hotspot": hotspot,
    "neighbor": nearest_neighbor,
}


def make_traffic(pattern: str, nx: int, ny: int, length: int,
                 **kw) -> Dict[str, np.ndarray]:
    """Dispatch by pattern name (see :data:`PATTERNS`); keyword arguments
    are forwarded to the generator (``rate``, ``op``, ``seed``, ...).

    Every generator accepts ``topology=`` (a
    :class:`repro.mesh.topology.Topology`); patterns whose classic
    definition is topology-relative (tornado) use it, the rest accept and
    ignore it so callers can thread one topology through uniformly.

    Raises :class:`ValueError` — one clear error per invalid combination —
    for unknown patterns, an injection rate outside ``(0, 1]``, invalid
    hotspot parameters (a ``spot`` outside the mesh or a ``fraction``
    outside ``(0, 1]``), a topology that cannot be laid onto the array
    (multi-chip with indivisible ``nx``), or an array on which the
    pattern is undefined (e.g. transpose on a non-square mesh).
    """
    try:
        fn = PATTERNS[pattern]
    except KeyError:
        raise ValueError(
            f"unknown pattern {pattern!r}; known: {sorted(PATTERNS)}") from None
    topo = kw.get("topology")
    if topo is not None:
        topo.validate_for(nx, ny)
    return fn(nx, ny, length, **kw)
