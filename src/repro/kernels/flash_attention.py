"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the standard flash algorithm: the grid is
``(batch, q_heads, q_blocks, kv_blocks)`` with the KV dimension innermost —
TPU grid steps execute *sequentially*, so the online-softmax statistics live
in VMEM scratch that persists across KV iterations (no atomics, no
inter-block communication — the mesh-network lesson: keep state local,
stream the data past it).

Block shapes are MXU-aligned: ``block_q x head_dim`` and
``block_k x head_dim`` tiles with ``head_dim`` padded to a multiple of 128
by the ops.py wrapper.  VMEM working set per step:
``block_q*hd (q) + 2*block_k*hd (k,v) + block_q*hd (acc) + block_q*block_k``
floats — about 2.4 MB at the default 512/512 fp32 blocks, comfortably inside
the ~16 MB VMEM budget.

GQA is handled in the BlockSpec index maps (the K/V block index maps a query
head to its KV head), so KV is never materialized repeated in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < kv_len                                # padded tail
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                                  # (bq,)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        m_ref[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    if causal:
        # skip fully-masked KV blocks (the block-sparse fast path)
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    kv_len: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, K, Sk, hd) with H % K == 0.

    Shapes must be pre-padded: Sq % block_q == 0, Sk % block_k == 0 and
    hd % 128 == 0 (ops.py does this).  ``kv_len`` masks the padded KV tail.
    ``interpret=None`` picks the right mode for the host (kernels.backend).
    """
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, (h, kh)
    group = h // kh
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    kv_len = sk if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale if sm_scale is not None else hd ** -0.5,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        # fp32 online-softmax state; persists across the (innermost) kv dim
        scratch_shapes=_scratch(block_q, hd),
        interpret=resolve_interpret(interpret),
    )(q, k, v)


def _scratch(block_q: int, hd: int):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        pltpu.VMEM((block_q,), jnp.float32),      # m (running max)
        pltpu.VMEM((block_q,), jnp.float32),      # l (running denominator)
    ]
