"""The per-cycle mesh router update as a single Pallas kernel.

The netsim transition (:func:`repro.netsim_jax.sim._step_core`) is a
neighbor-local int32 update over the stacked ``(2, ny, nx, ...)`` FIFO
lattice — routing off the packed header word, round-robin arbitration,
the post-arbitration deliver gate, credit return and one merged stacked
buffer write.  This module runs that whole transition as ONE
``pl.pallas_call``: every ``SimState`` leaf is resident on-chip for the
duration of the launch, and a static ``cycles_per_call`` ``fori_loop``
executes several mesh cycles per launch, amortizing the dispatch the way
``ssd_scan.py`` chunks its recurrence.

Design notes:

* **Shared trace.** The kernel body calls ``_step_core(kernel_safe=True)``
  — the very same function the fused XLA path runs, with its four
  traced-index scatter/gather ops swapped for one-hot select/sum forms
  (exact in int32).  There is no second implementation of the router to
  drift; bit-identity is by construction and enforced by
  ``tests/test_router_kernel.py``.
* **State in place.** Every state leaf is passed through
  ``input_output_aliases``, so the launch updates the simulator state
  buffers in place — the Pallas analogue of the ``donate_argnums`` the
  jitted drivers already use.
* **Packing.** Pallas refs want >= 2-D arrays of one dtype: leaves are
  viewed as int32 (bools widen, exactly) and scalars / 1-D leaves get
  leading unit axes; the kernel unpacks to the original pytree, steps,
  and repacks.  The per-cycle ``done``/``drained`` outputs come back as
  ``(cycles_per_call, 1)`` columns so the drivers keep exact per-cycle
  completion traces and drain fences across multi-cycle launches.
* **Fallback.** ``interpret=None`` resolves through
  :mod:`repro.kernels.backend`: native Mosaic on TPU, interpret mode
  (same traced program through XLA) everywhere else — CI on CPU checks
  correctness, not speed.
* **Topologies.** The topology (mesh / torus / ring-mesh / multi-chip,
  :mod:`repro.mesh.topology`) lives in the hashable ``SimConfig`` closed
  over by the kernel body, so every topology the fused step supports runs
  in the kernel — compiled and interpret alike — with no changes here:
  the wrap connectivity is static slice+concat (``jnp.roll`` would not
  lower on Mosaic), the routing function is pure ``where`` arithmetic,
  and the boundary gate compares against ``broadcasted_iota`` columns.
  Cross-topology bit-identity is enforced by ``tests/test_topology.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.netsim_jax import sim as _sim
from .backend import resolve_interpret

__all__ = ["router_step_call"]

I32 = jnp.int32


def _packed_shape(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Pallas refs want >= 2 dims: scalars become (1, 1), 1-D (1, n)."""
    return ((1,) * (2 - len(shape)) + tuple(shape)) if len(shape) < 2 \
        else tuple(shape)


def _pack(leaf: jax.Array) -> jax.Array:
    return jnp.asarray(leaf, I32).reshape(_packed_shape(leaf.shape))


def _unpack(val: jax.Array, meta) -> jax.Array:
    shape, dtype = meta
    val = val.reshape(shape)
    return (val != 0) if np.issubdtype(dtype, np.bool_) else val


def _router_kernel(cfg, st_def, st_metas, prog_def, prog_metas,
                   cycles_per_call: int, *refs):
    n_st, n_prog = len(st_metas), len(prog_metas)
    st_in = refs[:n_st]
    prog_in = refs[n_st:n_st + n_prog]
    st_out = refs[n_st + n_prog:n_st + n_prog + n_st]
    done_ref, drained_ref = refs[-2], refs[-1]

    st = jax.tree_util.tree_unflatten(
        st_def, [_unpack(r[...], m) for r, m in zip(st_in, st_metas)])
    prog = jax.tree_util.tree_unflatten(
        prog_def, [_unpack(r[...], m) for r, m in zip(prog_in, prog_metas)])

    C = cycles_per_call
    # 2-D iota (1-D iotas do not lower on Mosaic): row j of the (C, 1)
    # output columns belongs to inner cycle j
    row = jax.lax.broadcasted_iota(I32, (C, 1), 0)

    def body(j, carry):
        st, done, drained_v = carry
        st2, done_now = _sim._step_core(cfg, prog, st, kernel_safe=True)
        hit = row == j
        done = jnp.where(hit, done_now, done)
        drained_v = jnp.where(hit, _sim.drained(st2, prog).astype(I32),
                              drained_v)
        return st2, done, drained_v

    st, done, drained_v = jax.lax.fori_loop(
        0, C, body, (st, jnp.zeros((C, 1), I32), jnp.zeros((C, 1), I32)))

    for ref, leaf in zip(st_out, jax.tree_util.tree_leaves(st)):
        ref[...] = _pack(leaf)
    done_ref[...] = done
    drained_ref[...] = drained_v


def router_step_call(cfg, prog, st, cycles_per_call: int, *,
                     interpret: Optional[bool] = None):
    """Run ``cycles_per_call`` mesh cycles in one Pallas kernel launch.

    Returns ``(state', done, drained)``: ``done[j]`` is the completion
    count of inner cycle j and ``drained[j]`` the global drain fence
    *after* that cycle (int32 0/1), both shaped ``(cycles_per_call,)`` —
    exactly what ``cycles_per_call`` launches of the fused step would
    have produced.  ``interpret=None`` picks the right mode for the host
    (:mod:`repro.kernels.backend`).
    """
    C = int(cycles_per_call)
    if C < 1:
        raise ValueError(f"cycles_per_call must be >= 1, got {C}")
    st_leaves, st_def = jax.tree_util.tree_flatten(st)
    prog_leaves, prog_def = jax.tree_util.tree_flatten(prog)
    st_metas = tuple((tuple(l.shape), l.dtype) for l in st_leaves)
    prog_metas = tuple((tuple(l.shape), l.dtype) for l in prog_leaves)
    packed_st = [_pack(l) for l in st_leaves]
    packed_prog = [_pack(l) for l in prog_leaves]

    kernel = functools.partial(_router_kernel, cfg, st_def, st_metas,
                               prog_def, prog_metas, C)
    outs = pl.pallas_call(
        kernel,
        out_shape=([jax.ShapeDtypeStruct(p.shape, I32) for p in packed_st]
                   + [jax.ShapeDtypeStruct((C, 1), I32),
                      jax.ShapeDtypeStruct((C, 1), I32)]),
        # state updates in place: input i aliases output i (the kernel
        # reads every input once up front and writes outputs once at the
        # end, so the aliasing is hazard-free)
        input_output_aliases={i: i for i in range(len(packed_st))},
        interpret=resolve_interpret(interpret),
    )(*packed_st, *packed_prog)

    new_st = jax.tree_util.tree_unflatten(
        st_def, [_unpack(o, m) for o, m in zip(outs, st_metas)])
    return new_st, outs[-2][:, 0], outs[-1][:, 0]
