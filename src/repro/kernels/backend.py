"""Shared Pallas backend detection for every kernel in this package.

One policy, one place: a Pallas kernel compiles natively only where a
Mosaic backend exists (TPU); everywhere else — this CPU container, GPU
hosts without the Triton lowering enabled — the kernels run under
``interpret=True``, which executes the *same* traced kernel body through
XLA without the hardware lowering.  Bit-for-bit the same program, minus
the speed.

Every kernel entry point takes ``interpret: Optional[bool] = None`` and
resolves it through :func:`resolve_interpret`, so

* library code simply omits the argument and gets the right mode for the
  host (``flash_attention_op`` on TPU compiles, on CPU interprets);
* tests/benchmarks can force either mode explicitly;
* the decision logic is not re-sniffed per module (it used to live as
  ``ops.py::_interpret()`` and would have been copy-pasted into each new
  kernel).
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["has_compiled_backend", "use_interpret", "resolve_interpret"]

# backends with a native Pallas (Mosaic) lowering for these kernels
_COMPILED_BACKENDS = ("tpu",)


def has_compiled_backend() -> bool:
    """True when the default JAX backend can compile Pallas kernels
    natively (rather than executing them under the interpreter)."""
    return jax.default_backend() in _COMPILED_BACKENDS


def use_interpret() -> bool:
    """The default ``interpret=`` value for a Pallas call on this host."""
    return not has_compiled_backend()


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an explicit/omitted ``interpret`` argument: ``None`` means
    "whatever this host needs" (:func:`use_interpret`); a bool is taken
    at face value."""
    return use_interpret() if interpret is None else bool(interpret)
