"""Grouped (expert) matmul as a Pallas TPU kernel.

Computes ``out[e] = lhs[e] @ rhs[e]`` for E experts — the compute core of
the MoE FFN over capacity buffers.  Grid is
``(experts, rows_blocks, cols_blocks, k_blocks)`` with the contraction dim
innermost, accumulating into a VMEM fp32 scratch tile; MXU-aligned
``block_m x block_k`` / ``block_k x block_n`` tiles.

Unlike megablocks-style ragged GMM, the capacity-buffer layout (paper C2:
pre-provisioned FIFOs) makes every expert's tile count *static* — no
dynamic shapes, no host-side grouping metadata.  Padding rows (dropped
tokens / unused capacity) multiply zeros.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret


def _gmm_kernel(lhs_ref, rhs_ref, out_ref, acc_ref):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        lhs_ref[0].astype(jnp.float32), rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


def grouped_matmul_pallas(lhs: jax.Array, rhs: jax.Array, *,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 512,
                          interpret: Optional[bool] = None) -> jax.Array:
    """lhs: (E, M, K); rhs: (E, K, N) -> (E, M, N).

    M/N/K must be multiples of the block sizes (ops.py pads);
    ``interpret=None`` picks the right mode for the host (kernels.backend).
    """
    e, m, k = lhs.shape
    _, _, n = rhs.shape
    assert rhs.shape[:2] == (e, k), (lhs.shape, rhs.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (m, n, k, block_m, block_n, block_k)

    return pl.pallas_call(
        _gmm_kernel,
        grid=(e, m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((1, block_m, block_k),
                         lambda e_, im, in_, ik: (e_, im, ik)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e_, im, in_, ik: (e_, ik, in_)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e_, im, in_, ik: (e_, im, in_)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(lhs, rhs)
