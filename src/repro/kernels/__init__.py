"""Pallas TPU kernels for the compute hot spots.

Each kernel lives in ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), with ``ops.py`` providing the jit'd public wrappers (padding,
interpret-mode fallback on CPU, custom VJPs) and ``ref.py`` the pure-jnp
oracles the tests sweep against.
"""
from .backend import has_compiled_backend, resolve_interpret, use_interpret  # noqa: F401
from .ops import flash_attention_op, grouped_matmul, ssd_scan_op  # noqa: F401
from . import ref  # noqa: F401
